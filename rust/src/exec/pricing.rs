//! Plan pricing: turn a [`RoutePlan`] + [`LoadMatrix`] into a
//! [`StepReport`] using the cost models (paper Eq. 3/4 + comm model).
//!
//! Pricing runs once per MoE layer per step, so the intermediates that
//! never escape into the report (token chunks, byte matrices, per-device
//! SoA accumulators) live in a thread-local [`PriceScratch`] and are
//! reused across calls; per-device folds run straight over the work
//! lists instead of collecting token vectors. Weight-transfer time is
//! accumulated off the plan's own transfer list — planners emit it in
//! canonical `(to, from, expert)` order at construction
//! ([`RoutePlan::transfers_canonical`]), so the historical per-step
//! clone + sort survives only as a cold fallback for out-of-tree
//! planners.

use super::dispatch::{chunks_into, combine_bytes_into, device_work_into, Chunk};
use super::{Engine, GemmBackendKind, StepReport};
use crate::planner::{CacheStats, Planner, RoutePlan, WeightTransfer};
use crate::routing::LoadMatrix;
use std::cell::RefCell;

/// Timing decomposition of one step.
#[derive(Clone, Debug, Default)]
pub struct PhaseTimes {
    /// Load metadata all-gather + plan broadcast (small constant).
    pub meta_s: f64,
    /// Measured planner wall time (LLA is on the critical path).
    pub plan_s: f64,
    /// Dispatch All-to-All (max over devices).
    pub dispatch_s: f64,
    /// Weight P2P transfers (max over receiving devices).
    pub weights_s: f64,
    /// Expert GEMMs (max over devices).
    pub compute_s: f64,
    /// Combine All-to-All (max over devices).
    pub combine_s: f64,
}

impl PhaseTimes {
    pub fn total(&self) -> f64 {
        // weights overlap nothing in the base implementation; compute
        // starts after a device has its weights, so weights+compute share
        // the same barrier-to-barrier span per device (already folded in
        // by price_plan via per-device max).
        self.meta_s + self.plan_s + self.dispatch_s + self.compute_s + self.combine_s
    }
}

/// Reusable pricing intermediates (never escape into the report).
#[derive(Default)]
struct PriceScratch {
    chunks: Vec<Chunk>,
    work: Vec<Vec<(usize, u64)>>,
    disp: Vec<Vec<u64>>,
    comb: Vec<Vec<u64>>,
    dispatch_times: Vec<f64>,
    combine_times: Vec<f64>,
    weights_recv_s: Vec<f64>,
    /// Cold-path sort buffer for plans without canonical transfers.
    ordered: Vec<WeightTransfer>,
}

thread_local! {
    static PRICE_SCRATCH: RefCell<Option<PriceScratch>> = const { RefCell::new(None) };
}

/// Price `plan` over `lm`. `measured_compute`, when given (real backends),
/// overrides the Eq.-3 model with measured per-device compute seconds.
pub fn price_plan(
    engine: &Engine,
    plan: &RoutePlan,
    lm: &LoadMatrix,
    planner: &dyn Planner,
    plan_time_s: f64,
    measured_compute: Option<&[f64]>,
) -> StepReport {
    let mut ps = PRICE_SCRATCH.with(|slot| slot.borrow_mut().take()).unwrap_or_default();
    let report = price_plan_impl(engine, plan, lm, planner, plan_time_s, measured_compute, &mut ps);
    PRICE_SCRATCH.with(|slot| *slot.borrow_mut() = Some(ps));
    report
}

#[allow(clippy::too_many_arguments)]
fn price_plan_impl(
    engine: &Engine,
    plan: &RoutePlan,
    lm: &LoadMatrix,
    planner: &dyn Planner,
    plan_time_s: f64,
    measured_compute: Option<&[f64]>,
    ps: &mut PriceScratch,
) -> StepReport {
    let model = &engine.model;
    let devices = plan.devices;
    chunks_into(plan, lm, &mut ps.chunks);

    // ---- communication ----
    let in_bytes = (model.d_model * model.dtype_bytes) as u64;
    // SwiGLU output dim is D; the single-matrix form of §2.1 outputs H.
    let out_dim = if model.swiglu { model.d_model } else { model.d_ff };
    let out_bytes = (out_dim * model.dtype_bytes) as u64;
    dispatch_bytes_into(&ps.chunks, devices, in_bytes, &mut ps.disp);
    combine_bytes_into(&ps.chunks, devices, out_bytes, &mut ps.comb);
    engine.comm.all_to_all_times_into(&ps.disp, &mut ps.dispatch_times);
    engine.comm.all_to_all_times_into(&ps.comb, &mut ps.combine_times);
    let dispatch_s = ps.dispatch_times.iter().cloned().fold(0.0, f64::max);
    let combine_s = ps.combine_times.iter().cloned().fold(0.0, f64::max);
    let bytes_dispatch: u64 = ps.disp.iter().flatten().sum();
    let bytes_combine: u64 = ps.comb.iter().flatten().sum();

    // ---- weight transfers (P2P), charged to the receiving device ----
    // EPLB's replication is time-amortized (placements change rarely) but
    // still costs memory; LLEP pays per step. Policy comes from the
    // planner trait, not a closed enum.
    let pool = &engine.pool;
    let degraded = pool.is_degraded();
    let mut stranded = false;
    let charge_weights = planner.charges_weight_transfers();
    let wbytes = model.expert_weight_bytes() as u64;
    ps.weights_recv_s.clear();
    ps.weights_recv_s.resize(devices, 0.0);
    // Accumulate in the canonical `(to, from, expert)` order: two plans
    // with the same transfer *set* must price bit-identically regardless
    // of the order the planner emitted them (float addition is not
    // associative). In-tree planners canonicalize at construction, so
    // the plan's own list is read as-is; an out-of-tree plan that did
    // not is sorted on this cold path.
    let ordered: &[WeightTransfer] = if plan.transfers_canonical() {
        &plan.transfers
    } else {
        ps.ordered.clear();
        ps.ordered.extend_from_slice(&plan.transfers);
        ps.ordered.sort_unstable_by_key(|t| (t.to, t.from, t.expert));
        &ps.ordered
    };
    for t in ordered {
        if degraded && !pool.devices[t.from].alive {
            // The source HBM is gone with its device: weights restore
            // from the host checkpoint path, charged at (degraded)
            // inter-node bandwidth — the elastic-replan recovery cost.
            ps.weights_recv_s[t.to] +=
                engine.topo.latency_s + wbytes as f64 / engine.topo.inter_node_bw;
        } else {
            ps.weights_recv_s[t.to] += engine.comm.p2p_time(t.from, t.to, wbytes);
        }
        if degraded && !pool.devices[t.to].alive {
            stranded = true; // weights shipped to a dead device
        }
    }
    if !charge_weights {
        ps.weights_recv_s.iter_mut().for_each(|w| *w = 0.0);
    }
    let bytes_weights = plan.transfers.len() as u64 * wbytes;

    // ---- expert migrations (persistent placement) ----
    // Charged UNCONDITIONALLY, after the amortization zero-out above: a
    // migration is a one-time weight movement the placement layer
    // decided *this step*, so even planners whose steady-state spill
    // transfers are amortized away (EPLB-style) pay it now. Receiving
    // devices absorb it into the same pre-compute weights span — the new
    // resident weights must land before that device computes against the
    // new layout. `plan.migrations` is canonical `(to, from, expert)`
    // order, so accumulation is deterministic.
    let mut placement = planner.last_placement_stats().unwrap_or_default();
    if !plan.migrations.is_empty() {
        let mig_bytes = engine.migration_bytes_per_expert.unwrap_or(wbytes);
        let mut migration_s = 0.0f64;
        for t in &plan.migrations {
            let dt = if degraded && !pool.devices[t.from].alive {
                // The source HBM died with its device: the weights
                // restore from the host checkpoint path instead.
                engine.topo.latency_s + mig_bytes as f64 / engine.topo.inter_node_bw
            } else {
                engine.comm.p2p_time(t.from, t.to, mig_bytes)
            };
            ps.weights_recv_s[t.to] += dt;
            migration_s += dt;
            if degraded && !pool.devices[t.to].alive {
                stranded = true; // migrated onto a dead device
            }
        }
        placement.migration_bytes = plan.migrations.len() as u64 * mig_bytes;
        placement.migration_s = migration_s;
    }

    // ---- compute (Eq. 3 or measured) ----
    // A chunking planner splits each device's per-expert GEMMs into
    // chunk-sized pieces (gradient-checkpointing baseline, paper §3.1).
    // The fold runs straight over the work lists — same summation order
    // as the historical collect-then-sum, with zero intermediates.
    let chunk = planner.chunk_tokens();
    device_work_into(plan, lm, &mut ps.work);
    let work = &ps.work;
    let device_compute_s: Vec<f64> = match measured_compute {
        Some(m) => m.to_vec(),
        None => work
            .iter()
            .enumerate()
            .map(|(d, w)| {
                let mut t = 0.0f64;
                for &(_, tokens) in w {
                    match chunk {
                        None => t += engine.gemm.gemm_time(tokens, model),
                        Some(c) => {
                            for _ in 0..tokens / c {
                                t += engine.gemm.gemm_time(c, model);
                            }
                            if tokens % c > 0 {
                                t += engine.gemm.gemm_time(tokens % c, model);
                            }
                        }
                    }
                }
                if !degraded {
                    return t;
                }
                // Chaos view: completion time is work / speed. Work on a
                // dead device can never complete — the step is stranded
                // (latency stays finite so reports remain summable; the
                // flag is what invalidates the step).
                let state = pool.devices[d];
                if !state.alive {
                    if t > 0.0 {
                        stranded = true;
                    }
                    t
                } else {
                    t / state.speed
                }
            })
            .collect(),
    };

    // Between the dispatch and combine barriers each device needs its
    // imported weights before computing; with the §4 overlap optimization
    // the transfer hides behind compute.
    let compute_span = device_compute_s
        .iter()
        .zip(&ps.weights_recv_s)
        .map(|(c, w)| if engine.overlap_weights { c.max(*w) } else { c + w })
        .fold(0.0, f64::max);

    // ---- memory (Eq. 4) ----
    let m_resident = model.num_experts / devices;
    let mem_model = &engine.mem;
    let device_peak_bytes: Vec<u64> = (0..devices)
        .map(|d| {
            let tokens = work[d].iter().map(|&(_, t)| t);
            let imports = plan.imports_count(d);
            match chunk {
                Some(c) => mem_model
                    .device_peak_bytes_chunked_iter(model, tokens, m_resident, imports, c),
                None => mem_model.device_peak_bytes_iter(model, tokens, m_resident, imports),
            }
        })
        .collect();
    let oom = device_peak_bytes.iter().any(|&b| b > engine.system.mem_capacity_bytes);

    // ---- assemble ----
    let meta_s = engine.topo.latency_s * 2.0; // loads all-gather + plan bcast
    let phases = PhaseTimes {
        meta_s,
        plan_s: plan_time_s,
        dispatch_s,
        weights_s: ps.weights_recv_s.iter().cloned().fold(0.0, f64::max),
        compute_s: compute_span,
        combine_s,
    };
    let latency_s = meta_s + plan_time_s + dispatch_s + compute_span + combine_s;

    StepReport {
        planner: planner.label(),
        backend: if measured_compute.is_some() {
            GemmBackendKind::Native
        } else {
            GemmBackendKind::Modeled
        },
        latency_s,
        phases,
        device_compute_s,
        device_peak_bytes,
        bytes_dispatch,
        bytes_combine,
        bytes_weights,
        gemm_calls: plan.gemm_calls(),
        weight_transfers: plan.transfers.len(),
        oom,
        stranded,
        fallback_ep: plan.fallback_ep,
        tokens: lm.total_load() / lm.top_k as u64,
        cache: planner.last_cache_outcome().map(CacheStats::of).unwrap_or_default(),
        placement,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
    use crate::exec::Engine;
    use crate::planner::PlannerKind;
    use crate::routing::Scenario;
    use crate::util::rng::Rng;

    fn engine() -> Engine {
        Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        )
    }

    #[test]
    fn ep_pays_no_weight_transfers() {
        let e = engine();
        let mut rng = Rng::new(1);
        let lm = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 8192, &mut rng);
        let r = e.run_step_loads(&lm, &PlannerKind::StandardEp);
        assert_eq!(r.weight_transfers, 0);
        assert_eq!(r.bytes_weights, 0);
        assert_eq!(r.phases.weights_s, 0.0);
    }

    #[test]
    fn llep_pays_weight_transfers_eplb_does_not() {
        let e = engine();
        let mut rng = Rng::new(2);
        let lm = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 8192, &mut rng);
        let ll = e.run_step_loads(&lm, &PlannerKind::llep_default());
        assert!(ll.phases.weights_s > 0.0);
        let eplb = e.run_step_loads(&lm, &PlannerKind::Eplb { replicas: 7 });
        assert_eq!(eplb.phases.weights_s, 0.0, "EPLB weight moves amortized");
        assert!(eplb.weight_transfers > 0, "but they exist (memory)");
    }

    #[test]
    fn oom_detected_under_extreme_imbalance() {
        // Tiny memory capacity forces EP to OOM on the hot device.
        let model = ModelConfig::preset(ModelPreset::Fig1Layer);
        let mut sys = SystemConfig::preset(SystemPreset::H200x8);
        sys.mem_capacity_bytes = 4 << 30; // 4 GiB: LLEP fits, EP does not
        let e = Engine::modeled(model, sys);
        let mut rng = Rng::new(3);
        let lm = Scenario::concentrated(0.95, 1).generate_loads(&e.model, 8, 65_536, &mut rng);
        let ep = e.run_step_loads(&lm, &PlannerKind::StandardEp);
        let ll = e.run_step_loads(&lm, &PlannerKind::llep_default());
        assert!(ep.oom, "EP must OOM: peak {}", ep.max_peak_bytes());
        assert!(!ll.oom, "LLEP must fit: peak {}", ll.max_peak_bytes());
    }

    #[test]
    fn latency_decomposition_sums() {
        let e = engine();
        let mut rng = Rng::new(4);
        let lm = Scenario::concentrated(0.5, 4).generate_loads(&e.model, 8, 8192, &mut rng);
        let r = e.run_step_loads(&lm, &PlannerKind::llep_default());
        let p = &r.phases;
        let sum = p.meta_s + p.plan_s + p.dispatch_s + p.compute_s + p.combine_s;
        assert!((r.latency_s - sum).abs() < 1e-12);
    }

    #[test]
    fn chunked_ep_trades_time_for_memory() {
        let e = engine();
        let mut rng = Rng::new(21);
        let lm = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 32_768, &mut rng);
        let ep = e.run_step_loads(&lm, &PlannerKind::StandardEp);
        let chunked = e.run_step_loads(&lm, &PlannerKind::ChunkedEp { chunk_tokens: 4096 });
        let ll = e.run_step_loads(&lm, &PlannerKind::llep_default());
        // memory drops vs EP, but latency is worse than EP (extra kernel
        // launches) and far worse than LLEP — the paper's §3.1 point.
        assert!(chunked.max_peak_bytes() < ep.max_peak_bytes());
        assert!(chunked.latency_s >= ep.latency_s);
        assert!(chunked.latency_s > ll.latency_s * 2.0);
        // but memory is NOT bounded like LLEP's (inputs still resident)
        assert!(chunked.max_peak_bytes() > ll.max_peak_bytes());
    }

    #[test]
    fn overlap_hides_weight_transfers() {
        let e = engine();
        let mut rng = Rng::new(22);
        let lm = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 32_768, &mut rng);
        let base = e.run_step_loads(&lm, &PlannerKind::llep_default());
        let overlapped = e.clone().with_overlap().run_step_loads(&lm, &PlannerKind::llep_default());
        assert!(base.phases.weights_s > 0.0);
        assert!(
            overlapped.latency_s < base.latency_s,
            "overlap {} vs base {}",
            overlapped.latency_s,
            base.latency_s
        );
        // compute itself unchanged
        assert_eq!(overlapped.device_compute_s, base.device_compute_s);
    }

    #[test]
    fn straggler_slows_ep_but_llep_replans_around_it() {
        use crate::chaos::PoolState;
        let e = engine();
        let mut rng = Rng::new(31);
        let lm = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 32_768, &mut rng);
        let base_ep = e.run_step_loads(&lm, &PlannerKind::StandardEp);
        let mut pool = PoolState::healthy(8);
        pool.devices[0].speed = 0.25; // 4x straggler under the hot expert
        let slow = e.for_pool(pool);
        let slow_ep = slow.run_step_loads(&lm, &PlannerKind::StandardEp);
        // EP's hot device is the straggler: compute inflates ~4x.
        assert!(slow_ep.phases.compute_s > base_ep.phases.compute_s * 3.0);
        assert!(!slow_ep.stranded, "slow is not dead");
        // Speed-aware LLEP rebalances by normalized time.
        let slow_ll = slow.run_step_loads(&lm, &PlannerKind::llep_default());
        assert!(
            slow_ll.latency_s * 2.0 < slow_ep.latency_s,
            "LLEP {} vs EP {} under the straggler",
            slow_ll.latency_s,
            slow_ep.latency_s
        );
    }

    #[test]
    fn dead_device_strands_static_plans_only() {
        use crate::chaos::PoolState;
        let e = engine();
        let mut rng = Rng::new(32);
        let lm = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 8192, &mut rng);
        let mut pool = PoolState::healthy(8);
        pool.devices[0].alive = false;
        let broken = e.for_pool(pool);
        let ep = broken.run_step_loads(&lm, &PlannerKind::StandardEp);
        assert!(ep.stranded, "EP leaves the hot experts on the dead device");
        let ll = broken.run_step_loads(&lm, &PlannerKind::llep_default());
        assert!(!ll.stranded, "pool-aware LLEP plans around the hole");
        assert_eq!(ll.tokens, lm.total_load() / lm.top_k as u64, "no tokens lost");
        // The replanned step pays host-restore weight transfers for the
        // dead device's experts.
        assert!(ll.weight_transfers > 0);
        assert!(ll.phases.weights_s > 0.0);
    }

    #[test]
    fn degraded_links_stretch_collectives() {
        use crate::chaos::PoolState;
        let e = engine();
        let mut rng = Rng::new(33);
        let lm = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 32_768, &mut rng);
        let base = e.run_step_loads(&lm, &PlannerKind::llep_default());
        let mut pool = PoolState::healthy(8);
        pool.link_factor = 4.0;
        let slow_net = e.for_pool(pool);
        let r = slow_net.run_step_loads(&lm, &PlannerKind::llep_default());
        assert!(r.phases.dispatch_s > base.phases.dispatch_s * 2.0);
        assert_eq!(r.device_compute_s, base.device_compute_s, "compute untouched");
    }

    #[test]
    fn device_scoped_link_fault_stretches_collectives() {
        use crate::chaos::PoolState;
        let e = engine();
        let mut rng = Rng::new(33);
        let lm = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 32_768, &mut rng);
        let base = e.run_step_loads(&lm, &PlannerKind::llep_default());
        // The hot expert lives on device 0, so spilled tokens cross its
        // links: a device-0 link fault must slow dispatch/combine...
        let mut pool = PoolState::healthy(8);
        pool.degrade_device_link(0, 8.0);
        let r = e.for_pool(pool).run_step_loads(&lm, &PlannerKind::llep_default());
        assert!(
            r.phases.dispatch_s > base.phases.dispatch_s,
            "{} vs {}",
            r.phases.dispatch_s,
            base.phases.dispatch_s
        );
        assert_eq!(r.device_compute_s, base.device_compute_s, "compute untouched");
        // ... and strictly less than degrading every link by the same
        // factor (only transfers touching device 0 pay).
        let mut global = PoolState::healthy(8);
        global.link_factor = 8.0;
        let g = e.for_pool(global).run_step_loads(&lm, &PlannerKind::llep_default());
        assert!(r.phases.dispatch_s < g.phases.dispatch_s);
    }

    #[test]
    fn gemm_call_count_grows_with_spill() {
        let e = engine();
        let mut rng = Rng::new(5);
        let lm = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 32_768, &mut rng);
        let ep = e.run_step_loads(&lm, &PlannerKind::StandardEp);
        let ll = e.run_step_loads(&lm, &PlannerKind::llep_default());
        assert!(ll.gemm_calls > ep.gemm_calls);
    }
}
