//! Dispatch bookkeeping: mapping plan segments to per-(src, dst) token
//! movements and All-to-All byte matrices.
//!
//! An expert's tokens are globally ordered as the concatenation of each
//! origin device's local tokens (device-major), exactly the order the
//! sorted/index-selected `All-to-All` of paper Alg. 1/4 produces. A plan
//! segment `[start, end)` for expert `e` therefore overlaps a computable
//! set of origin devices; each overlap is one chunk moving
//! `origin -> segment.device`.

use crate::planner::RoutePlan;
use crate::routing::LoadMatrix;

/// One token chunk moving between devices for one expert.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    pub expert: usize,
    pub origin: usize,
    pub dest: usize,
    /// Token range within the origin device's local order for this expert.
    pub local_start: u64,
    pub local_end: u64,
}

impl Chunk {
    pub fn tokens(&self) -> u64 {
        self.local_end - self.local_start
    }
}

/// Compute, for expert `e`, each origin device's offset into the global
/// token order: `offsets[p] = sum_{q < p} counts[q][e]`.
pub fn origin_offsets(lm: &LoadMatrix, expert: usize) -> Vec<u64> {
    let mut offsets = Vec::with_capacity(lm.devices());
    let mut acc = 0u64;
    for p in 0..lm.devices() {
        offsets.push(acc);
        acc += lm.counts[p][expert];
    }
    offsets
}

/// Enumerate all chunks implied by `plan` over `lm` (only non-empty, and
/// including local "chunks" where origin == dest so compute accounting can
/// use the same stream; comm pricing skips those).
pub fn chunks(plan: &RoutePlan, lm: &LoadMatrix) -> Vec<Chunk> {
    let mut out = Vec::new();
    chunks_into(plan, lm, &mut out);
    out
}

/// [`chunks`] into a reusable buffer. The per-expert origin offsets are
/// accumulated inline rather than collected (the historical
/// implementation allocated one offsets vector per expert per priced
/// step), so the pricing hot path stays allocation-free once warm.
pub fn chunks_into(plan: &RoutePlan, lm: &LoadMatrix, out: &mut Vec<Chunk>) {
    out.clear();
    for (e, segs) in plan.assignments.iter().enumerate() {
        if segs.is_empty() {
            continue;
        }
        for seg in segs {
            // intersect [seg.start, seg.end) with each origin's range
            let mut o_start = 0u64;
            for p in 0..lm.devices() {
                let o_end = o_start + lm.counts[p][e];
                let lo = seg.start.max(o_start);
                let hi = seg.end.min(o_end);
                if lo < hi {
                    out.push(Chunk {
                        expert: e,
                        origin: p,
                        dest: seg.device,
                        local_start: lo - o_start,
                        local_end: hi - o_start,
                    });
                }
                o_start = o_end;
            }
        }
    }
}

/// Clear + size a per-(src, dst) byte matrix, reusing row allocations.
fn reset_matrix(m: &mut Vec<Vec<u64>>, devices: usize) {
    m.truncate(devices);
    for row in m.iter_mut() {
        row.clear();
        row.resize(devices, 0);
    }
    while m.len() < devices {
        m.push(vec![0u64; devices]);
    }
}

/// Per-(src, dst) byte matrix for the dispatch All-to-All, given bytes per
/// token (`token_bytes`). Local movements cost nothing.
pub fn dispatch_bytes(chunks: &[Chunk], devices: usize, token_bytes: u64) -> Vec<Vec<u64>> {
    let mut m = Vec::new();
    dispatch_bytes_into(chunks, devices, token_bytes, &mut m);
    m
}

/// [`dispatch_bytes`] into a reusable matrix (the pricing hot path).
pub fn dispatch_bytes_into(
    chunks: &[Chunk],
    devices: usize,
    token_bytes: u64,
    m: &mut Vec<Vec<u64>>,
) {
    reset_matrix(m, devices);
    for c in chunks {
        if c.origin != c.dest {
            m[c.origin][c.dest] += c.tokens() * token_bytes;
        }
    }
}

/// The combine All-to-All is the exact reverse of dispatch.
pub fn combine_bytes(chunks: &[Chunk], devices: usize, token_bytes: u64) -> Vec<Vec<u64>> {
    let mut m = Vec::new();
    combine_bytes_into(chunks, devices, token_bytes, &mut m);
    m
}

/// [`combine_bytes`] into a reusable matrix (the pricing hot path).
pub fn combine_bytes_into(
    chunks: &[Chunk],
    devices: usize,
    token_bytes: u64,
    m: &mut Vec<Vec<u64>>,
) {
    reset_matrix(m, devices);
    for c in chunks {
        if c.origin != c.dest {
            m[c.dest][c.origin] += c.tokens() * token_bytes;
        }
    }
}

/// Tokens each device must hold and compute: `work[d]` lists (expert,
/// tokens) in expert order — the grouped-GEMM batch sizes of the step.
pub fn device_work(plan: &RoutePlan, lm: &LoadMatrix) -> Vec<Vec<(usize, u64)>> {
    let mut work = Vec::new();
    device_work_into(plan, lm, &mut work);
    work
}

/// [`device_work`] into a reusable set of per-device buffers.
pub fn device_work_into(plan: &RoutePlan, lm: &LoadMatrix, work: &mut Vec<Vec<(usize, u64)>>) {
    work.truncate(plan.devices);
    for w in work.iter_mut() {
        w.clear();
    }
    while work.len() < plan.devices {
        work.push(Vec::new());
    }
    for (e, segs) in plan.assignments.iter().enumerate() {
        let _ = lm; // loads are implicit in the segments
        for s in segs {
            if s.len() > 0 {
                // merge consecutive segments of the same expert+device
                if let Some(last) = work[s.device].last_mut() {
                    if last.0 == e {
                        last.1 += s.len();
                        continue;
                    }
                }
                work[s.device].push((e, s.len()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{plan_ep, plan_llep};
    use crate::config::LlepConfig;

    /// 2 devices, 2 experts. Origin loads: device0 -> [3, 1], device1 -> [5, 7].
    fn lm() -> LoadMatrix {
        LoadMatrix { counts: vec![vec![3, 1], vec![5, 7]], top_k: 1 }
    }

    #[test]
    fn offsets_are_prefix_sums() {
        let lm = lm();
        assert_eq!(origin_offsets(&lm, 0), vec![0, 3]);
        assert_eq!(origin_offsets(&lm, 1), vec![0, 1]);
    }

    #[test]
    fn ep_chunks_route_to_native() {
        let lm = lm();
        let plan = plan_ep(2, 2, &lm.expert_loads()); // loads: e0=8, e1=8
        let cs = chunks(&plan, &lm);
        // expert 0 native device 0: dev0 keeps 3 local, dev1 sends 5
        // expert 1 native device 1: dev0 sends 1, dev1 keeps 7
        let want = Chunk { expert: 0, origin: 0, dest: 0, local_start: 0, local_end: 3 };
        assert!(cs.contains(&want));
        let want = Chunk { expert: 0, origin: 1, dest: 0, local_start: 0, local_end: 5 };
        assert!(cs.contains(&want));
        let want = Chunk { expert: 1, origin: 0, dest: 1, local_start: 0, local_end: 1 };
        assert!(cs.contains(&want));
        let want = Chunk { expert: 1, origin: 1, dest: 1, local_start: 0, local_end: 7 };
        assert!(cs.contains(&want));
        assert_eq!(cs.len(), 4);
        let total: u64 = cs.iter().map(|c| c.tokens()).sum();
        assert_eq!(total, 16);
    }

    #[test]
    fn byte_matrices_are_transposes() {
        let lm = lm();
        let plan = plan_ep(2, 2, &lm.expert_loads());
        let cs = chunks(&plan, &lm);
        let d = dispatch_bytes(&cs, 2, 10);
        let c = combine_bytes(&cs, 2, 10);
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(d[i][j], c[j][i]);
            }
        }
        // device1 sends expert-0 tokens (5) to device0: 50 bytes
        assert_eq!(d[1][0], 50);
        assert_eq!(d[0][1], 10);
        assert_eq!(d[0][0], 0);
    }

    #[test]
    fn segment_split_across_origins() {
        // Expert 0 has 8 tokens: 3 from dev0 then 5 from dev1. A segment
        // [2, 6) must split into (dev0 local [2,3)) and (dev1 local [0,3)).
        let lm = lm();
        let mut plan = plan_ep(2, 2, &lm.expert_loads());
        plan.assignments[0] = vec![
            crate::planner::Segment { device: 0, start: 0, end: 2, forced: false },
            crate::planner::Segment { device: 1, start: 2, end: 6, forced: false },
            crate::planner::Segment { device: 0, start: 6, end: 8, forced: false },
        ];
        let cs: Vec<Chunk> = chunks(&plan, &lm).into_iter().filter(|c| c.expert == 0).collect();
        let want = Chunk { expert: 0, origin: 0, dest: 1, local_start: 2, local_end: 3 };
        assert!(cs.contains(&want));
        let want = Chunk { expert: 0, origin: 1, dest: 1, local_start: 0, local_end: 3 };
        assert!(cs.contains(&want));
        let want = Chunk { expert: 0, origin: 1, dest: 0, local_start: 3, local_end: 5 };
        assert!(cs.contains(&want));
        let total: u64 = cs.iter().map(|c| c.tokens()).sum();
        assert_eq!(total, 8);
    }

    #[test]
    fn device_work_merges_contiguous() {
        let loads = vec![1000u64, 0, 0, 0];
        let lm = LoadMatrix { counts: vec![vec![250, 0, 0, 0]; 4], top_k: 1 };
        let cfg = LlepConfig { alpha: 1.0, min_gemm_tokens: 10, lambda: 1.3 };
        let plan = plan_llep(&cfg, 4, 4, &loads, None);
        let work = device_work(&plan, &lm);
        // every device computes exactly one (expert 0, 250) group
        for w in &work {
            assert_eq!(w.len(), 1);
            assert_eq!(w[0], (0, 250));
        }
    }

    #[test]
    fn chunks_conserve_tokens_under_llep() {
        let lm = LoadMatrix {
            counts: vec![vec![100, 3, 7, 2], vec![50, 9, 1, 40], vec![200, 0, 0, 8]],
            top_k: 1,
        };
        // 4 experts / 2 devices... need N % P == 0 with P=3 -> use N=3? keep
        // P dividing N: use devices=2 on 4 experts.
        let lm2 = LoadMatrix { counts: vec![lm.counts[0].clone(), lm.counts[1].clone()], top_k: 1 };
        let loads = lm2.expert_loads();
        let cfg = LlepConfig { alpha: 1.0, min_gemm_tokens: 5, lambda: 1.0 };
        let plan = plan_llep(&cfg, 4, 2, &loads, None);
        let cs = chunks(&plan, &lm2);
        let total: u64 = cs.iter().map(|c| c.tokens()).sum();
        assert_eq!(total, lm2.total_load());
    }
}
