//! Execution engine: prices and (optionally, with real numerics)
//! executes a routing plan over `P` virtual devices.
//!
//! ## Virtual-clock model
//!
//! This testbed has no GPUs (see DESIGN.md), so a "device" is a clock +
//! memory tracker. Each phase of the paper's dispatch-compute-combine is
//! charged to the owning device's clock; synchronous collectives are
//! barriers, so the step latency is
//!
//! ```text
//! T = T_meta + T_plan + max_p T_dispatch(p)
//!     + max_p (T_weights(p) + T_compute(p)) + max_p T_combine(p)
//! ```
//!
//! — the `max_i[time-of-GPU i]` collective latency the paper's §5.3
//! ablation reasons about. `T_plan` is the *measured* wall time of the
//! planner (LLA is on the critical path, exactly as in the paper).
//!
//! ## Backends
//!
//! * [`Engine::run_step`] — cost-model only, runs at paper scale.
//! * [`Engine::run_model`] — all MoE layers of one forward step, one plan
//!   per layer, planning pipelined against execution (see [`model`]).
//! * [`Engine::run_step_real`] — moves real token matrices through the
//!   plan and computes real expert FFNs via an [`ExpertCompute`] backend
//!   (native rust GEMMs, or PJRT-loaded HLO artifacts), proving the plan
//!   is an exact MoE computation.

pub mod dispatch;
pub mod model;
mod pricing;
mod real;

pub use model::{LayerStep, ModelStepReport};
pub use pricing::{price_plan, PhaseTimes};
pub use real::{run_backward_real, run_step_real, NativeCompute, RealStep};

use crate::chaos::PoolState;
use crate::config::{ModelConfig, SystemConfig};
use crate::costmodel::{CommCostModel, GemmCostModel, MemoryModel};
use crate::moe::ExpertWeights;
use crate::placement::PlacementStats;
use crate::planner::{CacheOutcome, CacheStats, Planner};
use crate::routing::{LoadMatrix, Routing};
use crate::tensor::Mat;
use crate::topology::Topology;

/// Pluggable expert-FFN compute for the real-numerics path.
pub trait ExpertCompute {
    /// Compute `ffn(x)` with the given expert weights.
    fn ffn(&self, x: &Mat, w: &ExpertWeights) -> Mat;
    /// Human-readable backend name (for reports).
    fn name(&self) -> &'static str;
}

/// Which compute backend the engine charges/executes with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmBackendKind {
    /// Analytic Eq.-3 model only (paper-scale simulations).
    Modeled,
    /// Real native-rust GEMMs, measured wall time charged to clocks.
    Native,
    /// PJRT-executed HLO artifacts (Pallas kernel path).
    Pjrt,
}

/// Report for one simulated/executed step.
#[derive(Clone, Debug)]
pub struct StepReport {
    pub planner: String,
    pub backend: GemmBackendKind,
    /// End-to-end step latency (virtual seconds).
    pub latency_s: f64,
    pub phases: PhaseTimes,
    /// Per-device compute time (the quantity LLEP balances).
    pub device_compute_s: Vec<f64>,
    /// Per-device peak memory per Eq. 4.
    pub device_peak_bytes: Vec<u64>,
    pub bytes_dispatch: u64,
    pub bytes_combine: u64,
    pub bytes_weights: u64,
    pub gemm_calls: usize,
    pub weight_transfers: usize,
    /// True when some device exceeded its memory capacity.
    pub oom: bool,
    /// True when the plan left expert work (or a weight destination) on a
    /// dead device: the step cannot actually complete on this pool. Only
    /// a pool-aware planner avoids this under failures — static EP
    /// cannot, which is the chaos layer's point.
    pub stranded: bool,
    /// True when the lambda guard reverted to standard EP.
    pub fallback_ep: bool,
    /// Total tokens processed this step.
    pub tokens: u64,
    /// Plan-cache outcome for this step's plan (all zero for planners
    /// without a cache; exactly one field is 1 for a [`CachedPlanner`]
    /// step).
    ///
    /// [`CachedPlanner`]: crate::planner::CachedPlanner
    pub cache: CacheStats,
    /// Persistent-placement activity behind this step's plan (all zero
    /// for planners without a `placed(...)` layer). `migration_bytes` /
    /// `migration_s` are what pricing actually charged into
    /// `latency_s` for the layout moves.
    pub placement: PlacementStats,
}

impl StepReport {
    pub fn max_peak_bytes(&self) -> u64 {
        self.device_peak_bytes.iter().copied().max().unwrap_or(0)
    }
    /// Tokens per (virtual) second.
    pub fn throughput(&self) -> f64 {
        if self.latency_s > 0.0 {
            self.tokens as f64 / self.latency_s
        } else {
            0.0
        }
    }
    /// Load-balance quality: max/mean of per-device compute time.
    pub fn compute_imbalance(&self) -> f64 {
        crate::util::stats::max_over_mean(&self.device_compute_s)
    }
}

/// Deterministic planner-latency model. By default the engine charges
/// the planner's *measured* wall time as `T_plan` (faithful to the
/// paper, but different on every run). With a `PlanCostModel` installed
/// ([`Engine::with_plan_cost`]) the engine instead charges `fresh_s` per
/// fresh plan and `hit_s` per plan-cache hit, making every priced
/// quantity a pure function of its inputs — the bit-identical-trials
/// contract the autotuner ([`crate::tune`]) is built on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanCostModel {
    /// Charged when the planner computed a fresh plan (or has no cache).
    pub fresh_s: f64,
    /// Charged when a plan-cache hit retargeted a cached plan
    /// (the O(segments) path of [`crate::planner::retarget_plan`]).
    pub hit_s: f64,
    /// Charged **per peeled segment** when the cache delta-repaired a
    /// retargeted plan: a repair costs
    /// `hit_s + peeled_segments * repair_s`, so a one-segment touch-up
    /// prices barely above a hit while a broad rebalance approaches a
    /// fresh plan — the repair tier's actual O(changed work) shape,
    /// instead of the historical flat per-repair constant.
    pub repair_s: f64,
}

impl Default for PlanCostModel {
    fn default() -> Self {
        // ~LLA wall time at N=128 experts vs the retarget path of a hit
        // (both in the range measured by `cargo bench --bench decode_loop`);
        // repair adds ~1 µs per peeled segment on top of the retarget
        // (each peel is one excess computation + spill re-insert), so
        // typical few-segment repairs land between hit_s and fresh_s.
        PlanCostModel { fresh_s: 25e-6, hit_s: 2e-6, repair_s: 1e-6 }
    }
}

/// The engine: model + system + cost models.
#[derive(Clone, Debug)]
pub struct Engine {
    pub model: ModelConfig,
    pub system: SystemConfig,
    pub topo: Topology,
    pub gemm: GemmCostModel,
    pub comm: CommCostModel,
    pub mem: MemoryModel,
    /// Overlap weight P2P transfers with native-expert compute (paper §4
    /// "the communication can be overlapped with computation"): a
    /// device's barrier-to-barrier span becomes `max(compute, weights)`
    /// instead of `compute + weights`. Off by default (the paper's base
    /// implementation does not overlap).
    pub overlap_weights: bool,
    /// When set, `T_plan` is charged from this model instead of measured
    /// planner wall time, making pricing fully deterministic.
    pub plan_cost: Option<PlanCostModel>,
    /// Bytes moved per expert migration (the persistent-placement
    /// layer). `None` charges the model's expert weight bytes; training
    /// setups that move optimizer state alongside the weights install a
    /// larger figure via [`with_placement`](Self::with_placement).
    pub migration_bytes_per_expert: Option<u64>,
    /// Per-device health/speed view (the chaos layer). Defaults to the
    /// system's nominal pool — homogeneous-healthy unless the preset
    /// declares `device_speeds`. While the pool is degraded, planners get
    /// it via [`Planner::plan_with_pool`] and pricing divides device
    /// compute time by effective speed; a healthy pool takes the exact
    /// pre-chaos code paths (bit-identical pricing).
    pub pool: PoolState,
    /// Execution-timeline recorder ([`crate::trace`]). Disabled by
    /// default — every emission site is a branch-and-return costing zero
    /// heap allocations (counting-allocator asserted in `trace::tests`).
    /// Clones and [`for_pool`](Self::for_pool) views share the enabled
    /// sink, so per-step chaos views record into the same timeline.
    pub tracer: crate::trace::Tracer,
}

impl Engine {
    /// Engine with analytic cost models derived from the presets.
    pub fn modeled(model: ModelConfig, system: SystemConfig) -> Engine {
        model.validate().expect("invalid model config");
        system.validate().expect("invalid system config");
        model
            .experts_per_device(system.devices)
            .expect("experts must divide devices");
        let topo = Topology::from_system(&system);
        Engine {
            gemm: GemmCostModel::from_system(&system),
            comm: CommCostModel::new(topo.clone()),
            mem: MemoryModel::from_model(&model),
            pool: PoolState::from_speeds(&system.device_speeds, system.devices),
            model,
            system,
            topo,
            overlap_weights: false,
            plan_cost: None,
            migration_bytes_per_expert: None,
            tracer: crate::trace::Tracer::disabled(),
        }
    }

    /// Install an execution-timeline tracer (see [`crate::trace`]).
    /// Typically an [`enabled`](crate::trace::Tracer::enabled) handle
    /// re-tagged with a per-planner / per-replica pid.
    pub fn with_tracer(mut self, tracer: crate::trace::Tracer) -> Engine {
        self.tracer = tracer;
        self
    }

    /// Install a pool view (chaos layer): the per-device speeds/liveness
    /// plus the link-degradation factor, which is folded into the
    /// topology's bandwidth tiers (always re-derived from the pristine
    /// system config, so repeated calls never compound). The serving
    /// simulators build one such view per step from their
    /// [`FaultPlan`](crate::chaos::FaultPlan).
    pub fn with_pool(mut self, pool: PoolState) -> Engine {
        assert_eq!(pool.len(), self.system.devices, "pool must cover every device");
        let topo = Topology::from_system(&self.system).degraded(pool.link_factor);
        // Per-device link divisors reach pricing only when one actually
        // deviates — an all-nominal profile keeps the exact integer
        // accumulation path (bit-identical to the pre-chaos code).
        let device_link = if pool.device_link.iter().any(|&f| f != 1.0) {
            pool.device_link.clone()
        } else {
            Vec::new()
        };
        self.comm = CommCostModel { topo: topo.clone(), fused: self.comm.fused, device_link };
        self.topo = topo;
        self.pool = pool;
        self
    }

    /// Borrowing counterpart of [`with_pool`](Self::with_pool) for
    /// per-step views.
    pub fn for_pool(&self, pool: PoolState) -> Engine {
        self.clone().with_pool(pool)
    }

    /// Charge `T_plan` from a deterministic cost model instead of
    /// measured planner wall time (reproducible pricing for the tuner).
    pub fn with_plan_cost(mut self, cost: PlanCostModel) -> Engine {
        self.plan_cost = Some(cost);
        self
    }

    /// Override the bytes charged per expert migration performed by a
    /// `placed(...)` planner. The default (without this call) is the
    /// model's per-expert weight size; set a larger figure when a move
    /// also ships optimizer state (training-time re-layouts).
    pub fn with_placement(mut self, bytes_per_expert: u64) -> Engine {
        self.migration_bytes_per_expert = Some(bytes_per_expert);
        self
    }

    /// Enable weight-transfer/compute overlap (paper §4 optimization).
    pub fn with_overlap(mut self) -> Engine {
        self.overlap_weights = true;
        self
    }

    /// Enable DeepEP-style fused collective launch accounting (paper §4).
    pub fn with_fused_comm(mut self) -> Engine {
        self.comm.fused = true;
        self
    }

    /// Plan + price one step from a load matrix (paper-scale path).
    pub fn run_step_loads(&self, lm: &LoadMatrix, planner: &dyn Planner) -> StepReport {
        self.run_step_loads_with_stats(lm, lm, planner)
    }

    /// Like [`run_step_loads`](Self::run_step_loads) but with separate
    /// placement statistics (for EPLB's time-delayed placement).
    pub fn run_step_loads_with_stats(
        &self,
        lm: &LoadMatrix,
        stats_lm: &LoadMatrix,
        planner: &dyn Planner,
    ) -> StepReport {
        let (report, plan) = self.plan_and_price(lm, stats_lm, planner);
        self.trace_step(self.tracer.time_base(), None, &report, &plan);
        // Single-step callers never see the plan: hand its buffers back
        // to this thread's planning arena (zero-alloc steady state).
        crate::planner::scratch::recycle_plan(plan);
        report
    }

    /// Emit one priced step onto the execution timeline (a no-op branch
    /// when the tracer is disabled). Events are placed at offsets from
    /// `start_s` on the virtual clock; `layer` labels multi-layer model
    /// steps. Emission is post-hoc from the priced report — the virtual
    /// clock means recording cost can never distort the timeline.
    pub(crate) fn trace_step(
        &self,
        start_s: f64,
        layer: Option<usize>,
        report: &StepReport,
        plan: &crate::planner::RoutePlan,
    ) {
        use crate::trace::{device_tid, ArgValue, FlowPoint, COORD_TID};
        let t = &self.tracer;
        if !t.is_enabled() {
            return;
        }
        let p = &report.phases;
        let layer_n = layer.unwrap_or(0) as f64;
        let plan_end = start_s + p.meta_s + p.plan_s;
        t.span(
            COORD_TID,
            "plan",
            "plan",
            start_s,
            p.meta_s + p.plan_s,
            &[
                ("layer", ArgValue::Num(layer_n)),
                ("plan_s", ArgValue::Num(p.plan_s)),
                ("weights_s", ArgValue::Num(p.weights_s)),
                ("tokens", ArgValue::Num(report.tokens as f64)),
            ],
        );
        // Plan provenance: which cache tier produced this step's plan
        // (all-zero CacheStats means a cacheless planner → fresh).
        let c = &report.cache;
        let outcome = if c.hits > 0 {
            "plan-cache-hit"
        } else if c.repairs > 0 {
            "plan-cache-repair"
        } else if c.forced > 0 {
            "plan-forced-replan"
        } else if c.misses > 0 {
            "plan-cache-miss"
        } else {
            "plan-fresh"
        };
        t.instant(
            COORD_TID,
            outcome,
            "plan",
            plan_end,
            &[
                ("hits", ArgValue::Num(c.hits as f64)),
                ("repairs", ArgValue::Num(c.repairs as f64)),
                ("misses", ArgValue::Num(c.misses as f64)),
                ("forced", ArgValue::Num(c.forced as f64)),
                ("fallback_ep", ArgValue::Num(report.fallback_ep as u8 as f64)),
            ],
        );
        // Device tracks: the dispatch/combine collectives are barriers
        // (same span on every device); compute is each device's own
        // Eq.-3 time — the spans whose max-vs-mean spread *is* the
        // straggler bubble. Combine starts at the compute barrier
        // (phases.compute_s folds weight-landing in, see PhaseTimes).
        let dispatch_end = plan_end + p.dispatch_s;
        let combine_start = start_s + report.latency_s - p.combine_s;
        for (d, &c_s) in report.device_compute_s.iter().enumerate() {
            if p.dispatch_s > 0.0 {
                t.span(device_tid(d), "dispatch", "a2a", plan_end, p.dispatch_s, &[]);
            }
            if c_s > 0.0 {
                t.span(
                    device_tid(d),
                    "compute",
                    "compute",
                    dispatch_end,
                    c_s,
                    &[("layer", ArgValue::Num(layer_n))],
                );
            }
            if p.combine_s > 0.0 {
                t.span(device_tid(d), "combine", "a2a", combine_start, p.combine_s, &[]);
            }
        }
        // Weight rebalancing as flow arrows: source device at plan end →
        // destination device at its compute start. EP never has these.
        let pid = t.pid();
        for tr in &plan.transfers {
            t.flow(
                "weights",
                "xfer",
                FlowPoint { pid, tid: device_tid(tr.from), ts_s: plan_end },
                FlowPoint { pid, tid: device_tid(tr.to), ts_s: dispatch_end },
                &[("expert", ArgValue::Num(tr.expert as f64))],
            );
        }
        // Persistent-placement migrations: one `migration` span on the
        // coordinator track per re-layout step, plus a flow arrow per
        // moved expert (distinct from per-step spill `weights` arrows —
        // these change where the expert *lives*).
        let pl = &report.placement;
        if !plan.migrations.is_empty() {
            t.span(
                COORD_TID,
                "migration",
                "placement",
                plan_end,
                pl.migration_s,
                &[
                    ("experts", ArgValue::Num(plan.migrations.len() as f64)),
                    ("bytes", ArgValue::Num(pl.migration_bytes as f64)),
                    ("standby_promotions", ArgValue::Num(pl.standby_promotions as f64)),
                ],
            );
            for tr in &plan.migrations {
                t.flow(
                    "migrate",
                    "placement",
                    FlowPoint { pid, tid: device_tid(tr.from), ts_s: plan_end },
                    FlowPoint { pid, tid: device_tid(tr.to), ts_s: dispatch_end },
                    &[("expert", ArgValue::Num(tr.expert as f64))],
                );
            }
        }
        // Metrics registry (dumped alongside the trace).
        t.count("engine/steps", 1);
        t.count(outcome, 1);
        t.count("engine/weight_transfers", report.weight_transfers as u64);
        if pl.migrations > 0 {
            t.count("placement/migrations", pl.migrations);
        }
        if pl.standby_promotions > 0 {
            t.count("placement/standby_promotions", pl.standby_promotions);
        }
        if pl.relayouts > 0 {
            t.count("placement/relayouts", pl.relayouts);
        }
        if report.oom {
            t.count("engine/oom_steps", 1);
        }
        if report.stranded {
            t.count("engine/stranded_steps", 1);
        }
        if report.fallback_ep {
            t.count("engine/fallback_ep_steps", 1);
        }
        t.observe("step/imbalance_ratio", report.compute_imbalance());
        t.observe("step/plan_s", p.plan_s);
        t.observe("step/latency_s", report.latency_s);
        t.counter("imbalance ratio", combine_start, report.compute_imbalance());
    }

    /// Shared plan-measure-price block behind every modeled step (single-
    /// layer and [`run_model`](Self::run_model) layers alike).
    pub(crate) fn plan_and_price(
        &self,
        lm: &LoadMatrix,
        stats_lm: &LoadMatrix,
        planner: &dyn Planner,
    ) -> (StepReport, crate::planner::RoutePlan) {
        let loads = lm.expert_loads();
        let stats = stats_lm.expert_loads();
        // The pool view reaches the planner only while degraded, so
        // healthy runs hit the exact pre-chaos planning path.
        let pool = self.pool.is_degraded().then_some(&self.pool);
        let plan_once = || {
            planner.plan_with_pool(self.system.devices, &loads, &stats, Some(&self.topo), pool)
        };
        let (plan, plan_time_s) = if let Some(cost) = self.plan_cost {
            // Deterministic pricing: charge the modeled planner latency
            // instead of wall time, so identical inputs price
            // bit-identically run to run (plan once — no warm run needed
            // when nothing is being measured).
            let plan = plan_once();
            let t = match planner.last_cache_outcome() {
                Some(CacheOutcome::Hit) => cost.hit_s,
                // A repair is a retarget (hit_s) plus per-peeled-segment
                // rebalance work — drift-dependent, not flat.
                Some(CacheOutcome::Repaired) => {
                    cost.hit_s + planner.last_repair_peeled() as f64 * cost.repair_s
                }
                _ => cost.fresh_s,
            };
            (plan, t)
        } else if planner.replay_safe() {
            // Run the planner twice and charge the *faster* wall time:
            // the first run absorbs first-call page faults, and the min
            // is robust to a preemption/contention spike landing on
            // either run (layers are planned on concurrent worker threads
            // in run_model). Planning is microseconds, so the extra run
            // is negligible. The warm plan's buffers are recycled into
            // this thread's planning arena before the timed run, so what
            // the clock actually measures is the allocation-free
            // steady-state path (see planner::scratch).
            let t_warm = std::time::Instant::now();
            let warm = plan_once();
            let warm_s = t_warm.elapsed().as_secs_f64();
            crate::planner::scratch::recycle_plan(warm);
            let t0 = std::time::Instant::now();
            let plan = plan_once();
            (plan, t0.elapsed().as_secs_f64().min(warm_s))
        } else {
            // Stateful planners (the plan cache) must observe each lookup
            // exactly once — a warm run would turn every miss into a hit.
            let t0 = std::time::Instant::now();
            let plan = plan_once();
            (plan, t0.elapsed().as_secs_f64())
        };
        (price_plan(self, &plan, lm, planner, plan_time_s, None), plan)
    }

    /// Convenience wrapper taking token-level routing.
    pub fn run_step(&self, routing: &Routing, planner: &dyn Planner) -> Result<StepReport, String> {
        routing.validate()?;
        if routing.devices() != self.system.devices {
            return Err(format!(
                "routing has {} devices, system has {}",
                routing.devices(),
                self.system.devices
            ));
        }
        Ok(self.run_step_loads(&routing.load_matrix(), planner))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelPreset, SystemPreset};
    use crate::planner::PlannerKind;
    use crate::routing::Scenario;
    use crate::util::rng::Rng;

    fn engine() -> Engine {
        Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        )
    }

    #[test]
    fn balanced_llep_matches_ep() {
        let e = engine();
        let mut rng = Rng::new(1);
        let lm = Scenario::balanced().generate_loads(&e.model, 8, 8192, &mut rng);
        let ep = e.run_step_loads(&lm, &PlannerKind::StandardEp);
        let ll = e.run_step_loads(&lm, &PlannerKind::llep_default());
        assert!(ll.fallback_ep, "balanced routing triggers the lambda guard");
        // identical plans; LLEP only adds (tiny, measured) plan time
        assert!((ll.latency_s - ep.latency_s).abs() / ep.latency_s < 0.05);
    }

    #[test]
    fn extreme_imbalance_speedup_and_memory() {
        let e = engine();
        let mut rng = Rng::new(2);
        let lm = Scenario::concentrated(0.95, 1).generate_loads(&e.model, 8, 32_768, &mut rng);
        let ep = e.run_step_loads(&lm, &PlannerKind::StandardEp);
        let ll = e.run_step_loads(&lm, &PlannerKind::llep_default());
        let speedup = ep.latency_s / ll.latency_s;
        assert!(speedup > 2.0, "expected big speedup, got {speedup:.2}x");
        assert!(
            ll.max_peak_bytes() * 2 < ep.max_peak_bytes(),
            "LLEP peak {} vs EP peak {}",
            ll.max_peak_bytes(),
            ep.max_peak_bytes()
        );
        assert!(!ll.fallback_ep);
        assert!(ll.weight_transfers > 0);
    }

    #[test]
    fn compute_imbalance_reduced() {
        let e = engine();
        let mut rng = Rng::new(3);
        let lm = Scenario::concentrated(0.8, 4).generate_loads(&e.model, 8, 32_768, &mut rng);
        let ep = e.run_step_loads(&lm, &PlannerKind::StandardEp);
        let ll = e.run_step_loads(&lm, &PlannerKind::llep_default());
        assert!(ll.compute_imbalance() < ep.compute_imbalance());
        assert!(ll.compute_imbalance() < 1.6, "{}", ll.compute_imbalance());
    }

    #[test]
    fn throughput_accounts_tokens() {
        let e = engine();
        let mut rng = Rng::new(4);
        let lm = Scenario::balanced().generate_loads(&e.model, 8, 1024, &mut rng);
        let r = e.run_step_loads(&lm, &PlannerKind::StandardEp);
        assert_eq!(r.tokens, 8 * 1024);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn single_device_llep_step_does_not_panic() {
        // Regression companion to lla::single_device_keeps_everything_native:
        // the whole engine path must be total for P=1 as well.
        let e = Engine::modeled(
            ModelConfig::preset(ModelPreset::Tiny),
            SystemConfig::preset(SystemPreset::CpuSim8).with_devices(1),
        );
        let mut rng = Rng::new(9);
        let lm = Scenario::concentrated(0.95, 1).generate_loads(&e.model, 1, 4096, &mut rng);
        let r = e.run_step_loads(&lm, &PlannerKind::llep_default());
        assert_eq!(r.tokens, 4096);
        assert!(!r.fallback_ep, "heavily imbalanced: LLA engages even at P=1");
        assert_eq!(r.weight_transfers, 0, "nowhere to transfer to");
    }

    #[test]
    fn plan_cost_model_prices_deterministically() {
        use crate::planner::CachedPlanner;
        let cost = PlanCostModel::default();
        let e = engine().with_plan_cost(cost);
        let mut rng = Rng::new(7);
        let lm = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 8192, &mut rng);
        let a = e.run_step_loads(&lm, &PlannerKind::llep_default());
        let b = e.run_step_loads(&lm, &PlannerKind::llep_default());
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "pricing is a pure function");
        assert_eq!(a.phases.plan_s, cost.fresh_s);
        // A plan-cache hit is charged at the cheaper hit rate.
        let cached = CachedPlanner::new(PlannerKind::llep_default().boxed());
        let miss = e.run_step_loads(&lm, &cached);
        let hit = e.run_step_loads(&lm, &cached);
        assert_eq!(miss.cache.misses, 1);
        assert_eq!(hit.cache.hits, 1);
        assert_eq!(miss.phases.plan_s, cost.fresh_s);
        assert_eq!(hit.phases.plan_s, cost.hit_s);
        assert!(hit.latency_s < miss.latency_s);
    }

    #[test]
    fn rejects_mismatched_routing() {
        let e = engine();
        let mut rng = Rng::new(5);
        let r = Scenario::balanced().generate(&e.model, 4, 16, &mut rng); // 4 != 8 devices
        assert!(e.run_step(&r, &PlannerKind::StandardEp).is_err());
    }
}
