//! Multi-layer pipelined execution: one *model* step = all
//! `model.num_moe_layers()` MoE layers of one forward pass, each layer
//! with its own [`LoadMatrix`] and its own routing plan.
//!
//! ## Pipelined planning
//!
//! A single-layer step exposes the planner on the critical path
//! (`T_meta + T_plan + ...`, see [`crate::exec`]). Across layers the
//! coordinator can do better: once the step's routing statistics are
//! known, the plan for layer `L+1` is computed *while* layer `L`
//! executes, so only layer 0 pays its metadata + planning latency in
//! full; every later layer pays only the part that does not fit inside
//! the previous layer's execution span:
//!
//! ```text
//! T_model = (meta_0 + plan_0)
//!         + Σ_l exec_l
//!         + Σ_{l>=1} max(0, (meta_l + plan_l) - exec_{l-1})
//! ```
//!
//! where `exec_l = dispatch_l + compute_l + combine_l`. The identity
//! `T_model = Σ_l T_l - overlap_saved` (serial sum minus the hidden
//! planning time) is asserted by the property tests.
//!
//! Host-side planning for the whole stack is fanned out over a
//! lightweight `std::thread::scope` pool (planning layers is embarrassingly
//! parallel — each layer's plan depends only on its own loads), so the
//! *wall* cost of planning 36+ layers stays near one layer's cost.

use super::{Engine, StepReport};
use crate::placement::PlacementStats;
use crate::planner::{CacheStats, Planner, RoutePlan};
use crate::routing::{DepthProfile, LoadMatrix};
use crate::util::rng::Rng;

/// One layer of a model step: the priced report plus the plan that
/// produced it (kept so callers can audit per-layer routing decisions).
#[derive(Clone, Debug)]
pub struct LayerStep {
    pub report: StepReport,
    pub plan: RoutePlan,
}

impl LayerStep {
    /// Metadata + planning latency — the part pipelining can hide.
    pub fn plan_span_s(&self) -> f64 {
        self.report.phases.meta_s + self.report.phases.plan_s
    }

    /// Dispatch + compute + combine latency — the part that cannot.
    pub fn exec_span_s(&self) -> f64 {
        self.report.latency_s - self.plan_span_s()
    }
}

/// Report for one full-model step (all MoE layers of one forward pass).
#[derive(Clone, Debug)]
pub struct ModelStepReport {
    pub planner: String,
    /// Per-layer reports + plans, in depth order.
    pub layers: Vec<LayerStep>,
    /// Pipelined end-to-end latency (planning overlapped with execution).
    pub latency_s: f64,
    /// Sum of stand-alone per-layer latencies (no overlap).
    pub serial_latency_s: f64,
    /// Planning/metadata time hidden behind execution:
    /// `serial_latency_s - latency_s`.
    pub overlap_saved_s: f64,
    /// Per-device peak bytes, max across layers (activations are freed
    /// between layers; per-layer Eq.-4 accounting as in the figures).
    pub device_peak_bytes: Vec<u64>,
    /// Tokens of the step's batch (each token traverses every layer).
    pub tokens: u64,
    /// True when any layer exceeded device memory.
    pub oom: bool,
    /// True when any layer left work on a dead device (see
    /// [`StepReport::stranded`]): the model step cannot complete on this
    /// pool and the serving layer must replan or error.
    pub stranded: bool,
    /// Layers whose lambda guard reverted to standard EP.
    pub fallback_layers: usize,
    /// Plan-cache counters summed across layers (all zero when the
    /// planner has no cache).
    pub cache: CacheStats,
    /// Persistent-placement activity summed across layers (all zero
    /// when the planner has no `placed(...)` layer).
    pub placement: PlacementStats,
}

impl ModelStepReport {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn max_peak_bytes(&self) -> u64 {
        self.device_peak_bytes.iter().copied().max().unwrap_or(0)
    }

    /// Tokens per (virtual) second through the whole model step.
    pub fn throughput(&self) -> f64 {
        if self.latency_s > 0.0 {
            self.tokens as f64 / self.latency_s
        } else {
            0.0
        }
    }

    /// Per-layer end-to-end latencies, in depth order.
    pub fn layer_latencies_s(&self) -> Vec<f64> {
        self.layers.iter().map(|l| l.report.latency_s).collect()
    }
}

impl Engine {
    /// Plan + price one step and also return the plan (the building block
    /// of [`run_model`](Self::run_model); single-layer callers normally
    /// want [`run_step_loads`](Self::run_step_loads)).
    pub fn run_step_loads_with_plan(
        &self,
        lm: &LoadMatrix,
        planner: &dyn Planner,
    ) -> (StepReport, RoutePlan) {
        self.plan_and_price(lm, lm, planner)
    }

    /// Execute one full-model step: one LLEP (or EP/EPLB) plan per MoE
    /// layer, planning for layer `L+1` overlapped with execution of layer
    /// `L`, per-layer planning fanned out across threads. `lms[l]` is the
    /// routing of layer `l`; all layers must share the engine's device
    /// count and expert count.
    pub fn run_model(
        &self,
        lms: &[LoadMatrix],
        planner: &dyn Planner,
    ) -> Result<ModelStepReport, String> {
        if lms.is_empty() {
            return Err("run_model needs at least one layer's loads".into());
        }
        for (l, lm) in lms.iter().enumerate() {
            lm.validate().map_err(|e| format!("layer {l}: {e}"))?;
            if lm.devices() != self.system.devices {
                return Err(format!(
                    "layer {l}: {} devices, system has {}",
                    lm.devices(),
                    self.system.devices
                ));
            }
            if lm.num_experts() != self.model.num_experts {
                return Err(format!(
                    "layer {l}: {} experts, model has {}",
                    lm.num_experts(),
                    self.model.num_experts
                ));
            }
            // One forward step pushes one batch through every layer.
            if lm.total_load() != lms[0].total_load() {
                return Err(format!(
                    "layer {l}: {} token slots, layer 0 has {} — all layers of one \
                     step must price the same batch",
                    lm.total_load(),
                    lms[0].total_load()
                ));
            }
        }

        let layers = self.plan_layers_parallel(lms, planner);

        // Fold per-layer spans into the pipelined virtual clock.
        let serial_latency_s: f64 = layers.iter().map(|l| l.report.latency_s).sum();
        let mut latency_s = 0.0;
        let mut overlap_saved_s = 0.0;
        let mut prev_exec = 0.0;
        for (i, layer) in layers.iter().enumerate() {
            let plan_span = layer.plan_span_s();
            let exec_span = layer.exec_span_s();
            if i == 0 {
                latency_s += plan_span;
            } else {
                let hidden = plan_span.min(prev_exec);
                overlap_saved_s += hidden;
                latency_s += plan_span - hidden;
            }
            latency_s += exec_span;
            prev_exec = exec_span;
        }

        // Timeline emission: replay the fold above to place each layer on
        // the virtual clock. A layer's events anchor at its *execution*
        // start; its plan span is drawn ending there, which draws hidden
        // (pipelined) planning overlapping the previous layer's execution
        // — exactly the overlap the fold credits.
        if self.tracer.is_enabled() {
            let base = self.tracer.time_base();
            let mut cursor = 0.0;
            let mut prev_exec = 0.0;
            for (i, layer) in layers.iter().enumerate() {
                let plan_span = layer.plan_span_s();
                let exec_span = layer.exec_span_s();
                let visible_plan =
                    if i == 0 { plan_span } else { (plan_span - prev_exec).max(0.0) };
                let exec_start = cursor + visible_plan;
                self.trace_step(base + exec_start - plan_span, Some(i), &layer.report, &layer.plan);
                cursor = exec_start + exec_span;
                prev_exec = exec_span;
            }
        }

        let devices = self.system.devices;
        let mut device_peak_bytes = vec![0u64; devices];
        for layer in &layers {
            for (d, &b) in layer.report.device_peak_bytes.iter().enumerate() {
                device_peak_bytes[d] = device_peak_bytes[d].max(b);
            }
        }

        let mut cache = CacheStats::default();
        let mut placement = PlacementStats::default();
        for layer in &layers {
            cache.absorb(&layer.report.cache);
            placement.absorb(&layer.report.placement);
        }

        Ok(ModelStepReport {
            planner: planner.label(),
            tokens: layers[0].report.tokens,
            oom: layers.iter().any(|l| l.report.oom),
            stranded: layers.iter().any(|l| l.report.stranded),
            fallback_layers: layers.iter().filter(|l| l.report.fallback_ep).count(),
            latency_s,
            serial_latency_s,
            overlap_saved_s,
            device_peak_bytes,
            cache,
            placement,
            layers,
        })
    }

    /// Draw one load matrix per layer from `profile` and run a full-model
    /// step (`tokens_per_device` tokens on every origin device).
    pub fn run_model_profile(
        &self,
        profile: &DepthProfile,
        planner: &dyn Planner,
        tokens_per_device: usize,
        rng: &mut Rng,
    ) -> ModelStepReport {
        let lms = profile.generate_loads(&self.model, self.system.devices, tokens_per_device, rng);
        self.run_model(&lms, planner).expect("profile-generated loads are always consistent")
    }

    /// Plan + price every layer, fanned out over scoped worker threads.
    /// Results land in depth order regardless of completion order.
    ///
    /// Stateful planners (the plan cache) are planned *sequentially* in
    /// depth order: concurrent lookups would observe the shared cache in
    /// a thread-race-dependent order, making hit/miss counters — and,
    /// under a deterministic [`PlanCostModel`](super::PlanCostModel),
    /// priced latency — irreproducible run to run.
    fn plan_layers_parallel(&self, lms: &[LoadMatrix], planner: &dyn Planner) -> Vec<LayerStep> {
        let plan_one = |lm: &LoadMatrix| {
            let (report, plan) = self.run_step_loads_with_plan(lm, planner);
            LayerStep { report, plan }
        };
        if !planner.replay_safe() {
            return lms.iter().map(plan_one).collect();
        }
        crate::util::par::parallel_map(lms, plan_one)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
    use crate::planner::PlannerKind;
    use crate::routing::Scenario;

    fn engine(preset: ModelPreset) -> Engine {
        Engine::modeled(
            ModelConfig::preset(preset),
            SystemConfig::preset(SystemPreset::H200x8),
        )
    }

    #[test]
    fn pipelined_latency_is_serial_minus_overlap() {
        let e = engine(ModelPreset::GptOss120b); // 36 layers
        let profile = DepthProfile::varying(&e.model, 0.4, 0.3);
        let mut rng = Rng::new(1);
        let r = e.run_model_profile(&profile, &PlannerKind::llep_default(), 8192, &mut rng);
        assert_eq!(r.num_layers(), 36);
        let identity = r.serial_latency_s - r.overlap_saved_s;
        assert!(
            (r.latency_s - identity).abs() <= 1e-9 * r.serial_latency_s.max(1e-30),
            "latency {} vs serial-overlap {}",
            r.latency_s,
            identity
        );
        assert!(r.latency_s <= r.serial_latency_s);
        assert!(r.overlap_saved_s >= 0.0);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn per_layer_plans_match_independent_planning() {
        let e = engine(ModelPreset::GptOss20b);
        let profile = DepthProfile::varying(&e.model, 0.35, 0.2);
        let mut rng = Rng::new(2);
        let lms = profile.generate_loads(&e.model, 8, 8192, &mut rng);
        let r = e.run_model(&lms, &PlannerKind::llep_default()).unwrap();
        for (layer, lm) in r.layers.iter().zip(&lms) {
            let independent =
                PlannerKind::llep_default().plan(8, &lm.expert_loads(), Some(&e.topo));
            assert_eq!(layer.plan, independent, "plans must not depend on batching");
        }
    }

    #[test]
    fn depth_varying_imbalance_mixes_fallback_and_llep_layers() {
        let e = engine(ModelPreset::GptOss20b); // 24 layers
        let profile = DepthProfile::from_scenarios(
            (0..e.model.num_moe_layers())
                .map(|i| {
                    if i % 2 == 0 {
                        Scenario::balanced()
                    } else {
                        Scenario::concentrated(0.9, 1)
                    }
                })
                .collect(),
        );
        let mut rng = Rng::new(3);
        let r = e.run_model_profile(&profile, &PlannerKind::llep_default(), 8192, &mut rng);
        assert_eq!(r.fallback_layers, 12, "balanced layers fall back to EP");
        assert!(!r.oom);
    }

    #[test]
    fn multi_layer_llep_beats_ep_under_depth_imbalance() {
        let e = engine(ModelPreset::GptOss120b);
        let profile = DepthProfile::varying(&e.model, 0.5, 0.2);
        let mut rng = Rng::new(4);
        let lms = profile.generate_loads(&e.model, 8, 16_384, &mut rng);
        let ep = e.run_model(&lms, &PlannerKind::StandardEp).unwrap();
        let ll = e.run_model(&lms, &PlannerKind::llep_default()).unwrap();
        assert!(
            ll.latency_s < ep.latency_s,
            "LLEP {} vs EP {}",
            ll.latency_s,
            ep.latency_s
        );
        assert!(ll.max_peak_bytes() <= ep.max_peak_bytes());
        assert_eq!(ep.tokens, ll.tokens);
    }

    #[test]
    fn single_layer_model_step_matches_single_step_structure() {
        let e = engine(ModelPreset::Fig1Layer); // 1 layer
        let mut rng = Rng::new(5);
        let lm = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 8192, &mut rng);
        let step = e.run_step_loads(&lm, &PlannerKind::llep_default());
        let model = e.run_model(std::slice::from_ref(&lm), &PlannerKind::llep_default()).unwrap();
        assert_eq!(model.num_layers(), 1);
        // Deterministic quantities agree exactly; only measured plan time
        // can differ between the two runs.
        let l = &model.layers[0].report;
        assert_eq!(l.device_compute_s, step.device_compute_s);
        assert_eq!(l.device_peak_bytes, step.device_peak_bytes);
        assert_eq!(l.bytes_dispatch, step.bytes_dispatch);
        assert_eq!(model.tokens, step.tokens);
        // A single layer has nothing to overlap with.
        assert_eq!(model.overlap_saved_s, 0.0);
    }

    #[test]
    fn stateful_planners_plan_layers_in_depth_order() {
        use crate::exec::PlanCostModel;
        use crate::planner::CachedPlanner;
        // With a shared plan cache across layers, lookups must happen in
        // depth order (not racing worker threads): identical per-layer
        // loads then give exactly one miss (layer 0) and hits everywhere
        // else, and — under the deterministic plan-cost model — two runs
        // price bit-identically.
        let e = engine(ModelPreset::GptOss20b).with_plan_cost(PlanCostModel::default());
        let layers = e.model.num_moe_layers(); // 24
        let profile = DepthProfile::uniform(Scenario::concentrated(0.9, 1), layers);
        let run = || {
            let cached = CachedPlanner::new(PlannerKind::llep_default().boxed());
            let mut rng = Rng::new(11);
            e.run_model_profile(&profile, &cached, 8192, &mut rng)
        };
        let a = run();
        let b = run();
        assert_eq!(a.cache.misses, 1, "only layer 0 misses: {:?}", a.cache);
        assert_eq!(a.cache.hits as usize, layers - 1);
        assert_eq!(a.cache, b.cache);
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "deterministic pricing");
    }

    #[test]
    fn run_model_rejects_inconsistent_inputs() {
        let e = engine(ModelPreset::Fig1Layer);
        assert!(e.run_model(&[], &PlannerKind::StandardEp).is_err());
        let mut rng = Rng::new(6);
        // wrong device count
        let lm4 = Scenario::balanced().generate_loads(&e.model, 4, 128, &mut rng);
        assert!(e.run_model(&[lm4], &PlannerKind::StandardEp).is_err());
        // wrong expert count
        let tiny = ModelConfig::preset(ModelPreset::Tiny);
        let lm_tiny = Scenario::balanced().generate_loads(&tiny, 8, 128, &mut rng);
        assert!(e.run_model(&[lm_tiny], &PlannerKind::StandardEp).is_err());
        // layers disagreeing on the batch size
        let a = Scenario::balanced().generate_loads(&e.model, 8, 128, &mut rng);
        let b = Scenario::balanced().generate_loads(&e.model, 8, 256, &mut rng);
        assert!(e.run_model(&[a, b], &PlannerKind::StandardEp).is_err());
    }
}
