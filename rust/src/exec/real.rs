//! Real-numerics execution of a routing plan.
//!
//! Moves actual token matrices through dispatch-compute-combine exactly
//! as Alg. 4 prescribes and computes expert FFNs with a pluggable
//! backend. Used to *prove* plans are exact (outputs match the
//! single-device reference bit-for-bit up to float accumulation order)
//! and to drive measured-time experiments. Wall time of each device's
//! GEMM work is charged to that device's virtual clock; communication is
//! still priced by the comm model (there is no real interconnect here).

use super::dispatch::{chunks, Chunk};
use super::{Engine, ExpertCompute, StepReport};
use crate::moe::{ffn_backward, ffn_forward, ExpertWeights, MoeLayer};
use crate::planner::{Planner, RoutePlan};
use crate::routing::Routing;
use crate::tensor::Mat;
use std::time::Instant;

/// Native rust GEMM backend.
pub struct NativeCompute;

impl ExpertCompute for NativeCompute {
    fn ffn(&self, x: &Mat, w: &ExpertWeights) -> Mat {
        ffn_forward(x, w)
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Output of a real step.
pub struct RealStep {
    /// Per origin device: `B_p x D` MoE outputs.
    pub outputs: Vec<Mat>,
    pub report: StepReport,
    pub plan: RoutePlan,
}

/// Per-(device, expert) local slot positions, in scan order — this is the
/// `sort` + `index_select` of Alg. 1/4: position `j` of expert `e`'s
/// local order on device `p` is `(token, slot) = index[p][e][j]`.
fn build_local_index(routing: &Routing) -> Vec<Vec<Vec<(u32, u8)>>> {
    let k = routing.top_k;
    routing
        .experts
        .iter()
        .map(|ids| {
            let mut per_expert: Vec<Vec<(u32, u8)>> = vec![Vec::new(); routing.num_experts];
            for (pos, &e) in ids.iter().enumerate() {
                per_expert[e as usize].push(((pos / k) as u32, (pos % k) as u8));
            }
            per_expert
        })
        .collect()
}

/// Execute one forward step with real numerics.
pub fn run_step_real(
    engine: &Engine,
    layer: &MoeLayer,
    xs: &[Mat],
    routing: &Routing,
    planner: &dyn Planner,
    backend: &dyn ExpertCompute,
) -> Result<RealStep, String> {
    routing.validate()?;
    if xs.len() != engine.system.devices || routing.devices() != engine.system.devices {
        return Err("xs/routing/device count mismatch".into());
    }
    for (p, x) in xs.iter().enumerate() {
        if x.rows != routing.tokens_on(p) || x.cols != engine.model.d_model {
            return Err(format!("device {p}: feature matrix shape mismatch"));
        }
    }

    let lm = routing.load_matrix();
    let loads = lm.expert_loads();
    let t_plan = Instant::now();
    let plan = planner.plan(engine.system.devices, &loads, Some(&engine.topo));
    let plan_time_s = t_plan.elapsed().as_secs_f64();
    crate::planner::validate::validate_plan(&plan, &loads)
        .map_err(|e| format!("planner produced an invalid plan: {e}"))?;

    let index = build_local_index(routing);
    let all_chunks = chunks(&plan, &lm);

    // Group chunks per destination device, preserving expert order.
    let mut per_dest: Vec<Vec<&Chunk>> = vec![Vec::new(); engine.system.devices];
    for c in &all_chunks {
        per_dest[c.dest].push(c);
    }

    let d_model = engine.model.d_model;
    let mut outputs: Vec<Mat> = xs.iter().map(|x| Mat::zeros(x.rows, d_model)).collect();
    let mut device_compute_s = vec![0.0f64; engine.system.devices];

    for (dest, chunk_list) in per_dest.iter().enumerate() {
        for c in chunk_list {
            // Gather the chunk's token rows from the origin device.
            let idx = &index[c.origin][c.expert];
            let rows: Vec<usize> = idx[c.local_start as usize..c.local_end as usize]
                .iter()
                .map(|&(t, _)| t as usize)
                .collect();
            let t0 = Instant::now();
            let x = xs[c.origin].gather_rows(&rows);
            let y = backend.ffn(&x, &layer.experts[c.expert]);
            device_compute_s[dest] += t0.elapsed().as_secs_f64();

            // Combine: gate-weight and scatter-add back to the origin.
            debug_assert_eq!(y.cols, d_model);
            for (r, &(t, slot)) in
                idx[c.local_start as usize..c.local_end as usize].iter().enumerate()
            {
                let gate = routing.gates[c.origin][t as usize * routing.top_k + slot as usize];
                let out_row = outputs[c.origin].row_mut(t as usize);
                for (o, v) in out_row.iter_mut().zip(y.row(r)) {
                    *o += gate * v;
                }
            }
        }
    }

    let report =
        super::price_plan(engine, &plan, &lm, planner, plan_time_s, Some(&device_compute_s));
    Ok(RealStep { outputs, report, plan })
}

/// Expert-weight gradients computed under a plan, with spilled segments'
/// gradients returned to and accumulated on the native device (the
/// paper's backward-pass support, §4 "Elaboration").
pub struct RealBackward {
    /// Per expert: accumulated `dL/dW` (lives on the native device).
    pub grads: Vec<ExpertWeights>,
    /// Per-device backward compute seconds (measured).
    pub device_compute_s: Vec<f64>,
    /// Bytes of gradient returned native-ward (foreign-segment grads).
    pub grad_return_bytes: u64,
}

/// Execute the backward pass for upstream gradients `dys` under `plan`.
pub fn run_backward_real(
    engine: &Engine,
    layer: &MoeLayer,
    xs: &[Mat],
    routing: &Routing,
    dys: &[Mat],
    plan: &RoutePlan,
) -> Result<RealBackward, String> {
    if dys.len() != xs.len() {
        return Err("dys/xs length mismatch".into());
    }
    let lm = routing.load_matrix();
    let index = build_local_index(routing);
    let all_chunks = chunks(plan, &lm);
    let m = engine.model.num_experts / engine.system.devices;

    let mut grads: Vec<ExpertWeights> =
        layer.experts.iter().map(|w| w.zeros_like()).collect();
    let mut device_compute_s = vec![0.0f64; engine.system.devices];
    let mut grad_return_bytes = 0u64;
    let wbytes = engine.model.expert_weight_bytes() as u64;

    for c in &all_chunks {
        let idx = &index[c.origin][c.expert];
        let slice = &idx[c.local_start as usize..c.local_end as usize];
        let rows: Vec<usize> = slice.iter().map(|&(t, _)| t as usize).collect();
        let t0 = Instant::now();
        let x = xs[c.origin].gather_rows(&rows);
        // gate-weighted upstream gradient rows
        let mut dy = dys[c.origin].gather_rows(&rows);
        for (r, &(t, slot)) in slice.iter().enumerate() {
            let gate = routing.gates[c.origin][t as usize * routing.top_k + slot as usize];
            for v in dy.row_mut(r) {
                *v *= gate;
            }
        }
        let g = ffn_backward(&x, &layer.experts[c.expert], &dy);
        device_compute_s[c.dest] += t0.elapsed().as_secs_f64();

        // Gradients of spilled segments travel back to the native device.
        if c.dest != c.expert / m {
            grad_return_bytes += wbytes;
        }
        grads[c.expert].add_assign(&g.d_weights);
    }

    Ok(RealBackward { grads, device_compute_s, grad_return_bytes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LlepConfig, ModelConfig, ModelPreset, SystemConfig, SystemPreset};
    use crate::moe::{backward_reference, forward_reference, route, MoeLayer};
    use crate::planner::PlannerKind;
    use crate::routing::Scenario;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Engine, MoeLayer, Vec<Mat>, Routing) {
        let model = ModelConfig::preset(ModelPreset::Tiny);
        let system = SystemConfig::preset(SystemPreset::CpuSim4);
        let engine = Engine::modeled(model.clone(), system);
        let mut rng = Rng::new(seed);
        let layer = MoeLayer::random(&model, &mut rng);
        let xs: Vec<Mat> =
            (0..4).map(|_| Mat::randn(24, model.d_model, 0.5, &mut rng)).collect();
        let routing = route(&layer, &xs);
        (engine, layer, xs, routing)
    }

    fn max_diff(a: &[Mat], b: &[Mat]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| {
                x.data
                    .iter()
                    .zip(&y.data)
                    .map(|(u, v)| (u - v).abs())
                    .fold(0f32, f32::max)
            })
            .fold(0f32, f32::max)
    }

    #[test]
    fn ep_real_matches_reference() {
        let (engine, layer, xs, routing) = setup(11);
        let reference = forward_reference(&layer, &xs, &routing);
        let step =
            run_step_real(&engine, &layer, &xs, &routing, &PlannerKind::StandardEp, &NativeCompute)
                .unwrap();
        assert!(max_diff(&reference, &step.outputs) < 1e-4);
    }

    #[test]
    fn llep_real_matches_reference_exactly_like_ep() {
        let (engine, layer, xs, routing) = setup(12);
        let reference = forward_reference(&layer, &xs, &routing);
        // aggressive LLEP so plenty of spilling happens
        let kind = PlannerKind::Llep(LlepConfig { alpha: 1.0, min_gemm_tokens: 2, lambda: 1.0 });
        let step =
            run_step_real(&engine, &layer, &xs, &routing, &kind, &NativeCompute).unwrap();
        assert!(!step.plan.is_pure_ep() || step.report.fallback_ep);
        assert!(max_diff(&reference, &step.outputs) < 1e-4, "LLEP must be exact");
    }

    #[test]
    fn eplb_real_matches_reference() {
        let (engine, layer, xs, routing) = setup(13);
        let reference = forward_reference(&layer, &xs, &routing);
        let step = run_step_real(
            &engine,
            &layer,
            &xs,
            &routing,
            &PlannerKind::Eplb { replicas: 4 },
            &NativeCompute,
        )
        .unwrap();
        assert!(max_diff(&reference, &step.outputs) < 1e-4);
    }

    #[test]
    fn backward_grads_match_reference() {
        let (engine, layer, xs, routing) = setup(14);
        let mut rng = Rng::new(99);
        let dys: Vec<Mat> =
            xs.iter().map(|x| Mat::randn(x.rows, x.cols, 0.5, &mut rng)).collect();
        let reference = backward_reference(&layer, &xs, &routing, &dys);

        let kind = PlannerKind::Llep(LlepConfig { alpha: 1.0, min_gemm_tokens: 2, lambda: 1.0 });
        let step =
            run_step_real(&engine, &layer, &xs, &routing, &kind, &NativeCompute).unwrap();
        let bwd = run_backward_real(&engine, &layer, &xs, &routing, &dys, &step.plan).unwrap();

        for (e, (got, want)) in bwd.grads.iter().zip(&reference).enumerate() {
            let d = got.max_abs_diff(want);
            assert!(d < 1e-3, "expert {e}: grad diff {d}");
        }
        // spilling happened => some gradient returns were needed
        if !step.plan.transfers.is_empty() {
            assert!(bwd.grad_return_bytes > 0);
        }
    }

    #[test]
    fn synthetic_routing_also_exact() {
        // Not router-generated: synthetic concentrated routing.
        let model = ModelConfig::preset(ModelPreset::Tiny);
        let system = SystemConfig::preset(SystemPreset::CpuSim4);
        let engine = Engine::modeled(model.clone(), system);
        let mut rng = Rng::new(15);
        let layer = MoeLayer::random(&model, &mut rng);
        let routing = Scenario::concentrated(0.9, 1).generate(&model, 4, 32, &mut rng);
        let xs: Vec<Mat> = (0..4)
            .map(|p| Mat::randn(routing.tokens_on(p), model.d_model, 0.5, &mut rng))
            .collect();
        let reference = forward_reference(&layer, &xs, &routing);
        for kind in [
            PlannerKind::StandardEp,
            PlannerKind::Llep(LlepConfig { alpha: 1.0, min_gemm_tokens: 4, lambda: 1.0 }),
            PlannerKind::Eplb { replicas: 3 },
        ] {
            let step =
                run_step_real(&engine, &layer, &xs, &routing, &kind, &NativeCompute).unwrap();
            assert!(
                max_diff(&reference, &step.outputs) < 1e-4,
                "{} not exact",
                kind.label()
            );
        }
    }

    #[test]
    fn shape_validation() {
        let (engine, layer, xs, routing) = setup(16);
        let bad_xs: Vec<Mat> = xs.iter().take(2).cloned().collect();
        let bad = run_step_real(
            &engine,
            &layer,
            &bad_xs,
            &routing,
            &PlannerKind::StandardEp,
            &NativeCompute,
        );
        assert!(bad.is_err());
    }
}
