//! Fault & heterogeneity injection: deterministic per-device chaos for
//! the virtual pool.
//!
//! The paper's whole premise is that EP breaks when routing violates its
//! balance assumption — but a device pool violates the *same* assumption
//! in hardware whenever it has stragglers, transient stalls, dead
//! devices, or mixed GPU generations. This module is the hardware-side
//! mirror of [`crate::routing::Scenario`]: where a `Scenario` perturbs
//! *loads*, a [`FaultPlan`] perturbs *devices*, and every existing
//! workload scenario can now be crossed with every fault plan.
//!
//! Two pieces:
//!
//! * [`PoolState`] / [`DeviceState`] — a per-step view of the pool: each
//!   device's relative speed multiplier and alive flag, plus a global
//!   link-bandwidth degradation factor. The engine carries one
//!   ([`crate::exec::Engine::with_pool`]); pricing divides device compute
//!   time by speed (`work/speed` — completion time is what LLEP's
//!   least-loaded objective naturally generalizes to) and marks steps
//!   that left work on a dead device as *stranded*.
//! * [`FaultPlan`] — a schedule of per-device events (slowdown, transient
//!   stall, permanent failure, recovery, link degradation, seeded speed
//!   jitter) parsed from a compact spec string or a TOML file.
//!   [`FaultPlan::state_at`] is a pure function of `(plan, step, base
//!   pool)`, so every run under a fault plan is bit-reproducible given
//!   `(fault spec, scenario, system, seed)`.
//!
//! ## Modeling notes
//!
//! Faults gate the *expert side* of the step: expert compute, expert
//! weight residency, and interconnect bandwidth. Routing origin rows (the
//! data-parallel attention side that emits tokens) are assumed re-hosted
//! by the serving layer and keep producing load. A weight transfer whose
//! source device is dead is re-sourced from the host checkpoint path and
//! charged at (degraded) inter-node bandwidth; a transfer *to* a dead
//! device, or compute *on* one, strands the step — the planner was not
//! pool-aware. Static EP can never adapt (its placement is the identity);
//! speed-aware LLEP re-plans around the hole, which is exactly the
//! comparison `llep chaos` and the `degraded_pool` bench quantify.

pub mod plan;
pub mod state;

pub use plan::{FaultEvent, FaultPlan};
pub use state::{DeviceState, PoolState};
