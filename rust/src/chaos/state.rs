//! Per-device health/speed view of the pool at one step.

/// One device's state: relative speed multiplier and liveness.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceState {
    /// Relative throughput multiplier: 1.0 = nominal, 0.25 = a 4x
    /// straggler. Pricing divides compute time by this.
    pub speed: f64,
    /// Dead devices are unschedulable: no expert compute, no weight
    /// residency. Their routed tokens must go elsewhere.
    pub alive: bool,
}

impl DeviceState {
    pub fn healthy() -> DeviceState {
        DeviceState { speed: 1.0, alive: true }
    }

    /// Speed usable for planning/pricing: 0.0 when dead.
    pub fn effective_speed(&self) -> f64 {
        if self.alive {
            self.speed
        } else {
            0.0
        }
    }
}

impl Default for DeviceState {
    fn default() -> DeviceState {
        DeviceState::healthy()
    }
}

/// The whole pool at one step: per-device states plus a global
/// link-bandwidth degradation factor (both bandwidth tiers are divided by
/// it — the wire got slower, not the endpoints).
#[derive(Clone, Debug, PartialEq)]
pub struct PoolState {
    pub devices: Vec<DeviceState>,
    /// >= 1.0; bandwidths are divided by this (1.0 = nominal).
    pub link_factor: f64,
    /// Per-device link divisors (>= 1.0): a message's bandwidth is
    /// divided by the worst divisor among its two endpoints, on top of
    /// the global `link_factor`. Empty = every link nominal (the
    /// fast-path representation — pricing stays bit-identical to the
    /// pre-chaos code when nothing is injected).
    pub device_link: Vec<f64>,
}

impl PoolState {
    /// All devices nominal and alive.
    pub fn healthy(devices: usize) -> PoolState {
        PoolState {
            devices: vec![DeviceState::healthy(); devices],
            link_factor: 1.0,
            device_link: Vec::new(),
        }
    }

    /// Heterogeneous but healthy pool (mixed-generation presets). An
    /// empty slice means a homogeneous pool of `devices` devices.
    pub fn from_speeds(speeds: &[f64], devices: usize) -> PoolState {
        if speeds.is_empty() {
            return PoolState::healthy(devices);
        }
        assert_eq!(speeds.len(), devices, "speed profile must cover every device");
        PoolState {
            devices: speeds.iter().map(|&s| DeviceState { speed: s, alive: true }).collect(),
            link_factor: 1.0,
            device_link: Vec::new(),
        }
    }

    /// Compound a device-scoped link degradation (the `link:dev=` fault):
    /// every transfer touching `device` is divided by `factor`.
    pub fn degrade_device_link(&mut self, device: usize, factor: f64) {
        if self.device_link.is_empty() {
            self.device_link = vec![1.0; self.len()];
        }
        if device < self.device_link.len() {
            self.device_link[device] *= factor;
        }
    }

    /// The link divisor for one device (1.0 when nominal).
    pub fn device_link_factor(&self, device: usize) -> f64 {
        self.device_link.get(device).copied().unwrap_or(1.0)
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn alive_count(&self) -> usize {
        self.devices.iter().filter(|d| d.alive).count()
    }

    /// True when anything deviates from the homogeneous-healthy
    /// assumption — the fast-path check the engine uses to keep pricing
    /// bit-identical to the pre-chaos code when nothing is injected.
    pub fn is_degraded(&self) -> bool {
        self.link_factor != 1.0
            || self.device_link.iter().any(|&f| f != 1.0)
            || self.devices.iter().any(|d| !d.alive || d.speed != 1.0)
    }

    /// Per-device effective speeds (0.0 for dead devices).
    pub fn effective_speeds(&self) -> Vec<f64> {
        self.devices.iter().map(|d| d.effective_speed()).collect()
    }

    /// Short human-readable summary for table titles and reports.
    pub fn label(&self) -> String {
        if !self.is_degraded() {
            return format!("healthy x{}", self.len());
        }
        let alive = self.alive_count();
        let min_speed = self
            .devices
            .iter()
            .filter(|d| d.alive)
            .map(|d| d.speed)
            .fold(f64::INFINITY, f64::min);
        let mut s = format!("{alive}/{} alive", self.len());
        if min_speed.is_finite() && min_speed != 1.0 {
            s.push_str(&format!(", min speed {min_speed:.2}"));
        }
        if self.link_factor != 1.0 {
            s.push_str(&format!(", link /{:.2}", self.link_factor));
        }
        let worst_dev_link = self.device_link.iter().copied().fold(1.0, f64::max);
        if worst_dev_link != 1.0 {
            s.push_str(&format!(", dev link /{worst_dev_link:.2}"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_pool_is_not_degraded() {
        let p = PoolState::healthy(8);
        assert_eq!(p.len(), 8);
        assert_eq!(p.alive_count(), 8);
        assert!(!p.is_degraded());
        assert_eq!(p.label(), "healthy x8");
        assert_eq!(p.effective_speeds(), vec![1.0; 8]);
    }

    #[test]
    fn speeds_deaths_and_links_degrade() {
        let mut p = PoolState::healthy(4);
        assert!(!p.is_degraded());
        p.devices[1].speed = 0.25;
        assert!(p.is_degraded());
        p.devices[1].speed = 1.0;
        p.devices[2].alive = false;
        assert!(p.is_degraded());
        assert_eq!(p.alive_count(), 3);
        assert_eq!(p.devices[2].effective_speed(), 0.0);
        p.devices[2].alive = true;
        p.link_factor = 2.0;
        assert!(p.is_degraded());
    }

    #[test]
    fn from_speeds_builds_heterogeneous_pool() {
        let p = PoolState::from_speeds(&[1.0, 1.0, 0.33, 0.33], 4);
        assert!(p.is_degraded());
        assert_eq!(p.alive_count(), 4);
        assert_eq!(p.effective_speeds(), vec![1.0, 1.0, 0.33, 0.33]);
        assert!(p.label().contains("min speed 0.33"), "{}", p.label());
        // empty profile = homogeneous
        assert!(!PoolState::from_speeds(&[], 4).is_degraded());
    }

    #[test]
    fn device_link_degrades_and_compounds() {
        let mut p = PoolState::healthy(4);
        assert_eq!(p.device_link_factor(2), 1.0, "nominal without allocation");
        assert!(p.device_link.is_empty());
        p.degrade_device_link(2, 2.0);
        assert!(p.is_degraded());
        assert_eq!(p.device_link_factor(2), 2.0);
        assert_eq!(p.device_link_factor(0), 1.0, "other devices untouched");
        p.degrade_device_link(2, 3.0);
        assert_eq!(p.device_link_factor(2), 6.0, "factors compound");
        assert!(p.label().contains("dev link /6.00"), "{}", p.label());
    }

    #[test]
    #[should_panic]
    fn mismatched_speed_profile_rejected() {
        PoolState::from_speeds(&[1.0, 0.5], 4);
    }
}
