//! Fault schedules: deterministic per-device event timelines.
//!
//! Grammar (events separated by `;`, keys by `,`):
//!
//! ```text
//! slow:dev=3,x=4                    4x straggler from step 0, forever
//! slow:dev=3,x=4,from=8,until=32    ... only for steps [8, 32)
//! stall:dev=1,at=5,steps=3          transient stall: dead for steps [5, 8)
//! fail:dev=2,at=10                  permanent failure from step 10
//! recover:dev=2,at=30               ... until recovery at step 30
//! link:x=2,from=0                   halve both bandwidth tiers
//! link:dev=5,x=4                    4x slower links touching device 5 only
//! jitter:amp=0.2,seed=7             seeded per-(step, device) speed noise
//! burst:dev=2-5,at=10               correlated burst: fail devices 2..=5 at 10
//! burst:dev=2-5,at=10,steps=4       ... transient (stall) variant
//! ```
//!
//! `burst:` is sugar for a correlated group failure (a rack/PSU/switch
//! domain dying at once): it desugars at parse time into one
//! `fail:`/`stall:` event per device in the range, so
//! [`FaultPlan::spec`] emits — and round-trips through — the desugared
//! form.
//!
//! A plan can also live in a TOML file:
//!
//! ```toml
//! [chaos]
//! faults = "slow:dev=0,x=4;fail:dev=3,at=16"
//! ```
//!
//! Unknown event kinds and unknown/leftover keys are hard errors — a typo
//! never silently changes the experiment. [`FaultPlan::spec`] round-trips
//! through [`FaultPlan::parse`].
//!
//! [`FaultPlan::state_at`] folds the schedule into a [`PoolState`] for
//! one step: a pure function of `(plan, step, base pool)`, so any run
//! driven by it is bit-reproducible. Jitter derives its noise from a
//! per-(step, device) SplitMix-style hash of the event's seed — no shared
//! RNG stream, hence no dependence on evaluation order.

use super::state::PoolState;
use crate::util::rng::Rng;
use crate::util::tomlmini;

/// One scheduled fault/heterogeneity event. Steps are engine-step
/// indices (each priced batch advances the sims by one step); `until` is
/// exclusive and `None` means "for the rest of the run".
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultEvent {
    /// Divide `device`'s speed by `factor` while active.
    Slow { device: usize, factor: f64, from: usize, until: Option<usize> },
    /// `device` is dead for `steps` steps starting at `at`, then returns
    /// on its own (a transient hang / preemption).
    Stall { device: usize, at: usize, steps: usize },
    /// `device` is dead from step `at` onward (until a matching
    /// [`FaultEvent::Recover`]).
    Fail { device: usize, at: usize },
    /// `device` rejoins the pool at step `at` (elastic scale-back-up).
    Recover { device: usize, at: usize },
    /// Divide link bandwidth by `factor` while active: both tiers
    /// globally when `device` is `None`, or only transfers touching
    /// `device` (a flaky NIC / downtrained PCIe lane) when given.
    Link { device: Option<usize>, factor: f64, from: usize, until: Option<usize> },
    /// Multiply every device's speed by `1 + amp * U(-1, 1)` with noise
    /// drawn deterministically per (step, device) from `seed`.
    Jitter { amp: f64, seed: u64, from: usize, until: Option<usize> },
}

impl FaultEvent {
    /// Canonical spec fragment (the inverse of event parsing).
    fn spec(&self) -> String {
        let window = |from: usize, until: Option<usize>| -> String {
            let mut s = String::new();
            if from != 0 {
                s.push_str(&format!(",from={from}"));
            }
            if let Some(u) = until {
                s.push_str(&format!(",until={u}"));
            }
            s
        };
        match *self {
            FaultEvent::Slow { device, factor, from, until } => {
                format!("slow:dev={device},x={factor}{}", window(from, until))
            }
            FaultEvent::Stall { device, at, steps } => {
                format!("stall:dev={device},at={at},steps={steps}")
            }
            FaultEvent::Fail { device, at } => format!("fail:dev={device},at={at}"),
            FaultEvent::Recover { device, at } => format!("recover:dev={device},at={at}"),
            FaultEvent::Link { device, factor, from, until } => match device {
                Some(d) => format!("link:dev={d},x={factor}{}", window(from, until)),
                None => format!("link:x={factor}{}", window(from, until)),
            },
            FaultEvent::Jitter { amp, seed, from, until } => {
                format!("jitter:amp={amp},seed={seed}{}", window(from, until))
            }
        }
    }

    /// Largest device index this event touches, if any.
    fn device(&self) -> Option<usize> {
        match *self {
            FaultEvent::Slow { device, .. }
            | FaultEvent::Stall { device, .. }
            | FaultEvent::Fail { device, .. }
            | FaultEvent::Recover { device, .. } => Some(device),
            FaultEvent::Link { device, .. } => device,
            FaultEvent::Jitter { .. } => None,
        }
    }
}

/// A deterministic fault schedule (possibly empty).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The no-faults plan.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the `;`-separated event grammar (see the module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            parse_event_into(part, &mut events)?;
        }
        if events.is_empty() {
            return Err(format!("fault spec {spec:?} contains no events"));
        }
        Ok(FaultPlan { events })
    }

    /// Parse a TOML document carrying `faults = "<spec>"` under
    /// `[chaos]`.
    pub fn from_toml(text: &str) -> Result<FaultPlan, String> {
        let doc = tomlmini::parse(text)?;
        let spec = doc
            .get("chaos", "faults")
            .ok_or("fault TOML needs `faults = \"<spec>\"` under [chaos]")?
            .as_str()
            .ok_or("[chaos] faults must be a string")?;
        FaultPlan::parse(spec)
    }

    /// Resolve a `--faults` argument: an existing file path is read as
    /// TOML, anything else is parsed as a spec string.
    pub fn resolve(arg: &str) -> Result<FaultPlan, String> {
        if std::path::Path::new(arg).exists() {
            let text = std::fs::read_to_string(arg).map_err(|e| format!("{arg}: {e}"))?;
            return FaultPlan::from_toml(&text).map_err(|e| format!("fault file {arg:?}: {e}"));
        }
        FaultPlan::parse(arg)
    }

    /// Canonical spec string; [`FaultPlan::parse`] on it reconstructs an
    /// equal plan (round-trip).
    pub fn spec(&self) -> String {
        self.events.iter().map(FaultEvent::spec).collect::<Vec<_>>().join(";")
    }

    /// Short label for report titles and tuner trial keys.
    pub fn label(&self) -> String {
        if self.is_empty() {
            "no faults".into()
        } else {
            self.spec()
        }
    }

    /// Check every event addresses a device inside a `devices`-wide pool.
    pub fn validate(&self, devices: usize) -> Result<(), String> {
        for ev in &self.events {
            if let Some(d) = ev.device() {
                if d >= devices {
                    return Err(format!(
                        "fault event {:?} addresses device {d}, pool has {devices}",
                        ev.spec()
                    ));
                }
            }
        }
        Ok(())
    }

    /// The pool view at `step`, folding every event over `base` (the
    /// system's nominal — possibly heterogeneous — pool). Pure in
    /// `(self, step, base)`.
    pub fn state_at(&self, step: usize, base: &PoolState) -> PoolState {
        let mut pool = base.clone();
        let n = pool.len();
        // Last fail/recover at or before `step` wins per device; ties on
        // the same step resolve to the later event in the list.
        let mut fate: Vec<Option<(usize, bool)>> = vec![None; n];
        let active = |from: usize, until: Option<usize>| match until {
            Some(u) => step >= from && step < u,
            None => step >= from,
        };
        // Later fail/recover events at the same (or a later) step shadow
        // earlier ones per device.
        let newer = |slot: &Option<(usize, bool)>, at: usize| match slot {
            Some((t, _)) => at >= *t,
            None => true,
        };
        for ev in &self.events {
            match *ev {
                FaultEvent::Slow { device, factor, from, until } => {
                    if device < n && active(from, until) && factor > 0.0 {
                        pool.devices[device].speed /= factor;
                    }
                }
                FaultEvent::Stall { device, at, steps } => {
                    if device < n && step >= at && step < at.saturating_add(steps) {
                        pool.devices[device].alive = false;
                    }
                }
                FaultEvent::Fail { device, at } => {
                    if device < n && at <= step && newer(&fate[device], at) {
                        fate[device] = Some((at, false));
                    }
                }
                FaultEvent::Recover { device, at } => {
                    if device < n && at <= step && newer(&fate[device], at) {
                        fate[device] = Some((at, true));
                    }
                }
                FaultEvent::Link { device, factor, from, until } => {
                    if active(from, until) && factor > 0.0 {
                        match device {
                            Some(d) if d < n => pool.degrade_device_link(d, factor),
                            Some(_) => {}
                            None => pool.link_factor *= factor,
                        }
                    }
                }
                FaultEvent::Jitter { amp, seed, from, until } => {
                    if active(from, until) {
                        for (d, dev) in pool.devices.iter_mut().enumerate() {
                            let mut rng = Rng::new(seed ^ jitter_key(step, d));
                            let noise = 1.0 + amp * (rng.f64() * 2.0 - 1.0);
                            dev.speed *= noise.max(1e-3);
                        }
                    }
                }
            }
        }
        for (d, f) in fate.iter().enumerate() {
            if let Some((_, alive)) = f {
                pool.devices[d].alive = pool.devices[d].alive && *alive;
            }
        }
        pool
    }

    /// Devices alive at `base` (or at step `step - 1`) but dead at
    /// `step` — the failures a step-`step` planner must react to and the
    /// in-flight work they abort.
    pub fn newly_dead(&self, step: usize, base: &PoolState) -> Vec<usize> {
        let cur = self.state_at(step, base);
        let prev = if step == 0 { base.clone() } else { self.state_at(step - 1, base) };
        (0..cur.len())
            .filter(|&d| prev.devices[d].alive && !cur.devices[d].alive)
            .collect()
    }
}

/// Order-free per-(step, device) stream selector for jitter noise.
fn jitter_key(step: usize, device: usize) -> u64 {
    (step as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((device as u64).wrapping_mul(0xD1B5_4A32_D192_ED03))
}

/// Parsed `key=value` list with loud leftovers (mirrors the planner
/// registry's parameter handling).
struct Params {
    kv: Vec<(String, String)>,
}

impl Params {
    fn parse(s: &str) -> Result<Params, String> {
        let mut kv = Vec::new();
        for part in s.split(',').filter(|p| !p.trim().is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("expected key=value, got {part:?}"))?;
            kv.push((k.trim().to_string(), v.trim().to_string()));
        }
        Ok(Params { kv })
    }

    fn take(&mut self, key: &str) -> Option<String> {
        self.kv.iter().position(|(k, _)| k == key).map(|i| self.kv.remove(i).1)
    }

    fn take_f64(&mut self, key: &str) -> Result<Option<f64>, String> {
        match self.take(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("{key} expects a number, got {v:?}")),
        }
    }

    fn take_usize(&mut self, key: &str) -> Result<Option<usize>, String> {
        match self.take(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| format!("{key} expects an integer, got {v:?}")),
        }
    }

    fn need_usize(&mut self, kind: &str, key: &str) -> Result<usize, String> {
        self.take_usize(key)?.ok_or_else(|| format!("{kind} requires {key}="))
    }

    fn need_f64(&mut self, kind: &str, key: &str) -> Result<f64, String> {
        self.take_f64(key)?.ok_or_else(|| format!("{kind} requires {key}="))
    }

    fn finish(&self, kind: &str) -> Result<(), String> {
        if self.kv.is_empty() {
            Ok(())
        } else {
            let keys: Vec<&str> = self.kv.iter().map(|(k, _)| k.as_str()).collect();
            Err(format!("unknown key(s) for {kind}: {}", keys.join(", ")))
        }
    }
}

/// Parse a `dev=` operand that is either a single index (`N`) or an
/// inclusive range (`LO-HI`).
fn parse_device_range(kind: &str, spec: &str) -> Result<(usize, usize), String> {
    let (lo, hi) = match spec.split_once('-') {
        Some((a, b)) => (a.trim(), b.trim()),
        None => (spec, spec),
    };
    let num = |s: &str| {
        s.parse::<usize>()
            .map_err(|_| format!("{kind}: dev expects an integer or LO-HI range, got {spec:?}"))
    };
    let (lo, hi) = (num(lo)?, num(hi)?);
    if hi < lo {
        return Err(format!("{kind}: dev range {spec:?} is inverted (hi < lo)"));
    }
    Ok((lo, hi))
}

/// Parse one `;`-part, desugaring `burst:` into its per-device events.
fn parse_event_into(part: &str, events: &mut Vec<FaultEvent>) -> Result<(), String> {
    let (kind, tail) = part.split_once(':').unwrap_or((part, ""));
    if kind == "burst" {
        let mut p = Params::parse(tail)?;
        let dev = p.take("dev").ok_or_else(|| "burst requires dev=".to_string())?;
        let (lo, hi) = parse_device_range(kind, &dev)?;
        let at = p.need_usize(kind, "at")?;
        let steps = p.take_usize("steps")?;
        p.finish(kind)?;
        for device in lo..=hi {
            events.push(match steps {
                Some(k) => FaultEvent::Stall { device, at, steps: k.max(1) },
                None => FaultEvent::Fail { device, at },
            });
        }
        return Ok(());
    }
    events.push(parse_event(part)?);
    Ok(())
}

fn parse_event(part: &str) -> Result<FaultEvent, String> {
    let (kind, tail) = part.split_once(':').unwrap_or((part, ""));
    let mut p = Params::parse(tail)?;
    let positive = |kind: &str, key: &str, v: f64| -> Result<f64, String> {
        if v > 0.0 && v.is_finite() {
            Ok(v)
        } else {
            Err(format!("{kind}: {key} must be a positive finite number, got {v}"))
        }
    };
    let ev = match kind {
        "slow" => FaultEvent::Slow {
            device: p.need_usize(kind, "dev")?,
            factor: positive(kind, "x", p.need_f64(kind, "x")?)?,
            from: p.take_usize("from")?.unwrap_or(0),
            until: p.take_usize("until")?,
        },
        "stall" => FaultEvent::Stall {
            device: p.need_usize(kind, "dev")?,
            at: p.need_usize(kind, "at")?,
            steps: p.take_usize("steps")?.unwrap_or(1).max(1),
        },
        "fail" => FaultEvent::Fail {
            device: p.need_usize(kind, "dev")?,
            at: p.take_usize("at")?.unwrap_or(0),
        },
        "recover" => FaultEvent::Recover {
            device: p.need_usize(kind, "dev")?,
            at: p.need_usize(kind, "at")?,
        },
        "link" => {
            let factor = positive(kind, "x", p.need_f64(kind, "x")?)?;
            if factor < 1.0 {
                // PoolState documents link_factor >= 1.0 and pricing
                // treats sub-1 factors as nominal; accepting them would
                // silently run a different experiment than reported.
                return Err(format!("link: x must be >= 1 (degradation factor), got {factor}"));
            }
            FaultEvent::Link {
                device: p.take_usize("dev")?,
                factor,
                from: p.take_usize("from")?.unwrap_or(0),
                until: p.take_usize("until")?,
            }
        }
        "jitter" => FaultEvent::Jitter {
            amp: positive(kind, "amp", p.need_f64(kind, "amp")?)?,
            seed: p.take_usize("seed")?.unwrap_or(0) as u64,
            from: p.take_usize("from")?.unwrap_or(0),
            until: p.take_usize("until")?,
        },
        other => {
            return Err(format!(
                "unknown fault kind {other:?} \
                 (known: slow, stall, fail, recover, link, jitter, burst)"
            ))
        }
    };
    p.finish(kind)?;
    Ok(ev)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(n: usize) -> PoolState {
        PoolState::healthy(n)
    }

    #[test]
    fn spec_round_trips() {
        let spec = "slow:dev=3,x=4,from=8,until=32;stall:dev=1,at=5,steps=3;\
                    fail:dev=2,at=10;recover:dev=2,at=30;link:x=2;link:dev=1,x=4,until=9;\
                    jitter:amp=0.2,seed=7";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.events.len(), 7);
        let canon = plan.spec();
        let plan2 = FaultPlan::parse(&canon).unwrap();
        assert_eq!(plan, plan2, "canonical spec must round-trip");
        assert_eq!(plan2.spec(), canon, "spec is a fixed point");
    }

    #[test]
    fn errors_are_loud() {
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("meteor:dev=1").unwrap_err().contains("unknown fault kind"));
        assert!(FaultPlan::parse("slow:dev=1").unwrap_err().contains("requires x="));
        assert!(FaultPlan::parse("slow:x=4").unwrap_err().contains("requires dev="));
        assert!(FaultPlan::parse("slow:dev=1,x=4,frob=2").unwrap_err().contains("unknown key"));
        assert!(FaultPlan::parse("slow:dev=1,x=0").unwrap_err().contains("positive"));
        assert!(FaultPlan::parse("slow:dev=1,x").unwrap_err().contains("key=value"));
        assert!(
            FaultPlan::parse("link:x=0.5").unwrap_err().contains("must be >= 1"),
            "sub-1 link factors would silently price as healthy links"
        );
    }

    #[test]
    fn slowdown_window_applies() {
        let plan = FaultPlan::parse("slow:dev=0,x=4,from=2,until=4").unwrap();
        assert!(!plan.state_at(0, &base(2)).is_degraded());
        assert!(!plan.state_at(1, &base(2)).is_degraded());
        assert_eq!(plan.state_at(2, &base(2)).devices[0].speed, 0.25);
        assert_eq!(plan.state_at(3, &base(2)).devices[0].speed, 0.25);
        assert!(!plan.state_at(4, &base(2)).is_degraded(), "until is exclusive");
    }

    #[test]
    fn stall_is_transient_death() {
        let plan = FaultPlan::parse("stall:dev=1,at=3,steps=2").unwrap();
        assert!(plan.state_at(2, &base(4)).devices[1].alive);
        assert!(!plan.state_at(3, &base(4)).devices[1].alive);
        assert!(!plan.state_at(4, &base(4)).devices[1].alive);
        assert!(plan.state_at(5, &base(4)).devices[1].alive, "comes back on its own");
    }

    #[test]
    fn fail_then_recover() {
        let plan = FaultPlan::parse("fail:dev=2,at=5;recover:dev=2,at=9").unwrap();
        assert!(plan.state_at(4, &base(4)).devices[2].alive);
        for s in 5..9 {
            assert!(!plan.state_at(s, &base(4)).devices[2].alive, "step {s}");
        }
        assert!(plan.state_at(9, &base(4)).devices[2].alive);
        assert_eq!(plan.newly_dead(5, &base(4)), vec![2]);
        assert!(plan.newly_dead(6, &base(4)).is_empty());
        assert!(plan.newly_dead(9, &base(4)).is_empty());
    }

    #[test]
    fn link_degradation_compounds() {
        let plan = FaultPlan::parse("link:x=2;link:x=3,from=4").unwrap();
        assert_eq!(plan.state_at(0, &base(2)).link_factor, 2.0);
        assert_eq!(plan.state_at(4, &base(2)).link_factor, 6.0);
    }

    #[test]
    fn device_link_is_scoped_and_windowed() {
        let plan = FaultPlan::parse("link:dev=1,x=4,from=2,until=5;link:x=2,from=3").unwrap();
        let before = plan.state_at(1, &base(4));
        assert!(!before.is_degraded());
        let during = plan.state_at(2, &base(4));
        assert_eq!(during.device_link_factor(1), 4.0);
        assert_eq!(during.device_link_factor(0), 1.0, "only device 1's links");
        assert_eq!(during.link_factor, 1.0, "global tier untouched");
        let both = plan.state_at(3, &base(4));
        assert_eq!(both.device_link_factor(1), 4.0);
        assert_eq!(both.link_factor, 2.0, "global and device-scoped compose");
        let after = plan.state_at(5, &base(4));
        assert_eq!(after.device_link_factor(1), 1.0, "until is exclusive");
        // Device-scoped links join the validation bound.
        assert!(plan.validate(1).is_err(), "dev=1 needs at least 2 devices");
        assert!(plan.validate(4).is_ok());
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let plan = FaultPlan::parse("jitter:amp=0.2,seed=7").unwrap();
        let a = plan.state_at(3, &base(8));
        let b = plan.state_at(3, &base(8));
        assert_eq!(a, b, "same (plan, step, base) must give the same pool");
        let other_step = plan.state_at(4, &base(8));
        assert_ne!(a, other_step, "noise varies across steps");
        for d in &a.devices {
            assert!(d.speed >= 0.8 - 1e-12 && d.speed <= 1.2 + 1e-12, "{}", d.speed);
            assert!(d.alive);
        }
    }

    #[test]
    fn events_compose_over_heterogeneous_base() {
        let het = PoolState::from_speeds(&[1.0, 0.5], 2);
        let plan = FaultPlan::parse("slow:dev=1,x=2").unwrap();
        let pool = plan.state_at(0, &het);
        assert_eq!(pool.devices[0].speed, 1.0);
        assert_eq!(pool.devices[1].speed, 0.25, "fault stacks on the base speed");
    }

    #[test]
    fn validate_bounds_device_indices() {
        let plan = FaultPlan::parse("fail:dev=9,at=0").unwrap();
        assert!(plan.validate(8).is_err());
        assert!(plan.validate(10).is_ok());
        assert!(FaultPlan::none().validate(1).is_ok());
    }

    #[test]
    fn burst_desugars_into_per_device_events() {
        // permanent flavor: one Fail per device in the range
        let plan = FaultPlan::parse("burst:dev=2-4,at=10;recover:dev=3,at=20").unwrap();
        assert_eq!(
            plan.events,
            vec![
                FaultEvent::Fail { device: 2, at: 10 },
                FaultEvent::Fail { device: 3, at: 10 },
                FaultEvent::Fail { device: 4, at: 10 },
                FaultEvent::Recover { device: 3, at: 20 },
            ]
        );
        // the canonical spec is the desugared form, and it round-trips
        let canon = plan.spec();
        assert!(canon.starts_with("fail:dev=2,at=10;"), "{canon}");
        let again = FaultPlan::parse(&canon).unwrap();
        assert_eq!(again, plan);
        assert_eq!(again.spec(), canon, "spec is a fixed point");
        // semantics: the whole group dies together, recover is per-device
        assert_eq!(plan.newly_dead(10, &base(8)), vec![2, 3, 4]);
        assert!(!plan.state_at(25, &base(8)).devices[2].alive);
        assert!(plan.state_at(25, &base(8)).devices[3].alive);

        // transient flavor: steps= turns the group into stalls
        let stall = FaultPlan::parse("burst:dev=1-2,at=5,steps=3").unwrap();
        assert_eq!(
            stall.events,
            vec![
                FaultEvent::Stall { device: 1, at: 5, steps: 3 },
                FaultEvent::Stall { device: 2, at: 5, steps: 3 },
            ]
        );
        assert!(stall.state_at(9, &base(4)).devices[1].alive, "comes back on its own");

        // a single index is a burst of one
        let one = FaultPlan::parse("burst:dev=3,at=0").unwrap();
        assert_eq!(one.events, vec![FaultEvent::Fail { device: 3, at: 0 }]);
    }

    #[test]
    fn burst_errors_are_loud() {
        assert!(FaultPlan::parse("burst:at=1").unwrap_err().contains("requires dev="));
        assert!(FaultPlan::parse("burst:dev=2-4").unwrap_err().contains("requires at="));
        assert!(FaultPlan::parse("burst:dev=4-2,at=1").unwrap_err().contains("inverted"));
        assert!(FaultPlan::parse("burst:dev=a-b,at=1").unwrap_err().contains("integer"));
        assert!(
            FaultPlan::parse("burst:dev=1-2,at=1,x=4").unwrap_err().contains("unknown key"),
            "leftover keys stay loud through the sugar"
        );
    }

    #[test]
    fn toml_and_resolve() {
        let plan =
            FaultPlan::from_toml("[chaos]\nfaults = \"slow:dev=0,x=4;fail:dev=3,at=16\"\n")
                .unwrap();
        assert_eq!(plan.events.len(), 2);
        assert!(FaultPlan::from_toml("[chaos]\n").is_err());
        let direct = FaultPlan::resolve("slow:dev=0,x=4").unwrap();
        assert_eq!(direct.events.len(), 1);
        assert!(FaultPlan::resolve("bogus").is_err());
    }

    #[test]
    fn labels() {
        assert_eq!(FaultPlan::none().label(), "no faults");
        let plan = FaultPlan::parse("fail:dev=1,at=2").unwrap();
        assert_eq!(plan.label(), "fail:dev=1,at=2");
    }
}
