//! The fleet event loop: N replicas, one router, one virtual clock.
//!
//! [`FleetSim`] owns a template [`Engine`] plus per-replica configs
//! (planner spec, speed multiplier, device-level fault plan) and runs a
//! deterministic discrete-event loop over three event kinds:
//!
//! 1. **Arrival** — the next workload request reaches the frontend; the
//!    [`Router`] picks an alive replica from the load snapshot.
//! 2. **Fleet fault** — a [`FleetFaultPlan`] event fires: a whole
//!    replica dies (its queued and in-flight requests drain back through
//!    the router to the survivors, at most one requeue per request per
//!    failure) or rejoins.
//! 3. **Replica step** — the alive replica with the earliest local clock
//!    prices one batched engine step via the shared
//!    [`Replica`](crate::coordinator::Replica) core.
//!
//! Ties break arrival → fault → lowest replica index, so the whole run
//! is a pure function of `(workload spec, replica configs, fault plan,
//! seed)` — bit-reproducible, property-tested in `rust/tests/fleet.rs`.
//! Every replica keeps its own exact [`TokenLedger`]; the fleet report
//! carries their sum, which must stay exact even across whole-replica
//! failures (a drained request's prefill is re-priced by the replica
//! that re-admits it, and each replica prices exactly what it admits).

use super::router::{ReplicaLoad, Router, RouterPolicy};
use super::workload::{Params, Workload};
use crate::chaos::{FaultPlan, PoolState};
use crate::coordinator::{
    uniform_profile, ChaosStats, Replica, ReplicaRequest, ReplicaStepOutcome, TokenLedger,
};
use crate::exec::{Engine, PlanCostModel};
use crate::placement::PlacementStats;
use crate::planner::{CacheStats, Planner, Registry};
use crate::routing::Scenario;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// One whole-replica chaos event on the fleet timeline (virtual
/// seconds, unlike device-level [`FaultPlan`]s, which are per-step).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FleetEvent {
    /// The replica dies: it stops stepping and its queue re-routes.
    Fail { replica: usize, at_s: f64 },
    /// The replica rejoins the routable set (empty-queued).
    Recover { replica: usize, at_s: f64 },
}

impl FleetEvent {
    pub fn at_s(&self) -> f64 {
        match self {
            FleetEvent::Fail { at_s, .. } | FleetEvent::Recover { at_s, .. } => *at_s,
        }
    }

    pub fn replica(&self) -> usize {
        match self {
            FleetEvent::Fail { replica, .. } | FleetEvent::Recover { replica, .. } => *replica,
        }
    }
}

/// Whole-replica fault schedule. Grammar: `;`-separated events,
/// `fail:r=1,at=0.02` / `recover:r=1,at=0.05` (`at` in virtual
/// seconds). [`spec`](Self::spec) round-trips through
/// [`parse`](Self::parse).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FleetFaultPlan {
    pub events: Vec<FleetEvent>,
}

impl FleetFaultPlan {
    pub fn parse(spec: &str) -> Result<FleetFaultPlan, String> {
        let mut events = Vec::new();
        for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind, tail) = part.split_once(':').unwrap_or((part, ""));
            let mut p = Params::parse(tail)?;
            let replica = p
                .take_usize("r")?
                .ok_or_else(|| format!("{kind}: missing r=<replica index>"))?;
            let at_s =
                p.take_f64("at")?.ok_or_else(|| format!("{kind}: missing at=<seconds>"))?;
            if !(at_s.is_finite() && at_s >= 0.0) {
                return Err(format!("{kind}: at must be a non-negative time, got {at_s}"));
            }
            p.finish(kind)?;
            events.push(match kind {
                "fail" => FleetEvent::Fail { replica, at_s },
                "recover" => FleetEvent::Recover { replica, at_s },
                other => {
                    return Err(format!(
                        "unknown fleet event {other:?} (expected fail, recover)"
                    ))
                }
            });
        }
        Ok(FleetFaultPlan { events })
    }

    /// Canonical spec string ([`parse`](Self::parse) round-trips it).
    pub fn spec(&self) -> String {
        self.events
            .iter()
            .map(|e| match e {
                FleetEvent::Fail { replica, at_s } => format!("fail:r={replica},at={at_s}"),
                FleetEvent::Recover { replica, at_s } => {
                    format!("recover:r={replica},at={at_s}")
                }
            })
            .collect::<Vec<_>>()
            .join(";")
    }

    /// Every event must reference a replica the fleet actually has.
    pub fn validate(&self, replicas: usize) -> Result<(), String> {
        for e in &self.events {
            if e.replica() >= replicas {
                return Err(format!(
                    "fleet fault references replica {} but the fleet has {replicas}",
                    e.replica()
                ));
            }
        }
        Ok(())
    }
}

/// Per-replica configuration: planner policy, a uniform speed multiplier
/// applied on top of the template engine's pool (0.5 = a half-speed
/// replica — older hardware or a noisy neighbour), and an optional
/// device-level fault plan local to this replica.
#[derive(Clone, Debug)]
pub struct ReplicaConfig {
    pub planner_spec: String,
    pub speed: f64,
    pub faults: Option<FaultPlan>,
}

impl Default for ReplicaConfig {
    fn default() -> ReplicaConfig {
        ReplicaConfig { planner_spec: "llep".to_string(), speed: 1.0, faults: None }
    }
}

impl ReplicaConfig {
    pub fn with_planner(mut self, spec: &str) -> ReplicaConfig {
        self.planner_spec = spec.to_string();
        self
    }

    pub fn with_speed(mut self, speed: f64) -> ReplicaConfig {
        self.speed = speed;
        self
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> ReplicaConfig {
        self.faults = Some(faults);
        self
    }
}

/// Per-replica slice of a [`FleetReport`].
#[derive(Clone, Debug)]
pub struct FleetReplicaReport {
    pub planner: String,
    pub speed: f64,
    /// Routing decisions that landed here (arrivals + requeues).
    pub routed: usize,
    /// Requests that finished here.
    pub completed: usize,
    /// Engine steps priced here.
    pub steps: usize,
    /// busy time / fleet makespan (0 when the fleet never ran).
    pub utilization: f64,
    /// This replica's exact admitted-vs-priced ledger.
    pub tokens: TokenLedger,
    /// Device-level chaos accounting local to this replica.
    pub chaos: ChaosStats,
    pub peak_bytes: u64,
    pub oom_steps: usize,
    pub fallback_steps: usize,
    pub plan_cache: CacheStats,
    /// Persistent-placement activity local to this replica (all zero
    /// for stateless planners).
    pub placement: PlacementStats,
}

/// Result of one fleet run.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub router: String,
    pub workload: String,
    /// Requests in the workload stream.
    pub requests: usize,
    /// Requests that finished (== `requests` on success).
    pub completed: usize,
    pub makespan_s: f64,
    /// Time to first token per request (first prefill only — a requeued
    /// request's re-prefill does not produce a second sample).
    pub ttft: Summary,
    /// Per-decode-token latency, weighted by active decodes per step
    /// (same accounting as [`ContinuousReport`](crate::coordinator::ContinuousReport)).
    pub tpot: Summary,
    /// Completion − arrival per request.
    pub request_latency: Summary,
    /// SLO deadline applied to request latency (None = everything is
    /// on time).
    pub deadline_s: Option<f64>,
    /// Requests completed within the deadline.
    pub on_time: usize,
    /// Nominal (prompt + decode) tokens of on-time requests / makespan.
    pub goodput_tps: f64,
    /// All admitted tokens / makespan. Exceeds the nominal rate when
    /// requeues re-price prefills — admitted work, not useful work.
    pub throughput_tps: f64,
    /// Sum of every replica's ledger — exact by contract even across
    /// whole-replica failures.
    pub tokens: TokenLedger,
    /// Sum of device-level chaos accounting across replicas.
    pub chaos: ChaosStats,
    /// Whole-replica failures / recoveries that fired.
    pub replica_failures: usize,
    pub replica_recoveries: usize,
    /// Requests requeued at least once by a whole-replica failure, and
    /// the worst per-request requeue count (the bounded-recovery
    /// contract: one per failure event that held the request).
    pub requeued_requests: usize,
    pub max_requeues: usize,
    pub replicas: Vec<FleetReplicaReport>,
}

/// Multi-replica cluster simulator (see the module docs for the event
/// loop). Build with [`FleetSim::new`], shape with the `with_*`
/// builders, run with [`try_run`](FleetSim::try_run).
pub struct FleetSim {
    pub engine: Engine,
    pub scenario: Scenario,
    pub replicas: Vec<ReplicaConfig>,
    pub router: RouterPolicy,
    pub workload: Workload,
    /// Max prefill tokens admitted per replica step.
    pub max_prefill_tokens: usize,
    pub faults: Option<FleetFaultPlan>,
    pub deadline_s: Option<f64>,
}

impl FleetSim {
    pub fn new(
        engine: Engine,
        scenario: Scenario,
        replicas: Vec<ReplicaConfig>,
        max_prefill_tokens: usize,
    ) -> FleetSim {
        FleetSim {
            engine,
            scenario,
            replicas,
            router: RouterPolicy::LeastQueue,
            workload: Workload::default_poisson(),
            max_prefill_tokens,
            faults: None,
            deadline_s: None,
        }
    }

    pub fn with_router(mut self, router: RouterPolicy) -> FleetSim {
        self.router = router;
        self
    }

    pub fn with_workload(mut self, workload: Workload) -> FleetSim {
        self.workload = workload;
        self
    }

    pub fn with_faults(mut self, faults: FleetFaultPlan) -> FleetSim {
        self.faults = Some(faults);
        self
    }

    pub fn with_deadline(mut self, deadline_s: f64) -> FleetSim {
        self.deadline_s = Some(deadline_s);
        self
    }

    /// Run the fleet to completion. Errors surface configuration
    /// mistakes (bad planner spec, fault plan out of range) and
    /// unrecoverable chaos (no alive replica to route to, a replica's
    /// own pool dying entirely).
    pub fn try_run(&self, seed: u64) -> Result<FleetReport, String> {
        let n = self.replicas.len();
        if n == 0 {
            return Err("fleet: need at least one replica".to_string());
        }
        for (i, cfg) in self.replicas.iter().enumerate() {
            if !(cfg.speed > 0.0 && cfg.speed.is_finite()) {
                return Err(format!(
                    "fleet: replica {i} speed must be positive and finite, got {}",
                    cfg.speed
                ));
            }
        }
        if let Some(plan) = &self.faults {
            plan.validate(n)?;
        }

        // A deterministic plan-cost model keeps every replica's pricing a
        // pure function of its inputs (the bit-reproducibility contract).
        let template = if self.engine.plan_cost.is_some() {
            self.engine.clone()
        } else {
            self.engine.clone().with_plan_cost(PlanCostModel::default())
        };
        let mut engines: Vec<Engine> = self
            .replicas
            .iter()
            .map(|cfg| {
                if cfg.speed == 1.0 {
                    template.clone()
                } else {
                    let speeds: Vec<f64> =
                        template.pool.devices.iter().map(|d| d.speed * cfg.speed).collect();
                    let devices = speeds.len();
                    template.for_pool(PoolState::from_speeds(&speeds, devices))
                }
            })
            .collect();
        let registry = Registry::builtin();
        let planners: Vec<Box<dyn Planner>> = self
            .replicas
            .iter()
            .map(|cfg| registry.parse(&cfg.planner_spec))
            .collect::<Result<_, _>>()?;
        // Trace layout: the frontend (workload + router) records under
        // the template tracer's pid; replica i becomes process i+1, so a
        // fleet trace shows every replica as its own track group with
        // router decisions flowing from the frontend into them.
        let tracer = template.tracer.clone();
        if tracer.is_enabled() {
            tracer.name_process("frontend / router");
            tracer.name_thread(crate::trace::COORD_TID, "workload");
            for (i, engine) in engines.iter_mut().enumerate() {
                let t = tracer.with_pid(i as u32 + 1);
                crate::trace::name_engine_tracks(
                    &t,
                    &format!(
                        "replica {i} ({}, {:.2}x)",
                        planners[i].label(),
                        self.replicas[i].speed
                    ),
                    engine.system.devices,
                );
                engine.tracer = t;
            }
        }
        let profile = uniform_profile(&template, self.scenario.clone());
        let mut reps: Vec<Replica> = Vec::with_capacity(n);
        for i in 0..n {
            reps.push(Replica::new(
                &engines[i],
                &*planners[i],
                &profile,
                self.max_prefill_tokens,
                self.replicas[i].faults.as_ref(),
            )?);
        }

        let requests = self.workload.generate(&mut Rng::new(seed));
        let total = requests.len();
        // Decorrelated per-replica pricing streams, all derived from the
        // one fleet seed.
        let mut rngs: Vec<Rng> = (0..n)
            .map(|i| Rng::new(seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)))
            .collect();
        let mut fleet_events: Vec<FleetEvent> =
            self.faults.as_ref().map(|p| p.events.clone()).unwrap_or_default();
        fleet_events.sort_by(|a, b| a.at_s().total_cmp(&b.at_s()));

        let mut router = Router::new(self.router);
        let mut alive = vec![true; n];
        let mut routed = vec![0usize; n];
        let mut completed_r = vec![0usize; n];
        let mut requeues = vec![0usize; total];
        let mut ttft_done = vec![false; total];
        let mut finished = vec![false; total];
        let mut ttft = Vec::with_capacity(total);
        let mut tpot = Vec::new();
        let mut latencies = Vec::with_capacity(total);
        let mut completed = 0usize;
        let mut on_time = 0usize;
        let mut on_time_tokens = 0u64;
        let mut makespan = 0.0f64;
        let mut replica_failures = 0usize;
        let mut replica_recoveries = 0usize;
        let mut next_req = 0usize;
        let mut next_ev = 0usize;

        // Event kinds at equal times: arrival (0) before fleet fault (1)
        // before replica step (2); steps tie-break to the lowest index.
        fn earlier(a: (f64, u8, usize), b: (f64, u8, usize)) -> bool {
            a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)).is_lt()
        }
        fn beats(best: Option<(f64, u8, usize)>, c: (f64, u8, usize)) -> bool {
            match best {
                None => true,
                Some(b) => earlier(c, b),
            }
        }

        while completed < total {
            let mut best: Option<(f64, u8, usize)> = None;
            if next_req < total {
                best = Some((requests[next_req].arrival_s, 0, 0));
            }
            if next_ev < fleet_events.len() {
                let c = (fleet_events[next_ev].at_s(), 1, 0);
                if beats(best, c) {
                    best = Some(c);
                }
            }
            for (i, rep) in reps.iter().enumerate() {
                if alive[i] && rep.has_work() {
                    let c = (rep.now(), 2, i);
                    if beats(best, c) {
                        best = Some(c);
                    }
                }
            }
            let Some((_, kind, idx)) = best else {
                return Err(format!(
                    "fleet: stuck with {completed}/{total} requests complete and no \
                     runnable event (dead replicas holding no work?)"
                ));
            };
            match kind {
                0 => {
                    // arrival: route via the load snapshot
                    let req = &requests[next_req];
                    let loads: Vec<ReplicaLoad> = reps
                        .iter()
                        .enumerate()
                        .map(|(i, r)| ReplicaLoad {
                            alive: alive[i],
                            queue_depth: r.queue_depth(),
                            pressure: r.pressure(),
                        })
                        .collect();
                    let Some(t) = router.pick(&loads) else {
                        return Err(format!(
                            "fleet: no alive replica to route request {} at t={:.6}",
                            req.id, req.arrival_s
                        ));
                    };
                    if !reps[t].has_work() {
                        reps[t].advance_to(req.arrival_s);
                    }
                    if tracer.is_enabled() {
                        use crate::trace::{ArgValue, FlowPoint, COORD_TID};
                        tracer.instant(
                            COORD_TID,
                            "arrival",
                            "router",
                            req.arrival_s,
                            &[
                                ("id", ArgValue::Num(req.id as f64)),
                                ("prompt_tokens", ArgValue::Num(req.prompt_tokens as f64)),
                            ],
                        );
                        tracer.flow(
                            "route",
                            "router",
                            FlowPoint {
                                pid: tracer.pid(),
                                tid: COORD_TID,
                                ts_s: req.arrival_s,
                            },
                            FlowPoint {
                                pid: t as u32 + 1,
                                tid: COORD_TID,
                                ts_s: req.arrival_s,
                            },
                            &[
                                ("id", ArgValue::Num(req.id as f64)),
                                ("replica", ArgValue::Num(t as f64)),
                            ],
                        );
                        tracer.count("router/arrivals", 1);
                    }
                    reps[t].submit(ReplicaRequest {
                        id: req.id,
                        arrival_s: req.arrival_s,
                        prompt_tokens: req.prompt_tokens,
                        decode_steps: req.decode_steps,
                    });
                    routed[t] += 1;
                    next_req += 1;
                }
                1 => {
                    match fleet_events[next_ev] {
                        FleetEvent::Fail { replica: r, at_s } => {
                            if alive[r] {
                                alive[r] = false;
                                replica_failures += 1;
                                if tracer.is_enabled() {
                                    use crate::trace::ArgValue;
                                    tracer.with_pid(r as u32 + 1).instant_process(
                                        "replica-fail",
                                        "fleet",
                                        at_s,
                                        &[("replica", ArgValue::Num(r as f64))],
                                    );
                                    tracer.count("fleet/replica_failures", 1);
                                }
                                // drain the dead replica's queue back
                                // through the router to the survivors
                                for req in reps[r].drain() {
                                    requeues[req.id] += 1;
                                    let loads: Vec<ReplicaLoad> = reps
                                        .iter()
                                        .enumerate()
                                        .map(|(i, rp)| ReplicaLoad {
                                            alive: alive[i],
                                            queue_depth: rp.queue_depth(),
                                            pressure: rp.pressure(),
                                        })
                                        .collect();
                                    let Some(t) = router.pick(&loads) else {
                                        return Err(format!(
                                            "fleet: replica {r} died at t={at_s:.6} with no \
                                             survivor to requeue request {} onto",
                                            req.id
                                        ));
                                    };
                                    if !reps[t].has_work() {
                                        reps[t].advance_to(at_s);
                                    }
                                    if tracer.is_enabled() {
                                        use crate::trace::{ArgValue, FlowPoint, COORD_TID};
                                        tracer.flow(
                                            "requeue",
                                            "fleet",
                                            FlowPoint {
                                                pid: r as u32 + 1,
                                                tid: COORD_TID,
                                                ts_s: at_s,
                                            },
                                            FlowPoint {
                                                pid: t as u32 + 1,
                                                tid: COORD_TID,
                                                ts_s: at_s,
                                            },
                                            &[("id", ArgValue::Num(req.id as f64))],
                                        );
                                        tracer.count("fleet/requeues", 1);
                                    }
                                    reps[t].submit(req);
                                    routed[t] += 1;
                                }
                            }
                        }
                        FleetEvent::Recover { replica: r, at_s } => {
                            if !alive[r] {
                                alive[r] = true;
                                replica_recoveries += 1;
                                if tracer.is_enabled() {
                                    use crate::trace::ArgValue;
                                    tracer.with_pid(r as u32 + 1).instant_process(
                                        "replica-recover",
                                        "fleet",
                                        at_s,
                                        &[("replica", ArgValue::Num(r as f64))],
                                    );
                                    tracer.count("fleet/replica_recoveries", 1);
                                }
                                reps[r].advance_to(at_s);
                            }
                        }
                    }
                    next_ev += 1;
                }
                _ => {
                    // step the earliest alive replica with work
                    let i = idx;
                    if let ReplicaStepOutcome::Stepped(ev) = reps[i].step(&mut rngs[i])? {
                        let now = reps[i].now();
                        for &(id, arrival_s) in &ev.prefilled {
                            if !ttft_done[id] {
                                ttft_done[id] = true;
                                ttft.push(now - arrival_s);
                            }
                        }
                        for _ in 0..ev.decode_tokens {
                            tpot.push(ev.latency_s);
                        }
                        for &(id, arrival_s) in &ev.finished {
                            if finished[id] {
                                continue;
                            }
                            finished[id] = true;
                            let latency = now - arrival_s;
                            latencies.push(latency);
                            completed += 1;
                            completed_r[i] += 1;
                            makespan = makespan.max(now);
                            let within_slo = match self.deadline_s {
                                None => true,
                                Some(d) => latency <= d,
                            };
                            if within_slo {
                                on_time += 1;
                                on_time_tokens += (requests[id].prompt_tokens
                                    + requests[id].decode_steps)
                                    as u64;
                            }
                        }
                    }
                }
            }
        }

        let mut tokens = TokenLedger::default();
        let mut chaos = ChaosStats::default();
        let mut per_replica = Vec::with_capacity(n);
        for (i, rep) in reps.iter().enumerate() {
            let ledger = rep.ledger();
            tokens.absorb(&ledger);
            chaos.absorb(&rep.chaos_stats());
            per_replica.push(FleetReplicaReport {
                planner: planners[i].label(),
                speed: self.replicas[i].speed,
                routed: routed[i],
                completed: completed_r[i],
                steps: rep.steps(),
                utilization: if makespan > 0.0 { rep.busy_s() / makespan } else { 0.0 },
                tokens: ledger,
                chaos: rep.chaos_stats(),
                peak_bytes: rep.peak_bytes(),
                oom_steps: rep.oom_steps(),
                fallback_steps: rep.fallback_steps(),
                plan_cache: rep.plan_cache(),
                placement: rep.placement(),
            });
        }
        Ok(FleetReport {
            router: router.policy.name().to_string(),
            workload: self.workload.spec(),
            requests: total,
            completed,
            makespan_s: makespan,
            ttft: Summary::of(&ttft),
            tpot: Summary::of(&tpot),
            request_latency: Summary::of(&latencies),
            deadline_s: self.deadline_s,
            on_time,
            goodput_tps: if makespan > 0.0 { on_time_tokens as f64 / makespan } else { 0.0 },
            throughput_tps: if makespan > 0.0 {
                tokens.admitted as f64 / makespan
            } else {
                0.0
            },
            tokens,
            chaos,
            replica_failures,
            replica_recoveries,
            requeued_requests: requeues.iter().filter(|&&c| c > 0).count(),
            max_requeues: requeues.iter().copied().max().unwrap_or(0),
            replicas: per_replica,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};

    fn engine() -> Engine {
        Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        )
    }

    fn small_fleet(n: usize) -> FleetSim {
        FleetSim::new(
            engine(),
            Scenario::concentrated(0.8, 4),
            vec![ReplicaConfig::default(); n],
            16_384,
        )
        .with_workload(Workload::parse("poisson:n=24,ia=0.0005,prompt=128-1024,decode=4-16").unwrap())
    }

    #[test]
    fn fleet_fault_plan_round_trips() {
        let plan = FleetFaultPlan::parse("fail:r=1,at=0.02;recover:r=1,at=0.05").unwrap();
        assert_eq!(plan.events.len(), 2);
        assert_eq!(FleetFaultPlan::parse(&plan.spec()).unwrap(), plan);
        assert!(plan.validate(2).is_ok());
        assert!(plan.validate(1).is_err(), "replica 1 out of range");
        assert!(FleetFaultPlan::parse("fail:at=1").is_err(), "missing r");
        assert!(FleetFaultPlan::parse("explode:r=0,at=1").is_err());
    }

    #[test]
    fn fleet_completes_every_request() {
        let r = small_fleet(2).try_run(42).unwrap();
        assert_eq!(r.completed, r.requests);
        assert_eq!(r.requests, 24);
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
        assert!(r.makespan_s > 0.0);
        assert!(r.goodput_tps > 0.0);
        assert_eq!(r.on_time, r.requests, "no deadline: everything on time");
        assert_eq!(r.replicas.len(), 2);
        assert_eq!(r.replicas.iter().map(|p| p.completed).sum::<usize>(), r.completed);
        assert_eq!(r.replicas.iter().map(|p| p.routed).sum::<usize>(), r.requests);
        for p in &r.replicas {
            assert!(p.tokens.is_exact(), "per-replica ledger: {:?}", p.tokens);
            assert!(p.utilization >= 0.0 && p.utilization <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn fleet_is_deterministic() {
        let sim = small_fleet(3).with_router(RouterPolicy::Pressure);
        let a = sim.try_run(7).unwrap();
        let b = sim.try_run(7).unwrap();
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.ttft.mean.to_bits(), b.ttft.mean.to_bits());
        assert_eq!(a.tokens, b.tokens);
        for (x, y) in a.replicas.iter().zip(&b.replicas) {
            assert_eq!(x.steps, y.steps);
            assert_eq!(x.routed, y.routed);
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn whole_replica_failure_requeues_and_recovers() {
        // Kill replica 1 early: everything it held must finish elsewhere
        // with at most one requeue and an exact summed ledger.
        let sim = small_fleet(2)
            .with_faults(FleetFaultPlan::parse("fail:r=1,at=0.001").unwrap());
        let r = sim.try_run(11).unwrap();
        assert_eq!(r.completed, r.requests);
        assert_eq!(r.replica_failures, 1);
        assert!(r.max_requeues <= 1, "single failure: one requeue max");
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
        assert!(r.goodput_tps > 0.0);
        assert_eq!(r.replicas[1].completed + r.replicas[0].completed, r.requests);
    }

    #[test]
    fn dead_fleet_errors_instead_of_hanging() {
        let sim = small_fleet(1).with_faults(FleetFaultPlan::parse("fail:r=0,at=0.0").unwrap());
        let err = sim.try_run(3).unwrap_err();
        assert!(err.contains("no alive replica"), "{err}");
    }

    #[test]
    fn recover_rejoins_the_routable_set() {
        let sim = small_fleet(2)
            .with_faults(FleetFaultPlan::parse("fail:r=1,at=0.0005;recover:r=1,at=0.002").unwrap());
        let r = sim.try_run(9).unwrap();
        assert_eq!(r.completed, r.requests);
        assert_eq!(r.replica_failures, 1);
        assert_eq!(r.replica_recoveries, 1);
        assert!(r.replicas[1].routed > 0, "recovered replica serves again");
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
    }

    #[test]
    fn bad_configs_are_loud() {
        assert!(small_fleet(0).try_run(1).is_err(), "empty fleet");
        let mut sim = small_fleet(2);
        sim.replicas[0].planner_spec = "warp-drive".to_string();
        assert!(sim.try_run(1).is_err(), "unknown planner spec");
        let mut sim = small_fleet(2);
        sim.replicas[1].speed = 0.0;
        assert!(sim.try_run(1).is_err(), "zero speed");
        let sim =
            small_fleet(2).with_faults(FleetFaultPlan::parse("fail:r=7,at=0.1").unwrap());
        assert!(sim.try_run(1).is_err(), "fault plan out of range");
    }

    #[test]
    fn deadline_splits_goodput_from_throughput() {
        // An absurdly tight deadline: nothing is on time, goodput is 0,
        // raw throughput is not.
        let r = small_fleet(2).with_deadline(1e-12).try_run(5).unwrap();
        assert_eq!(r.on_time, 0);
        assert_eq!(r.goodput_tps, 0.0);
        assert!(r.throughput_tps > 0.0);
        // And a generous one: everything is on time.
        let r = small_fleet(2).with_deadline(1e9).try_run(5).unwrap();
        assert_eq!(r.on_time, r.requests);
    }
}
