//! The fleet event loop: N replicas, one router, one virtual clock.
//!
//! [`FleetSim`] owns a template [`Engine`] plus per-replica configs
//! (planner spec, speed multiplier, device-level fault plan) and runs a
//! deterministic discrete-event loop over three event kinds:
//!
//! 1. **Arrival** — the next workload request reaches the frontend; the
//!    [`Router`] picks an alive replica from the load snapshot.
//! 2. **Fleet fault** — a [`FleetFaultPlan`] event fires: a whole
//!    replica dies (its queued and in-flight requests drain back through
//!    the router to the survivors, at most one requeue per request per
//!    failure) or rejoins.
//! 3. **Retry** — a backoff timer set by the overload-protection layer
//!    expires and a previously failed request re-enters routing.
//! 4. **Replica step** — the alive replica with the earliest local clock
//!    prices one batched engine step via the shared
//!    [`Replica`](crate::coordinator::Replica) core.
//! 5. **Breaker wake** — an open circuit breaker's cooldown elapses
//!    while the frontend queue holds work (so a fleet blocked only on
//!    open breakers cannot stall).
//!
//! Ties break arrival → fault → retry → lowest replica index → wake, so
//! the whole run is a pure function of `(workload spec, replica
//! configs, fault plan, overload config, seed)` — bit-reproducible,
//! property-tested in `rust/tests/fleet.rs`. Every replica keeps its
//! own exact [`TokenLedger`]; the fleet report carries their sum, which
//! must stay exact even across whole-replica failures (a drained
//! request's prefill is re-priced by the replica that re-admits it, and
//! each replica prices exactly what it admits).
//!
//! With [`OverloadConfig`] installed (see `fleet/admission.rs`) the
//! loop additionally sheds: admission control rejects requests no
//! eligible replica can serve within the deadline, queue caps spill
//! saturated replicas into a bounded frontend queue, and drained
//! requests retry with capped-exponential backoff at most `retries`
//! times. Shed requests leave the run's request ledger as the exact
//! identity `completed + shed == requests`.

use std::collections::VecDeque;

use super::admission::{Breaker, OverloadConfig, OverloadStats, ShedCause};
use super::router::{ReplicaLoad, Router, RouterPolicy};
use super::workload::{Params, Workload};
use crate::chaos::{FaultPlan, PoolState};
use crate::coordinator::{
    uniform_profile, ChaosStats, Replica, ReplicaRequest, ReplicaStepOutcome, TokenLedger,
};
use crate::exec::{Engine, PlanCostModel};
use crate::placement::PlacementStats;
use crate::planner::{CacheStats, Planner, Registry};
use crate::routing::Scenario;
use crate::trace::Tracer;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// One whole-replica chaos event on the fleet timeline (virtual
/// seconds, unlike device-level [`FaultPlan`]s, which are per-step).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FleetEvent {
    /// The replica dies: it stops stepping and its queue re-routes.
    Fail { replica: usize, at_s: f64 },
    /// The replica rejoins the routable set (empty-queued).
    Recover { replica: usize, at_s: f64 },
}

impl FleetEvent {
    pub fn at_s(&self) -> f64 {
        match self {
            FleetEvent::Fail { at_s, .. } | FleetEvent::Recover { at_s, .. } => *at_s,
        }
    }

    pub fn replica(&self) -> usize {
        match self {
            FleetEvent::Fail { replica, .. } | FleetEvent::Recover { replica, .. } => *replica,
        }
    }
}

/// Whole-replica fault schedule. Grammar: `;`-separated events,
/// `fail:r=1,at=0.02` / `recover:r=1,at=0.05` (`at` in virtual
/// seconds), plus the correlated-failure macro
/// `burst:r=1-3,at=0.02[,for=0.05]` — a contiguous replica group (one
/// rack, one power domain) dies at the same instant, optionally
/// recovering together `for` seconds later. `burst` desugars into
/// per-replica fail/recover events, so [`spec`](Self::spec) emits the
/// canonical desugared form and round-trips through
/// [`parse`](Self::parse).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FleetFaultPlan {
    pub events: Vec<FleetEvent>,
}

/// `N` or `LO-HI` (inclusive), for `burst:r=...` replica groups.
fn parse_replica_range(kind: &str, v: &str) -> Result<(usize, usize), String> {
    let (lo, hi) = match v.split_once('-') {
        None => (v, v),
        Some(pair) => pair,
    };
    let lo: usize = lo
        .trim()
        .parse()
        .map_err(|_| format!("{kind}: bad replica range bound {lo:?} in r={v}"))?;
    let hi: usize = hi
        .trim()
        .parse()
        .map_err(|_| format!("{kind}: bad replica range bound {hi:?} in r={v}"))?;
    if hi < lo {
        return Err(format!("{kind}: replica range must be lo-hi, got {v}"));
    }
    Ok((lo, hi))
}

impl FleetFaultPlan {
    pub fn parse(spec: &str) -> Result<FleetFaultPlan, String> {
        let mut events = Vec::new();
        for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind, tail) = part.split_once(':').unwrap_or((part, ""));
            let mut p = Params::parse(tail)?;
            let r_spec = p
                .take("r")
                .ok_or_else(|| format!("{kind}: missing r=<replica index>"))?;
            let at_s =
                p.take_f64("at")?.ok_or_else(|| format!("{kind}: missing at=<seconds>"))?;
            if !(at_s.is_finite() && at_s >= 0.0) {
                return Err(format!("{kind}: at must be a non-negative time, got {at_s}"));
            }
            match kind {
                "fail" | "recover" => {
                    let replica: usize = r_spec
                        .parse()
                        .map_err(|_| format!("{kind}: r expects an integer, got {r_spec:?}"))?;
                    p.finish(kind)?;
                    events.push(if kind == "fail" {
                        FleetEvent::Fail { replica, at_s }
                    } else {
                        FleetEvent::Recover { replica, at_s }
                    });
                }
                "burst" => {
                    let (lo, hi) = parse_replica_range(kind, &r_spec)?;
                    let for_s = p.take_f64("for")?;
                    if let Some(d) = for_s {
                        if !(d.is_finite() && d > 0.0) {
                            return Err(format!(
                                "burst: for must be a positive duration, got {d}"
                            ));
                        }
                    }
                    p.finish(kind)?;
                    // desugar: the whole group fails at the same instant
                    // (and recovers together when `for` is given)
                    for replica in lo..=hi {
                        events.push(FleetEvent::Fail { replica, at_s });
                    }
                    if let Some(d) = for_s {
                        for replica in lo..=hi {
                            events.push(FleetEvent::Recover { replica, at_s: at_s + d });
                        }
                    }
                }
                other => {
                    return Err(format!(
                        "unknown fleet event {other:?} (expected fail, recover, burst)"
                    ))
                }
            }
        }
        Ok(FleetFaultPlan { events })
    }

    /// Canonical spec string ([`parse`](Self::parse) round-trips it).
    pub fn spec(&self) -> String {
        self.events
            .iter()
            .map(|e| match e {
                FleetEvent::Fail { replica, at_s } => format!("fail:r={replica},at={at_s}"),
                FleetEvent::Recover { replica, at_s } => {
                    format!("recover:r={replica},at={at_s}")
                }
            })
            .collect::<Vec<_>>()
            .join(";")
    }

    /// Every event must reference a replica the fleet actually has.
    pub fn validate(&self, replicas: usize) -> Result<(), String> {
        for e in &self.events {
            if e.replica() >= replicas {
                return Err(format!(
                    "fleet fault references replica {} but the fleet has {replicas}",
                    e.replica()
                ));
            }
        }
        Ok(())
    }
}

/// Per-replica configuration: planner policy, a uniform speed multiplier
/// applied on top of the template engine's pool (0.5 = a half-speed
/// replica — older hardware or a noisy neighbour), and an optional
/// device-level fault plan local to this replica.
#[derive(Clone, Debug)]
pub struct ReplicaConfig {
    pub planner_spec: String,
    pub speed: f64,
    pub faults: Option<FaultPlan>,
}

impl Default for ReplicaConfig {
    fn default() -> ReplicaConfig {
        ReplicaConfig { planner_spec: "llep".to_string(), speed: 1.0, faults: None }
    }
}

impl ReplicaConfig {
    pub fn with_planner(mut self, spec: &str) -> ReplicaConfig {
        self.planner_spec = spec.to_string();
        self
    }

    pub fn with_speed(mut self, speed: f64) -> ReplicaConfig {
        self.speed = speed;
        self
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> ReplicaConfig {
        self.faults = Some(faults);
        self
    }
}

/// Per-replica slice of a [`FleetReport`].
#[derive(Clone, Debug)]
pub struct FleetReplicaReport {
    pub planner: String,
    pub speed: f64,
    /// Routing decisions that landed here (arrivals + requeues).
    pub routed: usize,
    /// Requests that finished here.
    pub completed: usize,
    /// Engine steps priced here.
    pub steps: usize,
    /// busy time / fleet makespan (0 when the fleet never ran).
    pub utilization: f64,
    /// This replica's exact admitted-vs-priced ledger.
    pub tokens: TokenLedger,
    /// Device-level chaos accounting local to this replica.
    pub chaos: ChaosStats,
    pub peak_bytes: u64,
    pub oom_steps: usize,
    pub fallback_steps: usize,
    pub plan_cache: CacheStats,
    /// Persistent-placement activity local to this replica (all zero
    /// for stateless planners).
    pub placement: PlacementStats,
    /// Times this replica's circuit breaker opened (0 when overload
    /// protection is off).
    pub breaker_opens: usize,
}

/// Result of one fleet run.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub router: String,
    pub workload: String,
    /// Requests in the workload stream.
    pub requests: usize,
    /// Requests that finished (`completed + shed == requests` on
    /// success; `shed` is 0 unless overload protection is on).
    pub completed: usize,
    /// Requests shed by the overload-protection layer instead of
    /// served (split by cause in [`overload`](Self::overload)).
    pub shed: usize,
    pub makespan_s: f64,
    /// Time to first token per request, measured at the first
    /// *successful* prefill: an attempt aborted by a replica failure
    /// does not count, the re-prefill on the surviving replica does
    /// (one sample per completed request).
    pub ttft: Summary,
    /// Per-decode-token latency, weighted by active decodes per step
    /// (same accounting as [`ContinuousReport`](crate::coordinator::ContinuousReport)).
    pub tpot: Summary,
    /// Completion − arrival per request.
    pub request_latency: Summary,
    /// SLO deadline applied to request latency (None = everything is
    /// on time).
    pub deadline_s: Option<f64>,
    /// Requests completed within the deadline.
    pub on_time: usize,
    /// Nominal (prompt + decode) tokens of on-time requests / makespan.
    pub goodput_tps: f64,
    /// All admitted tokens / makespan. Exceeds the nominal rate when
    /// requeues re-price prefills — admitted work, not useful work.
    pub throughput_tps: f64,
    /// Sum of every replica's ledger — exact by contract even across
    /// whole-replica failures.
    pub tokens: TokenLedger,
    /// Sum of device-level chaos accounting across replicas.
    pub chaos: ChaosStats,
    /// Whole-replica failures / recoveries that fired.
    pub replica_failures: usize,
    pub replica_recoveries: usize,
    /// Requests requeued at least once by a whole-replica failure, and
    /// the worst per-request requeue count (the bounded-recovery
    /// contract: one per failure event that held the request).
    pub requeued_requests: usize,
    pub max_requeues: usize,
    /// True when the run had an [`OverloadConfig`] installed (the CLI
    /// relaxes its exit contract to `completed + shed == requests`).
    pub protected: bool,
    /// Everything the protection layer did (all zero when off).
    pub overload: OverloadStats,
    pub replicas: Vec<FleetReplicaReport>,
}

/// Multi-replica cluster simulator (see the module docs for the event
/// loop). Build with [`FleetSim::new`], shape with the `with_*`
/// builders, run with [`try_run`](FleetSim::try_run).
pub struct FleetSim {
    pub engine: Engine,
    pub scenario: Scenario,
    pub replicas: Vec<ReplicaConfig>,
    pub router: RouterPolicy,
    pub workload: Workload,
    /// Max prefill tokens admitted per replica step.
    pub max_prefill_tokens: usize,
    pub faults: Option<FleetFaultPlan>,
    pub deadline_s: Option<f64>,
    /// Overload protection; `None` = legacy unbounded queueing (the
    /// unprotected baseline, bit-identical to pre-protection runs).
    pub overload: Option<OverloadConfig>,
}

impl FleetSim {
    pub fn new(
        engine: Engine,
        scenario: Scenario,
        replicas: Vec<ReplicaConfig>,
        max_prefill_tokens: usize,
    ) -> FleetSim {
        FleetSim {
            engine,
            scenario,
            replicas,
            router: RouterPolicy::LeastQueue,
            workload: Workload::default_poisson(),
            max_prefill_tokens,
            faults: None,
            deadline_s: None,
            overload: None,
        }
    }

    pub fn with_router(mut self, router: RouterPolicy) -> FleetSim {
        self.router = router;
        self
    }

    pub fn with_workload(mut self, workload: Workload) -> FleetSim {
        self.workload = workload;
        self
    }

    pub fn with_faults(mut self, faults: FleetFaultPlan) -> FleetSim {
        self.faults = Some(faults);
        self
    }

    pub fn with_deadline(mut self, deadline_s: f64) -> FleetSim {
        self.deadline_s = Some(deadline_s);
        self
    }

    /// Install overload protection (admission control, backpressure,
    /// retry/backoff, circuit breakers). Admission control only sheds
    /// when [`with_deadline`](Self::with_deadline) is also set.
    pub fn with_overload(mut self, overload: OverloadConfig) -> FleetSim {
        self.overload = Some(overload);
        self
    }

    /// Run the fleet to completion. Errors surface configuration
    /// mistakes (bad planner spec, fault plan out of range) and
    /// unrecoverable chaos (no alive replica to route to, a replica's
    /// own pool dying entirely).
    pub fn try_run(&self, seed: u64) -> Result<FleetReport, String> {
        let n = self.replicas.len();
        if n == 0 {
            return Err("fleet: need at least one replica".to_string());
        }
        for (i, cfg) in self.replicas.iter().enumerate() {
            if !(cfg.speed > 0.0 && cfg.speed.is_finite()) {
                return Err(format!(
                    "fleet: replica {i} speed must be positive and finite, got {}",
                    cfg.speed
                ));
            }
        }
        if let Some(plan) = &self.faults {
            plan.validate(n)?;
        }

        // A deterministic plan-cost model keeps every replica's pricing a
        // pure function of its inputs (the bit-reproducibility contract).
        let template = if self.engine.plan_cost.is_some() {
            self.engine.clone()
        } else {
            self.engine.clone().with_plan_cost(PlanCostModel::default())
        };
        let mut engines: Vec<Engine> = self
            .replicas
            .iter()
            .map(|cfg| {
                if cfg.speed == 1.0 {
                    template.clone()
                } else {
                    let speeds: Vec<f64> =
                        template.pool.devices.iter().map(|d| d.speed * cfg.speed).collect();
                    let devices = speeds.len();
                    template.for_pool(PoolState::from_speeds(&speeds, devices))
                }
            })
            .collect();
        let registry = Registry::builtin();
        let planners: Vec<Box<dyn Planner>> = self
            .replicas
            .iter()
            .map(|cfg| registry.parse(&cfg.planner_spec))
            .collect::<Result<_, _>>()?;
        // Trace layout: the frontend (workload + router) records under
        // the template tracer's pid; replica i becomes process i+1, so a
        // fleet trace shows every replica as its own track group with
        // router decisions flowing from the frontend into them.
        let tracer = template.tracer.clone();
        if tracer.is_enabled() {
            tracer.name_process("frontend / router");
            tracer.name_thread(crate::trace::COORD_TID, "workload");
            for (i, engine) in engines.iter_mut().enumerate() {
                let t = tracer.with_pid(i as u32 + 1);
                crate::trace::name_engine_tracks(
                    &t,
                    &format!(
                        "replica {i} ({}, {:.2}x)",
                        planners[i].label(),
                        self.replicas[i].speed
                    ),
                    engine.system.devices,
                );
                engine.tracer = t;
            }
        }
        let profile = uniform_profile(&template, self.scenario.clone());
        let mut reps: Vec<Replica> = Vec::with_capacity(n);
        for i in 0..n {
            reps.push(Replica::new(
                &engines[i],
                &*planners[i],
                &profile,
                self.max_prefill_tokens,
                self.replicas[i].faults.as_ref(),
            )?);
        }

        let requests = self.workload.generate(&mut Rng::new(seed));
        let total = requests.len();
        // Decorrelated per-replica pricing streams, all derived from the
        // one fleet seed.
        let mut rngs: Vec<Rng> = (0..n)
            .map(|i| Rng::new(seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)))
            .collect();
        let mut fleet_events: Vec<FleetEvent> =
            self.faults.as_ref().map(|p| p.events.clone()).unwrap_or_default();
        fleet_events.sort_by(|a, b| a.at_s().total_cmp(&b.at_s()));

        let overload = self.overload.clone();
        if let Some(cfg) = &overload {
            cfg.validate()?;
        }
        let mut router = Router::new(self.router);
        let mut breakers: Vec<Breaker> = match &overload {
            Some(cfg) => (0..n).map(|_| Breaker::new(cfg)).collect(),
            None => Vec::new(),
        };
        let mut ostats = OverloadStats::default();
        // Bounded frontend queue (protection only): holds requests while
        // every replica is saturated or breaker-blocked.
        let mut frontend: VecDeque<ReplicaRequest> = VecDeque::new();
        // Pending retry timers `(fire time, request)`. `Vec::remove`
        // keeps insertion order, so equal fire times stay FIFO and the
        // loop stays deterministic.
        let mut retryq: Vec<(f64, ReplicaRequest)> = Vec::new();
        let mut shed_flag = vec![false; total];
        let mut shed_count = 0usize;
        let mut alive = vec![true; n];
        let mut routed = vec![0usize; n];
        let mut completed_r = vec![0usize; n];
        let mut requeues = vec![0usize; total];
        // TTFT of the first *successful* prefill; cleared again when a
        // replica failure aborts the attempt before the request finished.
        let mut ttft_at: Vec<Option<f64>> = vec![None; total];
        let mut finished = vec![false; total];
        let mut tpot = Vec::new();
        let mut latencies = Vec::with_capacity(total);
        let mut completed = 0usize;
        let mut on_time = 0usize;
        let mut on_time_tokens = 0u64;
        let mut makespan = 0.0f64;
        let mut replica_failures = 0usize;
        let mut replica_recoveries = 0usize;
        let mut next_req = 0usize;
        let mut next_ev = 0usize;

        // Event kinds at equal times: arrival (0) before fleet fault (1)
        // before retry (2) before replica step (3) before breaker wake
        // (4); steps tie-break to the lowest index.
        fn earlier(a: (f64, u8, usize), b: (f64, u8, usize)) -> bool {
            a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)).is_lt()
        }
        fn beats(best: Option<(f64, u8, usize)>, c: (f64, u8, usize)) -> bool {
            match best {
                None => true,
                Some(b) => earlier(c, b),
            }
        }

        while completed + shed_count < total {
            let mut best: Option<(f64, u8, usize)> = None;
            if next_req < total {
                best = Some((requests[next_req].arrival_s, 0, 0));
            }
            if next_ev < fleet_events.len() {
                let c = (fleet_events[next_ev].at_s(), 1, 0);
                if beats(best, c) {
                    best = Some(c);
                }
            }
            for (qi, entry) in retryq.iter().enumerate() {
                let c = (entry.0, 2, qi);
                if beats(best, c) {
                    best = Some(c);
                }
            }
            for (i, rep) in reps.iter().enumerate() {
                if alive[i] && rep.has_work() {
                    let c = (rep.now(), 3, i);
                    if beats(best, c) {
                        best = Some(c);
                    }
                }
            }
            // A frontend queue blocked only on open breakers needs a
            // wake when the earliest cooldown elapses, or it would stall.
            if !frontend.is_empty() {
                for (i, b) in breakers.iter().enumerate() {
                    if alive[i] {
                        if let Some(w) = b.wake_at() {
                            let c = (w, 4, i);
                            if beats(best, c) {
                                best = Some(c);
                            }
                        }
                    }
                }
            }
            let Some((at, kind, idx)) = best else {
                return Err(format!(
                    "fleet: stuck with {completed}/{total} requests complete ({shed_count} \
                     shed) and no runnable event (dead replicas holding no work?)"
                ));
            };
            match kind {
                0 => {
                    // arrival: route via the load snapshot
                    let req = &requests[next_req];
                    if tracer.is_enabled() {
                        use crate::trace::{ArgValue, COORD_TID};
                        tracer.instant(
                            COORD_TID,
                            "arrival",
                            "router",
                            req.arrival_s,
                            &[
                                ("id", ArgValue::Num(req.id as f64)),
                                ("prompt_tokens", ArgValue::Num(req.prompt_tokens as f64)),
                            ],
                        );
                        tracer.count("router/arrivals", 1);
                    }
                    let request = ReplicaRequest {
                        id: req.id,
                        arrival_s: req.arrival_s,
                        prompt_tokens: req.prompt_tokens,
                        decode_steps: req.decode_steps,
                    };
                    match &overload {
                        None => {
                            // legacy unprotected path: route or die
                            let loads: Vec<ReplicaLoad> = reps
                                .iter()
                                .enumerate()
                                .map(|(i, r)| ReplicaLoad {
                                    alive: alive[i],
                                    accepting: true,
                                    queue_depth: r.queue_depth(),
                                    pressure: r.pressure(),
                                })
                                .collect();
                            let Some(t) = router.pick(&loads) else {
                                return Err(format!(
                                    "fleet: no alive replica to route request {} at t={:.6}",
                                    req.id, req.arrival_s
                                ));
                            };
                            submit_routed(
                                request,
                                t,
                                req.arrival_s,
                                &mut reps,
                                &mut routed,
                                &tracer,
                                "route",
                            );
                        }
                        Some(cfg) => match route_decision(
                            &request,
                            req.arrival_s,
                            cfg,
                            self.deadline_s,
                            &reps,
                            &alive,
                            &mut breakers,
                            &mut router,
                        ) {
                            RouteDecision::Route(t) => submit_routed(
                                request,
                                t,
                                req.arrival_s,
                                &mut reps,
                                &mut routed,
                                &tracer,
                                "route",
                            ),
                            RouteDecision::ShedDeadline => shed_request(
                                req.id,
                                ShedCause::Deadline,
                                req.arrival_s,
                                &mut shed_flag,
                                &mut shed_count,
                                &mut ostats,
                                &tracer,
                            ),
                            RouteDecision::Saturated => {
                                if frontend.len() < cfg.frontend_cap {
                                    frontend.push_back(request);
                                    ostats.frontend_peak_depth =
                                        ostats.frontend_peak_depth.max(frontend.len());
                                } else {
                                    shed_request(
                                        req.id,
                                        ShedCause::Backpressure,
                                        req.arrival_s,
                                        &mut shed_flag,
                                        &mut shed_count,
                                        &mut ostats,
                                        &tracer,
                                    );
                                }
                            }
                        },
                    }
                    next_req += 1;
                }
                1 => {
                    match fleet_events[next_ev] {
                        FleetEvent::Fail { replica: r, at_s } => {
                            if alive[r] {
                                alive[r] = false;
                                replica_failures += 1;
                                if tracer.is_enabled() {
                                    use crate::trace::ArgValue;
                                    tracer.with_pid(r as u32 + 1).instant_process(
                                        "replica-fail",
                                        "fleet",
                                        at_s,
                                        &[("replica", ArgValue::Num(r as f64))],
                                    );
                                    tracer.count("fleet/replica_failures", 1);
                                }
                                if let Some(cfg) = &overload {
                                    if breakers[r].on_failure(at_s, cfg.breaker_threshold)
                                        && tracer.is_enabled()
                                    {
                                        use crate::trace::ArgValue;
                                        tracer.with_pid(r as u32 + 1).instant_process(
                                            "breaker-open",
                                            "fleet",
                                            at_s,
                                            &[("replica", ArgValue::Num(r as f64))],
                                        );
                                        tracer.count("fleet/breaker_opens", 1);
                                    }
                                }
                                // drain the dead replica's queue back
                                // through the router to the survivors
                                for req in reps[r].drain() {
                                    // the aborted attempt's prefill no
                                    // longer counts toward TTFT (first
                                    // *successful* prefill only)
                                    if !finished[req.id] {
                                        ttft_at[req.id] = None;
                                    }
                                    match &overload {
                                        None => {
                                            // legacy: immediate reroute
                                            requeues[req.id] += 1;
                                            let loads: Vec<ReplicaLoad> = reps
                                                .iter()
                                                .enumerate()
                                                .map(|(i, rp)| ReplicaLoad {
                                                    alive: alive[i],
                                                    accepting: true,
                                                    queue_depth: rp.queue_depth(),
                                                    pressure: rp.pressure(),
                                                })
                                                .collect();
                                            let Some(t) = router.pick(&loads) else {
                                                return Err(format!(
                                                    "fleet: replica {r} died at t={at_s:.6} \
                                                     with no survivor to requeue request {} \
                                                     onto",
                                                    req.id
                                                ));
                                            };
                                            if tracer.is_enabled() {
                                                use crate::trace::{ArgValue, FlowPoint, COORD_TID};
                                                tracer.flow(
                                                    "requeue",
                                                    "fleet",
                                                    FlowPoint {
                                                        pid: r as u32 + 1,
                                                        tid: COORD_TID,
                                                        ts_s: at_s,
                                                    },
                                                    FlowPoint {
                                                        pid: t as u32 + 1,
                                                        tid: COORD_TID,
                                                        ts_s: at_s,
                                                    },
                                                    &[("id", ArgValue::Num(req.id as f64))],
                                                );
                                                tracer.count("fleet/requeues", 1);
                                            }
                                            if !reps[t].has_work() {
                                                reps[t].advance_to(at_s);
                                            }
                                            reps[t].submit(req);
                                            routed[t] += 1;
                                        }
                                        Some(cfg) => {
                                            // protected: retry with capped
                                            // exponential backoff, shed when
                                            // the retry budget is exhausted
                                            if requeues[req.id] >= cfg.max_retries {
                                                shed_request(
                                                    req.id,
                                                    ShedCause::Retries,
                                                    at_s,
                                                    &mut shed_flag,
                                                    &mut shed_count,
                                                    &mut ostats,
                                                    &tracer,
                                                );
                                            } else {
                                                requeues[req.id] += 1;
                                                let delay =
                                                    cfg.backoff_s(seed, req.id, requeues[req.id]);
                                                ostats.retries += 1;
                                                ostats.backoff_total_s += delay;
                                                if tracer.is_enabled() {
                                                    use crate::trace::{ArgValue, COORD_TID};
                                                    tracer.instant(
                                                        COORD_TID,
                                                        "retry-backoff",
                                                        "fleet",
                                                        at_s,
                                                        &[
                                                            ("id", ArgValue::Num(req.id as f64)),
                                                            ("delay_s", ArgValue::Num(delay)),
                                                            (
                                                                "attempt",
                                                                ArgValue::Num(
                                                                    requeues[req.id] as f64,
                                                                ),
                                                            ),
                                                        ],
                                                    );
                                                    tracer.count("fleet/retries", 1);
                                                }
                                                retryq.push((at_s + delay, req));
                                            }
                                        }
                                    }
                                }
                            }
                        }
                        FleetEvent::Recover { replica: r, at_s } => {
                            if !alive[r] {
                                alive[r] = true;
                                replica_recoveries += 1;
                                if tracer.is_enabled() {
                                    use crate::trace::ArgValue;
                                    tracer.with_pid(r as u32 + 1).instant_process(
                                        "replica-recover",
                                        "fleet",
                                        at_s,
                                        &[("replica", ArgValue::Num(r as f64))],
                                    );
                                    tracer.count("fleet/replica_recoveries", 1);
                                }
                                reps[r].advance_to(at_s);
                            }
                        }
                    }
                    next_ev += 1;
                }
                2 => {
                    // retry timer fired: the request re-enters routing
                    let cfg = overload
                        .as_ref()
                        .expect("retry events only exist under overload protection");
                    let (fire_at, req) = retryq.remove(idx);
                    match route_decision(
                        &req,
                        fire_at,
                        cfg,
                        self.deadline_s,
                        &reps,
                        &alive,
                        &mut breakers,
                        &mut router,
                    ) {
                        RouteDecision::Route(t) => submit_routed(
                            req,
                            t,
                            fire_at,
                            &mut reps,
                            &mut routed,
                            &tracer,
                            "retry-route",
                        ),
                        RouteDecision::ShedDeadline => shed_request(
                            req.id,
                            ShedCause::Deadline,
                            fire_at,
                            &mut shed_flag,
                            &mut shed_count,
                            &mut ostats,
                            &tracer,
                        ),
                        RouteDecision::Saturated => {
                            if frontend.len() < cfg.frontend_cap {
                                frontend.push_back(req);
                                ostats.frontend_peak_depth =
                                    ostats.frontend_peak_depth.max(frontend.len());
                            } else {
                                shed_request(
                                    req.id,
                                    ShedCause::Backpressure,
                                    fire_at,
                                    &mut shed_flag,
                                    &mut shed_count,
                                    &mut ostats,
                                    &tracer,
                                );
                            }
                        }
                    }
                }
                3 => {
                    // step the earliest alive replica with work
                    let i = idx;
                    if let ReplicaStepOutcome::Stepped(ev) = reps[i].step(&mut rngs[i])? {
                        if !breakers.is_empty() {
                            // a successfully priced step proves the
                            // replica healthy (closes a half-open probe)
                            breakers[i].on_success();
                        }
                        let now = reps[i].now();
                        for &(id, arrival_s) in &ev.prefilled {
                            if ttft_at[id].is_none() {
                                ttft_at[id] = Some(now - arrival_s);
                            }
                        }
                        for _ in 0..ev.decode_tokens {
                            tpot.push(ev.latency_s);
                        }
                        for &(id, arrival_s) in &ev.finished {
                            if finished[id] {
                                continue;
                            }
                            finished[id] = true;
                            let latency = now - arrival_s;
                            latencies.push(latency);
                            completed += 1;
                            completed_r[i] += 1;
                            makespan = makespan.max(now);
                            let within_slo = match self.deadline_s {
                                None => true,
                                Some(d) => latency <= d,
                            };
                            if within_slo {
                                on_time += 1;
                                on_time_tokens += (requests[id].prompt_tokens
                                    + requests[id].decode_steps)
                                    as u64;
                            }
                        }
                    }
                }
                _ => {
                    // breaker wake: no state of its own to mutate — the
                    // frontend drain below re-polls `accepting()`, which
                    // performs the Open -> HalfOpen transition
                }
            }
            if let Some(cfg) = &overload {
                // After every event, retry the frontend queue: a step may
                // have freed queue-cap capacity, a recovery or breaker
                // cooldown may have restored a replica, or queued heads
                // may have expired past the deadline.
                let drain_now = if kind == 3 { reps[idx].now() } else { at };
                drain_frontend(
                    drain_now,
                    cfg,
                    self.deadline_s,
                    &mut frontend,
                    &mut reps,
                    &alive,
                    &mut breakers,
                    &mut router,
                    &mut routed,
                    &mut shed_flag,
                    &mut shed_count,
                    &mut ostats,
                    &tracer,
                );
            }
        }

        // Breaker totals come straight from the per-replica breakers so
        // the fleet counters and per-replica reports can never disagree.
        ostats.breaker_opens = breakers.iter().map(|b| b.opens).sum();
        ostats.breaker_probes = breakers.iter().map(|b| b.probes).sum();
        let ttft: Vec<f64> = ttft_at.iter().flatten().copied().collect();
        let mut tokens = TokenLedger::default();
        let mut chaos = ChaosStats::default();
        let mut per_replica = Vec::with_capacity(n);
        for (i, rep) in reps.iter().enumerate() {
            let ledger = rep.ledger();
            tokens.absorb(&ledger);
            chaos.absorb(&rep.chaos_stats());
            per_replica.push(FleetReplicaReport {
                planner: planners[i].label(),
                speed: self.replicas[i].speed,
                routed: routed[i],
                completed: completed_r[i],
                steps: rep.steps(),
                utilization: if makespan > 0.0 { rep.busy_s() / makespan } else { 0.0 },
                tokens: ledger,
                chaos: rep.chaos_stats(),
                peak_bytes: rep.peak_bytes(),
                oom_steps: rep.oom_steps(),
                fallback_steps: rep.fallback_steps(),
                plan_cache: rep.plan_cache(),
                placement: rep.placement(),
                breaker_opens: breakers.get(i).map(|b| b.opens).unwrap_or(0),
            });
        }
        Ok(FleetReport {
            router: router.policy.name().to_string(),
            workload: self.workload.spec(),
            requests: total,
            completed,
            shed: shed_count,
            makespan_s: makespan,
            ttft: Summary::of(&ttft),
            tpot: Summary::of(&tpot),
            request_latency: Summary::of(&latencies),
            deadline_s: self.deadline_s,
            on_time,
            goodput_tps: if makespan > 0.0 { on_time_tokens as f64 / makespan } else { 0.0 },
            throughput_tps: if makespan > 0.0 {
                tokens.admitted as f64 / makespan
            } else {
                0.0
            },
            tokens,
            chaos,
            replica_failures,
            replica_recoveries,
            requeued_requests: requeues.iter().filter(|&&c| c > 0).count(),
            max_requeues: requeues.iter().copied().max().unwrap_or(0),
            protected: overload.is_some(),
            overload: ostats,
            replicas: per_replica,
        })
    }
}

/// Routing verdict for one request under overload protection.
enum RouteDecision {
    /// Send to this replica.
    Route(usize),
    /// Admission control: no eligible replica can meet the deadline.
    ShedDeadline,
    /// Nothing routable right now (dead, breaker-blocked, or at the
    /// queue cap everywhere): buffer in the frontend queue or shed.
    Saturated,
}

/// The protected routing pipeline: admission estimate over eligible
/// (alive + breaker-accepting) replicas first, then the router over the
/// accepting-and-under-cap set. Deadlines are measured from the
/// request's *original* arrival, so a retry carries the time it already
/// burned.
#[allow(clippy::too_many_arguments)]
fn route_decision(
    req: &ReplicaRequest,
    now: f64,
    cfg: &OverloadConfig,
    deadline_s: Option<f64>,
    reps: &[Replica],
    alive: &[bool],
    breakers: &mut [Breaker],
    router: &mut Router,
) -> RouteDecision {
    let mut any_eligible = false;
    let mut best_finish = f64::INFINITY;
    for (i, rep) in reps.iter().enumerate() {
        if !alive[i] || !breakers[i].accepting(now) {
            continue;
        }
        any_eligible = true;
        if cfg.admission && deadline_s.is_some() {
            best_finish =
                best_finish.min(rep.estimated_finish_s(now, req.prompt_tokens, req.decode_steps));
        }
    }
    if !any_eligible {
        return RouteDecision::Saturated;
    }
    if cfg.admission {
        if let Some(d) = deadline_s {
            if best_finish > req.arrival_s + d {
                return RouteDecision::ShedDeadline;
            }
        }
    }
    let loads: Vec<ReplicaLoad> = reps
        .iter()
        .enumerate()
        .map(|(i, rep)| ReplicaLoad {
            alive: alive[i],
            accepting: breakers[i].accepting(now) && !rep.at_capacity(cfg.queue_cap),
            queue_depth: rep.queue_depth(),
            pressure: rep.pressure(),
        })
        .collect();
    match router.pick(&loads) {
        Some(t) => {
            breakers[t].note_routed();
            RouteDecision::Route(t)
        }
        None => RouteDecision::Saturated,
    }
}

/// Hand a routed request to replica `t`: wake an idle replica's clock,
/// record the routing flow in the trace, submit.
fn submit_routed(
    req: ReplicaRequest,
    t: usize,
    now: f64,
    reps: &mut [Replica],
    routed: &mut [usize],
    tracer: &Tracer,
    flow_name: &'static str,
) {
    if !reps[t].has_work() {
        reps[t].advance_to(now);
    }
    if tracer.is_enabled() {
        use crate::trace::{ArgValue, FlowPoint, COORD_TID};
        tracer.flow(
            flow_name,
            "router",
            FlowPoint { pid: tracer.pid(), tid: COORD_TID, ts_s: now },
            FlowPoint { pid: t as u32 + 1, tid: COORD_TID, ts_s: now },
            &[("id", ArgValue::Num(req.id as f64)), ("replica", ArgValue::Num(t as f64))],
        );
    }
    routed[t] += 1;
    reps[t].submit(req);
}

/// Mark a request shed (idempotent) and record the cause.
fn shed_request(
    id: usize,
    cause: ShedCause,
    now: f64,
    shed_flag: &mut [bool],
    shed_count: &mut usize,
    ostats: &mut OverloadStats,
    tracer: &Tracer,
) {
    if shed_flag[id] {
        return;
    }
    shed_flag[id] = true;
    *shed_count += 1;
    ostats.note_shed(cause);
    if tracer.is_enabled() {
        use crate::trace::{ArgValue, COORD_TID};
        let name = match cause {
            ShedCause::Deadline => "admission-reject",
            ShedCause::Backpressure => "shed-backpressure",
            ShedCause::Retries => "shed-retries",
        };
        tracer.instant(COORD_TID, name, "fleet", now, &[("id", ArgValue::Num(id as f64))]);
        tracer.count("fleet/shed", 1);
    }
}

/// Route as many frontend-queued requests as capacity allows, shedding
/// heads whose deadline has already passed; stops at the first head the
/// fleet cannot place (FIFO — later requests never jump the queue).
#[allow(clippy::too_many_arguments)]
fn drain_frontend(
    now: f64,
    cfg: &OverloadConfig,
    deadline_s: Option<f64>,
    frontend: &mut VecDeque<ReplicaRequest>,
    reps: &mut [Replica],
    alive: &[bool],
    breakers: &mut [Breaker],
    router: &mut Router,
    routed: &mut [usize],
    shed_flag: &mut [bool],
    shed_count: &mut usize,
    ostats: &mut OverloadStats,
    tracer: &Tracer,
) {
    while let Some(head) = frontend.front() {
        // a queued request that has already blown its deadline can never
        // be on time — shed instead of burning survivor capacity on it
        if cfg.admission {
            if let Some(d) = deadline_s {
                if now > head.arrival_s + d {
                    let req = frontend.pop_front().expect("front checked above");
                    shed_request(
                        req.id,
                        ShedCause::Deadline,
                        now,
                        shed_flag,
                        shed_count,
                        ostats,
                        tracer,
                    );
                    continue;
                }
            }
        }
        match route_decision(head, now, cfg, deadline_s, reps, alive, breakers, router) {
            RouteDecision::Route(t) => {
                let req = frontend.pop_front().expect("front checked above");
                submit_routed(req, t, now, reps, routed, tracer, "frontend-route");
            }
            RouteDecision::ShedDeadline => {
                let req = frontend.pop_front().expect("front checked above");
                shed_request(
                    req.id,
                    ShedCause::Deadline,
                    now,
                    shed_flag,
                    shed_count,
                    ostats,
                    tracer,
                );
            }
            RouteDecision::Saturated => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};

    fn engine() -> Engine {
        Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        )
    }

    fn small_fleet(n: usize) -> FleetSim {
        FleetSim::new(
            engine(),
            Scenario::concentrated(0.8, 4),
            vec![ReplicaConfig::default(); n],
            16_384,
        )
        .with_workload(Workload::parse("poisson:n=24,ia=0.0005,prompt=128-1024,decode=4-16").unwrap())
    }

    #[test]
    fn fleet_fault_plan_round_trips() {
        let plan = FleetFaultPlan::parse("fail:r=1,at=0.02;recover:r=1,at=0.05").unwrap();
        assert_eq!(plan.events.len(), 2);
        assert_eq!(FleetFaultPlan::parse(&plan.spec()).unwrap(), plan);
        assert!(plan.validate(2).is_ok());
        assert!(plan.validate(1).is_err(), "replica 1 out of range");
        assert!(FleetFaultPlan::parse("fail:at=1").is_err(), "missing r");
        assert!(FleetFaultPlan::parse("explode:r=0,at=1").is_err());
    }

    #[test]
    fn burst_desugars_into_correlated_fail_recover_pairs() {
        // binary-exact times keep the f64 equality below honest
        let plan = FleetFaultPlan::parse("burst:r=1-3,at=0.25,for=0.5").unwrap();
        assert_eq!(
            plan.events,
            vec![
                FleetEvent::Fail { replica: 1, at_s: 0.25 },
                FleetEvent::Fail { replica: 2, at_s: 0.25 },
                FleetEvent::Fail { replica: 3, at_s: 0.25 },
                FleetEvent::Recover { replica: 1, at_s: 0.75 },
                FleetEvent::Recover { replica: 2, at_s: 0.75 },
                FleetEvent::Recover { replica: 3, at_s: 0.75 },
            ]
        );
        // the canonical spec is the desugared form and round-trips
        assert_eq!(FleetFaultPlan::parse(&plan.spec()).unwrap(), plan);
        assert!(plan.validate(4).is_ok());
        assert!(plan.validate(3).is_err(), "replica 3 out of range");
        // a single-replica burst without `for` is a plain group kill
        let kill = FleetFaultPlan::parse("burst:r=2,at=0.01").unwrap();
        assert_eq!(kill.events, vec![FleetEvent::Fail { replica: 2, at_s: 0.01 }]);
        assert!(FleetFaultPlan::parse("burst:r=3-1,at=0.01").is_err(), "inverted range");
        assert!(FleetFaultPlan::parse("burst:r=1-2,at=0.01,for=0").is_err(), "zero duration");
        assert!(FleetFaultPlan::parse("burst:r=1-2,at=0.01,steps=4").is_err(), "unknown key");
    }

    #[test]
    fn fleet_completes_every_request() {
        let r = small_fleet(2).try_run(42).unwrap();
        assert_eq!(r.completed, r.requests);
        assert_eq!(r.requests, 24);
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
        assert!(r.makespan_s > 0.0);
        assert!(r.goodput_tps > 0.0);
        assert_eq!(r.on_time, r.requests, "no deadline: everything on time");
        assert_eq!(r.replicas.len(), 2);
        assert_eq!(r.replicas.iter().map(|p| p.completed).sum::<usize>(), r.completed);
        assert_eq!(r.replicas.iter().map(|p| p.routed).sum::<usize>(), r.requests);
        for p in &r.replicas {
            assert!(p.tokens.is_exact(), "per-replica ledger: {:?}", p.tokens);
            assert!(p.utilization >= 0.0 && p.utilization <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn fleet_is_deterministic() {
        let sim = small_fleet(3).with_router(RouterPolicy::Pressure);
        let a = sim.try_run(7).unwrap();
        let b = sim.try_run(7).unwrap();
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.ttft.mean.to_bits(), b.ttft.mean.to_bits());
        assert_eq!(a.tokens, b.tokens);
        for (x, y) in a.replicas.iter().zip(&b.replicas) {
            assert_eq!(x.steps, y.steps);
            assert_eq!(x.routed, y.routed);
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn whole_replica_failure_requeues_and_recovers() {
        // Kill replica 1 early: everything it held must finish elsewhere
        // with at most one requeue and an exact summed ledger.
        let sim = small_fleet(2)
            .with_faults(FleetFaultPlan::parse("fail:r=1,at=0.001").unwrap());
        let r = sim.try_run(11).unwrap();
        assert_eq!(r.completed, r.requests);
        assert_eq!(r.replica_failures, 1);
        assert!(r.max_requeues <= 1, "single failure: one requeue max");
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
        assert!(r.goodput_tps > 0.0);
        assert_eq!(r.replicas[1].completed + r.replicas[0].completed, r.requests);
    }

    #[test]
    fn dead_fleet_errors_instead_of_hanging() {
        let sim = small_fleet(1).with_faults(FleetFaultPlan::parse("fail:r=0,at=0.0").unwrap());
        let err = sim.try_run(3).unwrap_err();
        assert!(err.contains("no alive replica"), "{err}");
    }

    #[test]
    fn recover_rejoins_the_routable_set() {
        let sim = small_fleet(2)
            .with_faults(FleetFaultPlan::parse("fail:r=1,at=0.0005;recover:r=1,at=0.002").unwrap());
        let r = sim.try_run(9).unwrap();
        assert_eq!(r.completed, r.requests);
        assert_eq!(r.replica_failures, 1);
        assert_eq!(r.replica_recoveries, 1);
        assert!(r.replicas[1].routed > 0, "recovered replica serves again");
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
    }

    #[test]
    fn bad_configs_are_loud() {
        assert!(small_fleet(0).try_run(1).is_err(), "empty fleet");
        let mut sim = small_fleet(2);
        sim.replicas[0].planner_spec = "warp-drive".to_string();
        assert!(sim.try_run(1).is_err(), "unknown planner spec");
        let mut sim = small_fleet(2);
        sim.replicas[1].speed = 0.0;
        assert!(sim.try_run(1).is_err(), "zero speed");
        let sim =
            small_fleet(2).with_faults(FleetFaultPlan::parse("fail:r=7,at=0.1").unwrap());
        assert!(sim.try_run(1).is_err(), "fault plan out of range");
    }

    #[test]
    fn generous_protection_prices_identically_to_legacy() {
        // No faults, no caps, no deadline: the protected pipeline must
        // make exactly the routing decisions the legacy path makes.
        let base = small_fleet(2).try_run(42).unwrap();
        assert!(!base.protected);
        assert_eq!(base.overload, OverloadStats::default());
        let cfg = OverloadConfig::parse("queue-cap=0,frontend-cap=64,retries=3").unwrap();
        let prot = small_fleet(2).with_overload(cfg).try_run(42).unwrap();
        assert!(prot.protected);
        assert_eq!(prot.completed, prot.requests);
        assert_eq!(prot.shed, 0);
        assert_eq!(prot.makespan_s.to_bits(), base.makespan_s.to_bits());
        assert_eq!(prot.tokens, base.tokens);
        assert_eq!(prot.overload.breaker_opens, 0);
    }

    #[test]
    fn tiny_queue_caps_shed_burst_overflow_exactly() {
        // 12 simultaneous arrivals against 2 replicas x cap 1 + frontend
        // 1: three requests find a home, nine are shed — deterministic
        // backpressure arithmetic, no deadline involved.
        let sim = FleetSim::new(
            engine(),
            Scenario::concentrated(0.8, 4),
            vec![ReplicaConfig::default(); 2],
            16_384,
        )
        .with_workload(
            Workload::parse("bursty:n=12,ia=0.0002,burst=12,every=12,prompt=128-512,decode=2-4")
                .unwrap(),
        )
        .with_overload(OverloadConfig::parse("queue-cap=1,frontend-cap=1").unwrap());
        let r = sim.try_run(8).unwrap();
        assert_eq!(r.shed, 9, "2 replica slots + 1 frontend slot out of 12");
        assert_eq!(r.overload.shed_frontend, 9, "all backpressure, no deadline");
        assert_eq!(r.completed + r.shed, r.requests);
        assert_eq!(r.completed, 3);
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
        assert_eq!(r.overload.frontend_peak_depth, 1);
    }

    #[test]
    fn deadline_splits_goodput_from_throughput() {
        // An absurdly tight deadline: nothing is on time, goodput is 0,
        // raw throughput is not.
        let r = small_fleet(2).with_deadline(1e-12).try_run(5).unwrap();
        assert_eq!(r.on_time, 0);
        assert_eq!(r.goodput_tps, 0.0);
        assert!(r.throughput_tps > 0.0);
        // And a generous one: everything is on time.
        let r = small_fleet(2).with_deadline(1e9).try_run(5).unwrap();
        assert_eq!(r.on_time, r.requests);
    }
}
