//! Multi-replica cluster simulator: N serving replicas behind a global
//! router, on one virtual timeline.
//!
//! One replica (the [`Replica`](crate::coordinator::Replica) core) can
//! tell you how a planner behaves under load; a *fleet* is where the
//! deployment-level questions live — does least-queue routing rescue a
//! half-speed replica, what does a whole-replica failure cost in p99
//! TTFT, how much goodput survives an SLO deadline. The module splits
//! three ways:
//!
//! * [`workload`] — deterministic arrival generators (Poisson, diurnal,
//!   bursty) with prompt/decode length mixtures, parsed from a spec
//!   string.
//! * [`router`] — pluggable admission policies over per-replica load
//!   snapshots: round-robin, least-queue, token-pressure-aware.
//! * [`sim`] — the discrete-event loop tying them together, plus
//!   whole-replica fail/recover chaos ([`FleetFaultPlan`], including
//!   correlated `burst:` group failures) layered on top of each
//!   replica's own device-level fault plan.
//! * [`admission`] — overload protection: deadline admission control,
//!   queue-cap backpressure with a bounded frontend queue, retry with
//!   capped-exponential backoff, and per-replica circuit breakers
//!   ([`OverloadConfig`]).
//!
//! Everything is bit-reproducible from `(workload spec, replica
//! configs, fault plan, overload config, seed)`, and the summed
//! [`TokenLedger`](crate::coordinator::TokenLedger) (admitted ==
//! priced) survives whole-replica failures; with protection on the
//! request ledger relaxes to the exact `completed + shed == admitted`.
//! Driven by the `llep fleet` CLI subcommand and `rust/tests/fleet.rs`.

mod admission;
mod router;
mod sim;
mod workload;

pub use admission::{Breaker, BreakerState, OverloadConfig, OverloadStats, ShedCause};
pub use router::{ReplicaLoad, Router, RouterPolicy};
pub use sim::{
    FleetEvent, FleetFaultPlan, FleetReplicaReport, FleetReport, FleetSim, ReplicaConfig,
};
pub use workload::{Workload, WorkloadKind};
