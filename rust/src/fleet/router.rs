//! Global request router: picks which replica admits each arrival.
//!
//! The router sees only a cheap per-replica load snapshot
//! ([`ReplicaLoad`]) — alive flag, queue depth, and token pressure — the
//! same signals a real frontend gets from replica heartbeats. Policies:
//!
//! * `round-robin` (`rr`) — rotate over alive replicas, load-blind.
//!   The baseline: cheap, fair in expectation, and pathological when one
//!   replica is slow (its queue grows without bound while the router
//!   keeps feeding it).
//! * `least-queue` (`lq`) — send to the alive replica with the fewest
//!   outstanding requests (waiting + in flight). Joins the shortest
//!   queue; reacts to slow replicas because their queues drain slowly.
//! * `pressure` — like least-queue but weighs queued *prompt tokens*
//!   plus in-flight generations, so one 8k-token prompt counts more than
//!   eight 64-token chats. The KV/compute-pressure-aware variant.
//!
//! Ties break to the lowest replica index so routing is a pure function
//! of the load snapshot (bit-reproducible fleets).
//!
//! Overload protection (`fleet/admission.rs`) adds an `accepting` bit to
//! the snapshot: replicas at their queue cap or behind an open circuit
//! breaker stay alive but refuse new work, so every policy spills to the
//! next-best accepting replica and returns `None` when the whole fleet
//! is saturated (the frontend queue's signal to buffer or shed).

/// Snapshot of one replica's load, as visible to the router.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplicaLoad {
    /// Dead replicas are never picked.
    pub alive: bool,
    /// Saturated (queue-capped) or breaker-blocked replicas are alive
    /// but not routable; `false` makes every policy spill past them.
    pub accepting: bool,
    /// Outstanding requests: waiting + actively decoding.
    pub queue_depth: usize,
    /// Queued prompt tokens + in-flight generations (compute pressure).
    pub pressure: usize,
}

/// Routing policy (see module docs for semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    LeastQueue,
    Pressure,
}

impl RouterPolicy {
    /// Parse a policy name (`rr`/`round-robin`, `lq`/`least-queue`,
    /// `pressure`).
    pub fn parse(spec: &str) -> Result<RouterPolicy, String> {
        match spec.trim() {
            "rr" | "round-robin" => Ok(RouterPolicy::RoundRobin),
            "lq" | "least-queue" => Ok(RouterPolicy::LeastQueue),
            "pressure" => Ok(RouterPolicy::Pressure),
            other => Err(format!(
                "unknown router policy {other:?} (expected round-robin, least-queue, pressure)"
            )),
        }
    }

    /// Canonical name; [`RouterPolicy::parse`] round-trips it.
    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastQueue => "least-queue",
            RouterPolicy::Pressure => "pressure",
        }
    }
}

/// Stateful router: owns the round-robin cursor.
#[derive(Clone, Debug)]
pub struct Router {
    pub policy: RouterPolicy,
    cursor: usize,
}

impl Router {
    pub fn new(policy: RouterPolicy) -> Router {
        Router { policy, cursor: 0 }
    }

    /// Pick the replica index for the next arrival, or `None` when no
    /// replica is alive and accepting. Deterministic: ties break to the
    /// lowest index.
    pub fn pick(&mut self, loads: &[ReplicaLoad]) -> Option<usize> {
        let n = loads.len();
        if !loads.iter().any(|l| l.alive && l.accepting) {
            return None;
        }
        match self.policy {
            RouterPolicy::RoundRobin => {
                // first routable replica scanning from the cursor
                let i = (0..n)
                    .map(|k| (self.cursor + k) % n)
                    .find(|&i| loads[i].alive && loads[i].accepting)
                    .expect("a routable replica exists");
                self.cursor = (i + 1) % n;
                Some(i)
            }
            RouterPolicy::LeastQueue => loads
                .iter()
                .enumerate()
                .filter(|(_, l)| l.alive && l.accepting)
                .min_by_key(|(i, l)| (l.queue_depth, *i))
                .map(|(i, _)| i),
            RouterPolicy::Pressure => loads
                .iter()
                .enumerate()
                .filter(|(_, l)| l.alive && l.accepting)
                .min_by_key(|(i, l)| (l.pressure, *i))
                .map(|(i, _)| i),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(alive: bool, queue_depth: usize, pressure: usize) -> ReplicaLoad {
        ReplicaLoad { alive, accepting: true, queue_depth, pressure }
    }

    #[test]
    fn policy_names_round_trip() {
        for p in [RouterPolicy::RoundRobin, RouterPolicy::LeastQueue, RouterPolicy::Pressure] {
            assert_eq!(RouterPolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(RouterPolicy::parse("rr").unwrap(), RouterPolicy::RoundRobin);
        assert_eq!(RouterPolicy::parse("lq").unwrap(), RouterPolicy::LeastQueue);
        assert!(RouterPolicy::parse("random").is_err());
    }

    #[test]
    fn round_robin_rotates_and_skips_dead() {
        let mut r = Router::new(RouterPolicy::RoundRobin);
        let loads = [load(true, 0, 0), load(false, 0, 0), load(true, 9, 9)];
        assert_eq!(r.pick(&loads), Some(0));
        assert_eq!(r.pick(&loads), Some(2), "skips the dead replica");
        assert_eq!(r.pick(&loads), Some(0), "wraps around");
    }

    #[test]
    fn least_queue_prefers_shallow_queue_lowest_index_on_tie() {
        let mut r = Router::new(RouterPolicy::LeastQueue);
        assert_eq!(r.pick(&[load(true, 3, 0), load(true, 1, 0), load(true, 1, 0)]), Some(1));
        assert_eq!(r.pick(&[load(false, 0, 0), load(true, 5, 0)]), Some(1));
    }

    #[test]
    fn pressure_weighs_tokens_not_request_count() {
        let mut r = Router::new(RouterPolicy::Pressure);
        // replica 0 has fewer requests but far more queued tokens
        let loads = [load(true, 1, 8192), load(true, 8, 512)];
        assert_eq!(r.pick(&loads), Some(1));
    }

    #[test]
    fn all_dead_routes_nowhere() {
        let mut r = Router::new(RouterPolicy::RoundRobin);
        assert_eq!(r.pick(&[load(false, 0, 0), load(false, 0, 0)]), None);
        assert_eq!(Router::new(RouterPolicy::LeastQueue).pick(&[]), None);
    }

    #[test]
    fn non_accepting_replicas_spill_like_dead_ones() {
        // alive-but-saturated replica 0 is skipped by every policy even
        // though its queue metrics would otherwise win
        let saturated = ReplicaLoad { alive: true, accepting: false, queue_depth: 0, pressure: 0 };
        let loads = [saturated, load(true, 5, 900)];
        for policy in [RouterPolicy::RoundRobin, RouterPolicy::LeastQueue, RouterPolicy::Pressure] {
            assert_eq!(Router::new(policy).pick(&loads), Some(1), "{policy:?}");
        }
        // nobody accepting: the frontend must buffer or shed
        assert_eq!(Router::new(RouterPolicy::LeastQueue).pick(&[saturated, saturated]), None);
    }
}
