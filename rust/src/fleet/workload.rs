//! Fleet workload generators: deterministic arrival streams with
//! prompt/decode length mixtures.
//!
//! Grammar (one shape, keys separated by `,`; all keys optional):
//!
//! ```text
//! poisson:n=64,ia=0.0002,prompt=128-1024,decode=4-32
//! diurnal:n=64,ia=0.0002,amp=0.5,period=0.05,prompt=128-1024,decode=4-32
//! bursty:n=64,ia=0.0002,burst=8,every=16,prompt=128-1024,decode=4-32
//! ```
//!
//! * `poisson` — exponential inter-arrival gaps with mean `ia` seconds.
//! * `diurnal` — Poisson with the gap scaled by `1 + amp·sin(2πt/period)`
//!   (`0 <= amp < 1`, `period > 0` seconds): rush hours and lulls on a
//!   virtual day of length `period`.
//! * `bursty` — a burst of `burst` simultaneous arrivals opens every
//!   `every`-th request; the remainder trickle in Poisson. The router
//!   stress case: queue depth spikes instantaneously.
//!
//! `prompt`/`decode` are inclusive `lo-hi` ranges drawn uniformly per
//! request. Unknown keys are hard errors (a typo never silently changes
//! the experiment) and [`Workload::spec`] round-trips through
//! [`Workload::parse`]. Generation is a pure function of `(spec, seed)`:
//! arrivals are monotone and every draw comes from the one seeded
//! [`Rng`] stream in request order.

use crate::coordinator::GenRequest;
use crate::util::rng::Rng;

/// Arrival-process shape. Lengths and counts live on [`Workload`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WorkloadKind {
    /// Exponential gaps with mean `ia`.
    Poisson,
    /// Gap mean modulated by `1 + amp·sin(2πt/period_s)`.
    Diurnal { amp: f64, period_s: f64 },
    /// `burst` simultaneous arrivals every `every` requests.
    Bursty { burst: usize, every: usize },
}

/// A parsed workload spec: arrival process + request-length mixture.
#[derive(Clone, Debug, PartialEq)]
pub struct Workload {
    pub kind: WorkloadKind,
    /// Number of requests.
    pub n: usize,
    /// Mean inter-arrival gap in (virtual) seconds.
    pub mean_interarrival_s: f64,
    /// Inclusive prompt-token range.
    pub prompt: (usize, usize),
    /// Inclusive decode-step range.
    pub decode: (usize, usize),
}

impl Workload {
    /// The default serving mix: 64 Poisson arrivals, mid-size prompts.
    pub fn default_poisson() -> Workload {
        Workload {
            kind: WorkloadKind::Poisson,
            n: 64,
            mean_interarrival_s: 2e-4,
            prompt: (128, 1024),
            decode: (4, 32),
        }
    }

    /// Parse the `kind:key=value,...` grammar (see the module docs).
    pub fn parse(spec: &str) -> Result<Workload, String> {
        let spec = spec.trim();
        let (kind, tail) = spec.split_once(':').unwrap_or((spec, ""));
        let mut p = Params::parse(tail)?;
        let mut w = Workload::default_poisson();
        w.kind = match kind {
            "poisson" => WorkloadKind::Poisson,
            "diurnal" => {
                let amp = p.take_f64("amp")?.unwrap_or(0.5);
                if !(0.0..1.0).contains(&amp) {
                    return Err(format!("diurnal: amp must be in [0, 1), got {amp}"));
                }
                let period_s = p.take_f64("period")?.unwrap_or(0.05);
                if !(period_s > 0.0 && period_s.is_finite()) {
                    return Err(format!("diurnal: period must be positive, got {period_s}"));
                }
                WorkloadKind::Diurnal { amp, period_s }
            }
            "bursty" => WorkloadKind::Bursty {
                burst: p.take_usize("burst")?.unwrap_or(8).max(1),
                every: p.take_usize("every")?.unwrap_or(16).max(1),
            },
            other => {
                return Err(format!(
                    "unknown workload kind {other:?} (expected poisson, diurnal, bursty)"
                ))
            }
        };
        if let Some(n) = p.take_usize("n")? {
            if n == 0 {
                return Err("workload: n must be at least 1".into());
            }
            w.n = n;
        }
        if let Some(ia) = p.take_f64("ia")? {
            if !(ia > 0.0 && ia.is_finite()) {
                return Err(format!("workload: ia must be positive and finite, got {ia}"));
            }
            w.mean_interarrival_s = ia;
        }
        if let Some(r) = p.take("prompt") {
            w.prompt = parse_range("prompt", &r)?;
        }
        if let Some(r) = p.take("decode") {
            w.decode = parse_range("decode", &r)?;
        }
        p.finish(kind)?;
        Ok(w)
    }

    /// Canonical spec string; [`Workload::parse`] on it reconstructs an
    /// equal workload (round-trip).
    pub fn spec(&self) -> String {
        let head = match self.kind {
            WorkloadKind::Poisson => "poisson".to_string(),
            WorkloadKind::Diurnal { amp, period_s } => {
                format!("diurnal:amp={amp},period={period_s},")
                    .trim_end_matches(',')
                    .to_string()
            }
            WorkloadKind::Bursty { burst, every } => format!("bursty:burst={burst},every={every}"),
        };
        let sep = if head.contains(':') { "," } else { ":" };
        format!(
            "{head}{sep}n={},ia={},prompt={}-{},decode={}-{}",
            self.n,
            self.mean_interarrival_s,
            self.prompt.0,
            self.prompt.1,
            self.decode.0,
            self.decode.1
        )
    }

    /// Generate the request stream: a pure function of `(self, rng
    /// seed)`. Arrivals are monotone non-decreasing; ids are `0..n`.
    pub fn generate(&self, rng: &mut Rng) -> Vec<GenRequest> {
        let mut t = 0.0f64;
        (0..self.n)
            .map(|id| {
                let in_burst = matches!(
                    self.kind,
                    WorkloadKind::Bursty { burst, every }
                        if id % every != 0 && id % every < burst
                );
                if !in_burst {
                    let scale = match self.kind {
                        WorkloadKind::Diurnal { amp, period_s } => {
                            1.0 + amp * (std::f64::consts::TAU * t / period_s).sin()
                        }
                        _ => 1.0,
                    };
                    t += -(self.mean_interarrival_s * scale) * (1.0 - rng.f64()).ln();
                }
                GenRequest {
                    id,
                    arrival_s: t,
                    prompt_tokens: rng.range(self.prompt.0, self.prompt.1),
                    decode_steps: rng.range(self.decode.0, self.decode.1),
                }
            })
            .collect()
    }

    /// Short label for report titles (the canonical spec).
    pub fn label(&self) -> String {
        self.spec()
    }
}

fn parse_range(key: &str, v: &str) -> Result<(usize, usize), String> {
    let (lo, hi) = v
        .split_once('-')
        .ok_or_else(|| format!("{key} expects lo-hi, got {v:?}"))?;
    let lo: usize =
        lo.trim().parse().map_err(|_| format!("{key}: bad lower bound {lo:?}"))?;
    let hi: usize =
        hi.trim().parse().map_err(|_| format!("{key}: bad upper bound {hi:?}"))?;
    if lo == 0 || hi < lo {
        return Err(format!("{key}: need 1 <= lo <= hi, got {lo}-{hi}"));
    }
    Ok((lo, hi))
}

/// Parsed `key=value` list with loud leftovers (mirrors the fault-plan
/// grammar's parameter handling). Shared with the fleet fault-plan
/// parser in `fleet/sim.rs`.
pub(crate) struct Params {
    kv: Vec<(String, String)>,
}

impl Params {
    pub(crate) fn parse(s: &str) -> Result<Params, String> {
        let mut kv = Vec::new();
        for part in s.split(',').filter(|p| !p.trim().is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("expected key=value, got {part:?}"))?;
            kv.push((k.trim().to_string(), v.trim().to_string()));
        }
        Ok(Params { kv })
    }

    pub(crate) fn take(&mut self, key: &str) -> Option<String> {
        self.kv.iter().position(|(k, _)| k == key).map(|i| self.kv.remove(i).1)
    }

    pub(crate) fn take_f64(&mut self, key: &str) -> Result<Option<f64>, String> {
        match self.take(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("{key} expects a number, got {v:?}")),
        }
    }

    pub(crate) fn take_usize(&mut self, key: &str) -> Result<Option<usize>, String> {
        match self.take(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| format!("{key} expects an integer, got {v:?}")),
        }
    }

    pub(crate) fn finish(&self, kind: &str) -> Result<(), String> {
        if self.kv.is_empty() {
            Ok(())
        } else {
            let keys: Vec<&str> = self.kv.iter().map(|(k, _)| k.as_str()).collect();
            Err(format!("unknown key(s) for {kind}: {}", keys.join(", ")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_spec_round_trips() {
        let w = Workload::parse("poisson:n=32,ia=0.001,prompt=64-256,decode=2-8").unwrap();
        assert_eq!(w.n, 32);
        assert_eq!(w.prompt, (64, 256));
        assert_eq!(Workload::parse(&w.spec()).unwrap(), w);
    }

    #[test]
    fn diurnal_and_bursty_round_trip() {
        for spec in [
            "diurnal:n=16,ia=0.0005,amp=0.7,period=0.02,prompt=64-128,decode=2-4",
            "bursty:n=40,ia=0.0003,burst=4,every=8,prompt=128-512,decode=4-16",
        ] {
            let w = Workload::parse(spec).unwrap();
            assert_eq!(Workload::parse(&w.spec()).unwrap(), w, "{spec}");
        }
    }

    #[test]
    fn defaults_apply_and_unknown_keys_are_loud() {
        let w = Workload::parse("poisson").unwrap();
        assert_eq!(w, Workload::default_poisson());
        assert!(Workload::parse("poisson:burst=4").is_err(), "burst is not a poisson key");
        assert!(Workload::parse("tidal:n=4").is_err());
        assert!(Workload::parse("diurnal:amp=1.5").is_err());
        assert!(Workload::parse("poisson:prompt=9-3").is_err());
    }

    #[test]
    fn generation_is_monotone_and_deterministic() {
        for spec in [
            "poisson:n=50",
            "diurnal:n=50,amp=0.9,period=0.01",
            "bursty:n=50,burst=8,every=16",
        ] {
            let w = Workload::parse(spec).unwrap();
            let a = w.generate(&mut Rng::new(7));
            let b = w.generate(&mut Rng::new(7));
            assert_eq!(a.len(), 50);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits(), "{spec}");
                assert_eq!(x.prompt_tokens, y.prompt_tokens);
                assert_eq!(x.decode_steps, y.decode_steps);
            }
            for pair in a.windows(2) {
                assert!(pair[0].arrival_s <= pair[1].arrival_s, "{spec}: monotone arrivals");
            }
        }
    }

    #[test]
    fn bursts_share_an_arrival_instant() {
        let w = Workload::parse("bursty:n=32,burst=8,every=16").unwrap();
        let reqs = w.generate(&mut Rng::new(3));
        // requests 0..8 and 16..24 each form one simultaneous burst
        for burst_start in [0, 16] {
            let t0 = reqs[burst_start].arrival_s;
            for r in &reqs[burst_start..burst_start + 8] {
                assert_eq!(r.arrival_s.to_bits(), t0.to_bits());
            }
            assert!(reqs[burst_start + 8].arrival_s > t0, "tail trickles after the burst");
        }
    }
}
