//! Fleet overload protection: admission control, backpressure, and
//! per-replica circuit breakers with capped-exponential retry backoff.
//!
//! The fleet's failure mode mirrors the paper's device-level one, one
//! layer up: a bursty workload plus whole-replica failures funnels
//! requests onto survivors with no mechanism to say "no", so queues —
//! and tail latency — grow without bound. [`OverloadConfig`] is the
//! knob block the [`FleetSim`](super::FleetSim) event loop consults to
//! push back instead:
//!
//! * **Admission control** (`admission=1`, requires a `--deadline`) —
//!   before routing, estimate the earliest finish time any eligible
//!   replica could give the request (its queued work divided by its
//!   observed priced-token rate, plus the request's own service time;
//!   see [`Replica::estimated_finish_s`]). If even the best estimate
//!   blows the deadline, shed the request instead of wasting survivor
//!   capacity on work that can no longer be on time.
//! * **Backpressure** (`queue-cap=N`) — replicas at or over the cap stop
//!   `accepting`; the router spills to the next-best replica, and when
//!   every replica is saturated the request waits in a *bounded*
//!   frontend queue (`frontend-cap=N`). Overflowing that sheds.
//! * **Retry with backoff + circuit breaker** — requests drained by a
//!   replica failure retry after a deterministic, seed-derived
//!   capped-exponential backoff ([`OverloadConfig::backoff_s`]), at most
//!   `retries=K` times before they are shed. Each replica carries a
//!   [`Breaker`]: `breaker-after=F` consecutive failures open it, an
//!   open breaker rejects traffic for `cooldown` seconds, then admits a
//!   single half-open probe; success closes it, another failure re-opens
//!   it with a doubled (capped) cooldown.
//!
//! Everything here is a pure function of `(config, seed, request id,
//! attempt)` — no wall clock, no global RNG — so protected fleet runs
//! stay bit-reproducible.
//!
//! [`Replica::estimated_finish_s`]: crate::coordinator::Replica::estimated_finish_s

use super::workload::Params;

/// Why a request was shed instead of served.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedCause {
    /// Admission control: no eligible replica could meet the deadline.
    Deadline,
    /// Backpressure: every replica saturated and the frontend queue full.
    Backpressure,
    /// The request exhausted its failure-retry budget.
    Retries,
}

/// Knob block for fleet overload protection. Parsed from / serialized
/// to a `key=value,...` spec ([`spec`](Self::spec) round-trips through
/// [`parse`](Self::parse)); [`FleetSim::with_overload`] turns it on.
///
/// [`FleetSim::with_overload`]: super::FleetSim::with_overload
#[derive(Clone, Debug, PartialEq)]
pub struct OverloadConfig {
    /// Shed requests no eligible replica can serve within the fleet
    /// deadline (only acts when the sim has one).
    pub admission: bool,
    /// Per-replica outstanding-request cap; `None` = unbounded (the
    /// router then never spills on depth).
    pub queue_cap: Option<usize>,
    /// Bounded frontend queue used once every replica is saturated.
    pub frontend_cap: usize,
    /// Max failure-requeues per request before it is shed.
    pub max_retries: usize,
    /// Retry backoff base (seconds); attempt k waits `base * 2^(k-1)`.
    pub backoff_base_s: f64,
    /// Retry backoff ceiling (seconds).
    pub backoff_cap_s: f64,
    /// Consecutive failures that open a replica's breaker.
    pub breaker_threshold: usize,
    /// Seconds an open breaker rejects traffic before its half-open
    /// probe. Re-opening doubles it, capped at 8x this base.
    pub breaker_cooldown_s: f64,
}

impl Default for OverloadConfig {
    fn default() -> OverloadConfig {
        OverloadConfig {
            admission: true,
            queue_cap: Some(8),
            frontend_cap: 64,
            max_retries: 3,
            backoff_base_s: 1e-3,
            backoff_cap_s: 16e-3,
            breaker_threshold: 1,
            breaker_cooldown_s: 5e-3,
        }
    }
}

impl OverloadConfig {
    /// Parse a `key=value,...` spec; missing keys take their defaults.
    /// Keys: `admission=0|1`, `queue-cap=N` (0 = unbounded),
    /// `frontend-cap=N`, `retries=N`, `backoff=S`, `backoff-cap=S`,
    /// `breaker-after=N`, `cooldown=S`.
    pub fn parse(spec: &str) -> Result<OverloadConfig, String> {
        let mut p = Params::parse(spec)?;
        let d = OverloadConfig::default();
        let cfg = OverloadConfig {
            admission: match p.take_usize("admission")? {
                None => d.admission,
                Some(0) => false,
                Some(1) => true,
                Some(v) => return Err(format!("overload: admission must be 0 or 1, got {v}")),
            },
            queue_cap: match p.take_usize("queue-cap")? {
                None => d.queue_cap,
                Some(0) => None,
                Some(c) => Some(c),
            },
            frontend_cap: p.take_usize("frontend-cap")?.unwrap_or(d.frontend_cap),
            max_retries: p.take_usize("retries")?.unwrap_or(d.max_retries),
            backoff_base_s: p.take_f64("backoff")?.unwrap_or(d.backoff_base_s),
            backoff_cap_s: p.take_f64("backoff-cap")?.unwrap_or(d.backoff_cap_s),
            breaker_threshold: p.take_usize("breaker-after")?.unwrap_or(d.breaker_threshold),
            breaker_cooldown_s: p.take_f64("cooldown")?.unwrap_or(d.breaker_cooldown_s),
        };
        p.finish("overload")?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Canonical spec string; [`parse`](Self::parse) round-trips it.
    pub fn spec(&self) -> String {
        format!(
            "admission={},queue-cap={},frontend-cap={},retries={},backoff={},\
             backoff-cap={},breaker-after={},cooldown={}",
            self.admission as usize,
            self.queue_cap.unwrap_or(0),
            self.frontend_cap,
            self.max_retries,
            self.backoff_base_s,
            self.backoff_cap_s,
            self.breaker_threshold,
            self.breaker_cooldown_s,
        )
    }

    /// Reject configurations that would hang or misbehave silently.
    pub fn validate(&self) -> Result<(), String> {
        if self.frontend_cap == 0 {
            return Err("overload: frontend-cap must be >= 1".to_string());
        }
        if self.breaker_threshold == 0 {
            return Err("overload: breaker-after must be >= 1".to_string());
        }
        for (name, v) in
            [("backoff", self.backoff_base_s), ("backoff-cap", self.backoff_cap_s)]
        {
            if !(v.is_finite() && v >= 0.0) {
                return Err(format!("overload: {name} must be a non-negative time, got {v}"));
            }
        }
        if self.backoff_cap_s < self.backoff_base_s {
            return Err(format!(
                "overload: backoff-cap ({}) below backoff base ({})",
                self.backoff_cap_s, self.backoff_base_s
            ));
        }
        if !(self.breaker_cooldown_s.is_finite() && self.breaker_cooldown_s > 0.0) {
            return Err(format!(
                "overload: cooldown must be a positive time, got {}",
                self.breaker_cooldown_s
            ));
        }
        Ok(())
    }

    /// Backoff before retry `attempt` (1-based) of request `id`:
    /// capped exponential `min(base * 2^(attempt-1), cap)` with up to
    /// +50% deterministic jitter hashed from `(seed, id, attempt)` so
    /// simultaneous retries de-synchronize without a shared RNG.
    pub fn backoff_s(&self, seed: u64, id: usize, attempt: usize) -> f64 {
        let exp = attempt.saturating_sub(1).min(63) as u32;
        let base = (self.backoff_base_s * f64::from(2u32.saturating_pow(exp.min(30))))
            .min(self.backoff_cap_s);
        let mut h = seed
            ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (attempt as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        // splitmix64 finalizer: decorrelate adjacent (id, attempt) pairs
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        h ^= h >> 31;
        let unit = (h >> 11) as f64 / (1u64 << 53) as f64;
        base * (1.0 + 0.5 * unit)
    }
}

/// Circuit-breaker state (see [`Breaker`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: traffic flows.
    Closed,
    /// Tripped: rejects all traffic until the cooldown elapses.
    Open,
    /// Cooldown elapsed: admits exactly one probe request.
    HalfOpen,
}

/// Per-replica circuit breaker. Consecutive replica failures open it;
/// an open breaker stops the router sending traffic to a flapping
/// replica, a half-open breaker admits a single probe after the
/// cooldown, and a successful step closes it again. Re-opening from
/// half-open doubles the cooldown (capped at 8x base) so a replica that
/// keeps dying is probed geometrically less often.
#[derive(Clone, Debug, PartialEq)]
pub struct Breaker {
    pub state: BreakerState,
    /// Consecutive failures since the last successful step.
    pub consecutive: usize,
    /// Virtual time at which an open breaker goes half-open.
    pub open_until_s: f64,
    cooldown_s: f64,
    base_cooldown_s: f64,
    probe_in_flight: bool,
    /// Times this breaker transitioned Closed/HalfOpen -> Open.
    pub opens: usize,
    /// Half-open probe requests routed through this breaker.
    pub probes: usize,
}

impl Breaker {
    pub fn new(cfg: &OverloadConfig) -> Breaker {
        Breaker {
            state: BreakerState::Closed,
            consecutive: 0,
            open_until_s: 0.0,
            cooldown_s: cfg.breaker_cooldown_s,
            base_cooldown_s: cfg.breaker_cooldown_s,
            probe_in_flight: false,
            opens: 0,
            probes: 0,
        }
    }

    /// Record a replica failure at `now`; returns `true` when this
    /// failure newly opened the breaker.
    pub fn on_failure(&mut self, now: f64, threshold: usize) -> bool {
        self.consecutive += 1;
        match self.state {
            BreakerState::Open => {
                // already open: push the probe point out
                self.open_until_s = self.open_until_s.max(now + self.cooldown_s);
                false
            }
            BreakerState::HalfOpen => {
                // the probe (or the replica itself) failed: re-open with
                // a doubled, capped cooldown
                self.cooldown_s = (self.cooldown_s * 2.0).min(8.0 * self.base_cooldown_s);
                self.state = BreakerState::Open;
                self.open_until_s = now + self.cooldown_s;
                self.probe_in_flight = false;
                self.opens += 1;
                true
            }
            BreakerState::Closed => {
                if self.consecutive >= threshold {
                    self.state = BreakerState::Open;
                    self.open_until_s = now + self.cooldown_s;
                    self.opens += 1;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record a successfully priced step: the replica is healthy again.
    pub fn on_success(&mut self) {
        self.state = BreakerState::Closed;
        self.consecutive = 0;
        self.cooldown_s = self.base_cooldown_s;
        self.probe_in_flight = false;
    }

    /// May the router send this replica a request at `now`? Transitions
    /// Open -> HalfOpen once the cooldown elapses; a half-open breaker
    /// accepts only while no probe is in flight.
    pub fn accepting(&mut self, now: f64) -> bool {
        if self.state == BreakerState::Open && now >= self.open_until_s {
            self.state = BreakerState::HalfOpen;
            self.probe_in_flight = false;
        }
        match self.state {
            BreakerState::Closed => true,
            BreakerState::Open => false,
            BreakerState::HalfOpen => !self.probe_in_flight,
        }
    }

    /// The router actually routed here; a half-open breaker marks its
    /// single probe as spent.
    pub fn note_routed(&mut self) {
        if self.state == BreakerState::HalfOpen && !self.probe_in_flight {
            self.probe_in_flight = true;
            self.probes += 1;
        }
    }

    /// When an open breaker next changes behaviour (the event loop
    /// schedules a wake so a frontend queue blocked only on open
    /// breakers cannot stall).
    pub fn wake_at(&self) -> Option<f64> {
        match self.state {
            BreakerState::Open => Some(self.open_until_s),
            _ => None,
        }
    }
}

/// Counters for everything the protection layer did during one run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OverloadStats {
    /// Requests shed by admission control (deadline unmeetable).
    pub shed_deadline: usize,
    /// Requests shed because replicas and the frontend queue were full.
    pub shed_frontend: usize,
    /// Requests shed after exhausting their retry budget.
    pub shed_retries: usize,
    /// Failure-requeues that were granted a retry (with backoff).
    pub retries: usize,
    /// Breaker open transitions across all replicas.
    pub breaker_opens: usize,
    /// Half-open probe requests routed.
    pub breaker_probes: usize,
    /// Total virtual seconds requests spent in retry backoff.
    pub backoff_total_s: f64,
    /// High-water mark of the bounded frontend queue.
    pub frontend_peak_depth: usize,
}

impl OverloadStats {
    /// Total requests shed, any cause.
    pub fn shed(&self) -> usize {
        self.shed_deadline + self.shed_frontend + self.shed_retries
    }

    pub fn note_shed(&mut self, cause: ShedCause) {
        match cause {
            ShedCause::Deadline => self.shed_deadline += 1,
            ShedCause::Backpressure => self.shed_frontend += 1,
            ShedCause::Retries => self.shed_retries += 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_spec_round_trips() {
        let d = OverloadConfig::default();
        assert_eq!(OverloadConfig::parse(&d.spec()).unwrap(), d);
        assert_eq!(OverloadConfig::parse("").unwrap(), d, "empty spec = defaults");
        let cfg = OverloadConfig::parse(
            "admission=0,queue-cap=4,frontend-cap=6,retries=2,backoff=0.0005,\
             backoff-cap=0.004,breaker-after=2,cooldown=0.002",
        )
        .unwrap();
        assert!(!cfg.admission);
        assert_eq!(cfg.queue_cap, Some(4));
        assert_eq!(cfg.max_retries, 2);
        assert_eq!(OverloadConfig::parse(&cfg.spec()).unwrap(), cfg);
        // queue-cap=0 means unbounded and round-trips as 0
        let unbounded = OverloadConfig::parse("queue-cap=0").unwrap();
        assert_eq!(unbounded.queue_cap, None);
        assert_eq!(OverloadConfig::parse(&unbounded.spec()).unwrap(), unbounded);
    }

    #[test]
    fn bad_configs_are_loud() {
        assert!(OverloadConfig::parse("admission=2").is_err());
        assert!(OverloadConfig::parse("frontend-cap=0").is_err());
        assert!(OverloadConfig::parse("breaker-after=0").is_err());
        assert!(OverloadConfig::parse("cooldown=0").is_err());
        assert!(OverloadConfig::parse("backoff=0.01,backoff-cap=0.001").is_err());
        assert!(OverloadConfig::parse("warp=9").is_err(), "unknown key");
    }

    #[test]
    fn backoff_is_capped_exponential_and_deterministic() {
        let cfg = OverloadConfig { backoff_base_s: 1e-3, backoff_cap_s: 4e-3, ..Default::default() };
        let b1 = cfg.backoff_s(7, 0, 1);
        let b2 = cfg.backoff_s(7, 0, 2);
        let b9 = cfg.backoff_s(7, 0, 9);
        // within [base*2^(k-1), 1.5 * that], and capped from attempt 3 on
        assert!((1e-3..1.5e-3 + 1e-12).contains(&b1), "{b1}");
        assert!((2e-3..3e-3 + 1e-12).contains(&b2), "{b2}");
        assert!((4e-3..6e-3 + 1e-12).contains(&b9), "{b9}");
        assert_eq!(cfg.backoff_s(7, 3, 1).to_bits(), cfg.backoff_s(7, 3, 1).to_bits());
        // different requests jitter differently (de-synchronized herd)
        assert_ne!(cfg.backoff_s(7, 0, 1).to_bits(), cfg.backoff_s(7, 1, 1).to_bits());
    }

    #[test]
    fn breaker_walks_closed_open_halfopen_closed() {
        let cfg =
            OverloadConfig { breaker_cooldown_s: 1.0, breaker_threshold: 2, ..Default::default() };
        let mut b = Breaker::new(&cfg);
        assert!(b.accepting(0.0));
        assert!(!b.on_failure(0.0, cfg.breaker_threshold), "below threshold");
        assert!(b.accepting(0.0), "one failure of two: still closed");
        assert!(b.on_failure(0.1, cfg.breaker_threshold), "threshold reached: opens");
        assert_eq!(b.state, BreakerState::Open);
        assert_eq!(b.opens, 1);
        assert!(!b.accepting(0.5), "cooling down");
        let wake = b.wake_at().expect("open breakers schedule a wake");
        assert!((wake - 1.1).abs() < 1e-9, "wake at open+cooldown, got {wake}");
        assert!(b.accepting(1.2), "cooldown elapsed: half-open probe");
        assert_eq!(b.state, BreakerState::HalfOpen);
        b.note_routed();
        assert_eq!(b.probes, 1);
        assert!(!b.accepting(1.2), "single probe in flight");
        b.on_success();
        assert_eq!(b.state, BreakerState::Closed);
        assert_eq!(b.consecutive, 0);
        assert!(b.accepting(1.3));
    }

    #[test]
    fn reopening_doubles_cooldown_up_to_cap() {
        let cfg =
            OverloadConfig { breaker_cooldown_s: 1.0, breaker_threshold: 1, ..Default::default() };
        let mut b = Breaker::new(&cfg);
        assert!(b.on_failure(0.0, 1));
        let mut expected = 1.0;
        let mut now = 0.0;
        for _ in 0..5 {
            now = b.open_until_s;
            assert!(b.accepting(now), "half-open at {now}");
            assert!(b.on_failure(now, 1), "probe failure re-opens");
            expected = (expected * 2.0).min(8.0);
            assert!(
                (b.open_until_s - now - expected).abs() < 1e-9,
                "cooldown {} != {expected}",
                b.open_until_s - now
            );
        }
        b.on_success();
        assert!(b.on_failure(now, 1));
        assert!((b.open_until_s - now - 1.0).abs() < 1e-9, "success resets the cooldown");
    }

    #[test]
    fn stats_split_shed_by_cause() {
        let mut s = OverloadStats::default();
        s.note_shed(ShedCause::Deadline);
        s.note_shed(ShedCause::Backpressure);
        s.note_shed(ShedCause::Backpressure);
        s.note_shed(ShedCause::Retries);
        assert_eq!((s.shed_deadline, s.shed_frontend, s.shed_retries), (1, 2, 1));
        assert_eq!(s.shed(), 4);
    }
}
