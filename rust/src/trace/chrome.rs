//! Chrome trace-event JSON export, built on [`crate::util::json`].
//!
//! The output is the "JSON Object Format" of the Chrome trace-event
//! spec: a top-level object with a `traceEvents` array, loadable
//! directly in [Perfetto](https://ui.perfetto.dev) or
//! `chrome://tracing`. Timestamps and durations are microseconds of
//! *virtual* (simulated) time. The metrics registry dumps alongside
//! under `llepMetrics` (viewers ignore unknown top-level keys).

use super::{ArgValue, EventKind, Histogram, TraceEvent, TraceSink};
use crate::util::json::Json;

fn args_json(args: &[(&'static str, ArgValue)]) -> Json {
    Json::obj(
        args.iter()
            .map(|(k, v)| {
                let jv = match v {
                    ArgValue::Num(n) => Json::num(*n),
                    ArgValue::Str(s) => Json::str(s),
                    ArgValue::Text(s) => Json::str(s.as_str()),
                };
                (*k, jv)
            })
            .collect(),
    )
}

const US_PER_S: f64 = 1e6;

fn event_json(e: &TraceEvent) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("name", Json::str(e.name)),
        ("cat", Json::str(e.cat)),
        ("pid", Json::num(e.pid as f64)),
        ("tid", Json::num(e.tid as f64)),
        ("ts", Json::num(e.ts_s * US_PER_S)),
    ];
    match e.kind {
        EventKind::Span => {
            fields.push(("ph", Json::str("X")));
            fields.push(("dur", Json::num(e.value * US_PER_S)));
        }
        EventKind::Instant => {
            fields.push(("ph", Json::str("i")));
            fields.push(("s", Json::str("t")));
        }
        EventKind::InstantProcess => {
            fields.push(("ph", Json::str("i")));
            fields.push(("s", Json::str("p")));
        }
        EventKind::Counter => {
            fields.push(("ph", Json::str("C")));
        }
        EventKind::FlowStart => {
            fields.push(("ph", Json::str("s")));
            fields.push(("id", Json::num(e.id as f64)));
        }
        EventKind::FlowEnd => {
            fields.push(("ph", Json::str("f")));
            fields.push(("id", Json::num(e.id as f64)));
            // bind the arrow head to the next slice on the track
            fields.push(("bp", Json::str("e")));
        }
    }
    if e.kind == EventKind::Counter {
        fields.push(("args", Json::obj(vec![("value", Json::num(e.value))])));
    } else if !e.args.is_empty() {
        fields.push(("args", args_json(&e.args)));
    }
    Json::obj(fields)
}

fn metadata_json(name: &'static str, pid: u32, tid: Option<u32>, value: &str) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("name", Json::str(name)),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
    ];
    if let Some(tid) = tid {
        fields.push(("tid", Json::num(tid as f64)));
    }
    fields.push(("args", Json::obj(vec![("name", Json::str(value))])));
    Json::obj(fields)
}

fn histogram_json(h: &Histogram) -> Json {
    // Only occupied buckets serialize (64 mostly-empty entries per
    // histogram would dominate the dump).
    let buckets = h.buckets.iter().enumerate().filter(|(_, &c)| c > 0).map(|(i, &c)| {
        Json::obj(vec![
            ("ge", Json::num(Histogram::bucket_lo(i))),
            ("lt", Json::num(Histogram::bucket_lo(i + 1))),
            ("count", Json::num(c as f64)),
        ])
    });
    Json::obj(vec![
        ("count", Json::num(h.count as f64)),
        ("sum", Json::num(h.sum)),
        ("mean", Json::num(h.mean())),
        ("buckets", Json::arr(buckets)),
    ])
}

/// Render the whole sink as one Chrome trace-event JSON document.
pub fn export(sink: &TraceSink) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(
        sink.events.len() + sink.process_names.len() + sink.thread_names.len(),
    );
    for (&pid, name) in &sink.process_names {
        events.push(metadata_json("process_name", pid, None, name));
    }
    for (&(pid, tid), name) in &sink.thread_names {
        events.push(metadata_json("thread_name", pid, Some(tid), name));
    }
    events.extend(sink.events.iter().map(event_json));

    let metrics = Json::obj(vec![
        (
            "counters",
            Json::obj(sink.counters.iter().map(|(&k, &v)| (k, Json::num(v as f64))).collect()),
        ),
        (
            "histograms",
            Json::obj(sink.histograms.iter().map(|(&k, h)| (k, histogram_json(h))).collect()),
        ),
    ]);

    Json::obj(vec![
        ("traceEvents", Json::arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        ("llepMetrics", metrics),
    ])
}
