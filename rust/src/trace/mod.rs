//! Execution-timeline tracing: a structured event recorder over the
//! virtual clock, exported as Chrome trace-event JSON (Perfetto /
//! `chrome://tracing`).
//!
//! The paper's central claim is a *timeline* claim — under imbalanced
//! routing, standard EP leaves most devices idle while one device's
//! compute blows up, and LLEP collapses that bubble — but reports and
//! tables only show end-of-run aggregates. This module records the
//! per-device, per-step execution timeline itself: compute spans on
//! device tracks, plan/cache-outcome instants on a coordinator track,
//! weight-transfer and router-decision flow arrows between tracks,
//! chaos fault windows as process-scoped instants, and a small metrics
//! registry (monotonic counters + fixed-bucket log2 histograms) riding
//! the same recorder.
//!
//! ## Handle design
//!
//! A [`Tracer`] is a cheap clonable handle: either **disabled** (the
//! default — no sink, every recording method is a branch-and-return
//! that performs **zero heap allocations**, asserted by the
//! counting-allocator tests below) or **enabled** (an
//! `Arc<Mutex<TraceSink>>` shared by every clone, buffering events into
//! a pre-grown arena). The [`Engine`](crate::exec::Engine) carries one;
//! `Engine::for_pool` / `clone` propagate it, so per-step chaos views
//! and fleet replicas record into the same sink. Each handle also
//! carries a `pid` (a Chrome "process"), which is how EP-vs-LLEP runs
//! and fleet replicas get side-by-side tracks on one timeline.
//!
//! ## Clock
//!
//! The trace clock is **simulated time** (virtual seconds, exported as
//! microseconds): the serving loops call
//! [`set_time_base`](Tracer::set_time_base) with their virtual clock
//! before pricing a step, and the engine emits each step's spans at
//! offsets from that base — so recording cost can never distort the
//! timeline, and an EP trace and an LLEP trace of the same workload are
//! directly comparable.

pub mod chrome;

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Track id of the per-process coordinator (plan spans, serve events).
pub const COORD_TID: u32 = 0;

/// Track id of device `d` within a process.
pub fn device_tid(d: usize) -> u32 {
    d as u32 + 1
}

/// What a [`TraceEvent`] renders as.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A complete span (Chrome `ph:"X"`): `[ts, ts+dur)` on one track.
    Span,
    /// A thread-scoped instant (`ph:"i"`, scope `t`).
    Instant,
    /// A process-scoped instant (`ph:"i"`, scope `p`) — spans every
    /// track of the process (fault windows, replica fail/recover).
    InstantProcess,
    /// A counter sample (`ph:"C"`): plotted as a per-process graph.
    Counter,
    /// Flow arrow start (`ph:"s"`), paired with an end by `id`.
    FlowStart,
    /// Flow arrow end (`ph:"f"`).
    FlowEnd,
}

/// One event argument value (rendered into the Chrome `args` object).
#[derive(Clone, Debug)]
pub enum ArgValue {
    Num(f64),
    Str(&'static str),
    Text(String),
}

/// One recorded event, in virtual seconds.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub kind: EventKind,
    pub name: &'static str,
    pub cat: &'static str,
    pub ts_s: f64,
    /// Span duration ([`EventKind::Span`]) or counter value
    /// ([`EventKind::Counter`]); unused otherwise.
    pub value: f64,
    pub pid: u32,
    pub tid: u32,
    /// Flow-pairing id ([`EventKind::FlowStart`]/[`FlowEnd`]).
    pub id: u64,
    pub args: Vec<(&'static str, ArgValue)>,
}

/// Bucket count of the fixed log2 histograms.
pub const HIST_BUCKETS: usize = 64;
/// Bucket `i` covers `[2^(i-HIST_BUCKET_BIAS), 2^(i+1-HIST_BUCKET_BIAS))`;
/// with a bias of 32 the histogram resolves values from `2^-32` (~2.3e-10
/// — well under a nanosecond) to `2^31`. Out-of-range values clamp to
/// the edge buckets.
pub const HIST_BUCKET_BIAS: i64 = 32;

/// A fixed-bucket log2 histogram (no allocation after construction).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub count: u64,
    pub sum: f64,
    pub buckets: [u64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram { count: 0, sum: 0.0, buckets: [0; HIST_BUCKETS] }
    }
}

impl Histogram {
    /// Bucket index of `v` (see [`HIST_BUCKET_BIAS`]); non-positive and
    /// non-finite values land in bucket 0.
    pub fn bucket_of(v: f64) -> usize {
        if !(v.is_finite() && v > 0.0) {
            return 0;
        }
        (v.log2().floor() as i64 + HIST_BUCKET_BIAS).clamp(0, HIST_BUCKETS as i64 - 1) as usize
    }

    /// Lower edge of bucket `i` (`2^(i-bias)`).
    pub fn bucket_lo(i: usize) -> f64 {
        ((i as i64 - HIST_BUCKET_BIAS) as f64).exp2()
    }

    pub fn observe(&mut self, v: f64) {
        self.count += 1;
        if v.is_finite() {
            self.sum += v;
        }
        self.buckets[Histogram::bucket_of(v)] += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.count > 0 {
            self.sum / self.count as f64
        } else {
            0.0
        }
    }
}

/// One endpoint of a flow arrow.
#[derive(Clone, Copy, Debug)]
pub struct FlowPoint {
    pub pid: u32,
    pub tid: u32,
    pub ts_s: f64,
}

/// The shared recording buffer behind an enabled [`Tracer`].
#[derive(Debug, Default)]
pub struct TraceSink {
    pub events: Vec<TraceEvent>,
    /// Virtual-time origin for the step currently being emitted (set by
    /// the serving loops; standalone runs leave it at 0).
    pub time_base_s: f64,
    next_flow_id: u64,
    pub counters: BTreeMap<&'static str, u64>,
    pub histograms: BTreeMap<&'static str, Histogram>,
    pub process_names: BTreeMap<u32, String>,
    pub thread_names: BTreeMap<(u32, u32), String>,
}

/// Cheap clonable tracing handle — see the module docs. The default is
/// [`disabled`](Tracer::disabled): every recording method early-returns
/// without touching the heap.
#[derive(Clone, Debug, Default)]
pub struct Tracer {
    sink: Option<Arc<Mutex<TraceSink>>>,
    pid: u32,
}

impl Tracer {
    /// A no-op tracer: records nothing, allocates nothing.
    pub fn disabled() -> Tracer {
        Tracer::default()
    }

    /// An enabled tracer with a fresh sink; the event arena is pre-grown
    /// so steady-state recording appends without reallocating.
    pub fn enabled() -> Tracer {
        let mut sink = TraceSink::default();
        sink.events.reserve(8 * 1024);
        Tracer { sink: Some(Arc::new(Mutex::new(sink))), pid: 0 }
    }

    pub fn is_enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// The Chrome process id this handle records under.
    pub fn pid(&self) -> u32 {
        self.pid
    }

    /// A handle to the same sink recording under a different process id
    /// (EP-vs-LLEP comparisons, fleet replicas).
    pub fn with_pid(&self, pid: u32) -> Tracer {
        Tracer { sink: self.sink.clone(), pid }
    }

    fn with_sink<R>(&self, f: impl FnOnce(&mut TraceSink) -> R) -> Option<R> {
        self.sink.as_ref().map(|s| f(&mut s.lock().expect("trace sink poisoned")))
    }

    /// Set the virtual-time origin subsequent engine emissions offset
    /// from (the serving loops call this with their clock per step).
    pub fn set_time_base(&self, t_s: f64) {
        self.with_sink(|s| s.time_base_s = t_s);
    }

    /// Current virtual-time origin (0 when disabled).
    pub fn time_base(&self) -> f64 {
        self.with_sink(|s| s.time_base_s).unwrap_or(0.0)
    }

    /// Name this handle's process (Chrome `process_name` metadata).
    pub fn name_process(&self, name: &str) {
        self.with_sink(|s| {
            s.process_names.insert(self.pid, name.to_string());
        });
    }

    /// Name a track of this handle's process.
    pub fn name_thread(&self, tid: u32, name: &str) {
        self.with_sink(|s| {
            s.thread_names.insert((self.pid, tid), name.to_string());
        });
    }

    fn push(
        &self,
        kind: EventKind,
        name: &'static str,
        cat: &'static str,
        ts_s: f64,
        value: f64,
        tid: u32,
        id: u64,
        args: &[(&'static str, ArgValue)],
    ) {
        self.with_sink(|s| {
            s.events.push(TraceEvent {
                kind,
                name,
                cat,
                ts_s,
                value,
                pid: self.pid,
                tid,
                id,
                args: args.to_vec(),
            });
        });
    }

    /// Record a complete span on track `tid`.
    pub fn span(
        &self,
        tid: u32,
        name: &'static str,
        cat: &'static str,
        start_s: f64,
        dur_s: f64,
        args: &[(&'static str, ArgValue)],
    ) {
        self.push(EventKind::Span, name, cat, start_s, dur_s, tid, 0, args);
    }

    /// Record a thread-scoped instant.
    pub fn instant(
        &self,
        tid: u32,
        name: &'static str,
        cat: &'static str,
        ts_s: f64,
        args: &[(&'static str, ArgValue)],
    ) {
        self.push(EventKind::Instant, name, cat, ts_s, 0.0, tid, 0, args);
    }

    /// Record a process-scoped (track-spanning) instant — fault windows,
    /// replica fail/recover.
    pub fn instant_process(
        &self,
        name: &'static str,
        cat: &'static str,
        ts_s: f64,
        args: &[(&'static str, ArgValue)],
    ) {
        self.push(EventKind::InstantProcess, name, cat, ts_s, 0.0, COORD_TID, 0, args);
    }

    /// Record a counter sample (plotted as a per-process graph track).
    pub fn counter(&self, name: &'static str, ts_s: f64, value: f64) {
        self.push(EventKind::Counter, name, "counter", ts_s, value, COORD_TID, 0, &[]);
    }

    /// Record a flow arrow between two (possibly cross-process) track
    /// points; `args` attach to the start event.
    pub fn flow(
        &self,
        name: &'static str,
        cat: &'static str,
        from: FlowPoint,
        to: FlowPoint,
        args: &[(&'static str, ArgValue)],
    ) {
        self.with_sink(|s| {
            s.next_flow_id += 1;
            let id = s.next_flow_id;
            s.events.push(TraceEvent {
                kind: EventKind::FlowStart,
                name,
                cat,
                ts_s: from.ts_s,
                value: 0.0,
                pid: from.pid,
                tid: from.tid,
                id,
                args: args.to_vec(),
            });
            s.events.push(TraceEvent {
                kind: EventKind::FlowEnd,
                name,
                cat,
                ts_s: to.ts_s,
                value: 0.0,
                pid: to.pid,
                tid: to.tid,
                id,
                args: Vec::new(),
            });
        });
    }

    /// Bump a monotonic counter in the metrics registry.
    pub fn count(&self, name: &'static str, delta: u64) {
        self.with_sink(|s| *s.counters.entry(name).or_insert(0) += delta);
    }

    /// Observe a value into a log2 histogram in the metrics registry.
    pub fn observe(&self, name: &'static str, v: f64) {
        self.with_sink(|s| s.histograms.entry(name).or_default().observe(v));
    }

    /// Events recorded so far (0 when disabled).
    pub fn event_count(&self) -> usize {
        self.with_sink(|s| s.events.len()).unwrap_or(0)
    }

    /// Export the whole sink as a Chrome trace-event JSON document
    /// (`None` when disabled).
    pub fn export(&self) -> Option<crate::util::json::Json> {
        self.with_sink(|s| chrome::export(s))
    }

    /// Write the Chrome trace JSON to `path`. Errors on a disabled
    /// tracer or an unwritable path (callers surface this as a non-zero
    /// exit).
    pub fn write(&self, path: &str) -> Result<(), String> {
        let json = self.export().ok_or("trace: tracer is disabled, nothing to write")?;
        std::fs::write(path, json.to_string()).map_err(|e| format!("trace: {path}: {e}"))
    }
}

/// Standard track naming for one engine's process: a coordinator track
/// plus one track per device.
pub fn name_engine_tracks(t: &Tracer, label: &str, devices: usize) {
    if !t.is_enabled() {
        return;
    }
    t.name_process(label);
    t.name_thread(COORD_TID, "coordinator");
    for d in 0..devices {
        t.name_thread(device_tid(d), &format!("device {d}"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_cover_the_virtual_time_range() {
        let mut h = Histogram::default();
        h.observe(2e-6); // plan-time scale
        h.observe(0.25); // step-latency scale
        h.observe(0.0); // degenerate
        h.observe(f64::NAN); // hostile
        assert_eq!(h.count, 4);
        assert!(h.sum > 0.25);
        assert_eq!(h.buckets.iter().sum::<u64>(), 4);
        assert_eq!(Histogram::bucket_of(0.0), 0);
        assert_eq!(Histogram::bucket_of(f64::INFINITY), HIST_BUCKETS - 1);
        assert_eq!(Histogram::bucket_of(1.0), HIST_BUCKET_BIAS as usize);
        // monotone in v
        assert!(Histogram::bucket_of(1e-6) < Histogram::bucket_of(1e-3));
    }

    #[test]
    fn enabled_tracer_records_and_exports() {
        let t = Tracer::enabled();
        assert!(t.is_enabled());
        name_engine_tracks(&t, "llep", 2);
        t.set_time_base(1.5);
        assert_eq!(t.time_base(), 1.5);
        t.span(device_tid(0), "compute", "compute", 1.5, 0.25, &[("tokens", ArgValue::Num(64.0))]);
        t.instant(COORD_TID, "plan-cache-hit", "plan", 1.5, &[]);
        t.instant_process("fault-window", "chaos", 1.5, &[("pool", ArgValue::Str("degraded"))]);
        t.counter("queue depth", 1.5, 3.0);
        t.flow(
            "weights",
            "xfer",
            FlowPoint { pid: 0, tid: device_tid(0), ts_s: 1.5 },
            FlowPoint { pid: 0, tid: device_tid(1), ts_s: 1.75 },
            &[("expert", ArgValue::Num(7.0))],
        );
        t.count("engine/steps", 1);
        t.observe("step/plan_s", 2e-6);
        assert_eq!(t.event_count(), 6); // span + 2 instants + counter + flow pair
        let doc = t.export().unwrap();
        let text = doc.to_string();
        let re = crate::util::json::parse(&text).unwrap();
        let events = re.get("traceEvents").unwrap().as_arr().unwrap();
        // metadata (process_name + 3 thread_name) + the 6 recorded events
        assert_eq!(events.len(), 10);
        for e in events {
            assert!(e.get("ph").is_some() && e.get("pid").is_some() && e.get("name").is_some());
        }
        assert!(events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("X")));
        assert!(events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("s")));
        assert!(events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("f")));
        let metrics = re.get("llepMetrics").unwrap();
        assert_eq!(
            metrics.get("counters").unwrap().get("engine/steps").unwrap().as_usize(),
            Some(1)
        );
        assert!(metrics.get("histograms").unwrap().get("step/plan_s").is_some());
    }

    #[test]
    fn pid_clones_share_one_sink() {
        let t = Tracer::enabled();
        let a = t.with_pid(1);
        let b = t.with_pid(2);
        a.instant(COORD_TID, "x", "c", 0.0, &[]);
        b.instant(COORD_TID, "y", "c", 0.0, &[]);
        assert_eq!(t.event_count(), 2);
        assert_eq!(a.pid(), 1);
        assert_eq!(b.pid(), 2);
    }

    /// The tentpole's hard requirement: a disabled tracer is a no-op on
    /// the heap — every recording method, clone, and pid re-tag performs
    /// zero allocations (counting-allocator asserted, same contract as
    /// `planner::scratch`'s steady-state tests).
    #[test]
    fn disabled_tracer_records_nothing_and_never_allocates() {
        let t = Tracer::disabled();
        assert!(!t.is_enabled());
        let before = crate::util::alloc_count::allocations_on_this_thread();
        for i in 0..64 {
            let tt = t.clone().with_pid(i as u32);
            tt.set_time_base(i as f64);
            tt.span(device_tid(0), "compute", "compute", 0.0, 1.0, &[("t", ArgValue::Num(1.0))]);
            tt.instant(COORD_TID, "plan-cache-hit", "plan", 0.0, &[]);
            tt.instant_process("fault-window", "chaos", 0.0, &[("p", ArgValue::Str("x"))]);
            tt.counter("queue depth", 0.0, 1.0);
            tt.flow(
                "weights",
                "xfer",
                FlowPoint { pid: 0, tid: 1, ts_s: 0.0 },
                FlowPoint { pid: 0, tid: 2, ts_s: 1.0 },
                &[],
            );
            tt.count("engine/steps", 1);
            tt.observe("step/plan_s", 1e-6);
            name_engine_tracks(&tt, "llep", 8);
        }
        let after = crate::util::alloc_count::allocations_on_this_thread();
        assert_eq!(after - before, 0, "disabled tracing must not touch the heap");
        assert_eq!(t.event_count(), 0);
        assert!(t.export().is_none());
        assert!(t.write("/dev/null").is_err());
    }

    /// The steady-state plan/price path with the (default, disabled)
    /// tracer threaded through the engine: per-iteration allocations
    /// stay exactly flat — tracing contributes nothing. Extends the
    /// `planner::scratch` counting-allocator suite one level up, to the
    /// full `run_step_loads` plan+price cycle the serving loops drive.
    #[test]
    fn disabled_tracer_keeps_engine_plan_price_allocations_flat() {
        use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
        use crate::exec::Engine;
        use crate::planner::PlannerKind;
        use crate::routing::Scenario;
        use crate::util::rng::Rng;

        let e = Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        );
        assert!(!e.tracer.is_enabled(), "engines default to a disabled tracer");
        let mut rng = Rng::new(5);
        let lm = Scenario::concentrated(0.9, 1).generate_loads(&e.model, 8, 8192, &mut rng);
        let planner = PlannerKind::llep_default();
        // Warm every arena (plan scratch, price scratch, report shapes).
        for _ in 0..3 {
            e.run_step_loads(&lm, &planner);
        }
        let t0 = crate::util::alloc_count::allocations_on_this_thread();
        e.run_step_loads(&lm, &planner);
        let per_iter = crate::util::alloc_count::allocations_on_this_thread() - t0;
        let t1 = crate::util::alloc_count::allocations_on_this_thread();
        for _ in 0..20 {
            e.run_step_loads(&lm, &planner);
        }
        let total = crate::util::alloc_count::allocations_on_this_thread() - t1;
        assert_eq!(
            total,
            20 * per_iter,
            "steady-state plan/price must not accrete allocations (tracer disabled)"
        );
    }
}
