//! # LLEP — Least-Loaded Expert Parallelism
//!
//! Reproduction of *"Least-Loaded Expert Parallelism: Load Balancing An
//! Imbalanced Mixture-of-Experts"* (Nguyen et al., 2026).
//!
//! The crate implements the paper's three-layer stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: the
//!   [`planner::lla`] least-loaded assignment algorithm (paper Alg. 2+3),
//!   the standard-EP baseline (Alg. 1), the EPLB redundancy baseline, and
//!   an execution engine ([`exec`]) that performs the full
//!   dispatch-compute-combine procedure over `P` virtual devices with
//!   exact numerics, virtual-clock latency, and analytic memory
//!   accounting (paper Eq. 3/4).
//! * **Layer 2/1 (python, build path only)** — a JAX MoE model whose
//!   hot-spot expert FFN is a Pallas kernel; lowered once to HLO text and
//!   executed from rust through the `runtime` module (PJRT CPU client).
//!   The PJRT path depends on the vendored `xla` + `anyhow` crates and is
//!   gated behind the `pjrt` cargo feature (off by default, so the crate
//!   builds fully offline with zero dependencies).
//!
//! The testbed substitution (no GPUs here — see DESIGN.md) is that the
//! `P` devices are *virtual*: every GEMM / transfer is charged to the
//! owning device's clock and the collective step latency is
//! `max_i time(device i)`, exactly the quantity the paper optimizes
//! (§5.3). Numerics are nevertheless real: the engine actually moves the
//! tokens and runs the GEMMs (native rust or PJRT backends), so
//! "LLEP is exact" is tested, not assumed.
//!
//! ## Quick tour
//!
//! ```no_run
//! use llep::prelude::*;
//!
//! let model = ModelConfig::preset(ModelPreset::Tiny);
//! let system = SystemConfig::preset(SystemPreset::CpuSim8);
//! // 80% of tokens concentrated into 4 experts:
//! let scenario = Scenario::concentrated(0.80, 4);
//! let mut rng = llep::util::rng::Rng::new(0);
//! let routing = scenario.generate(&model, system.devices, 512, &mut rng);
//!
//! let engine = Engine::modeled(model, system);
//! let ep   = engine.run_step(&routing, &PlannerKind::StandardEp).unwrap();
//! let ours = engine.run_step(&routing, &PlannerKind::llep_default()).unwrap();
//! assert!(ours.latency_s <= ep.latency_s * 1.001);
//! ```

pub mod chaos;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod exec;
pub mod fleet;
pub mod harness;
pub mod metrics;
pub mod moe;
pub mod placement;
pub mod planner;
pub mod routing;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod tensor;
pub mod topology;
pub mod trace;
#[cfg(feature = "pjrt")]
pub mod trainer;
pub mod tune;
pub mod util;

// The lib test binary counts per-thread allocations so the planner's
// zero-allocation steady-state contract is asserted, not assumed
// (`planner::scratch` tests).
#[cfg(test)]
#[global_allocator]
static COUNTING_ALLOC: util::alloc_count::CountingAlloc = util::alloc_count::CountingAlloc;

/// Convenience re-exports covering the most common entry points.
pub mod prelude {
    pub use crate::chaos::{DeviceState, FaultPlan, PoolState};
    pub use crate::config::{
        LlepConfig, ModelConfig, ModelPreset, SystemConfig, SystemPreset,
    };
    pub use crate::costmodel::{CommCostModel, GemmCostModel, MemoryModel};
    pub use crate::exec::{Engine, GemmBackendKind, ModelStepReport, PlanCostModel, StepReport};
    pub use crate::fleet::{FleetFaultPlan, FleetSim, ReplicaConfig, RouterPolicy, Workload};
    pub use crate::placement::{Placed, PlacementConfig, PlacementManager, PlacementStats};
    pub use crate::planner::{
        parse_planner, CacheStats, CachedPlanner, Planner, PlannerKind, RoutePlan,
    };
    pub use crate::routing::{DepthProfile, Routing, Scenario};
    pub use crate::topology::Topology;
    pub use crate::trace::Tracer;
    pub use crate::tune::{HardwareProfile, SearchSpace, SpaceBudget, Strategy, Tuner};
    pub use crate::util::rng::Rng;
}
