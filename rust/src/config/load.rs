//! Experiment configuration files (TOML subset).
//!
//! An experiment file selects a model + system preset (optionally
//! overriding fields) and the LLEP hyperparameters:
//!
//! ```toml
//! [model]
//! preset = "gpt-oss-120b"     # or explicit fields below
//! num_experts = 128
//!
//! [system]
//! preset = "h200x8"
//! devices = 8
//!
//! [llep]
//! alpha = 1.0
//! lambda = 1.3
//! min_gemm_tokens = 1024
//!
//! [workload]
//! tokens_per_device = 32768
//! scenario = "concentrated"   # balanced | concentrated | powerlaw
//! concentration = 0.8
//! hot_experts = 4
//! seed = 0
//! ```

use super::{LlepConfig, ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use crate::routing::Scenario;
use crate::util::tomlmini::{self, Doc};

/// A fully-resolved experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub model: ModelConfig,
    pub system: SystemConfig,
    pub llep: LlepConfig,
    pub scenario: Scenario,
    pub tokens_per_device: usize,
    pub seed: u64,
}

/// Parse an experiment TOML document.
pub fn load_experiment(text: &str) -> Result<ExperimentConfig, String> {
    let doc = tomlmini::parse(text)?;

    let model = load_model(&doc)?;
    let system = load_system(&doc)?;
    let llep = load_llep(&doc)?;
    let (scenario, tokens_per_device, seed) = load_workload(&doc, &model)?;

    model.validate()?;
    system.validate()?;
    llep.validate()?;
    model.experts_per_device(system.devices)?;
    Ok(ExperimentConfig { model, system, llep, scenario, tokens_per_device, seed })
}

fn get_usize(doc: &Doc, table: &str, key: &str) -> Result<Option<usize>, String> {
    match doc.get(table, key) {
        None => Ok(None),
        Some(v) => v
            .as_usize()
            .map(Some)
            .ok_or_else(|| format!("[{table}] {key} must be a non-negative integer")),
    }
}

fn get_f64(doc: &Doc, table: &str, key: &str) -> Result<Option<f64>, String> {
    match doc.get(table, key) {
        None => Ok(None),
        Some(v) => v.as_f64().map(Some).ok_or_else(|| format!("[{table}] {key} must be a number")),
    }
}

fn load_model(doc: &Doc) -> Result<ModelConfig, String> {
    let preset = match doc.get("model", "preset") {
        Some(v) => {
            let name = v.as_str().ok_or("[model] preset must be a string")?;
            ModelPreset::from_name(name).ok_or_else(|| {
                format!(
                    "unknown model preset {name:?}; known: {}",
                    ModelPreset::ALL.map(|p| p.name()).join(", ")
                )
            })?
        }
        None => ModelPreset::Tiny,
    };
    let mut m = ModelConfig::preset(preset);
    if let Some(x) = get_usize(doc, "model", "num_experts")? {
        m.num_experts = x;
    }
    if let Some(x) = get_usize(doc, "model", "top_k")? {
        m.top_k = x;
    }
    if let Some(x) = get_usize(doc, "model", "d_model")? {
        m.d_model = x;
    }
    if let Some(x) = get_usize(doc, "model", "d_ff")? {
        m.d_ff = x;
    }
    if let Some(x) = get_usize(doc, "model", "num_layers")? {
        m.num_layers = x;
    }
    if let Some(v) = doc.get("model", "swiglu") {
        m.swiglu = v.as_bool().ok_or("[model] swiglu must be a bool")?;
    }
    Ok(m)
}

fn load_system(doc: &Doc) -> Result<SystemConfig, String> {
    let preset = match doc.get("system", "preset") {
        Some(v) => {
            let name = v.as_str().ok_or("[system] preset must be a string")?;
            SystemPreset::from_name(name).ok_or_else(|| {
                format!(
                    "unknown system preset {name:?}; known: {}",
                    SystemPreset::ALL.map(|p| p.name()).join(", ")
                )
            })?
        }
        None => SystemPreset::CpuSim8,
    };
    let mut s = SystemConfig::preset(preset);
    if let Some(x) = get_usize(doc, "system", "devices")? {
        s = s.with_devices(x);
    }
    if let Some(x) = get_f64(doc, "system", "intra_node_gbps")? {
        s.comm.intra_node_bw = x * 1e9;
    }
    if let Some(x) = get_f64(doc, "system", "inter_node_gbps")? {
        s.comm.inter_node_bw = x * 1e9;
    }
    if let Some(x) = get_f64(doc, "system", "mem_capacity_gb")? {
        s.mem_capacity_bytes = (x * (1u64 << 30) as f64) as u64;
    }
    Ok(s)
}

fn load_llep(doc: &Doc) -> Result<LlepConfig, String> {
    let mut c = LlepConfig::default();
    if let Some(x) = get_f64(doc, "llep", "alpha")? {
        c.alpha = x;
    }
    if let Some(x) = get_f64(doc, "llep", "lambda")? {
        c.lambda = x;
    }
    if let Some(x) = get_usize(doc, "llep", "min_gemm_tokens")? {
        c.min_gemm_tokens = x;
    }
    Ok(c)
}

fn load_workload(doc: &Doc, model: &ModelConfig) -> Result<(Scenario, usize, u64), String> {
    let tokens = get_usize(doc, "workload", "tokens_per_device")?.unwrap_or(4096);
    let seed = get_usize(doc, "workload", "seed")?.unwrap_or(0) as u64;
    let kind = doc
        .get("workload", "scenario")
        .map(|v| v.as_str().ok_or("[workload] scenario must be a string"))
        .transpose()?
        .unwrap_or("balanced");
    let scenario = match kind {
        "balanced" => Scenario::balanced(),
        "concentrated" => {
            let conc = get_f64(doc, "workload", "concentration")?.unwrap_or(0.8);
            let hot = get_usize(doc, "workload", "hot_experts")?.unwrap_or(4);
            Scenario::concentrated(conc, hot)
        }
        "powerlaw" => {
            let expo = get_f64(doc, "workload", "exponent")?.unwrap_or(1.2);
            Scenario::power_law(expo)
        }
        other => {
            return Err(format!(
                "unknown scenario {other:?} (balanced | concentrated | powerlaw)"
            ))
        }
    };
    // Sanity: hot_experts can't exceed N.
    if let Scenario::Concentrated { hot_experts, .. } = &scenario {
        if *hot_experts > model.num_experts {
            return Err(format!(
                "hot_experts {} > num_experts {}",
                hot_experts, model.num_experts
            ));
        }
    }
    Ok((scenario, tokens, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document_roundtrip() {
        let cfg = load_experiment(
            r#"
[model]
preset = "gpt-oss-120b"

[system]
preset = "h200x8"

[llep]
alpha = 1.25
lambda = 1.3
min_gemm_tokens = 1024

[workload]
tokens_per_device = 32768
scenario = "concentrated"
concentration = 0.95
hot_experts = 1
seed = 7
"#,
        )
        .unwrap();
        assert_eq!(cfg.model.num_experts, 128);
        assert_eq!(cfg.system.devices, 8);
        assert_eq!(cfg.llep.alpha, 1.25);
        assert_eq!(cfg.tokens_per_device, 32768);
        assert_eq!(cfg.seed, 7);
        match cfg.scenario {
            Scenario::Concentrated { concentration, hot_experts } => {
                assert_eq!(concentration, 0.95);
                assert_eq!(hot_experts, 1);
            }
            _ => panic!("wrong scenario"),
        }
    }

    #[test]
    fn defaults_when_empty() {
        let cfg = load_experiment("").unwrap();
        assert_eq!(cfg.model.name, "tiny");
        assert_eq!(cfg.system.devices, 8);
        assert_eq!(cfg.llep, LlepConfig::default());
    }

    #[test]
    fn model_field_overrides() {
        let cfg =
            load_experiment("[model]\npreset = \"tiny\"\nnum_experts = 16\ntop_k = 4\n").unwrap();
        assert_eq!(cfg.model.num_experts, 16);
        assert_eq!(cfg.model.top_k, 4);
    }

    #[test]
    fn rejects_unknown_preset_and_scenario() {
        assert!(load_experiment("[model]\npreset = \"gpt5\"\n").is_err());
        assert!(load_experiment("[workload]\nscenario = \"chaotic\"\n").is_err());
    }

    #[test]
    fn rejects_inconsistent() {
        // 10 experts not divisible by 8 devices
        assert!(load_experiment("[model]\npreset = \"tiny\"\nnum_experts = 10\n").is_err());
        // hot_experts > N
        assert!(load_experiment(
            "[workload]\nscenario = \"concentrated\"\nhot_experts = 100\n"
        )
        .is_err());
    }
}
