//! MoE model geometry.
//!
//! Presets follow the architectures the paper benchmarks (§5.1): the MoE
//! layer of gpt-oss-20b/120b, DeepSeek-V3 and Kimi-K2, plus the synthetic
//! 128-expert layer of Fig. 1 and a tiny CPU-tractable geometry used by
//! the numeric tests and the end-to-end training example.

/// Named model presets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelPreset {
    /// Fig. 1a/1b synthetic layer: 128 experts, top-4, D=2048.
    Fig1Layer,
    /// gpt-oss-20b: 32 experts, top-4, D=2880, H=2880, 24 layers.
    GptOss20b,
    /// gpt-oss-120b: 128 experts, top-4, D=2880, H=2880, 36 layers.
    GptOss120b,
    /// DeepSeek-V3: 256 routed experts, top-8, D=7168, H=2048, 61 layers.
    DeepSeekV3,
    /// Kimi-K2: 384 routed experts, top-8, D=7168, H=2048, 61 layers.
    KimiK2,
    /// Tiny geometry for CPU-real execution: 8 experts, top-2, D=64, H=128.
    Tiny,
}

impl ModelPreset {
    pub const ALL: [ModelPreset; 6] = [
        ModelPreset::Fig1Layer,
        ModelPreset::GptOss20b,
        ModelPreset::GptOss120b,
        ModelPreset::DeepSeekV3,
        ModelPreset::KimiK2,
        ModelPreset::Tiny,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ModelPreset::Fig1Layer => "fig1-layer",
            ModelPreset::GptOss20b => "gpt-oss-20b",
            ModelPreset::GptOss120b => "gpt-oss-120b",
            ModelPreset::DeepSeekV3 => "deepseek-v3",
            ModelPreset::KimiK2 => "kimi-k2",
            ModelPreset::Tiny => "tiny",
        }
    }

    pub fn from_name(name: &str) -> Option<ModelPreset> {
        Self::ALL.iter().copied().find(|p| p.name() == name)
    }
}

/// Geometry of one MoE layer (and, for full-model throughput estimates,
/// the count of such layers plus dense/attention overhead parameters).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Number of routed experts `N`.
    pub num_experts: usize,
    /// Active experts per token `K`.
    pub top_k: usize,
    /// Model (hidden) dimension `D`.
    pub d_model: usize,
    /// Expert FFN intermediate dimension `H`.
    pub d_ff: usize,
    /// SwiGLU experts use three weight matrices (gate/up/down); a plain
    /// FFN expert uses one `D x H` matrix as in the paper's §2.1 notation.
    pub swiglu: bool,
    /// Number of MoE layers (used for full-model estimates, Fig. 1c).
    pub num_layers: usize,
    /// Bytes per parameter/activation element (2 = bf16, 4 = f32).
    pub dtype_bytes: usize,
    /// Shared (always-active) experts, computed outside EP dispatch.
    pub num_shared_experts: usize,
}

impl ModelConfig {
    pub fn preset(p: ModelPreset) -> ModelConfig {
        match p {
            // The Fig. 1 caption: "128 experts, 4 active experts, hidden
            // size of 2048".
            ModelPreset::Fig1Layer => ModelConfig {
                name: p.name().into(),
                num_experts: 128,
                top_k: 4,
                d_model: 2048,
                d_ff: 2048,
                swiglu: true,
                num_layers: 1,
                dtype_bytes: 2,
                num_shared_experts: 0,
            },
            ModelPreset::GptOss20b => ModelConfig {
                name: p.name().into(),
                num_experts: 32,
                top_k: 4,
                d_model: 2880,
                d_ff: 2880,
                swiglu: true,
                num_layers: 24,
                dtype_bytes: 2,
                num_shared_experts: 0,
            },
            ModelPreset::GptOss120b => ModelConfig {
                name: p.name().into(),
                num_experts: 128,
                top_k: 4,
                d_model: 2880,
                d_ff: 2880,
                swiglu: true,
                num_layers: 36,
                dtype_bytes: 2,
                num_shared_experts: 0,
            },
            ModelPreset::DeepSeekV3 => ModelConfig {
                name: p.name().into(),
                num_experts: 256,
                top_k: 8,
                d_model: 7168,
                d_ff: 2048,
                swiglu: true,
                num_layers: 58,
                dtype_bytes: 2,
                num_shared_experts: 1,
            },
            ModelPreset::KimiK2 => ModelConfig {
                name: p.name().into(),
                num_experts: 384,
                top_k: 8,
                d_model: 7168,
                d_ff: 2048,
                swiglu: true,
                num_layers: 60,
                dtype_bytes: 2,
                num_shared_experts: 1,
            },
            ModelPreset::Tiny => ModelConfig {
                name: p.name().into(),
                num_experts: 8,
                top_k: 2,
                d_model: 64,
                d_ff: 128,
                swiglu: true,
                num_layers: 2,
                dtype_bytes: 4,
                num_shared_experts: 0,
            },
        }
    }

    /// Number of MoE layers one full forward step executes — the layer
    /// count [`crate::exec::Engine::run_model`] prices (alias of
    /// `num_layers` under the name the multi-layer API uses).
    pub fn num_moe_layers(&self) -> usize {
        self.num_layers
    }

    /// Number of weight matrices per expert (3 for SwiGLU, 1 otherwise).
    pub fn mats_per_expert(&self) -> usize {
        if self.swiglu {
            3
        } else {
            1
        }
    }

    /// Bytes of one expert's weights.
    pub fn expert_weight_bytes(&self) -> usize {
        self.mats_per_expert() * self.d_model * self.d_ff * self.dtype_bytes
    }

    /// FLOPs to push one token through one expert (2 flops per MAC).
    pub fn flops_per_token(&self) -> f64 {
        2.0 * self.d_model as f64 * self.d_ff as f64 * self.mats_per_expert() as f64
    }

    /// Experts per device under `P`-way EP; errors if not divisible, as
    /// the paper assumes `M = N/P`.
    pub fn experts_per_device(&self, devices: usize) -> Result<usize, String> {
        if devices == 0 || self.num_experts % devices != 0 {
            return Err(format!(
                "num_experts {} not divisible by EP world size {}",
                self.num_experts, devices
            ));
        }
        Ok(self.num_experts / devices)
    }

    /// Native device of expert `i` under the paper's block layout.
    pub fn native_device(&self, expert: usize, devices: usize) -> usize {
        let m = self.num_experts / devices;
        expert / m
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<(), String> {
        if self.num_experts == 0 || self.top_k == 0 || self.d_model == 0 || self.d_ff == 0 {
            return Err("model dims must be positive".into());
        }
        if self.top_k > self.num_experts {
            return Err(format!("top_k {} > num_experts {}", self.top_k, self.num_experts));
        }
        if !matches!(self.dtype_bytes, 1 | 2 | 4) {
            return Err(format!("unsupported dtype_bytes {}", self.dtype_bytes));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for p in ModelPreset::ALL {
            let m = ModelConfig::preset(p);
            m.validate().unwrap();
            assert_eq!(ModelPreset::from_name(m.name.as_str()), Some(p));
        }
    }

    #[test]
    fn paper_geometries() {
        let g = ModelConfig::preset(ModelPreset::GptOss120b);
        assert_eq!((g.num_experts, g.top_k, g.d_model), (128, 4, 2880));
        let d = ModelConfig::preset(ModelPreset::DeepSeekV3);
        assert_eq!((d.num_experts, d.top_k), (256, 8));
        let k = ModelConfig::preset(ModelPreset::KimiK2);
        assert_eq!((k.num_experts, k.top_k), (384, 8));
    }

    #[test]
    fn expert_bytes_and_flops() {
        let t = ModelConfig::preset(ModelPreset::Tiny);
        // 3 mats * 64 * 128 * 4 bytes
        assert_eq!(t.expert_weight_bytes(), 3 * 64 * 128 * 4);
        assert_eq!(t.flops_per_token(), 2.0 * 64.0 * 128.0 * 3.0);
    }

    #[test]
    fn native_device_layout() {
        let m = ModelConfig::preset(ModelPreset::GptOss20b); // 32 experts
        assert_eq!(m.experts_per_device(8).unwrap(), 4);
        assert_eq!(m.native_device(0, 8), 0);
        assert_eq!(m.native_device(11, 8), 2); // paper §3.1: E11 lives on gpu-2
        assert_eq!(m.native_device(31, 8), 7);
        assert!(m.experts_per_device(7).is_err());
        assert!(m.experts_per_device(0).is_err());
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut m = ModelConfig::preset(ModelPreset::Tiny);
        m.top_k = 100;
        assert!(m.validate().is_err());
        m = ModelConfig::preset(ModelPreset::Tiny);
        m.dtype_bytes = 3;
        assert!(m.validate().is_err());
    }
}
