//! System (testbed) configuration: device count, per-device memory,
//! GEMM-throughput and interconnect parameters for the cost models.
//!
//! The `H200x8` preset mirrors the paper's testbed (8x H200 on one NVLink
//! node); `CpuSim8` models this environment's CPU so measured and modeled
//! runs can be cross-checked.

/// GEMM cost-model parameters (paper Eq. 3):
/// `T = overhead + tokens * t(B, D, H)` with per-token time degrading at
/// small batch via a saturation curve `eff(B) = B / (B + b_half)`.
#[derive(Clone, Debug, PartialEq)]
pub struct GemmCostParams {
    /// Kernel launch / setup latency per GEMM call, seconds (`T_overhead`).
    pub overhead_s: f64,
    /// Peak sustained throughput in FLOP/s at large B, D, H.
    pub peak_flops: f64,
    /// Token count at which efficiency reaches 50% (`b_half`).
    pub tokens_half_eff: f64,
    /// Dimension at which D/H-dependent efficiency reaches 50%; models
    /// that small D/H also waste the compute units (paper Fig. 7b).
    pub dim_half_eff: f64,
}

/// Interconnect cost-model parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct CommCostParams {
    /// Per-message latency, seconds (NCCL call + sync overhead).
    pub latency_s: f64,
    /// Intra-node per-device bandwidth, bytes/second (e.g. NVLink).
    pub intra_node_bw: f64,
    /// Inter-node per-device bandwidth, bytes/second (e.g. IB HDR).
    pub inter_node_bw: f64,
}

/// Named system presets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemPreset {
    /// The paper's testbed: single node, 8x H200 141GB, NVLink.
    H200x8,
    /// Two 8-GPU nodes (for the multi-node spill-preference discussion).
    H200x16TwoNodes,
    /// Single node, 8x H100 80GB — same NVLink generation as H200 but a
    /// much tighter HBM ceiling, so the latency/memory Pareto front the
    /// autotuner emits looks genuinely different per profile.
    H100x8,
    /// Mixed-generation single node: 4x H100 plus 4x A100-80G on the same
    /// fabric. The A100s sustain roughly a third of the H100's bf16 GEMM
    /// throughput, so the pool is *structurally* imbalanced before any
    /// routing skew — the heterogeneity case the chaos layer plans for.
    MixedH100A100,
    /// Virtual-device simulation calibrated to this repo's CPU.
    CpuSim8,
    /// Small CPU sim for tests (4 devices).
    CpuSim4,
}

impl SystemPreset {
    pub const ALL: [SystemPreset; 6] = [
        SystemPreset::H200x8,
        SystemPreset::H200x16TwoNodes,
        SystemPreset::H100x8,
        SystemPreset::MixedH100A100,
        SystemPreset::CpuSim8,
        SystemPreset::CpuSim4,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SystemPreset::H200x8 => "h200x8",
            SystemPreset::H200x16TwoNodes => "h200x16-2node",
            SystemPreset::H100x8 => "h100x8",
            SystemPreset::MixedH100A100 => "mixed-h100-a100",
            SystemPreset::CpuSim8 => "cpusim8",
            SystemPreset::CpuSim4 => "cpusim4",
        }
    }

    pub fn from_name(name: &str) -> Option<SystemPreset> {
        Self::ALL.iter().copied().find(|p| p.name() == name)
    }
}

/// Full system configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    pub name: String,
    /// EP world size `P`.
    pub devices: usize,
    /// Devices per node (communication between nodes is slower).
    pub devices_per_node: usize,
    /// Usable memory per device in bytes (for OOM detection).
    pub mem_capacity_bytes: u64,
    pub gemm: GemmCostParams,
    pub comm: CommCostParams,
    /// Per-device relative speed multipliers for mixed-generation pools
    /// (1.0 = the `gemm` parameters as stated). Empty = homogeneous. The
    /// engine folds these into its [`PoolState`] view, so planners and
    /// pricing see them exactly like injected slowdowns.
    ///
    /// [`PoolState`]: crate::chaos::PoolState
    pub device_speeds: Vec<f64>,
}

impl SystemConfig {
    pub fn preset(p: SystemPreset) -> SystemConfig {
        match p {
            SystemPreset::H200x8 => SystemConfig {
                name: p.name().into(),
                devices: 8,
                devices_per_node: 8,
                // 141 GB HBM3e minus ~20% framework reserve.
                mem_capacity_bytes: 113 * (1 << 30),
                gemm: GemmCostParams {
                    overhead_s: 6e-6,
                    // ~990 TFLOPs bf16 dense peak, ~65% sustained.
                    peak_flops: 650e12,
                    tokens_half_eff: 384.0,
                    dim_half_eff: 512.0,
                },
                comm: CommCostParams {
                    latency_s: 12e-6,
                    // NVLink 4: ~450 GB/s effective per direction per GPU.
                    intra_node_bw: 450e9,
                    inter_node_bw: 50e9,
                },
                device_speeds: Vec::new(),
            },
            SystemPreset::H200x16TwoNodes => {
                let mut c = SystemConfig::preset(SystemPreset::H200x8);
                c.name = p.name().into();
                c.devices = 16;
                c
            }
            SystemPreset::H100x8 => {
                let mut c = SystemConfig::preset(SystemPreset::H200x8);
                c.name = p.name().into();
                // 80 GB HBM3 minus ~20% framework reserve.
                c.mem_capacity_bytes = 64 * (1 << 30);
                // ~990 TFLOPs bf16 dense peak at lower sustained clocks.
                c.gemm.peak_flops = 560e12;
                c
            }
            SystemPreset::MixedH100A100 => {
                let mut c = SystemConfig::preset(SystemPreset::H100x8);
                c.name = p.name().into();
                // A100-80G: ~312 TFLOPs bf16 dense peak vs the H100's
                // ~990 — about a third of the sustained throughput the
                // `gemm` parameters describe. Same 80 GB HBM per card.
                c.device_speeds = vec![1.0, 1.0, 1.0, 1.0, 0.33, 0.33, 0.33, 0.33];
                c
            }
            SystemPreset::CpuSim8 => SystemConfig {
                name: p.name().into(),
                devices: 8,
                devices_per_node: 8,
                mem_capacity_bytes: 2 * (1 << 30),
                gemm: GemmCostParams {
                    // Calibrated against the native rust GEMM on this CPU
                    // (`llep calibrate`, post target-cpu=native: ~28
                    // GFLOP/s sustained, launch overhead below measurement
                    // noise — see EXPERIMENTS.md §Perf).
                    overhead_s: 1e-6,
                    peak_flops: 2.8e10,
                    tokens_half_eff: 8.0,
                    dim_half_eff: 48.0,
                },
                comm: CommCostParams {
                    latency_s: 1e-6,
                    intra_node_bw: 8e9,
                    inter_node_bw: 2e9,
                },
                device_speeds: Vec::new(),
            },
            SystemPreset::CpuSim4 => {
                let mut c = SystemConfig::preset(SystemPreset::CpuSim8);
                c.name = p.name().into();
                c.devices = 4;
                c.devices_per_node = 4;
                c
            }
        }
    }

    /// Node index of a device.
    pub fn node_of(&self, device: usize) -> usize {
        device / self.devices_per_node
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.devices == 0 {
            return Err("devices must be positive".into());
        }
        if self.devices_per_node == 0 || self.devices % self.devices_per_node != 0 {
            return Err(format!(
                "devices {} not divisible by devices_per_node {}",
                self.devices, self.devices_per_node
            ));
        }
        if self.gemm.peak_flops <= 0.0 || self.comm.intra_node_bw <= 0.0 {
            return Err("throughput parameters must be positive".into());
        }
        if !self.device_speeds.is_empty() {
            if self.device_speeds.len() != self.devices {
                return Err(format!(
                    "device_speeds has {} entries for {} devices",
                    self.device_speeds.len(),
                    self.devices
                ));
            }
            if self.device_speeds.iter().any(|&s| !s.is_finite() || s <= 0.0) {
                return Err("device_speeds must all be positive finite".into());
            }
        }
        Ok(())
    }

    /// Derive a copy with a different device count (keeps cost parameters).
    pub fn with_devices(&self, devices: usize) -> SystemConfig {
        let mut c = self.clone();
        c.devices = devices;
        if devices <= c.devices_per_node {
            c.devices_per_node = devices;
        }
        if !c.device_speeds.is_empty() {
            // Truncate or pad with nominal speed so the profile always
            // covers the new pool.
            c.device_speeds.resize(devices, 1.0);
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for p in SystemPreset::ALL {
            let s = SystemConfig::preset(p);
            s.validate().unwrap();
            assert_eq!(SystemPreset::from_name(s.name.as_str()), Some(p));
        }
    }

    #[test]
    fn node_mapping() {
        let two = SystemConfig::preset(SystemPreset::H200x16TwoNodes);
        assert_eq!(two.node_of(0), 0);
        assert_eq!(two.node_of(7), 0);
        assert_eq!(two.node_of(8), 1);
        assert_eq!(two.node_of(15), 1);
    }

    #[test]
    fn h100_is_h200_with_tighter_memory() {
        let h100 = SystemConfig::preset(SystemPreset::H100x8);
        let h200 = SystemConfig::preset(SystemPreset::H200x8);
        assert!(h100.mem_capacity_bytes < h200.mem_capacity_bytes);
        assert!(h100.gemm.peak_flops < h200.gemm.peak_flops);
        assert_eq!(h100.comm, h200.comm, "same NVLink generation");
        assert_eq!(h100.devices, 8);
    }

    #[test]
    fn mixed_preset_is_heterogeneous_h100_pool() {
        let mixed = SystemConfig::preset(SystemPreset::MixedH100A100);
        let h100 = SystemConfig::preset(SystemPreset::H100x8);
        assert_eq!(mixed.device_speeds.len(), mixed.devices);
        assert_eq!(&mixed.device_speeds[..4], &[1.0; 4], "H100 half at nominal speed");
        assert!(mixed.device_speeds[4..].iter().all(|&s| s < 0.5), "A100 half much slower");
        assert_eq!(mixed.gemm, h100.gemm, "nominal GEMM params are the H100's");
        assert_eq!(mixed.mem_capacity_bytes, h100.mem_capacity_bytes);
        // Homogeneous presets carry no speed profile.
        assert!(h100.device_speeds.is_empty());
        // Resizing keeps the profile covering every device.
        let shrunk = mixed.with_devices(4);
        shrunk.validate().unwrap();
        assert_eq!(shrunk.device_speeds, vec![1.0; 4]);
    }

    #[test]
    fn invalid_rejected() {
        let mut s = SystemConfig::preset(SystemPreset::CpuSim8);
        s.devices = 6; // not divisible by 8 per node
        assert!(s.validate().is_err());
        s = SystemConfig::preset(SystemPreset::CpuSim8);
        s.devices = 0;
        assert!(s.validate().is_err());
        s = SystemConfig::preset(SystemPreset::CpuSim8);
        s.device_speeds = vec![1.0; 3]; // wrong arity
        assert!(s.validate().is_err());
        s.device_speeds = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.0]; // zero speed
        assert!(s.validate().is_err());
    }

    #[test]
    fn with_devices_adjusts_node_size() {
        let s = SystemConfig::preset(SystemPreset::CpuSim8).with_devices(2);
        assert_eq!(s.devices, 2);
        assert_eq!(s.devices_per_node, 2);
        s.validate().unwrap();
    }
}
