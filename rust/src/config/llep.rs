//! LLEP hyperparameters (paper §4 "Constraints").

/// The three knobs of the LLA algorithm.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LlepConfig {
    /// Capacity factor `alpha`: a device is considered full at
    /// `m_alpha = alpha * total_tokens / P` assigned tokens.
    pub alpha: f64,
    /// Minimum tokens per spilled GEMM chunk `m` — smaller chunks are not
    /// worth the launch overhead + weight transfer (paper §3.2, Fig. 8).
    pub min_gemm_tokens: usize,
    /// Imbalance trigger `lambda`: if `max(l)/mean(l) < lambda` the
    /// routing is considered balanced and LLEP falls back to standard EP.
    pub lambda: f64,
}

impl Default for LlepConfig {
    /// The paper's §5.1 settings: `lambda=1.3, alpha=1, m=1024`.
    fn default() -> Self {
        LlepConfig { alpha: 1.0, min_gemm_tokens: 1024, lambda: 1.3 }
    }
}

impl LlepConfig {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.alpha.is_finite() && self.alpha >= 1.0) {
            // alpha < 1 would make total capacity < total tokens.
            return Err(format!("alpha must be >= 1.0, got {}", self.alpha));
        }
        if !(self.lambda.is_finite() && self.lambda >= 1.0) {
            // max/mean >= 1 always, so lambda < 1 would never trigger EP.
            return Err(format!("lambda must be >= 1.0, got {}", self.lambda));
        }
        Ok(())
    }

    pub fn with_alpha(mut self, alpha: f64) -> Self {
        self.alpha = alpha;
        self
    }
    pub fn with_lambda(mut self, lambda: f64) -> Self {
        self.lambda = lambda;
        self
    }
    pub fn with_min_gemm_tokens(mut self, m: usize) -> Self {
        self.min_gemm_tokens = m;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper() {
        let c = LlepConfig::default();
        assert_eq!(c.alpha, 1.0);
        assert_eq!(c.min_gemm_tokens, 1024);
        assert_eq!(c.lambda, 1.3);
        c.validate().unwrap();
    }

    #[test]
    fn invalid_rejected() {
        assert!(LlepConfig::default().with_alpha(0.5).validate().is_err());
        assert!(LlepConfig::default().with_lambda(0.9).validate().is_err());
        assert!(LlepConfig::default().with_alpha(f64::NAN).validate().is_err());
    }

    #[test]
    fn builders_chain() {
        let c = LlepConfig::default().with_alpha(1.5).with_lambda(2.0).with_min_gemm_tokens(64);
        assert_eq!((c.alpha, c.lambda, c.min_gemm_tokens), (1.5, 2.0, 64));
    }
}
