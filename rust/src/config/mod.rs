//! Configuration system: model geometry presets, system (testbed)
//! presets, LLEP hyperparameters, and TOML file loading.

mod llep;
mod load;
mod model;
mod system;

pub use llep::LlepConfig;
pub use load::{load_experiment, ExperimentConfig};
pub use model::{ModelConfig, ModelPreset};
pub use system::{SystemConfig, SystemPreset};
