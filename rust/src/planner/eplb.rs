//! EPLB — the DeepSeek-V3-style Expert Parallelism Load Balancer baseline
//! (Liu et al. 2024; see paper §3.1's related-work discussion).
//!
//! EPLB *replicates* heavily-loaded experts on under-loaded devices based
//! on (time-delayed) routing statistics, then splits each expert's tokens
//! evenly across its replica set. Compared to LLEP it (a) costs extra
//! memory for the replicas, (b) is inference-only (no gradient story),
//! and (c) places replicas from stale statistics, so a per-batch load
//! shift defeats it — all three effects are measurable with this
//! implementation (see `benches/ablations.rs`).
//!
//! Replica weight movement is amortized (placements change rarely), so
//! the engine charges EPLB transfers to memory but not to step latency.

use super::{Planner, RoutePlan, Segment, WeightTransfer};
use crate::topology::Topology;

/// EPLB as a trait planner. Places replicas from `stats` (possibly a
/// previous batch's loads — see [`Planner::wants_stale_stats`]) and
/// splits the actual `loads` across the replica set. Replica weight
/// movement is time-amortized, so it does not charge weight transfers to
/// step latency ([`Planner::charges_weight_transfers`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Eplb {
    pub replicas: usize,
}

impl Eplb {
    pub fn new(replicas: usize) -> Eplb {
        Eplb { replicas }
    }
}

impl Planner for Eplb {
    fn plan_with_stats(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        _topo: Option<&Topology>,
    ) -> RoutePlan {
        plan_eplb(self.replicas, loads.len(), devices, loads, stats)
    }

    fn label(&self) -> String {
        format!("EPLB(r={})", self.replicas)
    }

    fn spec(&self) -> String {
        format!("eplb:r={}", self.replicas)
    }

    fn charges_weight_transfers(&self) -> bool {
        false
    }

    fn wants_stale_stats(&self) -> bool {
        true
    }
}

/// Build an EPLB plan.
///
/// * `replicas` — replica budget (additional expert copies overall).
/// * `loads` — the loads actually executed this step.
/// * `stats` — the loads used for placement (pass an older batch's loads
///   to model the time delay; pass `loads` for EPLB's best case).
pub fn plan_eplb(
    replicas: usize,
    num_experts: usize,
    devices: usize,
    loads: &[u64],
    stats: &[u64],
) -> RoutePlan {
    assert_eq!(loads.len(), num_experts);
    assert_eq!(stats.len(), num_experts);
    assert!(devices > 0 && num_experts % devices == 0, "N must divide P");
    let m = num_experts / devices;

    // hosts[e] = devices holding a copy of expert e (native first).
    let mut hosts: Vec<Vec<usize>> = (0..num_experts).map(|e| vec![e / m]).collect();

    for _ in 0..replicas {
        // Projected per-device load with current replica sets.
        let proj = projected_loads(&hosts, stats, devices);
        // Expert with the highest per-copy share, breaking ties low-index.
        let Some((e, _)) = hosts
            .iter()
            .enumerate()
            .filter(|(e, h)| h.len() < devices && stats[*e] > 0)
            .map(|(e, h)| (e, stats[e] as f64 / h.len() as f64))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(b.0.cmp(&a.0)))
        else {
            break; // nothing left worth replicating
        };
        // Least-loaded device not already hosting e.
        let d = (0..devices)
            .filter(|d| !hosts[e].contains(d))
            .min_by(|&a, &b| proj[a].partial_cmp(&proj[b]).unwrap())
            .expect("filter guarantees a candidate");
        hosts[e].push(d);
    }

    // Split each expert's *actual* load evenly (contiguous chunks) across
    // its hosts, in host insertion order (native gets the first chunk).
    let mut assignments: Vec<Vec<Segment>> = vec![Vec::new(); num_experts];
    let mut transfers: Vec<WeightTransfer> = Vec::new();
    for (e, host_list) in hosts.iter().enumerate() {
        let l = loads[e];
        let native = e / m;
        for &h in host_list {
            if h != native {
                transfers.push(WeightTransfer { expert: e, from: native, to: h });
            }
        }
        if l == 0 {
            continue;
        }
        let k = host_list.len() as u64;
        let base = l / k;
        let extra = l % k;
        let mut start = 0u64;
        let mut segs = Vec::new();
        for (i, &h) in host_list.iter().enumerate() {
            let take = base + if (i as u64) < extra { 1 } else { 0 };
            if take == 0 {
                continue;
            }
            segs.push(Segment { device: h, start, end: start + take, forced: false });
            start += take;
        }
        // Keep coverage contract: segments sorted by start already.
        assignments[e] = segs;
    }

    // Drop transfers whose replica ended up with no tokens this step —
    // the validator requires transfers to match non-empty segments.
    transfers.retain(|t| {
        assignments[t.expert].iter().any(|s| s.device == t.to)
    });

    let mut plan = RoutePlan {
        num_experts,
        devices,
        assignments,
        transfers,
        migrations: Vec::new(),
        fallback_ep: false,
    };
    // Canonical transfer order: pricing reads the list as-is.
    plan.canonicalize_transfers();
    plan
}

fn projected_loads(hosts: &[Vec<usize>], stats: &[u64], devices: usize) -> Vec<f64> {
    let mut proj = vec![0.0f64; devices];
    for (e, host_list) in hosts.iter().enumerate() {
        let share = stats[e] as f64 / host_list.len() as f64;
        for &h in host_list {
            proj[h] += share;
        }
    }
    proj
}

/// Bytes of replica weights resident per device (EPLB's memory overhead).
pub fn replica_weight_bytes_per_device(
    plan: &RoutePlan,
    expert_weight_bytes: usize,
) -> Vec<u64> {
    let mut bytes = vec![0u64; plan.devices];
    for t in &plan.transfers {
        bytes[t.to] += expert_weight_bytes as u64;
    }
    bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::validate::validate_plan;

    #[test]
    fn zero_replicas_is_standard_ep() {
        let loads = vec![10, 20, 30, 40];
        let plan = plan_eplb(0, 4, 2, &loads, &loads);
        validate_plan(&plan, &loads).unwrap();
        assert!(plan.is_pure_ep());
    }

    #[test]
    fn replicates_hot_expert() {
        let loads = vec![1000, 10, 10, 10, 10, 10, 10, 10];
        let plan = plan_eplb(3, 8, 4, &loads, &loads);
        validate_plan(&plan, &loads).unwrap();
        // expert 0 should have been replicated 3 times -> 4 hosts
        assert_eq!(plan.assignments[0].len(), 4);
        let dl = plan.device_loads();
        assert!(*dl.iter().max().unwrap() < 1000, "spread the hot expert: {dl:?}");
    }

    #[test]
    fn stale_stats_misplace_replicas() {
        // Stats say expert 0 is hot, reality says expert 7.
        let stats = {
            let mut s = vec![10u64; 8];
            s[0] = 1000;
            s
        };
        let loads = {
            let mut l = vec![10u64; 8];
            l[7] = 1000;
            l
        };
        let plan = plan_eplb(3, 8, 4, &loads, &stats);
        validate_plan(&plan, &loads).unwrap();
        let dl = plan.device_loads();
        // Expert 7 (device 3) got no replicas -> device 3 stays overloaded.
        assert!(dl[3] >= 1000, "stale stats leave hotspot: {dl:?}");
    }

    #[test]
    fn replica_budget_respected() {
        let loads = vec![100, 100, 100, 100];
        let plan = plan_eplb(2, 4, 4, &loads, &loads);
        validate_plan(&plan, &loads).unwrap();
        assert!(plan.transfers.len() <= 2);
    }

    #[test]
    fn memory_overhead_counted() {
        let loads = vec![1000, 0, 0, 0];
        let plan = plan_eplb(3, 4, 4, &loads, &loads);
        let bytes = replica_weight_bytes_per_device(&plan, 100);
        // three replicas of expert 0 on devices 1..3
        assert_eq!(bytes.iter().sum::<u64>(), 300);
        assert_eq!(bytes[0], 0);
    }

    #[test]
    fn zero_load_expert_gets_no_segments() {
        let loads = vec![0, 50, 0, 50];
        let plan = plan_eplb(2, 4, 2, &loads, &loads);
        validate_plan(&plan, &loads).unwrap();
        assert!(plan.assignments[0].is_empty());
    }
}
