//! Greedy LPT (longest-processing-time) whole-expert rebalancer — a
//! mid-point baseline between standard EP and LLEP, added through the
//! open [`Planner`] trait (one file + one registry entry, no engine
//! changes).
//!
//! Experts are visited in decreasing-load order and each whole expert is
//! placed on the currently least-loaded device (classic LPT list
//! scheduling, a 4/3-approximation for makespan on identical machines).
//! Unlike LLEP it never *splits* an expert, so a single dominant expert
//! still bounds the step from below; unlike EP it does move experts off
//! overloaded devices, paying one weight transfer per relocated expert.
//! Experts below `min_tokens` stay native — a transfer plus a tiny GEMM
//! is not worth it (same §3.2/Fig. 8 reasoning as LLEP's `m`).

use super::scratch::{with_thread_scratch, PlanScratch};
use super::{Planner, RoutePlan, Segment, WeightTransfer};
use crate::chaos::PoolState;
use crate::topology::Topology;
use std::cmp::Reverse;

/// The LPT planner's single knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Lpt {
    /// Experts with fewer tokens than this stay on their native device.
    pub min_tokens: u64,
}

impl Default for Lpt {
    fn default() -> Lpt {
        Lpt { min_tokens: 1024 }
    }
}

impl Lpt {
    pub fn new(min_tokens: u64) -> Lpt {
        Lpt { min_tokens }
    }
}

impl Planner for Lpt {
    fn plan_with_stats(
        &self,
        devices: usize,
        loads: &[u64],
        _stats: &[u64],
        _topo: Option<&Topology>,
    ) -> RoutePlan {
        plan_lpt(self.min_tokens, loads.len(), devices, loads)
    }

    fn plan_with_pool(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
        pool: Option<&PoolState>,
    ) -> RoutePlan {
        match pool {
            Some(p) if p.is_degraded() && p.alive_count() > 0 => {
                plan_lpt_pool(self.min_tokens, loads.len(), devices, loads, p)
            }
            _ => self.plan_with_stats(devices, loads, stats, topo),
        }
    }

    fn label(&self) -> String {
        format!("LPT(min={})", self.min_tokens)
    }

    fn spec(&self) -> String {
        format!("lpt:min={}", self.min_tokens)
    }
}

/// Build the greedy-LPT plan for per-expert `loads`.
///
/// Panics if `num_experts` is not divisible by `devices` (the block
/// expert layout assumption shared by all planners here).
pub fn plan_lpt(min_tokens: u64, num_experts: usize, devices: usize, loads: &[u64]) -> RoutePlan {
    with_thread_scratch(|s| plan_lpt_scratch(min_tokens, num_experts, devices, loads, None, s))
}

/// Speed-aware greedy LPT over a degraded pool: experts go to the device
/// with the least *normalized* load (`tokens / speed`) among the alive
/// devices. Whole experts only, as ever — a dead native device forces
/// even sub-`min_tokens` experts to relocate.
pub fn plan_lpt_pool(
    min_tokens: u64,
    num_experts: usize,
    devices: usize,
    loads: &[u64],
    pool: &PoolState,
) -> RoutePlan {
    with_thread_scratch(|s| {
        plan_lpt_scratch(min_tokens, num_experts, devices, loads, Some(pool), s)
    })
}

/// The scratch-threaded LPT implementation behind [`plan_lpt`] and
/// [`plan_lpt_pool`]: all working state and the returned plan's buffers
/// come from `scratch` (allocation-free in steady state when finished
/// plans are recycled).
pub fn plan_lpt_scratch(
    min_tokens: u64,
    num_experts: usize,
    devices: usize,
    loads: &[u64],
    pool: Option<&PoolState>,
    scratch: &mut PlanScratch,
) -> RoutePlan {
    assert_eq!(loads.len(), num_experts);
    assert!(devices > 0 && num_experts % devices == 0, "N must divide P");
    if let Some(p) = pool {
        assert_eq!(p.len(), devices, "pool must cover every device");
        assert!(p.alive_count() > 0, "plan_lpt_pool needs at least one alive device");
    }
    let m = num_experts / devices;
    let speed = |d: usize| pool.map_or(1.0, |p| p.devices[d].effective_speed());

    scratch.order.clear();
    scratch.order.extend(0..num_experts);
    scratch.order.sort_unstable_by_key(|&e| (Reverse(loads[e]), e));
    scratch.prepare_devices(devices);

    let mut plan = scratch.take_plan(num_experts, devices);
    let PlanScratch { order, g_a: dev_load, .. } = scratch;
    for &e in order.iter() {
        let l = loads[e];
        if l == 0 {
            continue;
        }
        let native = e / m;
        let native_alive = speed(native) > 0.0;
        let target = if l < min_tokens && native_alive {
            native
        } else if pool.is_none() {
            // Least-loaded device; ties prefer native (no transfer), then
            // the lowest index (determinism).
            (0..devices)
                .min_by_key(|&d| (dev_load[d], d != native, d))
                .expect("devices > 0")
        } else {
            // Least normalized load among alive devices; ties prefer
            // native (no transfer), then the lowest index (determinism).
            (0..devices)
                .filter(|&d| speed(d) > 0.0)
                .min_by(|&a, &b| {
                    let norm = |d: usize| dev_load[d] as f64 / speed(d);
                    norm(a)
                        .total_cmp(&norm(b))
                        .then((a != native).cmp(&(b != native)))
                        .then(a.cmp(&b))
                })
                .expect("alive devices exist")
        };
        dev_load[target] += l;
        plan.assignments[e].push(Segment { device: target, start: 0, end: l, forced: false });
        if target != native {
            plan.transfers.push(WeightTransfer { expert: e, from: native, to: target });
        }
    }
    plan.canonicalize_transfers();
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LlepConfig;
    use crate::planner::validate::validate_plan;
    use crate::planner::{plan_ep, plan_llep};
    use crate::util::stats::max_over_mean;

    fn imbalance(plan: &RoutePlan) -> f64 {
        let loads: Vec<f64> = plan.device_loads().iter().map(|&l| l as f64).collect();
        max_over_mean(&loads)
    }

    #[test]
    fn whole_experts_only() {
        let loads = vec![500u64, 400, 300, 200, 100, 50, 25, 0];
        let plan = plan_lpt(1, 8, 4, &loads);
        validate_plan(&plan, &loads).unwrap();
        for (e, segs) in plan.assignments.iter().enumerate() {
            assert!(segs.len() <= 1, "expert {e} split into {} segments", segs.len());
        }
    }

    #[test]
    fn rebalances_hot_device_but_cannot_split_hot_expert() {
        // Experts 0 and 1 are native to device 0; LPT can move expert 1
        // away, but expert 0's 10k tokens stay whole — the structural gap
        // to LLEP.
        let loads = vec![10_000u64, 4_000, 10, 10, 10, 10, 10, 10];
        let plan = plan_lpt(1, 8, 4, &loads);
        validate_plan(&plan, &loads).unwrap();
        let ep = plan_ep(8, 4, &loads);
        assert!(imbalance(&plan) < imbalance(&ep), "LPT must beat EP");
        assert_eq!(plan.device_loads().iter().max(), Some(&10_000), "whole hot expert bounds LPT");
        let cfg = LlepConfig { min_gemm_tokens: 1, ..LlepConfig::default() };
        let ll = plan_llep(&cfg, 8, 4, &loads, None);
        assert!(
            plan.device_loads().iter().max() >= ll.device_loads().iter().max(),
            "LLEP splits the hot expert, LPT cannot"
        );
    }

    #[test]
    fn tiny_experts_stay_native() {
        let loads = vec![10u64, 10, 10, 10];
        let plan = plan_lpt(1024, 4, 2, &loads);
        validate_plan(&plan, &loads).unwrap();
        assert!(plan.transfers.is_empty(), "everything below min_tokens stays put");
        assert!(plan.is_pure_ep());
    }

    #[test]
    fn balanced_loads_stay_balanced() {
        // Equal loads: greedy LPT keeps a perfectly even makespan (it may
        // still shuffle experts — native only wins exact load ties).
        let loads = vec![100u64; 8];
        let plan = plan_lpt(1, 8, 4, &loads);
        validate_plan(&plan, &loads).unwrap();
        assert_eq!(plan.device_loads(), vec![200, 200, 200, 200]);
    }

    #[test]
    fn pool_aware_lpt_avoids_dead_and_relieves_stragglers() {
        // Device 1 dead: its native experts (2, 3) relocate, tiny or not.
        let loads = vec![500u64, 400, 300, 7];
        let mut pool = PoolState::healthy(2);
        pool.devices[1].alive = false;
        let plan = plan_lpt_pool(1024, 4, 2, &loads, &pool);
        validate_plan(&plan, &loads).unwrap();
        assert_eq!(plan.device_loads()[1], 0);
        assert_eq!(plan.device_loads()[0], 1207);

        // Straggler: normalized-load greedy gives the slow device less.
        let loads = vec![300u64, 300, 300, 300, 300, 300, 300, 300];
        let mut pool = PoolState::healthy(4);
        pool.devices[0].speed = 0.25;
        let plan = Lpt::new(1).plan_with_pool(4, &loads, &loads, None, Some(&pool));
        validate_plan(&plan, &loads).unwrap();
        let dl = plan.device_loads();
        assert!(dl[0] < dl[1], "straggler takes fewer tokens: {dl:?}");
        // Healthy pool through the trait path falls through to plain LPT.
        let plain = Lpt::new(1).plan_with_pool(4, &loads, &loads, None, None);
        assert_eq!(plain, plan_lpt(1, 8, 4, &loads));
    }

    #[test]
    fn trait_label_and_spec() {
        let p = Lpt::new(512);
        assert_eq!(p.label(), "LPT(min=512)");
        assert_eq!(p.spec(), "lpt:min=512");
        assert_eq!(Lpt::default().min_tokens, 1024);
    }
}
