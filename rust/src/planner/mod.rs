//! Routing planners: standard EP (paper Alg. 1), LLEP's least-loaded
//! assignment (Alg. 2 + 3), and the EPLB redundancy baseline.
//!
//! A [`RoutePlan`] says, for every expert, which device computes which
//! contiguous segment of that expert's globally-ordered tokens, plus the
//! weight transfers needed to make that possible. Plans are *data*: the
//! execution engine ([`crate::exec`]) interprets them, the validators
//! ([`validate`]) check their invariants, and the cost models price them.

pub mod eplb;
pub mod placement;
pub mod lla;
pub mod validate;

mod ep;

pub use ep::plan_ep;
pub use eplb::plan_eplb;
pub use placement::Placement;
pub use lla::plan_llep;

use crate::config::LlepConfig;
use crate::routing::imbalance_ratio;
use crate::topology::Topology;

/// A contiguous slice `[start, end)` of one expert's global token order,
/// assigned to `device`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    pub device: usize,
    pub start: u64,
    pub end: u64,
    /// True when this segment was force-assigned over capacity (LLAS
    /// fallback) or kept local under the min-GEMM exception.
    pub forced: bool,
}

impl Segment {
    pub fn len(&self) -> u64 {
        self.end - self.start
    }
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// A weight transfer: expert `expert`'s weights move `from -> to` for this
/// step (paper: the P2P transfer preceding foreign-expert GEMMs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WeightTransfer {
    pub expert: usize,
    pub from: usize,
    pub to: usize,
}

/// A complete routing plan for one step.
#[derive(Clone, Debug, PartialEq)]
pub struct RoutePlan {
    pub num_experts: usize,
    pub devices: usize,
    /// Per expert: ordered, disjoint segments covering `[0, l_e)`.
    pub assignments: Vec<Vec<Segment>>,
    pub transfers: Vec<WeightTransfer>,
    /// True when the lambda guard reverted to standard EP.
    pub fallback_ep: bool,
}

impl RoutePlan {
    /// Total tokens assigned to each device.
    pub fn device_loads(&self) -> Vec<u64> {
        let mut loads = vec![0u64; self.devices];
        for segs in &self.assignments {
            for s in segs {
                loads[s.device] += s.len();
            }
        }
        loads
    }

    /// (expert, segment) pairs computed on `device`, in expert order.
    pub fn work_on(&self, device: usize) -> Vec<(usize, Segment)> {
        let mut out = Vec::new();
        for (e, segs) in self.assignments.iter().enumerate() {
            for s in segs {
                if s.device == device && !s.is_empty() {
                    out.push((e, *s));
                }
            }
        }
        out
    }

    /// Experts whose weights must be present on `device` to execute this
    /// plan (native residents are not listed — only imports).
    pub fn imports_to(&self, device: usize) -> Vec<usize> {
        self.transfers.iter().filter(|t| t.to == device).map(|t| t.expert).collect()
    }

    /// Number of distinct GEMM calls the plan implies (one per non-empty
    /// (expert, device) pair).
    pub fn gemm_calls(&self) -> usize {
        self.assignments.iter().map(|segs| segs.iter().filter(|s| !s.is_empty()).count()).sum()
    }

    /// True when the plan is exactly "every expert entirely on its native
    /// device" (standard EP shape).
    pub fn is_pure_ep(&self) -> bool {
        let m = self.num_experts / self.devices;
        self.transfers.is_empty()
            && self.assignments.iter().enumerate().all(|(e, segs)| {
                segs.len() <= 1 && segs.iter().all(|s| s.device == e / m)
            })
    }
}

/// Which planner to run.
#[derive(Clone, Debug, PartialEq)]
pub enum PlannerKind {
    /// Paper Alg. 1: every expert computes on its native device.
    StandardEp,
    /// Paper Alg. 2-4 with the given hyperparameters.
    Llep(LlepConfig),
    /// DeepSeek-V3-style EP load balancer: up to `replicas` redundant
    /// expert copies, placed from (possibly stale) load statistics.
    Eplb { replicas: usize },
    /// Chained gradient-checkpointing baseline (paper §3.1): standard EP
    /// routing, but each device processes at most `chunk_tokens` of an
    /// expert per GEMM, bounding activation memory at the cost of more
    /// kernel launches. "Remains inefficient and is still constrained by
    /// a hard memory ceiling" — quantified by the ablation bench.
    ChunkedEp { chunk_tokens: usize },
}

impl PlannerKind {
    /// LLEP with the paper's default hyperparameters.
    pub fn llep_default() -> PlannerKind {
        PlannerKind::Llep(LlepConfig::default())
    }

    pub fn label(&self) -> String {
        match self {
            PlannerKind::StandardEp => "EP".into(),
            PlannerKind::Llep(c) => {
                format!("LLEP(a={},m={},l={})", c.alpha, c.min_gemm_tokens, c.lambda)
            }
            PlannerKind::Eplb { replicas } => format!("EPLB(r={replicas})"),
            PlannerKind::ChunkedEp { chunk_tokens } => format!("ChunkedEP(c={chunk_tokens})"),
        }
    }

    /// Produce a plan for per-expert loads `loads`. `topo` enables the
    /// intra-node spill preference; EPLB may be given stale loads via
    /// [`PlannerKind::plan_with_stats`].
    pub fn plan(&self, devices: usize, loads: &[u64], topo: Option<&Topology>) -> RoutePlan {
        self.plan_with_stats(devices, loads, loads, topo)
    }

    /// Like [`plan`](Self::plan) but the placement statistics (`stats`)
    /// may differ from the loads actually executed (`loads`) — models
    /// EPLB's time-delayed statistics.
    pub fn plan_with_stats(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
    ) -> RoutePlan {
        match self {
            PlannerKind::StandardEp => plan_ep(loads.len(), devices, loads),
            PlannerKind::Llep(cfg) => {
                let ratio = imbalance_ratio(loads);
                if ratio < cfg.lambda {
                    // Alg. 4 guard: balanced enough — standard EP.
                    let mut p = plan_ep(loads.len(), devices, loads);
                    p.fallback_ep = true;
                    p
                } else {
                    plan_llep(cfg, loads.len(), devices, loads, topo)
                }
            }
            PlannerKind::Eplb { replicas } => {
                plan_eplb(*replicas, loads.len(), devices, loads, stats)
            }
            // Chunking is an execution policy, not a routing change: the
            // plan is standard EP; the engine's pricing splits each
            // device's GEMMs into `chunk_tokens` pieces.
            PlannerKind::ChunkedEp { .. } => plan_ep(loads.len(), devices, loads),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(device: usize, start: u64, end: u64) -> Segment {
        Segment { device, start, end, forced: false }
    }

    #[test]
    fn device_loads_sum_segments() {
        let plan = RoutePlan {
            num_experts: 2,
            devices: 2,
            assignments: vec![vec![seg(0, 0, 10), seg(1, 10, 30)], vec![seg(1, 0, 5)]],
            transfers: vec![WeightTransfer { expert: 0, from: 0, to: 1 }],
            fallback_ep: false,
        };
        assert_eq!(plan.device_loads(), vec![10, 25]);
        assert_eq!(plan.gemm_calls(), 3);
        assert_eq!(plan.work_on(1), vec![(0, seg(1, 10, 30)), (1, seg(1, 0, 5))]);
        assert_eq!(plan.imports_to(1), vec![0]);
        assert!(!plan.is_pure_ep());
    }

    #[test]
    fn planner_labels() {
        assert_eq!(PlannerKind::StandardEp.label(), "EP");
        assert!(PlannerKind::llep_default().label().starts_with("LLEP"));
        assert_eq!(PlannerKind::Eplb { replicas: 4 }.label(), "EPLB(r=4)");
    }

    #[test]
    fn lambda_guard_falls_back_to_ep() {
        // perfectly balanced loads, lambda = 1.3 -> ratio 1.0 < 1.3
        let kind = PlannerKind::llep_default();
        let plan = kind.plan(2, &[100, 100, 100, 100], None);
        assert!(plan.fallback_ep);
        assert!(plan.is_pure_ep());
        assert!(plan.transfers.is_empty());
    }

    #[test]
    fn imbalanced_does_not_fall_back() {
        let kind = PlannerKind::llep_default();
        let plan = kind.plan(2, &[1000, 0, 0, 0], None);
        assert!(!plan.fallback_ep);
    }
}
