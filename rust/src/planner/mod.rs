//! Routing planners behind one open, object-safe [`Planner`] trait:
//! standard EP (paper Alg. 1), LLEP's least-loaded assignment
//! (Alg. 2 + 3 + the Alg. 4 lambda guard), the EPLB redundancy baseline,
//! the chunked-EP gradient-checkpointing baseline, a greedy LPT
//! whole-expert rebalancer, and the [`CachedPlanner`] decorator that
//! reuses plans across steps when the load signature barely drifts.
//!
//! A [`RoutePlan`] says, for every expert, which device computes which
//! contiguous segment of that expert's globally-ordered tokens, plus the
//! weight transfers needed to make that possible. Plans are *data*: the
//! execution engine ([`crate::exec`]) interprets them, the validators
//! ([`validate`]) check their invariants, and the cost models price them.
//!
//! ## Adding a planner
//!
//! New planners are one new file: implement [`Planner`] (a pure
//! `plan_with_stats` plus a `label`/`spec` pair), then add one
//! [`registry`] entry so `--planner <spec>` strings like
//! `llep:alpha=1.0,m=64` can construct it. Execution-policy knobs
//! (chunked pricing, amortized weight transfers, stale-statistics
//! placement) are trait methods with defaults — the engine never matches
//! on a closed enum. [`PlannerKind`] survives only as a thin constructor
//! layer for backward compatibility; everything engine-side dispatches
//! through `&dyn Planner`.
//!
//! Planning is on every step's critical path, so the in-tree planners
//! draw all working state and the returned plan's buffers from a
//! reusable [`scratch::PlanScratch`] arena (zero heap allocations in
//! steady state once finished plans are [recycled](recycle_plan)), and
//! every plan stores its transfers in canonical `(to, from, expert)`
//! order at construction so pricing never re-sorts
//! ([`RoutePlan::transfers_canonical`]).

pub mod cache;
pub mod eplb;
pub mod lla;
pub mod lpt;
pub mod placement;
pub mod registry;
pub mod scratch;
pub mod validate;

mod ep;

pub use cache::{
    load_signature_into, pool_signature_into, retarget_plan, CacheOutcome, CacheStats,
    CachedPlanner,
};
pub use ep::{plan_ep, plan_ep_scratch, ChunkedEp, StandardEp};
pub use eplb::{plan_eplb, Eplb};
pub use lla::{plan_llep, plan_llep_pool, plan_llep_scratch, Llep};
pub use lpt::{plan_lpt, plan_lpt_pool, plan_lpt_scratch, Lpt};
pub use placement::Placement;
pub use registry::{
    parse_planner, ParamSpec, Params, PlannerEntry, Registry, CACHED_PARAMS, PLACED_PARAMS,
};
pub use scratch::{recycle_plan, with_thread_scratch, PlanScratch};

use crate::chaos::PoolState;
use crate::config::LlepConfig;
use crate::placement::PlacementStats;
use crate::topology::Topology;

/// A contiguous slice `[start, end)` of one expert's global token order,
/// assigned to `device`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    pub device: usize,
    pub start: u64,
    pub end: u64,
    /// True when this segment was force-assigned over capacity (LLAS
    /// fallback) or kept local under the min-GEMM exception.
    pub forced: bool,
}

impl Segment {
    pub fn len(&self) -> u64 {
        self.end - self.start
    }
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// A weight transfer: expert `expert`'s weights move `from -> to` for this
/// step (paper: the P2P transfer preceding foreign-expert GEMMs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WeightTransfer {
    pub expert: usize,
    pub from: usize,
    pub to: usize,
}

/// A complete routing plan for one step.
#[derive(Clone, Debug, PartialEq)]
pub struct RoutePlan {
    pub num_experts: usize,
    pub devices: usize,
    /// Per expert: ordered, disjoint segments covering `[0, l_e)`.
    pub assignments: Vec<Vec<Segment>>,
    pub transfers: Vec<WeightTransfer>,
    /// Persistent re-layout moves decided by the placement layer
    /// ([`crate::placement`]) for *this* step: unlike `transfers` (spill
    /// copies re-bought every step), a migration permanently changes
    /// which device owns an expert's weights. Pricing charges them into
    /// step latency unconditionally — even for planners whose spill
    /// transfers are amortized away (EPLB) — in canonical
    /// `(to, from, expert)` order. Empty for every non-placed planner.
    pub migrations: Vec<WeightTransfer>,
    /// True when the lambda guard reverted to standard EP.
    pub fallback_ep: bool,
}

impl RoutePlan {
    /// Total tokens assigned to each device.
    pub fn device_loads(&self) -> Vec<u64> {
        let mut loads = vec![0u64; self.devices];
        for segs in &self.assignments {
            for s in segs {
                loads[s.device] += s.len();
            }
        }
        loads
    }

    /// (expert, segment) pairs computed on `device`, in expert order.
    pub fn work_on(&self, device: usize) -> Vec<(usize, Segment)> {
        let mut out = Vec::new();
        for (e, segs) in self.assignments.iter().enumerate() {
            for s in segs {
                if s.device == device && !s.is_empty() {
                    out.push((e, *s));
                }
            }
        }
        out
    }

    /// Experts whose weights must be present on `device` to execute this
    /// plan (native residents are not listed — only imports).
    pub fn imports_to(&self, device: usize) -> Vec<usize> {
        self.transfers.iter().filter(|t| t.to == device).map(|t| t.expert).collect()
    }

    /// Number of imported experts on `device` — the allocation-free
    /// counterpart of `imports_to(device).len()` (pricing hot path).
    pub fn imports_count(&self, device: usize) -> usize {
        self.transfers.iter().filter(|t| t.to == device).count()
    }

    /// True when `transfers` is in the canonical `(to, from, expert)`
    /// order every in-tree planner emits at construction. Pricing
    /// accumulates weight-transfer time in this order (float addition is
    /// not associative), so two plans with the same transfer *set* price
    /// bit-identically; plans from out-of-tree planners that skip
    /// [`canonicalize_transfers`](Self::canonicalize_transfers) are
    /// sorted on a cold path instead.
    pub fn transfers_canonical(&self) -> bool {
        self.transfers
            .windows(2)
            .all(|w| (w[0].to, w[0].from, w[0].expert) <= (w[1].to, w[1].from, w[1].expert))
    }

    /// Sort `transfers` into the canonical `(to, from, expert)` order
    /// (in place, allocation-free).
    pub fn canonicalize_transfers(&mut self) {
        self.transfers.sort_unstable_by_key(|t| (t.to, t.from, t.expert));
    }

    /// Number of distinct GEMM calls the plan implies (one per non-empty
    /// (expert, device) pair).
    pub fn gemm_calls(&self) -> usize {
        self.assignments.iter().map(|segs| segs.iter().filter(|s| !s.is_empty()).count()).sum()
    }

    /// True when the plan is exactly "every expert entirely on its native
    /// device" (standard EP shape).
    pub fn is_pure_ep(&self) -> bool {
        let m = self.num_experts / self.devices;
        self.transfers.is_empty()
            && self.assignments.iter().enumerate().all(|(e, segs)| {
                segs.len() <= 1 && segs.iter().all(|s| s.device == e / m)
            })
    }
}

/// The capacity model a planner exposes so the plan cache's delta-repair
/// tier ([`CachedPlanner`]) can rebalance a retargeted plan under the
/// same bound a fresh plan would obey: per-device capacity
/// `alpha * total / P` (speed-proportional under a degraded pool) and
/// the min-GEMM chunk floor below which spilling is unprofitable.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RepairParams {
    /// Capacity slack factor (the planner's `alpha`).
    pub alpha: f64,
    /// Minimum profitable spill chunk in tokens (`m` in the paper).
    pub min_gemm_tokens: u64,
}

/// An object-safe routing planner: turns per-expert loads into a
/// [`RoutePlan`]. Everything engine-side dispatches through
/// `&dyn Planner`; implementations are registered in [`registry`] so CLI
/// spec strings can construct them.
pub trait Planner: Send + Sync {
    /// Produce a plan for the loads actually executed (`loads`), placing
    /// from possibly different statistics (`stats`) — models EPLB's
    /// time-delayed statistics. Planners that do not use statistics
    /// ignore `stats`.
    fn plan_with_stats(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
    ) -> RoutePlan;

    /// Human-readable name with hyperparameters (for reports).
    fn label(&self) -> String;

    /// Canonical `--planner` spec string; [`registry::parse_planner`] on
    /// this string reconstructs an equivalent planner (round-trip).
    fn spec(&self) -> String;

    /// Produce a plan for per-expert loads `loads`. `topo` enables the
    /// intra-node spill preference.
    fn plan(&self, devices: usize, loads: &[u64], topo: Option<&Topology>) -> RoutePlan {
        self.plan_with_stats(devices, loads, loads, topo)
    }

    /// Like [`plan_with_stats`](Planner::plan_with_stats) but with a
    /// per-device health/speed view (the chaos layer). The engine passes
    /// `Some` only when the pool is degraded. The default ignores it —
    /// static planners *cannot* adapt, which is the point the chaos
    /// evaluation axis measures. Pool-aware planners (LLEP, LPT)
    /// override this to minimize *normalized* completion time
    /// (`tokens / speed`) and to never schedule a dead device.
    fn plan_with_pool(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
        pool: Option<&PoolState>,
    ) -> RoutePlan {
        let _ = pool;
        self.plan_with_stats(devices, loads, stats, topo)
    }

    /// Execution policy: split each device's per-expert GEMMs into pieces
    /// of at most this many tokens (the chunked-EP baseline). `None` =
    /// unchunked.
    fn chunk_tokens(&self) -> Option<u64> {
        None
    }

    /// Whether weight transfers are charged to step latency. EPLB's
    /// replica movement is time-amortized (placements change rarely), so
    /// it returns false; per-step planners pay per step.
    fn charges_weight_transfers(&self) -> bool {
        true
    }

    /// Whether multi-batch runners should feed this planner the previous
    /// batch's loads as placement statistics (EPLB's stale pipeline).
    fn wants_stale_stats(&self) -> bool {
        false
    }

    /// False for stateful planners (the plan cache): the engine must not
    /// warm-run them, because every lookup has to be observed exactly
    /// once.
    fn replay_safe(&self) -> bool {
        true
    }

    /// Outcome of the most recent `plan_with_stats` call made on the
    /// *current thread* (cache decorators only; `None` for pure
    /// planners).
    fn last_cache_outcome(&self) -> Option<CacheOutcome> {
        None
    }

    /// Capacity model for the plan cache's delta-repair tier. `None`
    /// (the default) means the planner has no spill-capacity semantics
    /// to repair against, so [`CachedPlanner`] falls back to a fresh
    /// plan past the retarget threshold.
    fn repair_params(&self) -> Option<RepairParams> {
        None
    }

    /// Monotone counter identifying the expert layout this planner
    /// currently plans against. Stateless planners always plan against
    /// the block-native layout (generation 0); the placement decorator
    /// ([`crate::placement::Placed`]) bumps it on every re-layout so
    /// [`CachedPlanner`] keys entries to the layout they were planned
    /// under and never retargets a plan across layouts.
    fn layout_generation(&self) -> u64 {
        0
    }

    /// Placement activity of the most recent plan call on the *current
    /// thread* (placement decorators only; `None` for planners with a
    /// fixed layout).
    fn last_placement_stats(&self) -> Option<PlacementStats> {
        None
    }

    /// Segments peeled by the most recent repair-tier rebalance on the
    /// *current thread* (cache decorators only). The engine's
    /// [`crate::exec::PlanCostModel`] charges repaired lookups
    /// proportionally to this, so light repairs price near a hit and
    /// heavy ones approach a fresh plan.
    fn last_repair_peeled(&self) -> u64 {
        0
    }
}

/// Which planner to run — retained as a thin constructor layer over the
/// trait implementations ([`StandardEp`], [`Llep`], [`Eplb`],
/// [`ChunkedEp`]) for backward compatibility. New planners do not get a
/// variant here; they go through [`registry`].
#[derive(Clone, Debug, PartialEq)]
pub enum PlannerKind {
    /// Paper Alg. 1: every expert computes on its native device.
    StandardEp,
    /// Paper Alg. 2-4 with the given hyperparameters.
    Llep(LlepConfig),
    /// DeepSeek-V3-style EP load balancer: up to `replicas` redundant
    /// expert copies, placed from (possibly stale) load statistics.
    Eplb { replicas: usize },
    /// Chained gradient-checkpointing baseline (paper §3.1): standard EP
    /// routing, but each device processes at most `chunk_tokens` of an
    /// expert per GEMM, bounding activation memory at the cost of more
    /// kernel launches. "Remains inefficient and is still constrained by
    /// a hard memory ceiling" — quantified by the ablation bench.
    ChunkedEp { chunk_tokens: usize },
}

impl PlannerKind {
    /// LLEP with the paper's default hyperparameters.
    pub fn llep_default() -> PlannerKind {
        PlannerKind::Llep(LlepConfig::default())
    }

    /// Materialize the concrete trait-based planner this variant denotes.
    pub fn boxed(&self) -> Box<dyn Planner> {
        match self {
            PlannerKind::StandardEp => Box::new(StandardEp),
            PlannerKind::Llep(cfg) => Box::new(Llep::new(*cfg)),
            PlannerKind::Eplb { replicas } => Box::new(Eplb::new(*replicas)),
            PlannerKind::ChunkedEp { chunk_tokens } => Box::new(ChunkedEp::new(*chunk_tokens)),
        }
    }

    pub fn label(&self) -> String {
        Planner::label(self)
    }

    /// Produce a plan for per-expert loads `loads`. `topo` enables the
    /// intra-node spill preference; EPLB may be given stale loads via
    /// [`PlannerKind::plan_with_stats`].
    pub fn plan(&self, devices: usize, loads: &[u64], topo: Option<&Topology>) -> RoutePlan {
        Planner::plan(self, devices, loads, topo)
    }

    /// Like [`plan`](Self::plan) but the placement statistics (`stats`)
    /// may differ from the loads actually executed (`loads`) — models
    /// EPLB's time-delayed statistics.
    pub fn plan_with_stats(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
    ) -> RoutePlan {
        Planner::plan_with_stats(self, devices, loads, stats, topo)
    }
}

// Dispatch by match to stack-constructed concrete planners — the hot
// engine paths call these per layer, so no per-call boxing.
impl Planner for PlannerKind {
    fn plan_with_stats(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
    ) -> RoutePlan {
        match self {
            PlannerKind::StandardEp => StandardEp.plan_with_stats(devices, loads, stats, topo),
            PlannerKind::Llep(cfg) => Llep::new(*cfg).plan_with_stats(devices, loads, stats, topo),
            PlannerKind::Eplb { replicas } => {
                Eplb::new(*replicas).plan_with_stats(devices, loads, stats, topo)
            }
            PlannerKind::ChunkedEp { chunk_tokens } => {
                ChunkedEp::new(*chunk_tokens).plan_with_stats(devices, loads, stats, topo)
            }
        }
    }

    fn label(&self) -> String {
        match self {
            PlannerKind::StandardEp => StandardEp.label(),
            PlannerKind::Llep(cfg) => Llep::new(*cfg).label(),
            PlannerKind::Eplb { replicas } => Eplb::new(*replicas).label(),
            PlannerKind::ChunkedEp { chunk_tokens } => ChunkedEp::new(*chunk_tokens).label(),
        }
    }

    fn spec(&self) -> String {
        match self {
            PlannerKind::StandardEp => StandardEp.spec(),
            PlannerKind::Llep(cfg) => Llep::new(*cfg).spec(),
            PlannerKind::Eplb { replicas } => Eplb::new(*replicas).spec(),
            PlannerKind::ChunkedEp { chunk_tokens } => ChunkedEp::new(*chunk_tokens).spec(),
        }
    }

    fn plan_with_pool(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
        pool: Option<&PoolState>,
    ) -> RoutePlan {
        match self {
            // Speed-aware: forward the pool to the concrete planner.
            PlannerKind::Llep(cfg) => {
                Llep::new(*cfg).plan_with_pool(devices, loads, stats, topo, pool)
            }
            // Static placements by construction — the pool view cannot
            // change what they produce.
            _ => self.plan_with_stats(devices, loads, stats, topo),
        }
    }

    fn chunk_tokens(&self) -> Option<u64> {
        match self {
            PlannerKind::ChunkedEp { chunk_tokens } => Some((*chunk_tokens).max(1) as u64),
            _ => None,
        }
    }

    fn repair_params(&self) -> Option<RepairParams> {
        match self {
            PlannerKind::Llep(cfg) => Llep::new(*cfg).repair_params(),
            _ => None,
        }
    }

    fn charges_weight_transfers(&self) -> bool {
        !matches!(self, PlannerKind::Eplb { .. })
    }

    fn wants_stale_stats(&self) -> bool {
        matches!(self, PlannerKind::Eplb { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(device: usize, start: u64, end: u64) -> Segment {
        Segment { device, start, end, forced: false }
    }

    #[test]
    fn device_loads_sum_segments() {
        let plan = RoutePlan {
            num_experts: 2,
            devices: 2,
            assignments: vec![vec![seg(0, 0, 10), seg(1, 10, 30)], vec![seg(1, 0, 5)]],
            transfers: vec![WeightTransfer { expert: 0, from: 0, to: 1 }],
            migrations: Vec::new(),
            fallback_ep: false,
        };
        assert_eq!(plan.device_loads(), vec![10, 25]);
        assert_eq!(plan.gemm_calls(), 3);
        assert_eq!(plan.work_on(1), vec![(0, seg(1, 10, 30)), (1, seg(1, 0, 5))]);
        assert_eq!(plan.imports_to(1), vec![0]);
        assert!(!plan.is_pure_ep());
    }

    #[test]
    fn planner_labels() {
        assert_eq!(PlannerKind::StandardEp.label(), "EP");
        assert!(PlannerKind::llep_default().label().starts_with("LLEP"));
        assert_eq!(PlannerKind::Eplb { replicas: 4 }.label(), "EPLB(r=4)");
    }

    #[test]
    fn lambda_guard_falls_back_to_ep() {
        // perfectly balanced loads, lambda = 1.3 -> ratio 1.0 < 1.3
        let kind = PlannerKind::llep_default();
        let plan = kind.plan(2, &[100, 100, 100, 100], None);
        assert!(plan.fallback_ep);
        assert!(plan.is_pure_ep());
        assert!(plan.transfers.is_empty());
    }

    #[test]
    fn imbalanced_does_not_fall_back() {
        let kind = PlannerKind::llep_default();
        let plan = kind.plan(2, &[1000, 0, 0, 0], None);
        assert!(!plan.fallback_ep);
    }

    #[test]
    fn kind_and_trait_dispatch_agree() {
        // The enum is a thin constructor layer: going through the trait
        // object must produce exactly the plan the inherent API produces.
        let loads = [900u64, 10, 40, 50, 0, 0, 0, 0];
        for kind in [
            PlannerKind::StandardEp,
            PlannerKind::llep_default(),
            PlannerKind::Eplb { replicas: 4 },
            PlannerKind::ChunkedEp { chunk_tokens: 16 },
        ] {
            let via_kind = kind.plan(4, &loads, None);
            let via_trait = kind.boxed().plan(4, &loads, None);
            assert_eq!(via_kind, via_trait, "{}", kind.label());
        }
    }

    #[test]
    fn execution_policy_is_trait_driven() {
        assert_eq!(PlannerKind::ChunkedEp { chunk_tokens: 64 }.boxed().chunk_tokens(), Some(64));
        assert_eq!(PlannerKind::StandardEp.boxed().chunk_tokens(), None);
        assert!(!PlannerKind::Eplb { replicas: 2 }.boxed().charges_weight_transfers());
        assert!(PlannerKind::Eplb { replicas: 2 }.boxed().wants_stale_stats());
        assert!(PlannerKind::llep_default().boxed().charges_weight_transfers());
        assert!(PlannerKind::llep_default().boxed().replay_safe());
    }
}
