//! Plan invariant checking.
//!
//! Every planner output must satisfy the same structural contract; the
//! property tests drive random loads through the planners and call
//! [`validate_plan`] on each result.

use super::{RoutePlan, WeightTransfer};

/// Check all structural invariants of `plan` against the loads it was
/// built for:
///
/// 1. per-expert segments are ordered, non-overlapping, and exactly cover
///    `[0, l_e)` (exactness: every token computed once);
/// 2. segment devices are in range;
/// 3. a weight transfer exists iff a foreign device computes a non-empty
///    segment of that expert, and never targets the native device;
/// 4. no duplicate transfers.
pub fn validate_plan(plan: &RoutePlan, loads: &[u64]) -> Result<(), String> {
    if loads.len() != plan.num_experts {
        return Err("loads/plan expert count mismatch".into());
    }
    if plan.devices == 0 || plan.num_experts % plan.devices != 0 {
        return Err("invalid device count".into());
    }
    let m = plan.num_experts / plan.devices;

    // 1 & 2: coverage per expert.
    for (e, segs) in plan.assignments.iter().enumerate() {
        let mut cursor = 0u64;
        for s in segs {
            if s.device >= plan.devices {
                return Err(format!("expert {e}: device {} out of range", s.device));
            }
            if s.start != cursor {
                return Err(format!(
                    "expert {e}: segment starts at {} but cursor is {cursor} (gap/overlap)",
                    s.start
                ));
            }
            if s.end <= s.start {
                return Err(format!("expert {e}: empty/negative segment {s:?}"));
            }
            cursor = s.end;
        }
        if cursor != loads[e] {
            return Err(format!("expert {e}: covers {cursor} of {} tokens", loads[e]));
        }
    }

    // 3: transfers <-> foreign segments.
    let mut needed: Vec<WeightTransfer> = Vec::new();
    for (e, segs) in plan.assignments.iter().enumerate() {
        let native = e / m;
        let mut devices_seen = Vec::new();
        for s in segs {
            if s.device != native && !devices_seen.contains(&s.device) {
                devices_seen.push(s.device);
                needed.push(WeightTransfer { expert: e, from: native, to: s.device });
            }
        }
    }
    let mut have = plan.transfers.clone();
    have.sort_by_key(|t| (t.expert, t.from, t.to));
    let mut want = needed;
    want.sort_by_key(|t| (t.expert, t.from, t.to));
    // 4: duplicates would differ in length after dedup.
    let mut have_dedup = have.clone();
    have_dedup.dedup();
    if have_dedup.len() != have.len() {
        return Err("duplicate weight transfers".into());
    }
    if have != want {
        return Err(format!(
            "transfer mismatch:\n  plan: {have:?}\n  need: {want:?}"
        ));
    }
    for t in &have {
        if t.from != t.expert / m {
            return Err(format!("transfer {t:?} does not originate from native device"));
        }
        if t.to == t.from {
            return Err(format!("self transfer {t:?}"));
        }
    }
    Ok(())
}

/// Additionally check the LLEP capacity contract: device loads are within
/// `ceil(m_alpha)` except where the plan marks forced segments.
pub fn validate_capacity(plan: &RoutePlan, loads: &[u64], alpha: f64) -> Result<(), String> {
    if plan.fallback_ep {
        // The lambda guard reverted to standard EP; the LLA capacity
        // contract does not apply (paper Alg. 4 guard).
        return Ok(());
    }
    let total: u64 = loads.iter().sum();
    if total == 0 {
        return Ok(());
    }
    let m_alpha = alpha * total as f64 / plan.devices as f64;
    let device_loads = plan.device_loads();
    for (d, &l) in device_loads.iter().enumerate() {
        if l as f64 > m_alpha {
            let has_forced =
                plan.assignments.iter().flatten().any(|s| s.device == d && s.forced);
            if !has_forced {
                return Err(format!(
                    "device {d} holds {l} > m_alpha {m_alpha:.1} without forced segments"
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{plan_ep, Segment};

    #[test]
    fn ep_plan_validates() {
        let loads = vec![5, 0, 9, 2];
        let plan = plan_ep(4, 2, &loads);
        validate_plan(&plan, &loads).unwrap();
    }

    #[test]
    fn detects_gap() {
        let loads = vec![10u64];
        let mut plan = plan_ep(1, 1, &loads);
        plan.assignments[0] = vec![Segment { device: 0, start: 0, end: 4, forced: false }];
        assert!(validate_plan(&plan, &loads).unwrap_err().contains("covers 4"));
    }

    #[test]
    fn detects_overlap() {
        let loads = vec![10u64];
        let mut plan = plan_ep(1, 1, &loads);
        plan.assignments[0] = vec![
            Segment { device: 0, start: 0, end: 6, forced: false },
            Segment { device: 0, start: 4, end: 10, forced: false },
        ];
        assert!(validate_plan(&plan, &loads).is_err());
    }

    #[test]
    fn detects_missing_transfer() {
        let loads = vec![10u64, 0];
        let mut plan = plan_ep(2, 2, &loads);
        // move expert 0 to device 1 without a transfer
        plan.assignments[0] = vec![Segment { device: 1, start: 0, end: 10, forced: false }];
        assert!(validate_plan(&plan, &loads).unwrap_err().contains("transfer mismatch"));
    }

    #[test]
    fn detects_spurious_transfer() {
        let loads = vec![10u64, 0];
        let mut plan = plan_ep(2, 2, &loads);
        plan.transfers.push(WeightTransfer { expert: 0, from: 0, to: 1 });
        assert!(validate_plan(&plan, &loads).is_err());
    }

    #[test]
    fn capacity_flags_unforced_overflow() {
        let loads = vec![100u64, 0, 0, 0];
        let plan = plan_ep(4, 4, &loads); // EP dumps all 100 on device 0
        // alpha=1 -> m_alpha=25; EP has no forced segments
        assert!(validate_capacity(&plan, &loads, 1.0).is_err());
        // huge alpha passes
        validate_capacity(&plan, &loads, 4.0).unwrap();
    }
}
