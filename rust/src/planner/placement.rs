//! Static expert placement (locality-aware placement baseline).
//!
//! The paper's related work (Hu et al. 2025, "communication-efficient MoE
//! fine-tuning with locality-aware expert placement") rebalances by
//! *statically re-assigning experts to devices* from historical load
//! statistics, instead of re-routing tokens per step. This module
//! implements that baseline: an LPT (longest-processing-time) greedy
//! packer that groups experts into `P` equal-count groups with minimal
//! maximum expected load, exposed as an expert **relabeling** so every
//! planner (EP/LLEP/EPLB) can run under a custom placement without
//! changing the block-layout assumption (`native(e) = e / M`).
//!
//! Like EPLB, a static placement is only as good as its statistics: it
//! neutralizes a *persistent* hotspot but not per-batch drift — the
//! ablation bench quantifies both regimes against LLEP.

use super::RoutePlan;
use crate::routing::LoadMatrix;

/// A placement: `slot_of[e]` gives expert `e`'s position in the relabeled
/// expert space (so its device is `slot_of[e] / M`).
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    pub slot_of: Vec<usize>,
    pub devices: usize,
}

impl Placement {
    /// Identity placement (the paper's default block layout).
    pub fn identity(num_experts: usize, devices: usize) -> Placement {
        Placement { slot_of: (0..num_experts).collect(), devices }
    }

    /// LPT placement from expected per-expert loads: sort experts by
    /// decreasing load; assign each to the currently-lightest device that
    /// still has a free slot (each device hosts exactly `M = N/P`).
    pub fn balanced_lpt(stats: &[u64], devices: usize) -> Placement {
        let n = stats.len();
        assert!(devices > 0 && n % devices == 0, "N must divide P");
        let m = n / devices;
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_unstable_by_key(|&e| std::cmp::Reverse(stats[e]));

        let mut dev_load = vec![0u64; devices];
        let mut dev_fill = vec![0usize; devices];
        let mut slot_of = vec![0usize; n];
        for &e in &order {
            // lightest device with room
            let d = (0..devices)
                .filter(|&d| dev_fill[d] < m)
                .min_by_key(|&d| (dev_load[d], d))
                .expect("some device always has room");
            slot_of[e] = d * m + dev_fill[d];
            dev_fill[d] += 1;
            dev_load[d] += stats[e];
        }
        Placement { slot_of, devices }
    }

    pub fn num_experts(&self) -> usize {
        self.slot_of.len()
    }

    /// Device hosting expert `e` under this placement.
    pub fn device_of(&self, e: usize) -> usize {
        self.slot_of[e] / (self.num_experts() / self.devices)
    }

    /// Relabel per-expert loads into placement space.
    pub fn permute_loads(&self, loads: &[u64]) -> Vec<u64> {
        let mut out = vec![0u64; loads.len()];
        for (e, &slot) in self.slot_of.iter().enumerate() {
            out[slot] = loads[e];
        }
        out
    }

    /// Relabel a load matrix into placement space.
    pub fn permute_matrix(&self, lm: &LoadMatrix) -> LoadMatrix {
        let counts = lm
            .counts
            .iter()
            .map(|row| {
                let mut out = vec![0u64; row.len()];
                for (e, &slot) in self.slot_of.iter().enumerate() {
                    out[slot] = row[e];
                }
                out
            })
            .collect();
        LoadMatrix { counts, top_k: lm.top_k }
    }

    /// Map a plan computed in placement space back to original expert ids.
    pub fn unpermute_plan(&self, plan: RoutePlan) -> RoutePlan {
        let mut assignments = vec![Vec::new(); plan.num_experts];
        for (e, &slot) in self.slot_of.iter().enumerate() {
            assignments[e] = plan.assignments[slot].clone();
        }
        let mut inverse = vec![0usize; self.slot_of.len()];
        for (e, &slot) in self.slot_of.iter().enumerate() {
            inverse[slot] = e;
        }
        let transfers = plan
            .transfers
            .iter()
            .map(|t| super::WeightTransfer { expert: inverse[t.expert], ..*t })
            .collect();
        RoutePlan { assignments, transfers, ..plan }
    }

    /// Max/mean native-device load ratio under this placement — the
    /// quantity LPT minimizes.
    pub fn native_imbalance(&self, loads: &[u64]) -> f64 {
        let m = self.num_experts() / self.devices;
        let permuted = self.permute_loads(loads);
        let dev: Vec<f64> = (0..self.devices)
            .map(|d| permuted[d * m..(d + 1) * m].iter().sum::<u64>() as f64)
            .collect();
        crate::util::stats::max_over_mean(&dev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{plan_ep, validate::validate_plan};

    #[test]
    fn identity_is_noop() {
        let p = Placement::identity(8, 4);
        assert_eq!(p.device_of(5), 2);
        let loads = vec![5, 4, 3, 2, 1, 0, 7, 6];
        assert_eq!(p.permute_loads(&loads), loads);
    }

    #[test]
    fn lpt_balances_persistent_hotspot() {
        // Two huge experts both native to device 0 under block layout.
        let stats = vec![100u64, 100, 1, 1, 1, 1, 1, 1];
        let block = Placement::identity(8, 4);
        let lpt = Placement::balanced_lpt(&stats, 4);
        assert!(block.native_imbalance(&stats) > 3.0);
        // two 100-load experts on 4 devices bound the ratio near 2 — LPT
        // reaches that bound (vs ~3.9 under block layout)
        assert!(lpt.native_imbalance(&stats) < 2.0, "{}", lpt.native_imbalance(&stats));
        // LPT must separate the two hot experts
        assert_ne!(lpt.device_of(0), lpt.device_of(1));
    }

    #[test]
    fn lpt_is_a_valid_permutation_with_equal_fill() {
        let stats = vec![9u64, 3, 7, 1, 5, 5, 2, 8];
        let p = Placement::balanced_lpt(&stats, 4);
        let mut slots = p.slot_of.clone();
        slots.sort_unstable();
        assert_eq!(slots, (0..8).collect::<Vec<_>>());
        // each device hosts exactly M = 2
        for d in 0..4 {
            let count = (0..8).filter(|&e| p.device_of(e) == d).count();
            assert_eq!(count, 2);
        }
    }

    #[test]
    fn permute_roundtrip_plan_validates() {
        let stats = vec![50u64, 40, 30, 20, 10, 5, 2, 1];
        let p = Placement::balanced_lpt(&stats, 4);
        let loads = vec![7u64, 13, 2, 9, 4, 4, 8, 3];
        let permuted = p.permute_loads(&loads);
        let plan = plan_ep(8, 4, &permuted);
        validate_plan(&plan, &permuted).unwrap();
        let back = p.unpermute_plan(plan);
        // every expert's coverage is preserved under relabeling
        for (e, segs) in back.assignments.iter().enumerate() {
            let covered: u64 = segs.iter().map(|s| s.len()).sum();
            assert_eq!(covered, loads[e], "expert {e}");
            for s in segs {
                assert_eq!(s.device, p.device_of(e));
            }
        }
    }

    #[test]
    fn permute_matrix_preserves_totals() {
        let p = Placement::balanced_lpt(&[10, 1, 1, 10], 2);
        let lm = LoadMatrix { counts: vec![vec![4, 1, 0, 3], vec![6, 0, 1, 7]], top_k: 1 };
        let out = p.permute_matrix(&lm);
        assert_eq!(out.total_load(), lm.total_load());
        assert_eq!(out.tokens_per_device(), lm.tokens_per_device());
    }
}
