//! Cross-step plan reuse: the [`CachedPlanner`] decorator.
//!
//! The paper puts planning on the step's critical path
//! (`T = T_meta + T_plan + …`) and its §4/§5.3 ablations argue that
//! shaving planner latency matters most in the small-batch decode
//! regime. Decode steps also change very little from one step to the
//! next: the batch is the same set of requests minus completions, so the
//! per-expert load *shares* are nearly stationary. `CachedPlanner`
//! exploits that: it keys a small cache on a quantized per-expert load
//! signature and, when the signature drift since the cached plan is below
//! a threshold, reuses that plan instead of replanning.
//!
//! ## Honest reuse
//!
//! A reused plan is *re-materialized* against the true loads
//! ([`retarget_plan`]): each expert keeps the cached placement fractions
//! (largest-remainder split), exactly like EPLB splits actual loads
//! across a stale placement. Pricing therefore always uses the loads
//! actually executed — a stale plan can be worse than a fresh one (the
//! hot expert moved, min-GEMM chunks shrank below profitability) and the
//! report shows it. Hit/miss/forced-replan counters surface in
//! [`StepReport`](crate::exec::StepReport) and every aggregate report
//! above it.
//!
//! The signature is share-based (quantized `l_e / total`), so a decode
//! step that shrinks because requests completed still hits as long as the
//! routing distribution holds. With several MoE layers sharing one cache,
//! each layer's signature claims its own entry (capacity defaults to 64
//! ≥ any preset's layer count); layers with genuinely similar routing may
//! share an entry, which is just more reuse.
//!
//! ## Repair tier
//!
//! Past the retarget threshold the cache used to be all-or-nothing: any
//! larger drift paid a full fresh replan. With a repair ceiling
//! (`repair=` > `drift=`), drift in the middle band takes the **delta
//! repair** path instead: the cached plan is retargeted as usual, then
//! only the devices whose load ended up over the inner planner's
//! capacity threshold get their excess peeled off (stale spill targets
//! first, forced segments never) and re-spilled through the same LLAS
//! least-loaded machinery — seeded with the surviving devices' loads —
//! so the work is O(changed devices · log P), not a full
//! O(E·log E + S·log P) replan. The repaired plan obeys the same
//! capacity bound a fresh plan does (every device ≤ the inner planner's
//! `m_alpha`, forced overflow excepted — property-tested in
//! `tests/plan_reuse.rs`), the entry is re-anchored on the repaired
//! plan so the next drift is measured from it, and `replan_every`
//! bounds repair→repair chains with a periodic forced fresh plan.
//! Repair needs the inner planner's capacity model
//! ([`Planner::repair_params`]); inner planners without one fall back
//! to a fresh plan past the threshold exactly as before. Dead-device
//! pools never reach this tier — they stay forced-fresh.
//!
//! ## Degraded pools
//!
//! A quantized per-device speed fingerprint ([`pool_signature_into`])
//! joins the cache key, so degraded-but-fully-alive pools (stragglers,
//! statically heterogeneous presets) reuse plans amongst steps that see
//! the same pool instead of forcing a fresh plan for the whole degraded
//! window. Pools with a *dead* device stay forced-fresh: a retargeted
//! segment could land on the hole, which no drift threshold can excuse.
//!
//! A fingerprint that differs only *within the quantization band* (same
//! shape, every device within one quantization step) is not a different
//! pool — it is the same degraded pool observed through measurement
//! noise. Such near-matches are eligible for the **repair tier only**:
//! the repair re-derives per-device capacities from the *current* pool
//! speeds, so any placement the speed wobble invalidated is peeled and
//! re-spilled, and the entry is re-anchored on the new fingerprint.
//! Exact-fingerprint entries are always preferred over band matches.
//!
//! ## Placement interplay
//!
//! When the inner planner owns a mutable expert layout (the
//! `placed(...)` decorator), its [`Planner::layout_generation`] joins
//! the cache key: entries installed under one layout never serve (and
//! are never repaired into) steps planned under another — a re-layout
//! atomically invalidates every stale plan. Migration transfers are
//! one-shot events, so they are stripped from installed entries; a
//! reused plan never re-pays a migration that already happened.
//!
//! ## Hot path
//!
//! Lookups go through one mutex (stateful planners plan sequentially,
//! so it is uncontended); signatures, retarget working buffers, and the
//! returned plan shell are all recycled, making the steady-state hit
//! path allocation-free (asserted by the counting-allocator test in
//! `scratch.rs`).

use super::lla::{merge_adjacent, spill};
use super::scratch::{with_thread_scratch, PlanScratch};
use super::{Planner, RepairParams, RoutePlan, Segment, WeightTransfer};
use crate::chaos::PoolState;
use crate::placement::PlacementStats;
use crate::topology::Topology;
use std::cell::RefCell;
use std::cmp::Reverse;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

// The outcome of the most recent lookup is reported back to the engine
// (price_plan) on the same thread that planned, so it lives in a
// thread-local keyed by a unique per-cache id: no shared map to race on
// or to grow without bound as scoped layer-planning threads come and go.
thread_local! {
    static LAST_OUTCOME: RefCell<Vec<(usize, CacheOutcome, u64)>> =
        const { RefCell::new(Vec::new()) };
}

static NEXT_CACHE_ID: AtomicUsize = AtomicUsize::new(0);

/// What one `plan_with_stats` call on a [`CachedPlanner`] did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Signature matched: the cached plan was retargeted and reused.
    Hit,
    /// Signature drift landed between the retarget threshold and the
    /// repair ceiling: the cached plan was retargeted, then only the
    /// overloaded devices' excess was peeled and re-spilled (the delta
    /// repair tier).
    Repaired,
    /// No cached plan within the reuse ceiling: planned fresh.
    Miss,
    /// Signature matched but the `replan_every` policy forced a fresh
    /// plan (periodic refresh against slow drift).
    Forced,
}

/// Hit/repair/miss/forced-replan counters; zero everywhere for uncached
/// planners. Aggregated per step, per model step, and per serving run.
/// By construction `hits + repairs + misses + forced == lookups()`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    /// Middle-tier lookups: retargeted *and* delta-repaired.
    pub repairs: u64,
    pub misses: u64,
    pub forced: u64,
}

impl CacheStats {
    /// Stats with exactly one outcome recorded.
    pub fn of(outcome: CacheOutcome) -> CacheStats {
        let mut s = CacheStats::default();
        s.record(outcome);
        s
    }

    pub fn record(&mut self, outcome: CacheOutcome) {
        match outcome {
            CacheOutcome::Hit => self.hits += 1,
            CacheOutcome::Repaired => self.repairs += 1,
            CacheOutcome::Miss => self.misses += 1,
            CacheOutcome::Forced => self.forced += 1,
        }
    }

    pub fn absorb(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.repairs += other.repairs;
        self.misses += other.misses;
        self.forced += other.forced;
    }

    /// Total lookups observed.
    pub fn lookups(&self) -> u64 {
        self.hits + self.repairs + self.misses + self.forced
    }

    /// Fraction of lookups that reused a plan — retargeted or repaired
    /// (0.0 when no lookups).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            (self.hits + self.repairs) as f64 / self.lookups() as f64
        }
    }
}

/// Quantized per-expert load shares: `sig[e] ≈ quant * l_e / total`.
/// Share-based, so uniformly scaling a batch leaves the signature fixed.
pub fn load_signature(loads: &[u64], quant: u64) -> Vec<u64> {
    let mut out = Vec::new();
    load_signature_into(loads, quant, &mut out);
    out
}

/// [`load_signature`] into a reusable buffer (the lookup hot path).
pub fn load_signature_into(loads: &[u64], quant: u64, out: &mut Vec<u64>) {
    out.clear();
    let total: u64 = loads.iter().sum();
    if total == 0 {
        out.resize(loads.len(), 0);
        return;
    }
    out.extend(loads.iter().map(|&l| (l as u128 * quant as u128 / total as u128) as u64));
}

/// Quantized per-device effective-speed signature of a pool view, into a
/// reusable buffer. Empty = healthy pool (the historical cache key).
/// Any degraded pool (stragglers, heterogeneous presets, link-only
/// degradation) gets a per-device `round(256 * speed)` fingerprint:
/// steps that see the *same* degraded pool share cache entries, so a
/// stable straggler or a statically heterogeneous preset regains plan
/// reuse instead of forcing fresh plans for the whole degraded window.
/// Note a link-only pool fingerprints as `[256; P]`, distinct from the
/// healthy empty key even though speeds are uniform: pool-aware
/// planners bypass the lambda guard whenever the pool is degraded, so
/// their degraded-pool plans are not interchangeable with healthy-pool
/// plans — only steps under the same degradation may share entries.
pub fn pool_signature_into(pool: Option<&PoolState>, out: &mut Vec<u64>) {
    out.clear();
    if let Some(p) = pool {
        if p.is_degraded() {
            out.extend(p.devices.iter().map(|d| (d.effective_speed() * 256.0).round() as u64));
        }
    }
}

/// L1 distance between two signatures in share units (range `0..=2`):
/// the total fraction of routed tokens that moved between experts.
pub fn signature_drift(a: &[u64], b: &[u64], quant: u64) -> f64 {
    let l1: u64 = a.iter().zip(b).map(|(&x, &y)| x.abs_diff(y)).sum();
    l1 as f64 / quant as f64
}

/// Re-materialize `plan` (built for `old_loads`) against `new_loads`:
/// per expert, the cached segment lengths are scaled proportionally
/// (largest-remainder, so coverage is exact) onto the same devices in the
/// same order, and weight transfers are recomputed from the surviving
/// foreign segments. An expert with no cached precedent (`old` load 0)
/// stays native, flagged forced. O(total segments) — this is what a cache
/// hit costs instead of a full replan.
pub fn retarget_plan(plan: &RoutePlan, old_loads: &[u64], new_loads: &[u64]) -> RoutePlan {
    let shell = with_thread_scratch(|s| s.take_plan(plan.num_experts, plan.devices));
    let mut buf = RetargetBuffers::default();
    retarget_plan_into(plan, old_loads, new_loads, shell, &mut buf)
}

/// Reusable working buffers for [`retarget_plan_into`] — the cache keeps
/// one set per planner so steady-state hits allocate nothing.
#[derive(Default)]
struct RetargetBuffers {
    lens: Vec<u64>,
    rems: Vec<(u64, usize)>,
    seen: Vec<bool>,
}

/// [`retarget_plan`] writing into a recycled plan shell (`out` must come
/// from [`PlanScratch::take_plan`](super::PlanScratch) sized for this
/// plan) with caller-owned working buffers — the zero-allocation cache
/// hit path.
fn retarget_plan_into(
    plan: &RoutePlan,
    old_loads: &[u64],
    new_loads: &[u64],
    mut out: RoutePlan,
    buf: &mut RetargetBuffers,
) -> RoutePlan {
    assert_eq!(old_loads.len(), plan.num_experts, "old loads/plan mismatch");
    assert_eq!(new_loads.len(), plan.num_experts, "new loads/plan mismatch");
    debug_assert_eq!(out.num_experts, plan.num_experts);
    debug_assert_eq!(out.devices, plan.devices);
    let m = plan.num_experts / plan.devices;
    out.fallback_ep = plan.fallback_ep;
    buf.seen.clear();
    buf.seen.resize(plan.devices, false);
    for (e, old_segs) in plan.assignments.iter().enumerate() {
        let l_new = new_loads[e];
        let l_old = old_loads[e];
        let native = e / m;
        let segs = &mut out.assignments[e];
        if l_new > 0 {
            if l_old == 0 || old_segs.is_empty() {
                segs.push(Segment { device: native, start: 0, end: l_new, forced: true });
            } else {
                // Largest-remainder proportional split across the cached
                // segments (they cover [0, l_old) exactly).
                buf.lens.clear();
                buf.rems.clear();
                let mut assigned = 0u64;
                for (i, s) in old_segs.iter().enumerate() {
                    let num = s.len() as u128 * l_new as u128;
                    let q = (num / l_old as u128) as u64;
                    buf.lens.push(q);
                    buf.rems.push(((num % l_old as u128) as u64, i));
                    assigned += q;
                }
                let mut left = l_new - assigned; // < old_segs.len()
                buf.rems.sort_unstable_by_key(|&(r, i)| (std::cmp::Reverse(r), i));
                for &(_, i) in buf.rems.iter() {
                    if left == 0 {
                        break;
                    }
                    buf.lens[i] += 1;
                    left -= 1;
                }
                let mut start = 0u64;
                for (s, &len) in old_segs.iter().zip(buf.lens.iter()) {
                    if len == 0 {
                        continue;
                    }
                    let end = start + len;
                    segs.push(Segment { device: s.device, start, end, forced: s.forced });
                    start += len;
                }
            }
        }
        for s in segs.iter() {
            if s.device != native && !buf.seen[s.device] {
                buf.seen[s.device] = true;
                out.transfers.push(WeightTransfer { expert: e, from: native, to: s.device });
            }
        }
        for s in segs.iter() {
            buf.seen[s.device] = false;
        }
    }
    out.canonicalize_transfers();
    out
}

/// Rebalance a retargeted plan in place: peel the excess off every
/// device the drift pushed over the inner planner's capacity threshold
/// and re-spill just that excess through the LLAS least-loaded
/// machinery, seeded with the surviving devices' loads. Stale spill
/// targets (foreign segments) are peeled before native ones, forced
/// segments never — they encode legitimate overflow (min-GEMM locality,
/// LLAS force-assignment). O(E + S + changed devices · log P) instead
/// of a fresh O(E·log E + S·log P) replan, and allocation-free in
/// steady state: every working buffer lives in `scratch`.
///
/// Returns the number of peeled segments — the repair's actual work
/// metric, which [`PlanCostModel`](crate::exec::PlanCostModel) charges
/// per peel instead of assuming a flat repair cost.
fn repair_excess(
    plan: &mut RoutePlan,
    loads: &[u64],
    rp: RepairParams,
    topo: Option<&Topology>,
    pool: Option<&PoolState>,
    scratch: &mut PlanScratch,
) -> u64 {
    let devices = plan.devices;
    let m_per_dev = plan.num_experts / devices;
    let total: u64 = loads.iter().sum();
    if total == 0 {
        return 0;
    }

    // Same capacity model as `plan_llep_scratch`: the paper's scalar
    // `alpha * total / P` on homogeneous pools, the speed-proportional
    // split of the same `alpha * total` budget under a pool view.
    let m_alpha = rp.alpha * total as f64 / devices as f64;
    scratch.caps.clear();
    if let Some(p) = pool {
        let sum: f64 = p.devices.iter().map(|d| d.effective_speed()).sum();
        let denom = sum.max(f64::MIN_POSITIVE);
        scratch.caps.extend(
            p.devices.iter().map(|d| rp.alpha * total as f64 * d.effective_speed() / denom),
        );
    }

    // Current per-device load of the retargeted plan, and each device's
    // excess over capacity. `g_p` stays zero — there is no pending
    // native load during repair, `g_a` alone seeds the spill ordering.
    scratch.prepare_devices(devices);
    for segs in plan.assignments.iter() {
        for seg in segs.iter() {
            scratch.g_a[seg.device] += seg.len();
        }
    }
    scratch.over.clear();
    let mut any_over = false;
    for d in 0..devices {
        let cap = if scratch.caps.is_empty() { m_alpha } else { scratch.caps[d] };
        let over = scratch.g_a[d].saturating_sub(cap.max(0.0).floor() as u64);
        any_over |= over > 0;
        scratch.over.push(over);
    }
    if !any_over {
        return 0; // within capacity everywhere — the retarget was enough
    }

    // Peel candidates: non-forced segments on overloaded devices, stale
    // spill targets (foreign segments) before native residents, largest
    // first. `over` turns into "still to peel" as takes are assigned.
    scratch.peel.clear();
    for (e, segs) in plan.assignments.iter().enumerate() {
        for (i, seg) in segs.iter().enumerate() {
            if !seg.forced && scratch.over[seg.device] > 0 {
                let native = (seg.device == e / m_per_dev) as u8;
                scratch.peel.push((seg.device, native, seg.len(), e, i));
            }
        }
    }
    scratch.peel.sort_unstable_by_key(|&(d, nat, len, e, i)| (d, nat, Reverse(len), e, i));
    scratch.takes.clear();
    for k in 0..scratch.peel.len() {
        let (d, _, len, e, i) = scratch.peel[k];
        let take = scratch.over[d].min(len);
        if take == 0 {
            continue;
        }
        scratch.over[d] -= take;
        scratch.g_a[d] -= take;
        scratch.takes.push((e, i, take));
    }
    if scratch.takes.is_empty() {
        return 0; // every overflow is forced (legitimate) — nothing to peel
    }
    scratch.takes.sort_unstable();

    // Apply the takes expert by expert: compact the surviving segments
    // onto fresh offsets, then refill the native device up to capacity
    // and spill the rest least-loaded-first — the fresh planner's
    // placement rules, restricted to the peeled tokens.
    let PlanScratch { g_p, g_a, seen, caps, spill: heaps, takes, .. } = scratch;
    let cap_of = |d: usize| if caps.is_empty() { m_alpha } else { caps[d] };
    let mut t = 0usize;
    while t < takes.len() {
        let e = takes[t].0;
        let ng = e / m_per_dev;
        let segs = &mut plan.assignments[e];
        let mut removed = 0u64;
        let mut cursor = 0u64;
        let mut w = 0usize;
        for i in 0..segs.len() {
            let mut seg = segs[i];
            let take = if t < takes.len() && takes[t].0 == e && takes[t].1 == i {
                let k = takes[t].2;
                t += 1;
                k
            } else {
                0
            };
            removed += take;
            let len = seg.len() - take;
            if len == 0 {
                continue;
            }
            seg.start = cursor;
            seg.end = cursor + len;
            cursor += len;
            segs[w] = seg;
            w += 1;
        }
        segs.truncate(w);
        let native_dead = pool.is_some_and(|p| p.devices[ng].effective_speed() <= 0.0);
        if !native_dead {
            let spare = (cap_of(ng) - g_a[ng] as f64).floor() as i64;
            if spare > 0 {
                let c = (spare as u64).min(removed);
                segs.push(Segment { device: ng, start: cursor, end: cursor + c, forced: false });
                g_a[ng] += c;
                cursor += c;
                removed -= c;
            }
        }
        if removed > 0 {
            spill(
                ng,
                removed,
                cursor,
                segs,
                g_a,
                g_p,
                &cap_of,
                rp.min_gemm_tokens,
                topo,
                pool,
                heaps,
            );
        }
        merge_adjacent(segs);
    }

    // Segments moved: regenerate the transfer list (the `seen` marks are
    // zeroed above and reset per expert; the vector keeps its capacity).
    plan.transfers.clear();
    for (e, segs) in plan.assignments.iter().enumerate() {
        let ng = e / m_per_dev;
        for s in segs.iter() {
            if s.device != ng && !seen[s.device] {
                seen[s.device] = true;
                plan.transfers.push(WeightTransfer { expert: e, from: ng, to: s.device });
            }
        }
        for s in segs.iter() {
            seen[s.device] = false;
        }
    }
    plan.canonicalize_transfers();
    // Whatever guard shape the cached plan had, the repaired plan is a
    // least-loaded assignment again.
    plan.fallback_ep = false;
    takes.len() as u64
}

struct CacheEntry {
    devices: usize,
    sig: Vec<u64>,
    /// Quantized pool-speed fingerprint the plan was built under (empty
    /// = healthy pool). Entries only match lookups with the identical
    /// fingerprint, so degraded-pool plans never serve healthy steps and
    /// vice versa.
    pool_sig: Vec<u64>,
    /// Loads the cached plan was (freshly) built for — retarget source
    /// and drift anchor.
    loads: Vec<u64>,
    /// The inner planner's layout generation at install time. Planners
    /// with a mutable expert layout (`placed(...)`) bump it on every
    /// re-layout; entries keyed to an old generation never match — a
    /// plan must not be retargeted (or repaired) across layouts.
    layout_gen: u64,
    /// The cached plan. Installed with `migrations` stripped: migration
    /// transfers are one-shot events, already paid by the step that
    /// planned them, never part of a reused plan.
    plan: RoutePlan,
    /// Hits served from this entry since its last fresh plan.
    reuses: usize,
    last_used: u64,
}

#[derive(Default)]
struct CacheState {
    entries: Vec<CacheEntry>,
    stats: CacheStats,
    clock: u64,
    /// Lookup signature buffers + retarget working set, reused across
    /// lookups (they live under the same lock that serializes lookups).
    sig: Vec<u64>,
    pool_sig: Vec<u64>,
    retarget: RetargetBuffers,
}

/// Decorator that reuses the wrapped planner's plans across steps.
/// Stateful (interior mutability), hence [`Planner::replay_safe`] =
/// false: the engine times exactly one lookup per priced plan.
pub struct CachedPlanner {
    inner: Box<dyn Planner>,
    /// Distinguishes this cache's thread-local outcome slot from other
    /// caches used on the same thread.
    id: usize,
    /// Reuse when the signature drift (share units, `0..=2`) is at most
    /// this much.
    pub drift_threshold: f64,
    /// Delta-repair drift in `(drift_threshold, repair_ceiling]` instead
    /// of replanning fresh (0 = disabled, the default). Only effective
    /// when the inner planner publishes [`Planner::repair_params`].
    pub repair_ceiling: f64,
    /// Share quantization buckets for the signature.
    pub quant: u64,
    /// Force a fresh plan after this many consecutive reuses of one
    /// entry (0 = never). The `--replan-every` serving policy.
    pub replan_every: usize,
    /// Max distinct signatures tracked (LRU eviction beyond this).
    pub capacity: usize,
    state: Mutex<CacheState>,
}

impl CachedPlanner {
    pub fn new(inner: Box<dyn Planner>) -> CachedPlanner {
        CachedPlanner {
            inner,
            id: NEXT_CACHE_ID.fetch_add(1, Ordering::Relaxed),
            drift_threshold: 0.05,
            repair_ceiling: 0.0,
            quant: 1024,
            replan_every: 0,
            capacity: 64,
            state: Mutex::new(CacheState::default()),
        }
    }

    pub fn with_drift_threshold(mut self, t: f64) -> CachedPlanner {
        self.drift_threshold = t;
        self
    }

    pub fn with_repair_ceiling(mut self, t: f64) -> CachedPlanner {
        self.repair_ceiling = t;
        self
    }

    /// Largest drift any reuse tier (retarget or repair) accepts.
    fn reuse_ceiling(&self) -> f64 {
        self.drift_threshold.max(self.repair_ceiling)
    }

    pub fn with_quant(mut self, quant: u64) -> CachedPlanner {
        self.quant = quant.max(1);
        self
    }

    pub fn with_replan_every(mut self, n: usize) -> CachedPlanner {
        self.replan_every = n;
        self
    }

    pub fn with_capacity(mut self, capacity: usize) -> CachedPlanner {
        self.capacity = capacity.max(1);
        self
    }

    /// Cumulative hit/miss/forced counters since creation (or [`reset`]).
    ///
    /// [`reset`]: CachedPlanner::reset
    pub fn stats(&self) -> CacheStats {
        self.state.lock().expect("cache lock").stats
    }

    /// Drop all cached plans and zero the counters (the last per-thread
    /// outcome is left in place — it describes a lookup that did happen).
    pub fn reset(&self) {
        let mut st = self.state.lock().expect("cache lock");
        st.entries.clear();
        st.stats = CacheStats::default();
    }
}

/// How a candidate entry's pool fingerprint relates to the lookup's.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PoolMatch {
    /// Identical fingerprint: every reuse tier applies.
    Exact,
    /// Same shape, every device within one quantization step — the same
    /// degraded pool seen through measurement noise. Only the repair
    /// tier may reuse such an entry: the repair re-derives capacities
    /// from the *current* pool speeds, so a placement the wobble
    /// invalidated is peeled and re-spilled rather than trusted.
    Band,
}

fn pool_match(entry: &[u64], lookup: &[u64]) -> Option<PoolMatch> {
    if entry == lookup {
        return Some(PoolMatch::Exact);
    }
    if !entry.is_empty()
        && entry.len() == lookup.len()
        && entry.iter().zip(lookup).all(|(&a, &b)| a.abs_diff(b) <= 1)
    {
        return Some(PoolMatch::Band);
    }
    None
}

/// Index + drift + pool-match kind of the entry whose signature is
/// L1-closest to `sig` (same device count, expert count, and layout
/// generation; pool fingerprint exact or within the quantization band).
/// Exact pool matches are preferred over band matches regardless of
/// drift.
fn closest(
    entries: &[CacheEntry],
    devices: usize,
    sig: &[u64],
    pool_sig: &[u64],
    layout_gen: u64,
    quant: u64,
) -> Option<(usize, f64, PoolMatch)> {
    entries
        .iter()
        .enumerate()
        .filter(|(_, en)| {
            en.devices == devices && en.sig.len() == sig.len() && en.layout_gen == layout_gen
        })
        .filter_map(|(i, en)| {
            pool_match(&en.pool_sig, pool_sig)
                .map(|pm| (i, signature_drift(&en.sig, sig, quant), pm))
        })
        .min_by(|a, b| {
            let band_a = (a.2 == PoolMatch::Band) as u8;
            let band_b = (b.2 == PoolMatch::Band) as u8;
            band_a.cmp(&band_b).then(a.1.total_cmp(&b.1))
        })
}

impl CachedPlanner {
    /// Record the lookup outcome (and, for repairs, how many segments
    /// were peeled) in the calling thread's slot. The slot vec holds one
    /// entry per cache instance used on this thread — a handful at most
    /// — and dies with the thread.
    fn set_last_outcome(&self, outcome: CacheOutcome, peeled: u64) {
        LAST_OUTCOME.with(|slot| {
            let mut v = slot.borrow_mut();
            match v.iter_mut().find(|(id, _, _)| *id == self.id) {
                Some(entry) => {
                    entry.1 = outcome;
                    entry.2 = peeled;
                }
                None => v.push((self.id, outcome, peeled)),
            }
        });
    }
}

impl CachedPlanner {
    /// The shared lookup behind both trait entry points. `pool` is
    /// `None` for healthy steps and `Some` for degraded-but-fully-alive
    /// pools; either way it joins the cache key via its quantized speed
    /// fingerprint ([`pool_signature_into`]).
    fn lookup(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
        pool: Option<&PoolState>,
    ) -> RoutePlan {
        // Phase 1: probe under the lock. Stateful planners plan layers
        // sequentially (replay_safe = false), so the lock is uncontended
        // in practice; a hit retargets the cached plan *in place* under
        // the lock — no entry clone, no allocation (the shell and every
        // working buffer are recycled). What the engine's timed window
        // sees is the cache's real per-lookup cost, keeping T_plan
        // honest.
        let outcome;
        let layout_gen = self.inner.layout_generation();
        {
            let mut guard = self.state.lock().expect("cache lock");
            let st = &mut *guard;
            st.clock += 1;
            let clock = st.clock;
            load_signature_into(loads, self.quant, &mut st.sig);
            pool_signature_into(pool, &mut st.pool_sig);
            match closest(&st.entries, devices, &st.sig, &st.pool_sig, layout_gen, self.quant) {
                Some((i, drift, pm)) if drift <= self.reuse_ceiling() => {
                    // Forced refresh only after the entry has already
                    // served `replan_every` reuses (so N=1 still allows
                    // one reuse per fresh plan). Repairs count as reuses,
                    // so repair→repair chains are periodically reset and
                    // repair error cannot accumulate unboundedly.
                    let force = self.replan_every > 0 && st.entries[i].reuses >= self.replan_every;
                    // The repair tier needs the inner planner's capacity
                    // model; without one, past-threshold drift (and any
                    // band-matched pool fingerprint) plans fresh exactly
                    // as before. A band match must repair even below the
                    // retarget threshold — the capacities moved, not the
                    // loads — and needs the repair tier enabled.
                    let needs_repair = pm == PoolMatch::Band || drift > self.drift_threshold;
                    let repair = (needs_repair
                        && self.repair_ceiling > 0.0
                        && drift <= self.repair_ceiling)
                        .then(|| self.inner.repair_params())
                        .flatten();
                    if force {
                        outcome = CacheOutcome::Forced;
                    } else if pm == PoolMatch::Exact && drift <= self.drift_threshold {
                        let shell = with_thread_scratch(|s| s.take_plan(loads.len(), devices));
                        let en = &mut st.entries[i];
                        en.reuses += 1;
                        en.last_used = clock;
                        let plan = retarget_plan_into(
                            &en.plan,
                            &en.loads,
                            loads,
                            shell,
                            &mut st.retarget,
                        );
                        st.stats.record(CacheOutcome::Hit);
                        drop(guard);
                        self.set_last_outcome(CacheOutcome::Hit, 0);
                        return plan;
                    } else if let Some(rp) = repair {
                        // Delta repair: retarget, then rebalance only the
                        // devices the drift pushed over capacity. One
                        // scratch closure end to end — the arena leaves
                        // its thread-local slot for the duration, so a
                        // nested `with_thread_scratch` would see a fresh
                        // arena and allocate.
                        let CacheState { entries, retarget, sig, pool_sig, stats, .. } = st;
                        let en = &mut entries[i];
                        en.reuses += 1;
                        en.last_used = clock;
                        let mut peeled = 0;
                        let plan = with_thread_scratch(|s| {
                            let shell = s.take_plan(loads.len(), devices);
                            let mut plan =
                                retarget_plan_into(&en.plan, &en.loads, loads, shell, retarget);
                            peeled = repair_excess(&mut plan, loads, rp, topo, pool, s);
                            plan
                        });
                        // Re-anchor the entry on the repaired plan, the
                        // loads it was repaired for, and the pool it was
                        // repaired under (a band match adopts the new
                        // fingerprint): the next lookup's drift is
                        // measured from the latest repair, not the
                        // long-gone fresh plan. Field-wise so
                        // `Vec::clone_from` reuses the entry's buffers
                        // (the derived whole-struct `clone_from` would
                        // allocate a full clone).
                        en.plan.num_experts = plan.num_experts;
                        en.plan.devices = plan.devices;
                        en.plan.assignments.clone_from(&plan.assignments);
                        en.plan.transfers.clone_from(&plan.transfers);
                        en.plan.fallback_ep = plan.fallback_ep;
                        en.loads.clear();
                        en.loads.extend_from_slice(loads);
                        en.sig.clone_from(sig);
                        en.pool_sig.clone_from(pool_sig);
                        stats.record(CacheOutcome::Repaired);
                        drop(guard);
                        self.set_last_outcome(CacheOutcome::Repaired, peeled);
                        return plan;
                    } else {
                        outcome = CacheOutcome::Miss;
                    }
                }
                _ => outcome = CacheOutcome::Miss,
            }
        }
        // Phase 2: plan fresh OUTSIDE the lock — the expensive part of a
        // miss must not serialize concurrent layer-planning threads
        // behind one Mutex.
        let fresh = self.inner.plan_with_pool(devices, loads, stats, topo, pool);
        // The install keys on the generation AFTER the inner plan: a
        // stateful inner planner may have re-laid-out mid-plan, and the
        // fresh plan belongs to the layout it actually planned against.
        let layout_gen = self.inner.layout_generation();
        // Phase 3: install. Entries (and the signature buffers) may have
        // changed while unlocked, so recompute and re-probe for the slot
        // to refresh instead of trusting an index.
        let mut guard = self.state.lock().expect("cache lock");
        let st = &mut *guard;
        st.clock += 1;
        let clock = st.clock;
        load_signature_into(loads, self.quant, &mut st.sig);
        pool_signature_into(pool, &mut st.pool_sig);
        // Refresh any entry within the reuse ceiling (not just the
        // retarget threshold): a fresh plan born of repair-band drift
        // replaces the drifted entry instead of duplicating it. A
        // band-matched fingerprint is re-anchored the same way.
        let slot = closest(&st.entries, devices, &st.sig, &st.pool_sig, layout_gen, self.quant)
            .and_then(|(i, drift, _)| (drift <= self.reuse_ceiling()).then_some(i));
        match slot {
            Some(i) => {
                let en = &mut st.entries[i];
                en.sig.clone_from(&st.sig);
                en.pool_sig.clone_from(&st.pool_sig);
                en.loads.clear();
                en.loads.extend_from_slice(loads);
                en.plan = fresh.clone();
                en.plan.migrations.clear();
                en.reuses = 0;
                en.last_used = clock;
            }
            None => {
                if st.entries.len() >= self.capacity {
                    let lru = st
                        .entries
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, en)| en.last_used)
                        .map(|(i, _)| i)
                        .expect("capacity >= 1");
                    st.entries.swap_remove(lru);
                }
                let mut plan = fresh.clone();
                plan.migrations.clear();
                st.entries.push(CacheEntry {
                    devices,
                    sig: st.sig.clone(),
                    pool_sig: st.pool_sig.clone(),
                    loads: loads.to_vec(),
                    layout_gen,
                    plan,
                    reuses: 0,
                    last_used: clock,
                });
            }
        }
        st.stats.record(outcome);
        drop(guard);
        self.set_last_outcome(outcome, 0);
        fresh
    }
}

impl Planner for CachedPlanner {
    fn plan_with_pool(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
        pool: Option<&PoolState>,
    ) -> RoutePlan {
        match pool {
            Some(p) if p.is_degraded() => {
                if p.alive_count() < p.len() {
                    // A dead device invalidates cached placements
                    // outright (a retargeted segment could land on the
                    // hole), so failures force fresh pool-aware plans
                    // for the whole outage window. The cache is left
                    // untouched — entries stay valid for after recovery,
                    // and no dead-pool plan is ever installed.
                    let plan = self.inner.plan_with_pool(devices, loads, stats, topo, pool);
                    self.state.lock().expect("cache lock").stats.record(CacheOutcome::Forced);
                    self.set_last_outcome(CacheOutcome::Forced, 0);
                    plan
                } else {
                    // Degraded but fully alive (stragglers, heterogeneous
                    // presets, link factors): a plan is a pure function
                    // of (loads, speeds), so reuse is safe when the
                    // quantized pool fingerprint joins the cache key —
                    // a stable straggler window or a statically
                    // heterogeneous preset gets plan reuse back instead
                    // of forcing fresh plans for the whole degraded
                    // window (ROADMAP: fault-plan-aware cache reuse).
                    self.lookup(devices, loads, stats, topo, Some(p))
                }
            }
            _ => self.plan_with_stats(devices, loads, stats, topo),
        }
    }

    fn plan_with_stats(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
    ) -> RoutePlan {
        self.lookup(devices, loads, stats, topo, None)
    }

    fn label(&self) -> String {
        format!("Cached[{}]", self.inner.label())
    }

    fn spec(&self) -> String {
        format!(
            "cached({}):drift={},every={},q={},repair={}",
            self.inner.spec(),
            self.drift_threshold,
            self.replan_every,
            self.quant,
            self.repair_ceiling
        )
    }

    fn chunk_tokens(&self) -> Option<u64> {
        self.inner.chunk_tokens()
    }

    fn charges_weight_transfers(&self) -> bool {
        self.inner.charges_weight_transfers()
    }

    fn wants_stale_stats(&self) -> bool {
        self.inner.wants_stale_stats()
    }

    fn replay_safe(&self) -> bool {
        false
    }

    fn last_cache_outcome(&self) -> Option<CacheOutcome> {
        LAST_OUTCOME.with(|slot| {
            slot.borrow().iter().find(|(id, _, _)| *id == self.id).map(|&(_, o, _)| o)
        })
    }

    fn last_repair_peeled(&self) -> u64 {
        LAST_OUTCOME.with(|slot| {
            slot.borrow()
                .iter()
                .find(|(id, _, _)| *id == self.id)
                .map_or(0, |&(_, o, peeled)| if o == CacheOutcome::Repaired { peeled } else { 0 })
        })
    }

    fn layout_generation(&self) -> u64 {
        self.inner.layout_generation()
    }

    /// `None` on reuse (Hit/Repaired): the inner planner never ran, so
    /// no placement round happened this lookup — the engine must not
    /// re-report the round that produced the cached plan.
    fn last_placement_stats(&self) -> Option<PlacementStats> {
        match self.last_cache_outcome() {
            Some(CacheOutcome::Hit) | Some(CacheOutcome::Repaired) => None,
            _ => self.inner.last_placement_stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::validate::validate_plan;
    use crate::planner::PlannerKind;

    fn llep_cached() -> CachedPlanner {
        CachedPlanner::new(PlannerKind::llep_default().boxed())
    }

    #[test]
    fn identical_loads_hit_and_replay_the_plan() {
        let loads = vec![9_000u64, 100, 200, 300, 0, 50, 150, 250];
        let c = llep_cached();
        let first = c.plan(4, &loads, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Miss));
        let second = c.plan(4, &loads, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Hit));
        validate_plan(&second, &loads).unwrap();
        // Same segments; transfers may be recorded in a different order,
        // so compare them as sets.
        assert_eq!(first.assignments, second.assignments);
        let mut a = first.transfers.clone();
        let mut b = second.transfers.clone();
        a.sort_by_key(|t| (t.expert, t.from, t.to));
        b.sort_by_key(|t| (t.expert, t.from, t.to));
        assert_eq!(a, b);
        assert_eq!(c.stats(), CacheStats { hits: 1, repairs: 0, misses: 1, forced: 0 });
    }

    #[test]
    fn scaled_loads_hit_via_share_signature() {
        // Same distribution, 3x the tokens (decode batch grew): the
        // share signature is unchanged, so the plan is reused and scaled.
        let loads = vec![6_000u64, 1_000, 500, 500, 0, 0, 1_000, 1_000];
        let scaled: Vec<u64> = loads.iter().map(|&l| l * 3).collect();
        let c = llep_cached();
        let _ = c.plan(4, &loads, None);
        let reused = c.plan(4, &scaled, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Hit));
        validate_plan(&reused, &scaled).unwrap();
    }

    #[test]
    fn big_drift_misses() {
        let hot0 = vec![9_000u64, 0, 0, 0, 0, 0, 0, 1_000];
        let hot7 = vec![1_000u64, 0, 0, 0, 0, 0, 0, 9_000];
        let c = llep_cached();
        let _ = c.plan(4, &hot0, None);
        let _ = c.plan(4, &hot7, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Miss));
        assert_eq!(c.stats().misses, 2);
        // ... and each signature now has its own entry.
        let _ = c.plan(4, &hot0, None);
        let _ = c.plan(4, &hot7, None);
        assert_eq!(c.stats().hits, 2);
    }

    #[test]
    fn replan_every_forces_refresh() {
        let loads = vec![8_000u64, 0, 0, 0, 0, 0, 0, 2_000];
        let c = llep_cached().with_replan_every(3);
        for _ in 0..9 {
            let _ = c.plan(4, &loads, None);
        }
        // miss, 3 hits, forced, 3 hits, forced: an entry serves exactly
        // `replan_every` reuses before the next lookup replans fresh.
        assert_eq!(c.stats(), CacheStats { hits: 6, repairs: 0, misses: 1, forced: 2 });
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Forced));
    }

    #[test]
    fn replan_every_one_still_alternates_reuse() {
        // Boundary: N=1 must not degenerate into never-hitting.
        let loads = vec![8_000u64, 0, 0, 0, 0, 0, 0, 2_000];
        let c = llep_cached().with_replan_every(1);
        for _ in 0..5 {
            let _ = c.plan(4, &loads, None);
        }
        // miss, hit, forced, hit, forced
        assert_eq!(c.stats(), CacheStats { hits: 2, repairs: 0, misses: 1, forced: 2 });
    }

    #[test]
    fn capacity_evicts_lru() {
        let c = llep_cached().with_capacity(2);
        let a = vec![9_000u64, 0, 0, 1_000];
        let b = vec![0u64, 9_000, 1_000, 0];
        let d = vec![1_000u64, 0, 9_000, 0];
        let _ = c.plan(2, &a, None);
        let _ = c.plan(2, &b, None);
        let _ = c.plan(2, &d, None); // evicts a
        let _ = c.plan(2, &a, None); // miss again
        assert_eq!(c.stats().misses, 4);
    }

    #[test]
    fn retarget_identity_when_loads_unchanged() {
        let loads = vec![10_000u64, 3_000, 0, 500, 700, 900, 1_100, 1_300];
        let plan = PlannerKind::llep_default().plan(4, &loads, None);
        let re = retarget_plan(&plan, &loads, &loads);
        assert_eq!(plan.assignments, re.assignments);
        validate_plan(&re, &loads).unwrap();
    }

    #[test]
    fn retarget_covers_drifted_loads_exactly() {
        let old = vec![10_000u64, 3_000, 0, 500, 700, 900, 1_100, 1_300];
        let new = vec![9_500u64, 3_300, 40, 450, 800, 850, 1_000, 1_500];
        let plan = PlannerKind::llep_default().plan(4, &old, None);
        let re = retarget_plan(&plan, &old, &new);
        validate_plan(&re, &new).unwrap();
        assert_eq!(re.device_loads().iter().sum::<u64>(), new.iter().sum::<u64>());
    }

    #[test]
    fn reset_clears_everything() {
        let loads = vec![5_000u64, 0, 0, 5_000];
        let c = llep_cached();
        let _ = c.plan(2, &loads, None);
        let _ = c.plan(2, &loads, None);
        assert!(c.stats().lookups() > 0);
        c.reset();
        assert_eq!(c.stats(), CacheStats::default());
        let _ = c.plan(2, &loads, None);
        assert_eq!(c.stats().misses, 1, "entries were dropped too");
    }

    #[test]
    fn degraded_alive_pool_reuses_with_pool_keyed_entries() {
        use crate::chaos::PoolState;
        // A stable straggler: after one miss, every further step on the
        // identical pool hits — the ROADMAP "fault-plan-aware reuse".
        let loads = vec![9_000u64, 100, 200, 300, 0, 50, 150, 250];
        let mut pool = PoolState::healthy(4);
        pool.devices[0].speed = 0.25;
        let c = llep_cached();
        let first = c.plan_with_pool(4, &loads, &loads, None, Some(&pool));
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Miss));
        validate_plan(&first, &loads).unwrap();
        let second = c.plan_with_pool(4, &loads, &loads, None, Some(&pool));
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Hit));
        assert_eq!(first.assignments, second.assignments);
        // A healthy step with the same loads must NOT hit the degraded
        // entry (different pool fingerprint) ...
        let healthy = c.plan_with_pool(4, &loads, &loads, None, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Miss));
        validate_plan(&healthy, &loads).unwrap();
        assert_ne!(healthy.assignments, first.assignments, "straggler shifts the split");
        // ... and a different straggler is a different fingerprint too.
        let mut other = PoolState::healthy(4);
        other.devices[1].speed = 0.25;
        let _ = c.plan_with_pool(4, &loads, &loads, None, Some(&other));
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Miss));
        assert_eq!(c.stats(), CacheStats { hits: 1, repairs: 0, misses: 3, forced: 0 });
    }

    #[test]
    fn dead_device_still_forces_fresh_plans() {
        use crate::chaos::PoolState;
        let loads = vec![9_000u64, 100, 200, 300, 0, 50, 150, 250];
        let mut pool = PoolState::healthy(4);
        pool.devices[2].alive = false;
        let c = llep_cached();
        for _ in 0..3 {
            let p = c.plan_with_pool(4, &loads, &loads, None, Some(&pool));
            assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Forced));
            validate_plan(&p, &loads).unwrap();
            assert_eq!(p.device_loads()[2], 0, "nothing on the dead device");
        }
        assert_eq!(c.stats(), CacheStats { hits: 0, repairs: 0, misses: 0, forced: 3 });
    }

    #[test]
    fn pool_signature_shapes() {
        use crate::chaos::PoolState;
        let mut out = vec![7u64; 3];
        pool_signature_into(None, &mut out);
        assert!(out.is_empty(), "healthy = empty fingerprint");
        pool_signature_into(Some(&PoolState::healthy(4)), &mut out);
        assert!(out.is_empty(), "non-degraded pool = healthy key");
        let mut p = PoolState::healthy(2);
        p.devices[1].speed = 0.5;
        pool_signature_into(Some(&p), &mut out);
        assert_eq!(out, vec![256, 128]);
        p.devices[1].alive = false;
        pool_signature_into(Some(&p), &mut out);
        assert_eq!(out, vec![256, 0], "dead device quantizes to zero");
    }

    #[test]
    fn signature_math() {
        assert_eq!(load_signature(&[0, 0], 1024), vec![0, 0]);
        let sig = load_signature(&[750, 250], 1000);
        assert_eq!(sig, vec![750, 250]);
        assert_eq!(signature_drift(&sig, &sig, 1000), 0.0);
        let moved = load_signature(&[250, 750], 1000);
        assert!((signature_drift(&sig, &moved, 1000) - 1.0).abs() < 1e-12);
    }

    /// LLEP inner with a small min-GEMM floor so repairs actually spill,
    /// wrapped with a repair ceiling: drift in (0.05, 0.15] repairs.
    fn llep_repairing() -> CachedPlanner {
        use crate::config::LlepConfig;
        use crate::planner::Llep;
        let cfg = LlepConfig { alpha: 1.0, min_gemm_tokens: 16, lambda: 1.3 };
        CachedPlanner::new(Box::new(Llep::new(cfg))).with_repair_ceiling(0.15)
    }

    // Moving 400 of 10_000 tokens from expert 0 to expert 3 is an L1
    // share drift of 0.08 — past the 0.05 retarget threshold, under the
    // 0.15 repair ceiling.
    const A: [u64; 8] = [5_000, 1_000, 1_000, 1_000, 500, 500, 500, 500];
    const B: [u64; 8] = [4_600, 1_000, 1_000, 1_400, 500, 500, 500, 500];

    #[test]
    fn repair_tier_repairs_between_thresholds_and_reanchors() {
        let c = llep_repairing();
        let _ = c.plan(4, &A, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Miss));
        let repaired = c.plan(4, &B, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Repaired));
        validate_plan(&repaired, &B).unwrap();
        // Repair restores the fresh planner's capacity bound: no device
        // above `alpha * total / P` beyond the min-GEMM slack forced
        // remainders may keep local.
        let cap = 10_000 / 4;
        let max = repaired.device_loads().into_iter().max().unwrap();
        assert!(max <= cap + 16, "repaired max {max} > capacity {cap} + min-GEMM slack");
        // The entry was re-anchored on the repaired plan: replaying the
        // same loads is now a plain retarget hit, not another repair.
        let again = c.plan(4, &B, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Hit));
        validate_plan(&again, &B).unwrap();
        assert_eq!(c.stats(), CacheStats { hits: 1, repairs: 1, misses: 1, forced: 0 });
    }

    #[test]
    fn drift_beyond_repair_ceiling_still_misses() {
        // 1_000 of 10_000 tokens moved = 0.2 drift > the 0.15 ceiling.
        let far = vec![4_000u64, 1_000, 1_000, 2_000, 500, 500, 500, 500];
        let c = llep_repairing();
        let _ = c.plan(4, &A, None);
        let p = c.plan(4, &far, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Miss));
        validate_plan(&p, &far).unwrap();
        assert_eq!(c.stats(), CacheStats { hits: 0, repairs: 0, misses: 2, forced: 0 });
    }

    #[test]
    fn repair_disabled_by_default() {
        // Same drift, no `repair=`: past-threshold lookups plan fresh,
        // bit-for-bit the pre-repair behavior.
        let c = llep_cached();
        let _ = c.plan(4, &A, None);
        let _ = c.plan(4, &B, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Miss));
        assert_eq!(c.stats(), CacheStats { hits: 0, repairs: 0, misses: 2, forced: 0 });
    }

    #[test]
    fn repair_needs_the_inner_capacity_model() {
        // Standard EP publishes no `repair_params`; the ceiling alone
        // must not invent a capacity to repair against.
        let c = CachedPlanner::new(PlannerKind::StandardEp.boxed()).with_repair_ceiling(0.15);
        let _ = c.plan(4, &A, None);
        let _ = c.plan(4, &B, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Miss));
        assert_eq!(c.stats(), CacheStats { hits: 0, repairs: 0, misses: 2, forced: 0 });
    }

    #[test]
    fn pool_band_wobble_repairs_instead_of_missing() {
        use crate::chaos::PoolState;
        // speed 0.25 fingerprints as 64; 0.254 as 65 — the same
        // straggler seen through measurement noise, one quantization
        // step apart. The band match may only feed the repair tier.
        let c = llep_repairing();
        let mut pool = PoolState::healthy(4);
        pool.devices[0].speed = 0.25;
        let _ = c.plan_with_pool(4, &A, &A, None, Some(&pool));
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Miss));
        let mut wobble = PoolState::healthy(4);
        wobble.devices[0].speed = 0.254;
        let p = c.plan_with_pool(4, &A, &A, None, Some(&wobble));
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Repaired));
        validate_plan(&p, &A).unwrap();
        // The entry re-anchored on the new fingerprint: replaying the
        // same pool is now an exact-match hit.
        let _ = c.plan_with_pool(4, &A, &A, None, Some(&wobble));
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Hit));
        assert_eq!(c.stats(), CacheStats { hits: 1, repairs: 1, misses: 1, forced: 0 });
    }

    #[test]
    fn pool_band_without_repair_tier_misses() {
        use crate::chaos::PoolState;
        // No repair ceiling: a band-matched fingerprint must not be
        // blindly retargeted — the capacities moved, not the loads — so
        // it plans fresh exactly as before.
        let c = llep_cached();
        let mut pool = PoolState::healthy(4);
        pool.devices[0].speed = 0.25;
        let _ = c.plan_with_pool(4, &A, &A, None, Some(&pool));
        let mut wobble = PoolState::healthy(4);
        wobble.devices[0].speed = 0.254;
        let _ = c.plan_with_pool(4, &A, &A, None, Some(&wobble));
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Miss));
        assert_eq!(c.stats(), CacheStats { hits: 0, repairs: 0, misses: 2, forced: 0 });
    }

    #[test]
    fn re_layout_invalidates_cached_entries() {
        use crate::placement::{Placed, PlacementConfig};
        let inner = Placed::with_config(
            PlannerKind::llep_default().boxed(),
            PlacementConfig { budget: 8, ..PlacementConfig::default() },
        );
        let c = CachedPlanner::new(Box::new(inner));
        let mut hot_lo = vec![100u64; 16];
        for l in hot_lo.iter_mut().take(4) {
            *l = 4_000;
        }
        let mut hot_hi = vec![100u64; 16];
        for l in hot_hi.iter_mut().skip(8).take(4) {
            *l = 4_000;
        }
        let _ = c.plan(4, &hot_lo, None); // miss; placement migrates mid-plan
        assert!(c.layout_generation() > 0, "colliding hotspot re-laid-out");
        let gen = c.layout_generation();
        let _ = c.plan(4, &hot_lo, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Hit), "stable layout replays");
        // A new regime re-lays-out; the old entry is keyed to the old
        // generation and must never be retargeted across layouts.
        let _ = c.plan(4, &hot_hi, None);
        assert!(c.layout_generation() > gen, "new hotspot moved the layout");
        let _ = c.plan(4, &hot_lo, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Miss));
    }

    #[test]
    fn repairs_count_as_reuses_for_replan_every() {
        // miss, repair, repair, forced: the periodic fresh plan bounds
        // repair→repair chains so repair error cannot accumulate.
        let c = llep_repairing().with_replan_every(2);
        let _ = c.plan(4, &A, None);
        let _ = c.plan(4, &B, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Repaired));
        let _ = c.plan(4, &A, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Repaired));
        let _ = c.plan(4, &B, None);
        assert_eq!(c.last_cache_outcome(), Some(CacheOutcome::Forced));
        assert_eq!(c.stats(), CacheStats { hits: 0, repairs: 2, misses: 1, forced: 1 });
    }
}
