//! Standard expert parallelism plan (paper Alg. 1).
//!
//! Every expert's entire load is computed on its native device under the
//! block layout (`M = N/P` consecutive experts per device). No weight
//! transfers. Under imbalanced routing this is the plan whose worst
//! device dominates the collective latency (paper §3.2).

use super::scratch::{with_thread_scratch, PlanScratch};
use super::{Planner, RoutePlan, Segment};
use crate::topology::Topology;

/// Standard expert parallelism (paper Alg. 1) as a trait planner.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StandardEp;

impl Planner for StandardEp {
    fn plan_with_stats(
        &self,
        devices: usize,
        loads: &[u64],
        _stats: &[u64],
        _topo: Option<&Topology>,
    ) -> RoutePlan {
        plan_ep(loads.len(), devices, loads)
    }

    fn label(&self) -> String {
        "EP".into()
    }

    fn spec(&self) -> String {
        "ep".into()
    }
}

/// Chained gradient-checkpointing baseline (paper §3.1): standard-EP
/// routing, but the engine's pricing splits each device's per-expert
/// GEMMs into `chunk_tokens`-sized pieces (see
/// [`Planner::chunk_tokens`]), bounding activation memory at the cost of
/// more kernel launches. Chunking is an execution policy, not a routing
/// change.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkedEp {
    pub chunk_tokens: usize,
}

impl ChunkedEp {
    pub fn new(chunk_tokens: usize) -> ChunkedEp {
        ChunkedEp { chunk_tokens }
    }
}

impl Planner for ChunkedEp {
    fn plan_with_stats(
        &self,
        devices: usize,
        loads: &[u64],
        _stats: &[u64],
        _topo: Option<&Topology>,
    ) -> RoutePlan {
        plan_ep(loads.len(), devices, loads)
    }

    fn label(&self) -> String {
        format!("ChunkedEP(c={})", self.chunk_tokens)
    }

    fn spec(&self) -> String {
        format!("chunked:c={}", self.chunk_tokens)
    }

    fn chunk_tokens(&self) -> Option<u64> {
        Some((self.chunk_tokens.max(1)) as u64)
    }
}

/// Build the standard-EP plan for per-expert `loads`.
///
/// Panics if `num_experts` is not divisible by `devices` (the paper's EP
/// assumption, enforced upstream by `ModelConfig::experts_per_device`).
pub fn plan_ep(num_experts: usize, devices: usize, loads: &[u64]) -> RoutePlan {
    with_thread_scratch(|s| plan_ep_scratch(num_experts, devices, loads, s))
}

/// [`plan_ep`] with the plan shell drawn from a reusable arena
/// (allocation-free in steady state — see [`PlanScratch`]).
pub fn plan_ep_scratch(
    num_experts: usize,
    devices: usize,
    loads: &[u64],
    scratch: &mut PlanScratch,
) -> RoutePlan {
    assert_eq!(loads.len(), num_experts);
    assert!(devices > 0 && num_experts % devices == 0, "N must divide P");
    let m = num_experts / devices;
    let mut plan = scratch.take_plan(num_experts, devices);
    for (e, &l) in loads.iter().enumerate() {
        if l > 0 {
            plan.assignments[e].push(Segment { device: e / m, start: 0, end: l, forced: false });
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assigns_native_only() {
        let plan = plan_ep(4, 2, &[7, 0, 3, 9]);
        let want = vec![Segment { device: 0, start: 0, end: 7, forced: false }];
        assert_eq!(plan.assignments[0], want);
        assert!(plan.assignments[1].is_empty());
        assert_eq!(plan.assignments[2][0].device, 1);
        assert_eq!(plan.assignments[3][0].device, 1);
        assert!(plan.transfers.is_empty());
        assert!(plan.is_pure_ep());
        assert_eq!(plan.device_loads(), vec![7, 12]);
    }

    #[test]
    fn concentrates_under_imbalance() {
        // all load on expert 0 -> all on device 0 (the paper's failure mode)
        let plan = plan_ep(8, 4, &[1000, 0, 0, 0, 0, 0, 0, 0]);
        assert_eq!(plan.device_loads(), vec![1000, 0, 0, 0]);
    }

    #[test]
    #[should_panic]
    fn rejects_indivisible() {
        plan_ep(5, 2, &[1; 5]);
    }
}
