//! Planner registry: constructs trait planners from `--planner` spec
//! strings.
//!
//! Grammar: `name[:key=value,key=value,...]`, plus the decorator forms
//! `cached(<inner spec>)[:drift=F,every=N,q=Q,repair=F]` and
//! `placed(<inner spec>)[:ema=F,budget=N,horizon=F,standby=N]`.
//! Examples:
//!
//! ```text
//! ep
//! llep:alpha=1.0,m=64
//! eplb:r=8
//! chunked:c=4096
//! lpt:min=1024
//! cached(llep:alpha=1.2):drift=0.05,every=32
//! placed(llep):ema=0.25,budget=4,horizon=32,standby=1
//! ```
//!
//! Decorators nest (`placed(cached(llep))`, `cached(placed(llep))`):
//! placement-outside keeps the EMA fresh on every step while the inner
//! cache reuses plans within a layout; cache-outside keys entries to the
//! layout generation so re-layouts invalidate stale plans.
//!
//! Unknown names and unknown/leftover parameters are hard errors so a
//! typo never silently changes an experiment. Every planner's
//! [`Planner::spec`] string round-trips through [`Registry::parse`].
//! Adding a planner is one new file implementing [`Planner`] plus one
//! [`PlannerEntry`] in [`Registry::builtin`] (or a runtime
//! [`Registry::register`] call — see the tests for an out-of-tree
//! planner).

use super::{CachedPlanner, ChunkedEp, Eplb, Llep, Lpt, Planner, StandardEp};
use crate::config::LlepConfig;
use crate::placement::{Placed, PlacementConfig};

/// Parsed `key=value` parameter list; builders [`take`](Params::take)
/// what they recognize and [`finish`](Params::finish) rejects leftovers.
pub struct Params {
    kv: Vec<(String, String)>,
}

impl Params {
    fn parse(s: &str) -> Result<Params, String> {
        let mut kv = Vec::new();
        for part in s.split(',').filter(|p| !p.trim().is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("expected key=value, got {part:?}"))?;
            kv.push((k.trim().to_string(), v.trim().to_string()));
        }
        Ok(Params { kv })
    }

    /// Remove and return the raw value for `key`, if present.
    pub fn take(&mut self, key: &str) -> Option<String> {
        self.kv.iter().position(|(k, _)| k == key).map(|i| self.kv.remove(i).1)
    }

    pub fn take_f64(&mut self, key: &str) -> Result<Option<f64>, String> {
        match self.take(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("{key} expects a number, got {v:?}")),
        }
    }

    pub fn take_usize(&mut self, key: &str) -> Result<Option<usize>, String> {
        match self.take(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| format!("{key} expects an integer, got {v:?}")),
        }
    }

    pub fn take_u64(&mut self, key: &str) -> Result<Option<u64>, String> {
        match self.take(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<u64>()
                .map(Some)
                .map_err(|_| format!("{key} expects an integer, got {v:?}")),
        }
    }

    /// Error if any parameter was not consumed by the builder.
    pub fn finish(&self, name: &str) -> Result<(), String> {
        if self.kv.is_empty() {
            Ok(())
        } else {
            let keys: Vec<&str> = self.kv.iter().map(|(k, _)| k.as_str()).collect();
            Err(format!("unknown parameter(s) for {name}: {}", keys.join(", ")))
        }
    }
}

/// One tunable spec parameter (the registry's introspection hook for the
/// autotuner, [`crate::tune`]): which key is searchable and over which
/// canonical value grid. Runtime-registered planners declare theirs the
/// same way, so spec-space search covers out-of-tree planners too.
#[derive(Clone, Copy, Debug)]
pub struct ParamSpec {
    /// Spec key (`alpha`, `m`, ...).
    pub key: &'static str,
    /// Canonical search values, ascending. Integer parameters list whole
    /// numbers here and set `integer`.
    pub grid: &'static [f64],
    /// Format synthesized values as integers (`m=1024`, not `m=1024.0`).
    pub integer: bool,
}

impl ParamSpec {
    /// Render one grid value the way a spec string spells it.
    pub fn format_value(&self, v: f64) -> String {
        if self.integer {
            format!("{}", v as i64)
        } else {
            format!("{v}")
        }
    }
}

/// Tunable dimensions of the `cached(...)` decorator (not a registry
/// entry — the parser special-cases it — but searchable all the same).
pub const CACHED_PARAMS: &[ParamSpec] = &[
    ParamSpec { key: "drift", grid: &[0.02, 0.05, 0.15], integer: false },
    ParamSpec { key: "every", grid: &[0.0, 32.0], integer: true },
    ParamSpec { key: "repair", grid: &[0.0, 0.15], integer: false },
];

/// Tunable dimensions of the `placed(...)` decorator (`standby` is a
/// fault-tolerance knob, not a throughput dimension, so it stays out of
/// the search grids).
pub const PLACED_PARAMS: &[ParamSpec] = &[
    ParamSpec { key: "ema", grid: &[0.1, 0.25, 0.5], integer: false },
    ParamSpec { key: "budget", grid: &[2.0, 4.0, 8.0], integer: true },
    ParamSpec { key: "horizon", grid: &[8.0, 32.0, 128.0], integer: true },
];

/// One registered planner constructor.
pub struct PlannerEntry {
    /// Spec name (the part before `:`).
    pub name: &'static str,
    /// One-line description for `llep info`.
    pub help: &'static str,
    /// Example spec string shown in help output (canonical: parsing it
    /// and re-emitting [`Planner::spec`] extends it with defaults only).
    pub example: &'static str,
    /// Tunable parameters with their canonical search grids.
    pub params: &'static [ParamSpec],
    /// Build the planner from its parameters.
    pub build: fn(&mut Params) -> Result<Box<dyn Planner>, String>,
}

/// The open planner registry. [`Registry::builtin`] knows the in-tree
/// planners; [`Registry::register`] adds more at runtime (later
/// registrations shadow earlier ones of the same name).
pub struct Registry {
    entries: Vec<PlannerEntry>,
}

impl Registry {
    /// Registry with the five in-tree planners.
    pub fn builtin() -> Registry {
        let mut r = Registry { entries: Vec::new() };
        r.register(PlannerEntry {
            name: "ep",
            help: "standard expert parallelism (paper Alg. 1)",
            example: "ep",
            params: &[],
            build: |_| Ok(Box::new(StandardEp)),
        });
        r.register(PlannerEntry {
            name: "llep",
            help: "least-loaded expert parallelism (paper Alg. 2-4)",
            example: "llep:alpha=1,m=1024,lambda=1.3",
            params: &[
                ParamSpec { key: "alpha", grid: &[1.0, 1.25, 1.5], integer: false },
                ParamSpec { key: "m", grid: &[256.0, 1024.0, 4096.0], integer: true },
                ParamSpec { key: "lambda", grid: &[1.1, 1.3, 2.0], integer: false },
            ],
            build: |p| {
                let mut cfg = LlepConfig::default();
                if let Some(v) = p.take_f64("alpha")? {
                    cfg.alpha = v;
                }
                if let Some(v) = p.take_usize("m")? {
                    cfg.min_gemm_tokens = v;
                }
                if let Some(v) = p.take_f64("lambda")? {
                    cfg.lambda = v;
                }
                cfg.validate()?;
                Ok(Box::new(Llep::new(cfg)))
            },
        });
        r.register(PlannerEntry {
            name: "eplb",
            help: "EPLB replication baseline (r = replica budget)",
            example: "eplb:r=8",
            params: &[ParamSpec { key: "r", grid: &[4.0, 8.0, 16.0], integer: true }],
            build: |p| {
                let replicas = p.take_usize("r")?.unwrap_or(8);
                Ok(Box::new(Eplb::new(replicas)))
            },
        });
        r.register(PlannerEntry {
            name: "chunked",
            help: "chunked standard EP (gradient-checkpointing baseline)",
            example: "chunked:c=4096",
            params: &[ParamSpec { key: "c", grid: &[2048.0, 4096.0, 8192.0], integer: true }],
            build: |p| {
                let c = p.take_usize("c")?.unwrap_or(4096);
                if c == 0 {
                    return Err("chunked: c must be positive".into());
                }
                Ok(Box::new(ChunkedEp::new(c)))
            },
        });
        r.register(PlannerEntry {
            name: "lpt",
            help: "greedy longest-processing-time whole-expert rebalancer",
            example: "lpt:min=1024",
            params: &[ParamSpec { key: "min", grid: &[256.0, 1024.0, 4096.0], integer: true }],
            build: |p| {
                let min = p.take_u64("min")?.unwrap_or(1024);
                Ok(Box::new(Lpt::new(min)))
            },
        });
        r
    }

    /// Register a planner; shadows an earlier entry of the same name.
    pub fn register(&mut self, entry: PlannerEntry) {
        self.entries.push(entry);
    }

    pub fn entries(&self) -> &[PlannerEntry] {
        &self.entries
    }

    fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name).collect()
    }

    /// Parse a spec string into a planner.
    pub fn parse(&self, spec: &str) -> Result<Box<dyn Planner>, String> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err("empty planner spec".into());
        }
        if let Some(rest) = spec.strip_prefix("cached(") {
            let close = matching_paren(rest)
                .ok_or_else(|| format!("unbalanced parentheses in {spec:?}"))?;
            let inner = self.parse(&rest[..close])?;
            let tail = &rest[close + 1..];
            let param_str = match tail.strip_prefix(':') {
                Some(s) => s,
                None if tail.is_empty() => "",
                None => return Err(format!("unexpected trailing {tail:?} in {spec:?}")),
            };
            let mut params = Params::parse(param_str)?;
            let mut cp = CachedPlanner::new(inner);
            if let Some(v) = params.take_f64("drift")? {
                cp = cp.with_drift_threshold(v);
            }
            if let Some(v) = params.take_usize("every")? {
                cp = cp.with_replan_every(v);
            }
            if let Some(v) = params.take_u64("q")? {
                cp = cp.with_quant(v);
            }
            if let Some(v) = params.take_f64("repair")? {
                cp = cp.with_repair_ceiling(v);
            }
            params.finish("cached")?;
            return Ok(Box::new(cp));
        }
        if let Some(rest) = spec.strip_prefix("placed(") {
            let close = matching_paren(rest)
                .ok_or_else(|| format!("unbalanced parentheses in {spec:?}"))?;
            let inner = self.parse(&rest[..close])?;
            let tail = &rest[close + 1..];
            let param_str = match tail.strip_prefix(':') {
                Some(s) => s,
                None if tail.is_empty() => "",
                None => return Err(format!("unexpected trailing {tail:?} in {spec:?}")),
            };
            let mut params = Params::parse(param_str)?;
            let mut cfg = PlacementConfig::default();
            if let Some(v) = params.take_f64("ema")? {
                if !(v > 0.0 && v <= 1.0) {
                    return Err(format!("placed: ema must be in (0, 1], got {v}"));
                }
                cfg.ema = v;
            }
            if let Some(v) = params.take_usize("budget")? {
                cfg.budget = v;
            }
            if let Some(v) = params.take_f64("horizon")? {
                if v < 0.0 {
                    return Err(format!("placed: horizon must be >= 0, got {v}"));
                }
                cfg.horizon = v;
            }
            if let Some(v) = params.take_usize("standby")? {
                cfg.standby = v;
            }
            params.finish("placed")?;
            return Ok(Box::new(Placed::with_config(inner, cfg)));
        }
        let (name, tail) = spec.split_once(':').unwrap_or((spec, ""));
        let entry = self
            .entries
            .iter()
            .rev()
            .find(|e| e.name == name)
            .ok_or_else(|| {
                format!("unknown planner {name:?} (known: {})", self.names().join(", "))
            })?;
        let mut params = Params::parse(tail)?;
        let planner = (entry.build)(&mut params)?;
        params.finish(name)?;
        Ok(planner)
    }
}

/// Index of the `)` balancing the implicit `(` already consumed.
fn matching_paren(s: &str) -> Option<usize> {
    let mut depth = 1usize;
    for (i, c) in s.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// Parse a `--planner` spec against the builtin registry.
pub fn parse_planner(spec: &str) -> Result<Box<dyn Planner>, String> {
    Registry::builtin().parse(spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{CacheOutcome, RoutePlan};
    use crate::topology::Topology;

    #[test]
    fn all_builtin_specs_round_trip() {
        for spec in [
            "ep",
            "llep:alpha=1.5,m=64,lambda=1.2",
            "eplb:r=6",
            "chunked:c=2048",
            "lpt:min=512",
        ] {
            let p = parse_planner(spec).unwrap();
            let canon = p.spec();
            let p2 = parse_planner(&canon)
                .unwrap_or_else(|e| panic!("canonical spec {canon:?} must reparse: {e}"));
            assert_eq!(p2.spec(), canon, "spec fixed point for {spec}");
            assert_eq!(p2.label(), p.label(), "same planner for {spec}");
        }
    }

    #[test]
    fn defaults_fill_in() {
        assert_eq!(parse_planner("llep").unwrap().label(), "LLEP(a=1,m=1024,l=1.3)");
        assert_eq!(parse_planner("eplb").unwrap().label(), "EPLB(r=8)");
        assert_eq!(parse_planner("lpt").unwrap().label(), "LPT(min=1024)");
        assert_eq!(parse_planner("chunked").unwrap().label(), "ChunkedEP(c=4096)");
    }

    #[test]
    fn cached_decorator_parses_and_round_trips() {
        let p = parse_planner("cached(llep:alpha=1.5):drift=0.1,every=16").unwrap();
        assert!(p.label().starts_with("Cached[LLEP"));
        assert!(!p.replay_safe());
        let canon = p.spec();
        let p2 = parse_planner(&canon).unwrap();
        assert_eq!(p2.spec(), canon);
        // bare decorator, defaults only
        let bare = parse_planner("cached(ep)").unwrap();
        assert_eq!(bare.label(), "Cached[EP]");
        // repair ceiling round-trips through the canonical spec
        let r = parse_planner("cached(llep):repair=0.15").unwrap();
        assert!(r.spec().contains("repair=0.15"), "spec {:?}", r.spec());
        let r2 = parse_planner(&r.spec()).unwrap();
        assert_eq!(r2.spec(), r.spec());
    }

    #[test]
    fn cached_parse_produces_working_cache() {
        let p = parse_planner("cached(llep)").unwrap();
        let loads = vec![9_000u64, 0, 0, 1_000];
        let _ = p.plan(2, &loads, None);
        let _ = p.plan(2, &loads, None);
        assert_eq!(p.last_cache_outcome(), Some(CacheOutcome::Hit));
    }

    #[test]
    fn examples_extend_canonically_and_params_synthesize_valid_specs() {
        let reg = Registry::builtin();
        for e in reg.entries() {
            // The example must parse, and its canonical form must begin
            // with the example's explicit assignments (defaults are only
            // appended, never respelled) — keeps help text and registry
            // output in sync.
            let p = parse_planner(e.example)
                .unwrap_or_else(|err| panic!("example {:?} must parse: {err}", e.example));
            let canon = p.spec();
            assert!(
                canon.starts_with(e.example) || canon == e.example,
                "{}: example {:?} is not a prefix of canonical {:?}",
                e.name,
                e.example,
                canon
            );
            // Every declared grid value produces a valid single-parameter
            // spec (the autotuner's synthesis contract).
            for ps in e.params {
                for &v in ps.grid {
                    let spec = format!("{}:{}={}", e.name, ps.key, ps.format_value(v));
                    parse_planner(&spec)
                        .unwrap_or_else(|err| panic!("synthesized {spec:?} must parse: {err}"));
                }
            }
        }
        // Decorator dimensions synthesize too.
        for ps in CACHED_PARAMS {
            for &v in ps.grid {
                let spec = format!("cached(ep):{}={}", ps.key, ps.format_value(v));
                parse_planner(&spec)
                    .unwrap_or_else(|err| panic!("synthesized {spec:?} must parse: {err}"));
            }
        }
        for ps in super::PLACED_PARAMS {
            for &v in ps.grid {
                let spec = format!("placed(ep):{}={}", ps.key, ps.format_value(v));
                parse_planner(&spec)
                    .unwrap_or_else(|err| panic!("synthesized {spec:?} must parse: {err}"));
            }
        }
    }

    #[test]
    fn placed_decorator_parses_round_trips_and_nests() {
        let p = parse_planner("placed(llep):ema=0.5,budget=2,horizon=16,standby=1").unwrap();
        assert_eq!(p.label(), "Placed[LLEP(a=1,m=1024,l=1.3)]");
        assert!(!p.replay_safe());
        let canon = p.spec();
        let p2 = parse_planner(&canon).unwrap();
        assert_eq!(p2.spec(), canon, "placed spec fixed point");
        // Bare decorator fills defaults; EPLB policy bits pass through.
        let bare = parse_planner("placed(eplb:r=4)").unwrap();
        assert_eq!(bare.label(), "Placed[EPLB(r=4)]");
        assert!(!bare.charges_weight_transfers());
        assert!(bare.wants_stale_stats());
        // Both nesting orders parse and round-trip.
        for spec in ["placed(cached(llep)):ema=0.25", "cached(placed(llep)):drift=0.05"] {
            let p = parse_planner(spec).unwrap();
            let canon = p.spec();
            assert_eq!(parse_planner(&canon).unwrap().spec(), canon, "{spec}");
        }
        // Errors stay loud.
        assert!(parse_planner("placed(llep").unwrap_err().contains("unbalanced"));
        assert!(parse_planner("placed(ep)x").unwrap_err().contains("trailing"));
        assert!(parse_planner("placed(ep):frob=1").unwrap_err().contains("unknown parameter"));
        assert!(parse_planner("placed(ep):ema=0").unwrap_err().contains("ema"));
    }

    #[test]
    fn errors_are_loud() {
        assert!(parse_planner("bogus").unwrap_err().contains("unknown planner"));
        assert!(parse_planner("llep:frob=1").unwrap_err().contains("unknown parameter"));
        assert!(parse_planner("llep:alpha=abc").unwrap_err().contains("expects a number"));
        assert!(parse_planner("llep:alpha").unwrap_err().contains("key=value"));
        assert!(parse_planner("cached(llep").unwrap_err().contains("unbalanced"));
        assert!(parse_planner("cached(ep)x").unwrap_err().contains("trailing"));
        assert!(parse_planner("").is_err());
        assert!(parse_planner("llep:alpha=0.5").is_err(), "LlepConfig::validate applies");
    }

    #[test]
    fn runtime_registration_extends_the_set() {
        // Prove extensibility: an out-of-tree planner joins via one
        // register() call, no enum edits anywhere.
        struct EverythingOnZero;
        impl crate::planner::Planner for EverythingOnZero {
            fn plan_with_stats(
                &self,
                devices: usize,
                loads: &[u64],
                _stats: &[u64],
                _topo: Option<&Topology>,
            ) -> RoutePlan {
                let mut plan = crate::planner::plan_ep(loads.len(), devices, loads);
                plan.fallback_ep = false;
                plan
            }
            fn label(&self) -> String {
                "ZERO".into()
            }
            fn spec(&self) -> String {
                "zero".into()
            }
        }
        let mut reg = Registry::builtin();
        reg.register(PlannerEntry {
            name: "zero",
            help: "test-only",
            example: "zero",
            params: &[],
            build: |_| Ok(Box::new(EverythingOnZero)),
        });
        let p = reg.parse("zero").unwrap();
        assert_eq!(p.label(), "ZERO");
        let plan = p.plan(2, &[5, 5, 5, 5], None);
        assert_eq!(plan.num_experts, 4);
        // ... and the decorator composes with it.
        let cached = reg.parse("cached(zero):drift=0.2").unwrap();
        assert_eq!(cached.label(), "Cached[ZERO]");
    }
}
