//! Reusable planning arena: the zero-allocation hot path.
//!
//! Planning runs once per MoE layer per serve step and thousands of
//! times per tuner run, so allocator traffic — not the assignment
//! algorithm — used to dominate the inner loop. [`PlanScratch`] owns
//! every buffer a planner needs (expert order, per-device load
//! accumulators, spill heaps, and a pool of retired [`RoutePlan`]
//! shells whose segment vectors are recycled), so steady-state planning
//! touches the heap zero times: the counting-allocator test at the
//! bottom of this file asserts exactly that.
//!
//! Two ways to get a scratch:
//!
//! * **Explicit** — construct a [`PlanScratch`], pass it to the
//!   `*_scratch` planner entry points, and hand finished plans back via
//!   [`PlanScratch::recycle`]. This is what the benches and the
//!   zero-alloc test use.
//! * **Thread-local** — [`with_thread_scratch`] lends each thread one
//!   arena; every trait-planner entry point plans through it, and
//!   [`recycle_plan`] returns a retired plan's buffers to the calling
//!   thread's arena (the engine recycles its warm run, the serving
//!   sims and tuner recycle priced layer plans). Scoped worker threads
//!   (per-layer planning, tuner trial evaluation) each get their own
//!   arena, so there is no cross-thread contention to pay for.

use super::{RoutePlan, Segment};
use std::cell::RefCell;
use std::cmp::Ordering;
use std::cmp::Reverse;

/// Retired plan shells kept per arena (warm plan + a few layers).
const PLAN_POOL_CAP: usize = 8;
/// Spare per-expert segment vectors kept when plan shapes shrink.
const SPARE_SEGS_CAP: usize = 1024;

/// Spill candidate under a speed profile: least *normalized* load
/// first, intra-node peers preferred on ties, then lowest index — the
/// exact order `lla.rs` historically re-sorted per spill iteration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) struct NormCand {
    pub norm: f64,
    pub inter: u8,
    pub dev: usize,
}

impl Eq for NormCand {}

impl PartialOrd for NormCand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for NormCand {
    fn cmp(&self, other: &Self) -> Ordering {
        self.norm
            .total_cmp(&other.norm)
            .then(self.inter.cmp(&other.inter))
            .then(self.dev.cmp(&other.dev))
    }
}

/// Heap backings for the least-loaded spill (Alg. 3). `BinaryHeap` is
/// built from (and drained back into) these vectors, so the heap
/// storage itself is reused across experts and steps.
#[derive(Default)]
pub(crate) struct SpillHeaps {
    pub heap_u: Vec<Reverse<(u64, u8, usize)>>,
    pub popped_u: Vec<(u64, u8, usize)>,
    pub heap_f: Vec<Reverse<NormCand>>,
    pub popped_f: Vec<NormCand>,
}

/// The reusable planning arena. See the module docs.
#[derive(Default)]
pub struct PlanScratch {
    /// Expert indices, sorted by decreasing load per plan.
    pub(crate) order: Vec<usize>,
    /// Pending (not-yet-visited) native load per device.
    pub(crate) g_p: Vec<u64>,
    /// Assigned load per device (doubles as LPT's `dev_load`).
    pub(crate) g_a: Vec<u64>,
    /// Per-device "transfer already recorded" marks.
    pub(crate) seen: Vec<bool>,
    /// Speed-proportional per-device capacities (empty = homogeneous).
    pub(crate) caps: Vec<f64>,
    pub(crate) spill: SpillHeaps,
    /// Delta-repair: tokens over capacity per device.
    pub(crate) over: Vec<u64>,
    /// Delta-repair peel candidates:
    /// `(device, native-flag, seg len, expert, seg index)` — sorted so
    /// stale spill targets shed foreign segments first, largest first.
    pub(crate) peel: Vec<(usize, u8, u64, usize, usize)>,
    /// Delta-repair accepted peels: `(expert, seg index, tokens taken)`.
    pub(crate) takes: Vec<(usize, usize, u64)>,
    /// Retired plans whose assignment/transfer vectors get reused.
    plans: Vec<RoutePlan>,
    /// Spare per-expert segment vectors (kept when shapes shrink).
    spare_segs: Vec<Vec<Segment>>,
}

impl PlanScratch {
    pub fn new() -> PlanScratch {
        PlanScratch::default()
    }

    /// A cleared plan shell sized for `num_experts`/`devices`. Buffers
    /// come from the recycled pool where possible, so in steady state
    /// (same shapes step to step) this performs no heap allocation.
    pub(crate) fn take_plan(&mut self, num_experts: usize, devices: usize) -> RoutePlan {
        let mut plan = self.plans.pop().unwrap_or_else(|| RoutePlan {
            num_experts,
            devices,
            assignments: Vec::new(),
            transfers: Vec::new(),
            migrations: Vec::new(),
            fallback_ep: false,
        });
        plan.num_experts = num_experts;
        plan.devices = devices;
        plan.fallback_ep = false;
        plan.transfers.clear();
        plan.migrations.clear();
        while plan.assignments.len() > num_experts {
            let mut v = plan.assignments.pop().expect("len checked");
            if self.spare_segs.len() < SPARE_SEGS_CAP {
                v.clear();
                self.spare_segs.push(v);
            }
        }
        for segs in &mut plan.assignments {
            segs.clear();
        }
        while plan.assignments.len() < num_experts {
            plan.assignments.push(self.spare_segs.pop().unwrap_or_default());
        }
        plan
    }

    /// Return a finished plan's buffers to the arena so the next
    /// [`take_plan`](Self::take_plan) reuses them.
    pub fn recycle(&mut self, mut plan: RoutePlan) {
        if self.plans.len() >= PLAN_POOL_CAP {
            return;
        }
        plan.transfers.clear();
        plan.migrations.clear();
        self.plans.push(plan);
    }

    /// Clear + size the per-device accumulators.
    pub(crate) fn prepare_devices(&mut self, devices: usize) {
        self.g_p.clear();
        self.g_p.resize(devices, 0);
        self.g_a.clear();
        self.g_a.resize(devices, 0);
        self.seen.clear();
        self.seen.resize(devices, false);
    }
}

thread_local! {
    static SCRATCH: RefCell<Option<PlanScratch>> = const { RefCell::new(None) };
}

/// Run `f` with this thread's arena. The arena is taken out of the slot
/// for the duration (a re-entrant call sees an empty slot and falls
/// back to a fresh arena rather than aborting on a double borrow).
pub fn with_thread_scratch<R>(f: impl FnOnce(&mut PlanScratch) -> R) -> R {
    let mut s = SCRATCH.with(|slot| slot.borrow_mut().take()).unwrap_or_default();
    let r = f(&mut s);
    SCRATCH.with(|slot| *slot.borrow_mut() = Some(s));
    r
}

/// Return a finished plan's buffers to the calling thread's arena. The
/// engine calls this on its warm run and the serving/tuning loops call
/// it on priced layer plans, closing the take/recycle cycle that makes
/// steady-state planning allocation-free.
pub fn recycle_plan(plan: RoutePlan) {
    with_thread_scratch(|s| s.recycle(plan));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LlepConfig;
    use crate::planner::{plan_llep_scratch, validate::validate_plan, Planner, PlannerKind};

    #[test]
    fn take_plan_resizes_and_clears() {
        let mut s = PlanScratch::new();
        let mut p = s.take_plan(4, 2);
        p.assignments[0].push(Segment { device: 0, start: 0, end: 5, forced: false });
        p.transfers.push(crate::planner::WeightTransfer { expert: 0, from: 0, to: 1 });
        p.fallback_ep = true;
        s.recycle(p);
        let p = s.take_plan(2, 1);
        assert_eq!(p.num_experts, 2);
        assert_eq!(p.devices, 1);
        assert!(!p.fallback_ep);
        assert!(p.transfers.is_empty());
        assert!(p.assignments.iter().all(Vec::is_empty));
        s.recycle(p);
        // Growing again reuses the spare segment vectors.
        let p = s.take_plan(8, 4);
        assert_eq!(p.assignments.len(), 8);
        assert!(p.assignments.iter().all(Vec::is_empty));
    }

    #[test]
    fn reused_scratch_plans_bit_identically_to_fresh() {
        let cfg = LlepConfig { alpha: 1.0, min_gemm_tokens: 16, lambda: 1.3 };
        let loads = vec![977u64, 3, 250, 41, 0, 123, 77, 529];
        let mut reused = PlanScratch::new();
        for _ in 0..10 {
            let fresh = plan_llep_scratch(&cfg, 8, 4, &loads, None, None, &mut PlanScratch::new());
            let warm = plan_llep_scratch(&cfg, 8, 4, &loads, None, None, &mut reused);
            assert_eq!(fresh, warm);
            validate_plan(&warm, &loads).unwrap();
            reused.recycle(warm);
        }
    }

    /// The tentpole contract: once warmed up, planning with a recycled
    /// arena performs ZERO heap allocations — asserted with the
    /// per-thread counting allocator installed for the lib test binary
    /// (see `util::alloc_count`).
    #[test]
    fn steady_state_planning_allocates_nothing() {
        let cfg = LlepConfig { alpha: 1.0, min_gemm_tokens: 64, lambda: 1.3 };
        // A skewed load: hot expert spills across devices, exercising
        // the heap path, segment pushes, and transfer recording.
        let mut loads = vec![64u64; 128];
        loads[0] = 40_000;
        loads[7] = 9_000;
        let mut s = PlanScratch::new();
        // Warm up: establish every buffer's capacity.
        for _ in 0..3 {
            let p = plan_llep_scratch(&cfg, 128, 8, &loads, None, None, &mut s);
            s.recycle(p);
        }
        let before = crate::util::alloc_count::allocations_on_this_thread();
        for _ in 0..50 {
            let p = plan_llep_scratch(&cfg, 128, 8, &loads, None, None, &mut s);
            s.recycle(p);
        }
        let after = crate::util::alloc_count::allocations_on_this_thread();
        assert_eq!(after - before, 0, "steady-state plan_llep must not allocate");
    }

    #[test]
    fn steady_state_trait_planning_allocates_nothing() {
        // The trait path (`plan_with_stats` via the thread-local arena)
        // is what the engine actually times as T_plan: it must be
        // allocation-free too once plans are recycled.
        let planner = PlannerKind::llep_default().boxed();
        let mut loads = vec![64u64; 128];
        loads[3] = 50_000;
        for _ in 0..3 {
            recycle_plan(planner.plan_with_stats(8, &loads, &loads, None));
        }
        let before = crate::util::alloc_count::allocations_on_this_thread();
        for _ in 0..50 {
            recycle_plan(planner.plan_with_stats(8, &loads, &loads, None));
        }
        let after = crate::util::alloc_count::allocations_on_this_thread();
        assert_eq!(after - before, 0, "steady-state trait planning must not allocate");
    }

    #[test]
    fn steady_state_cached_hit_allocates_nothing() {
        use crate::planner::CachedPlanner;
        let cached = CachedPlanner::new(PlannerKind::llep_default().boxed());
        let mut loads = vec![64u64; 128];
        loads[0] = 30_000;
        // Miss once, then warm the hit path's buffers.
        for _ in 0..3 {
            recycle_plan(cached.plan(8, &loads, None));
        }
        let before = crate::util::alloc_count::allocations_on_this_thread();
        for _ in 0..50 {
            recycle_plan(cached.plan(8, &loads, None));
        }
        let after = crate::util::alloc_count::allocations_on_this_thread();
        assert_eq!(after - before, 0, "steady-state cache hits must not allocate");
    }

    #[test]
    fn steady_state_cached_repair_allocates_nothing() {
        use crate::planner::{CacheOutcome, CachedPlanner, Llep};
        // Drift between the retarget threshold and the repair ceiling on
        // every step: alternate two load vectors whose hot expert sheds
        // ~5% of total to a neighbour, so each lookup takes the
        // delta-repair path (asserted below), never the fresh-plan path.
        let cfg = LlepConfig { alpha: 1.0, min_gemm_tokens: 16, lambda: 1.3 };
        let cached = CachedPlanner::new(Box::new(Llep::new(cfg))).with_repair_ceiling(0.2);
        let mut a = vec![64u64; 128];
        a[0] = 30_000;
        let mut b = a.clone();
        b[0] = 28_000;
        b[1] = 2_064;
        // Miss once, then warm both alternating shapes' buffers.
        recycle_plan(cached.plan(8, &a, None));
        for i in 0..6 {
            let loads = if i % 2 == 0 { &b } else { &a };
            recycle_plan(cached.plan(8, loads, None));
            assert_eq!(cached.last_cache_outcome(), Some(CacheOutcome::Repaired));
        }
        let before = crate::util::alloc_count::allocations_on_this_thread();
        for i in 0..50 {
            let loads = if i % 2 == 0 { &b } else { &a };
            recycle_plan(cached.plan(8, loads, None));
        }
        let after = crate::util::alloc_count::allocations_on_this_thread();
        assert_eq!(after - before, 0, "steady-state repairs must not allocate");
        assert_eq!(cached.last_cache_outcome(), Some(CacheOutcome::Repaired));
    }
}
