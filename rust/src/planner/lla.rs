//! Least-Loaded Assignment — the paper's Alg. 2 (LLA) and Alg. 3 (LLAS).
//!
//! For each expert, in decreasing-load order, assign its tokens to the
//! native device up to the capacity threshold
//! `m_alpha = alpha * total / P`, then spill the excess in chunks to the
//! least-loaded other devices. Chunks smaller than `m` tokens are not
//! worth a GEMM launch + weight transfer (paper §3.2 / Fig. 8), so they
//! are either kept local (native min-GEMM exception) or skipped for a
//! fuller device; if nobody can take a chunk within capacity, the
//! remainder is force-assigned to the least-loaded device (LLAS
//! fallback), which is the only way a device may exceed `m_alpha`.
//!
//! ## Hot path
//!
//! Planning sits on the critical path of every step, so the
//! implementation is engineered around a reusable [`PlanScratch`] arena
//! (zero heap allocations in steady state — see `scratch.rs`) and the
//! spill candidates live in a `BinaryHeap` keyed by (normalized) load:
//! one spill iteration changes a single device's key, so each chunk
//! costs `O(log P)` instead of the historical `O(P log P)` re-sort
//! (`O(S·log P)` per expert over `S` spill segments). The heap pops
//! candidates in exactly the order the re-sort produced, so plans are
//! bit-identical to the sort-based implementation — property-tested
//! against a reference reimplementation in `rust/tests/hotpath.rs`.

use super::scratch::{with_thread_scratch, NormCand, PlanScratch, SpillHeaps};
use super::{plan_ep_scratch, Planner, RepairParams, RoutePlan, Segment, WeightTransfer};
use crate::chaos::PoolState;
use crate::config::LlepConfig;
use crate::routing::imbalance_ratio;
use crate::topology::Topology;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// LLEP (paper Alg. 2-4) as a trait planner: the Alg. 4 lambda guard
/// reverts to standard EP when the routing is balanced enough, otherwise
/// runs the least-loaded assignment.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Llep {
    pub cfg: LlepConfig,
}

impl Llep {
    pub fn new(cfg: LlepConfig) -> Llep {
        Llep { cfg }
    }

    fn plan_into(
        &self,
        devices: usize,
        loads: &[u64],
        topo: Option<&Topology>,
        scratch: &mut PlanScratch,
    ) -> RoutePlan {
        if imbalance_ratio(loads) < self.cfg.lambda {
            // Alg. 4 guard: balanced enough — standard EP.
            let mut p = plan_ep_scratch(loads.len(), devices, loads, scratch);
            p.fallback_ep = true;
            p
        } else {
            plan_llep_scratch(&self.cfg, loads.len(), devices, loads, topo, None, scratch)
        }
    }
}

impl Planner for Llep {
    fn plan_with_stats(
        &self,
        devices: usize,
        loads: &[u64],
        _stats: &[u64],
        topo: Option<&Topology>,
    ) -> RoutePlan {
        with_thread_scratch(|s| self.plan_into(devices, loads, topo, s))
    }

    fn plan_with_pool(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
        pool: Option<&PoolState>,
    ) -> RoutePlan {
        match pool {
            Some(p) if p.is_degraded() => {
                // A degraded pool invalidates the Alg. 4 guard: equal
                // *token* loads are not equal *completion times* when
                // speeds differ, and a dead native device must be
                // re-planned around no matter how balanced the routing
                // looks. Always run the (speed-aware) assignment.
                if p.alive_count() == 0 {
                    // Nothing schedulable. Return the degenerate native
                    // plan; pricing strands it and the sims surface the
                    // error — planners themselves stay total.
                    return with_thread_scratch(|s| {
                        plan_ep_scratch(loads.len(), devices, loads, s)
                    });
                }
                with_thread_scratch(|s| {
                    plan_llep_scratch(&self.cfg, loads.len(), devices, loads, topo, Some(p), s)
                })
            }
            _ => self.plan_with_stats(devices, loads, stats, topo),
        }
    }

    fn label(&self) -> String {
        format!(
            "LLEP(a={},m={},l={})",
            self.cfg.alpha, self.cfg.min_gemm_tokens, self.cfg.lambda
        )
    }

    fn spec(&self) -> String {
        format!(
            "llep:alpha={},m={},lambda={}",
            self.cfg.alpha, self.cfg.min_gemm_tokens, self.cfg.lambda
        )
    }

    fn repair_params(&self) -> Option<RepairParams> {
        Some(RepairParams {
            alpha: self.cfg.alpha,
            min_gemm_tokens: self.cfg.min_gemm_tokens as u64,
        })
    }
}

/// Build the LLEP plan. `topo`, when given, breaks least-loaded ties in
/// favour of intra-node devices (paper §4 "Implementation & Optimization"
/// — multi-node spill preference).
pub fn plan_llep(
    cfg: &LlepConfig,
    num_experts: usize,
    devices: usize,
    loads: &[u64],
    topo: Option<&Topology>,
) -> RoutePlan {
    with_thread_scratch(|s| plan_llep_scratch(cfg, num_experts, devices, loads, topo, None, s))
}

/// Speed-aware LLEP over a degraded/heterogeneous pool: capacities and
/// least-loaded ordering are in *normalized time* (`tokens / speed`), so
/// a device's token share is proportional to its effective speed and the
/// makespan `max_d load_d / s_d` — the quantity a straggler actually
/// bounds — is what gets balanced. Dead devices (speed 0) have zero
/// capacity and are never spilled to; experts native to a dead device
/// spill entirely, which is the elastic replan the serving layer relies
/// on after a failure.
pub fn plan_llep_pool(
    cfg: &LlepConfig,
    num_experts: usize,
    devices: usize,
    loads: &[u64],
    topo: Option<&Topology>,
    pool: &PoolState,
) -> RoutePlan {
    with_thread_scratch(|s| {
        plan_llep_scratch(cfg, num_experts, devices, loads, topo, Some(pool), s)
    })
}

/// The scratch-threaded LLA/LLAS implementation behind [`plan_llep`] and
/// [`plan_llep_pool`]: all working state and the returned plan's buffers
/// come from `scratch`, so a caller that recycles finished plans
/// ([`PlanScratch::recycle`]) plans allocation-free in steady state.
pub fn plan_llep_scratch(
    cfg: &LlepConfig,
    num_experts: usize,
    devices: usize,
    loads: &[u64],
    topo: Option<&Topology>,
    pool: Option<&PoolState>,
    scratch: &mut PlanScratch,
) -> RoutePlan {
    assert_eq!(loads.len(), num_experts);
    assert!(devices > 0 && num_experts % devices == 0, "N must divide P");
    if let Some(p) = pool {
        assert_eq!(p.len(), devices, "pool must cover every device");
    }
    let m_per_dev = num_experts / devices;
    let total: u64 = loads.iter().sum();
    let mut plan = scratch.take_plan(num_experts, devices);
    if total == 0 {
        return plan;
    }

    // m_alpha: capacity threshold per device (tokens). Homogeneous pools
    // keep the paper's scalar `alpha * total / P` (bit-identical to the
    // pre-chaos planner); a speed profile splits the same total budget
    // `alpha * total` proportionally to effective speed, so every
    // device's *normalized* capacity `m_alpha_d / s_d` is equal and dead
    // devices get exactly zero.
    let m_alpha = cfg.alpha * total as f64 / devices as f64;
    scratch.caps.clear();
    if let Some(p) = pool {
        let sum: f64 = p.devices.iter().map(|d| d.effective_speed()).sum();
        let denom = sum.max(f64::MIN_POSITIVE);
        scratch.caps.extend(
            p.devices.iter().map(|d| cfg.alpha * total as f64 * d.effective_speed() / denom),
        );
    }
    let min_chunk = cfg.min_gemm_tokens as u64;

    // Sorted expert order, decreasing load (stable on index for ties).
    scratch.order.clear();
    scratch.order.extend(0..num_experts);
    scratch.order.sort_unstable_by_key(|&e| (Reverse(loads[e]), e));

    // Native (pending) and assigned load per device.
    scratch.prepare_devices(devices);
    for (e, &l) in loads.iter().enumerate() {
        scratch.g_p[e / m_per_dev] += l;
    }

    // Disjoint field borrows for the expert loop.
    let PlanScratch { order, g_p, g_a, seen, caps, spill: heaps, .. } = scratch;
    let cap_of = |d: usize| if caps.is_empty() { m_alpha } else { caps[d] };
    let speed = |d: usize| pool.map_or(1.0, |p| p.devices[d].effective_speed());

    for &e in order.iter() {
        let load = loads[e];
        let ng = e / m_per_dev;
        g_p[ng] -= load;
        if load == 0 {
            continue;
        }
        let segs = &mut plan.assignments[e];

        // Available native capacity (may be negative). A dead native
        // device has no capacity at all: everything must spill, even
        // loads below the min-GEMM size.
        let native_dead = pool.is_some() && speed(ng) <= 0.0;
        let occupied = (g_a[ng] + g_p[ng]) as f64;
        let na = if native_dead { i64::MIN } else { (cap_of(ng) - occupied).floor() as i64 };

        if !native_dead && na >= load as i64 {
            // Case 1: native device takes everything. This is the common
            // case on balanced-ish loads — no spill machinery touched.
            segs.push(Segment { device: ng, start: 0, end: load, forced: false });
            g_a[ng] += load;
        } else if na > 0 {
            // Case 2: native takes what fits; spill the rest — unless the
            // remainder is below the min-GEMM size, in which case keeping
            // it local beats a weight transfer (paper §4 constraint 2).
            let nc = (na as u64).min(load);
            let remaining = load - nc;
            if remaining < min_chunk {
                segs.push(Segment { device: ng, start: 0, end: load, forced: true });
                g_a[ng] += load;
            } else {
                segs.push(Segment { device: ng, start: 0, end: nc, forced: false });
                g_a[ng] += nc;
                spill(ng, remaining, nc, segs, g_a, g_p, &cap_of, min_chunk, topo, pool, heaps);
            }
        } else {
            // Case 3: native is already at/over capacity — spill the whole
            // expert, except tiny loads which stay local (never on a dead
            // native device: those must move regardless of size).
            if load < min_chunk && !native_dead {
                segs.push(Segment { device: ng, start: 0, end: load, forced: true });
                g_a[ng] += load;
            } else {
                spill(ng, load, 0, segs, g_a, g_p, &cap_of, min_chunk, topo, pool, heaps);
            }
        }

        merge_adjacent(segs);
        // Record weight transfers for foreign segments (scratch `seen` is
        // reused across experts and reset only where touched).
        for s in segs.iter() {
            if s.device != ng && !seen[s.device] {
                seen[s.device] = true;
                plan.transfers.push(WeightTransfer { expert: e, from: ng, to: s.device });
            }
        }
        for s in segs.iter() {
            seen[s.device] = false;
        }
    }
    // Canonical `(to, from, expert)` transfer order at construction:
    // pricing accumulates straight off the borrowed slice (no per-step
    // clone + sort) and two plans with the same transfer *set* price
    // bit-identically.
    plan.canonicalize_transfers();
    plan
}

/// Alg. 3 (LLAS): spill `r` remaining tokens of one expert, starting at
/// global token offset `to`, to the least-loaded non-native devices.
/// With a speed profile, "least loaded" means least *normalized* load
/// (`tokens / speed`) over the alive devices, and per-device capacities
/// come from `cap_of`.
///
/// Candidates sit in a min-heap keyed exactly like the historical
/// per-iteration re-sort (`(load, inter-node, index)`, or the normalized
/// float triple under a speed profile). One iteration pops candidates in
/// sorted order until one accepts a chunk; skipped candidates are pushed
/// back unchanged (their loads did not move) and the accepted device is
/// re-keyed — so the pop order of the next iteration matches a full
/// re-sort, while costing `O(log P)` per chunk.
///
/// `pub(crate)` so the plan cache's delta-repair tier (`cache.rs`) can
/// re-spill a repaired plan's excess through the exact same machinery,
/// seeded with the surviving devices' loads.
#[allow(clippy::too_many_arguments)]
pub(crate) fn spill(
    ng: usize,
    r: u64,
    to: u64,
    segs: &mut Vec<Segment>,
    g_a: &mut [u64],
    g_p: &[u64],
    cap_of: &impl Fn(usize) -> f64,
    min_chunk: u64,
    topo: Option<&Topology>,
    pool: Option<&PoolState>,
    heaps: &mut SpillHeaps,
) {
    let devices = g_a.len();
    let inter = |d: usize| topo.map_or(0u8, |t| !t.same_node(ng, d) as u8);
    match pool {
        None => {
            let mut vec = std::mem::take(&mut heaps.heap_u);
            vec.clear();
            vec.extend(
                (0..devices)
                    .filter(|&d| d != ng)
                    .map(|d| Reverse((g_a[d] + g_p[d], inter(d), d))),
            );
            if vec.is_empty() {
                heaps.heap_u = vec;
                force_native(ng, r, to, segs, g_a);
                return;
            }
            let mut heap = BinaryHeap::from(vec);
            spill_heap_u(r, to, segs, g_a, g_p, cap_of, min_chunk, &mut heap, &mut heaps.popped_u);
            let mut vec = heap.into_vec();
            vec.clear();
            heaps.heap_u = vec;
        }
        Some(p) => {
            // Dead devices are unschedulable: never spill candidates.
            let sp = |d: usize| p.devices[d].effective_speed();
            let mut vec = std::mem::take(&mut heaps.heap_f);
            vec.clear();
            vec.extend((0..devices).filter(|&d| d != ng && sp(d) > 0.0).map(|d| {
                Reverse(NormCand {
                    norm: (g_a[d] + g_p[d]) as f64 / sp(d),
                    inter: inter(d),
                    dev: d,
                })
            }));
            if vec.is_empty() {
                // P=1 (or everything else dead): there is nowhere to
                // spill — keep the whole remainder native, flagged forced
                // (it exceeds m_alpha by construction, which is the only
                // legal way to exceed it). On a dead native device
                // pricing strands the plan and the serving layer raises
                // the error.
                heaps.heap_f = vec;
                force_native(ng, r, to, segs, g_a);
                return;
            }
            let mut heap = BinaryHeap::from(vec);
            spill_heap_f(
                r,
                to,
                segs,
                g_a,
                g_p,
                cap_of,
                min_chunk,
                &sp,
                &mut heap,
                &mut heaps.popped_f,
            );
            let mut vec = heap.into_vec();
            vec.clear();
            heaps.heap_f = vec;
        }
    }
}

fn force_native(ng: usize, r: u64, to: u64, segs: &mut Vec<Segment>, g_a: &mut [u64]) {
    segs.push(Segment { device: ng, start: to, end: to + r, forced: true });
    g_a[ng] += r;
}

/// Homogeneous spill loop over the `(load, inter, index)` min-heap.
#[allow(clippy::too_many_arguments)]
fn spill_heap_u(
    mut r: u64,
    mut to: u64,
    segs: &mut Vec<Segment>,
    g_a: &mut [u64],
    g_p: &[u64],
    cap_of: &impl Fn(usize) -> f64,
    min_chunk: u64,
    heap: &mut BinaryHeap<Reverse<(u64, u8, usize)>>,
    popped: &mut Vec<(u64, u8, usize)>,
) {
    while r > 0 {
        popped.clear();
        let mut first: Option<usize> = None;
        let mut accepted: Option<(u64, u8, usize)> = None;
        while let Some(Reverse(cand)) = heap.pop() {
            let (_, i, d) = cand;
            if first.is_none() {
                first = Some(d);
            }
            let occupied = (g_a[d] + g_p[d]) as f64;
            let cap = (cap_of(d) - occupied).floor() as i64;
            if cap <= 0 {
                popped.push(cand); // device full
                continue;
            }
            let c = r.min(cap as u64);
            if c < min_chunk && r > c {
                // Chunk too small to justify a transfer + tiny GEMM, and
                // it would not even finish the expert — skip this device.
                popped.push(cand);
                continue;
            }
            segs.push(Segment { device: d, start: to, end: to + c, forced: false });
            g_a[d] += c;
            r -= c;
            to += c;
            accepted = Some((g_a[d] + g_p[d], i, d));
            break;
        }
        for &cand in popped.iter() {
            heap.push(Reverse(cand));
        }
        match accepted {
            Some(key) => heap.push(Reverse(key)),
            None => {
                // Force-assign the entire remainder to the least-loaded
                // other device (it will exceed m_alpha — flagged forced).
                let o = first.expect("candidate set is non-empty");
                segs.push(Segment { device: o, start: to, end: to + r, forced: true });
                g_a[o] += r;
                return;
            }
        }
    }
}

/// Speed-aware spill loop over the normalized-load min-heap.
#[allow(clippy::too_many_arguments)]
fn spill_heap_f(
    mut r: u64,
    mut to: u64,
    segs: &mut Vec<Segment>,
    g_a: &mut [u64],
    g_p: &[u64],
    cap_of: &impl Fn(usize) -> f64,
    min_chunk: u64,
    sp: &impl Fn(usize) -> f64,
    heap: &mut BinaryHeap<Reverse<NormCand>>,
    popped: &mut Vec<NormCand>,
) {
    while r > 0 {
        popped.clear();
        let mut first: Option<usize> = None;
        let mut accepted: Option<NormCand> = None;
        while let Some(Reverse(cand)) = heap.pop() {
            let d = cand.dev;
            if first.is_none() {
                first = Some(d);
            }
            let occupied = (g_a[d] + g_p[d]) as f64;
            let cap = (cap_of(d) - occupied).floor() as i64;
            if cap <= 0 {
                popped.push(cand);
                continue;
            }
            let c = r.min(cap as u64);
            if c < min_chunk && r > c {
                popped.push(cand);
                continue;
            }
            segs.push(Segment { device: d, start: to, end: to + c, forced: false });
            g_a[d] += c;
            r -= c;
            to += c;
            let norm = (g_a[d] + g_p[d]) as f64 / sp(d);
            accepted = Some(NormCand { norm, inter: cand.inter, dev: d });
            break;
        }
        for &cand in popped.iter() {
            heap.push(Reverse(cand));
        }
        match accepted {
            Some(key) => heap.push(Reverse(key)),
            None => {
                let o = first.expect("candidate set is non-empty");
                segs.push(Segment { device: o, start: to, end: to + r, forced: true });
                g_a[o] += r;
                return;
            }
        }
    }
}

/// Merge adjacent segments that landed on the same device, in place.
/// Segments are constructed in ascending token order (native first,
/// spills at increasing offsets), so no sort is needed — asserted in
/// debug builds.
pub(crate) fn merge_adjacent(segs: &mut Vec<Segment>) {
    debug_assert!(segs.windows(2).all(|w| w[0].start <= w[1].start));
    let mut w = 0usize;
    for i in 0..segs.len() {
        let s = segs[i];
        if w > 0 {
            let last = &mut segs[w - 1];
            if last.device == s.device && last.end == s.start {
                last.end = s.end;
                last.forced |= s.forced;
                continue;
            }
        }
        segs[w] = s;
        w += 1;
    }
    segs.truncate(w);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::validate::validate_plan;

    fn cfg(alpha: f64, m: usize, lambda: f64) -> LlepConfig {
        LlepConfig { alpha, min_gemm_tokens: m, lambda }
    }

    #[test]
    fn balanced_loads_stay_native() {
        let loads = vec![100u64; 8];
        let plan = plan_llep(&cfg(1.0, 8, 1.3), 8, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        assert!(plan.is_pure_ep(), "{plan:?}");
        assert_eq!(plan.device_loads(), vec![200; 4]);
    }

    #[test]
    fn single_hot_expert_spreads_evenly() {
        // All 1000 tokens on expert 0; 4 devices; capacity = 250 each.
        let mut loads = vec![0u64; 8];
        loads[0] = 1000;
        let plan = plan_llep(&cfg(1.0, 10, 1.3), 8, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        let dl = plan.device_loads();
        assert_eq!(dl.iter().sum::<u64>(), 1000);
        assert_eq!(*dl.iter().max().unwrap(), 250, "{dl:?}");
        // expert 0's weights must reach the three foreign devices
        assert_eq!(plan.transfers.len(), 3);
        assert!(plan.transfers.iter().all(|t| t.expert == 0 && t.from == 0));
    }

    #[test]
    fn capacity_threshold_respected_without_force() {
        let loads = vec![600, 10, 10, 10, 10, 10, 10, 10]; // total 670, P=4
        let plan = plan_llep(&cfg(1.0, 1, 1.3), 8, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        let m_alpha: f64 = 670.0 / 4.0; // 167.5 -> 167 usable
        for (d, &l) in plan.device_loads().iter().enumerate() {
            assert!(
                l as f64 <= m_alpha.floor() + 0.0 || plan_has_forced_on(&plan, d),
                "device {d} over capacity: {l}"
            );
        }
    }

    fn plan_has_forced_on(plan: &RoutePlan, device: usize) -> bool {
        plan.assignments.iter().flatten().any(|s| s.device == device && s.forced)
    }

    #[test]
    fn min_chunk_keeps_small_excess_local() {
        // Native capacity 100 (alpha=1, total=400, P=4); expert 0 has 130:
        // the 30-token excess < m=64 stays local (forced).
        let loads = vec![130, 90, 90, 90];
        let plan = plan_llep(&cfg(1.0, 64, 1.3), 4, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        assert_eq!(plan.assignments[0].len(), 1);
        assert_eq!(plan.assignments[0][0].device, 0);
        assert!(plan.assignments[0][0].forced);
        assert!(plan.transfers.is_empty());
    }

    #[test]
    fn min_chunk_spills_when_excess_is_large() {
        let loads = vec![260, 90, 90, 40];
        // capacity = 120; excess of expert 0 = 140 >= m=64 -> spills.
        let plan = plan_llep(&cfg(1.0, 64, 1.3), 4, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        assert!(plan.assignments[0].len() >= 2, "{:?}", plan.assignments[0]);
        assert!(!plan.transfers.is_empty());
    }

    #[test]
    fn force_assign_when_all_full() {
        // alpha=1 with extreme skew: capacity 25*4=100 but one expert has
        // 100 and every other expert adds 0 load; devices can absorb it.
        // Harder: two experts of 100 each native to device 0; capacity 50.
        let loads = vec![100, 100, 0, 0, 0, 0, 0, 0]; // N=8, P=4 -> M=2, both on dev0
        let plan = plan_llep(&cfg(1.0, 1, 1.3), 8, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        let dl = plan.device_loads();
        assert_eq!(dl.iter().sum::<u64>(), 200);
        assert_eq!(*dl.iter().max().unwrap(), 50, "{dl:?}");
    }

    #[test]
    fn zero_total_yields_empty_plan() {
        let plan = plan_llep(&cfg(1.0, 16, 1.3), 4, 2, &[0, 0, 0, 0], None);
        assert_eq!(plan.device_loads(), vec![0, 0]);
        assert!(plan.transfers.is_empty());
    }

    #[test]
    fn alpha_two_allows_more_local() {
        let loads = vec![300, 50, 50, 0, 0, 0, 0, 0]; // total 400, P=4
        // alpha=2 -> capacity 200: expert 0 spills only 100.
        let plan = plan_llep(&cfg(2.0, 1, 1.3), 8, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        let native_part: u64 = plan.assignments[0]
            .iter()
            .filter(|s| s.device == 0)
            .map(|s| s.len())
            .sum();
        assert!(native_part >= 200 - 100, "native keeps most: {native_part}");
        let dl = plan.device_loads();
        assert!(dl[0] <= 200, "{dl:?}");
    }

    #[test]
    fn intra_node_preferred_on_ties() {
        use crate::config::{SystemConfig, SystemPreset};
        let topo = Topology::from_system(&SystemConfig::preset(SystemPreset::H200x16TwoNodes));
        // Expert 0 native to device 0 (node 0); everything else idle, so
        // all 15 other devices tie at load 0 — spill must pick node-0
        // peers first.
        let mut loads = vec![0u64; 16];
        loads[0] = 16_000;
        let plan = plan_llep(&cfg(1.0, 100, 1.3), 16, 16, &loads, Some(&topo));
        validate_plan(&plan, &loads).unwrap();
        // Check ordering: segments after the native one go to devices 1..8
        // before crossing the node boundary.
        let segs = &plan.assignments[0];
        let first_foreign: Vec<usize> =
            segs.iter().filter(|s| s.device != 0).map(|s| s.device).collect();
        assert!(first_foreign[..7].iter().all(|&d| d < 8), "{first_foreign:?}");
    }

    #[test]
    fn single_device_keeps_everything_native() {
        // Regression: with P=1 `spill` used to index `others[0]` on an
        // empty candidate list and panic. The remainder must stay on the
        // native (only) device instead, forced past m_alpha.
        let loads = vec![900u64, 50, 30, 20];
        // alpha < 1 is outside the validated config range but plan_llep is
        // a public building block and must stay total: it forces the
        // native capacity to overflow, exercising the old panic path.
        let plan = plan_llep(&cfg(0.5, 16, 1.0), 4, 1, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        assert_eq!(plan.device_loads(), vec![1000]);
        assert!(plan.transfers.is_empty());

        // In-range alpha on one device: trivially all-native, no panic.
        let plan = plan_llep(&cfg(1.0, 16, 1.0), 4, 1, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        assert_eq!(plan.device_loads(), vec![1000]);
        assert!(plan.transfers.is_empty());
    }

    #[test]
    fn segments_are_contiguous_cover() {
        let loads = vec![977, 3, 250, 41, 0, 123, 77, 529];
        let plan = plan_llep(&cfg(1.0, 50, 1.3), 8, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
    }

    #[test]
    fn transfers_are_canonical_at_construction() {
        let loads = vec![9_000u64, 10, 4_000, 30, 0, 2_500, 70, 900];
        let plan = plan_llep(&cfg(1.0, 16, 1.3), 8, 4, &loads, None);
        assert!(plan.transfers.len() > 1, "spills produce transfers");
        assert!(plan.transfers_canonical(), "{:?}", plan.transfers);
    }

    fn pool_with_speeds(speeds: &[f64]) -> PoolState {
        let mut p = PoolState::healthy(speeds.len());
        for (d, &s) in speeds.iter().enumerate() {
            if s <= 0.0 {
                p.devices[d].alive = false;
            } else {
                p.devices[d].speed = s;
            }
        }
        p
    }

    #[test]
    fn uniform_pool_matches_homogeneous_planner() {
        // A degraded-typed but speed-uniform pool must reproduce the
        // homogeneous plan exactly (the normalized capacities coincide).
        let loads = vec![977, 3, 250, 41, 0, 123, 77, 529];
        let pool = PoolState::healthy(4);
        let a = plan_llep(&cfg(1.0, 50, 1.3), 8, 4, &loads, None);
        let b = plan_llep_pool(&cfg(1.0, 50, 1.3), 8, 4, &loads, None, &pool);
        assert_eq!(a, b);
    }

    #[test]
    fn straggler_gets_a_proportionally_smaller_share() {
        // One hot expert, device 0 at quarter speed: the normalized-time
        // balance gives device 0 about half the tokens of a full-speed
        // peer... speeds [0.25, 1, 1, 1] -> shares 1/13, 4/13, 4/13, 4/13.
        let mut loads = vec![0u64; 8];
        loads[0] = 13_000;
        let pool = pool_with_speeds(&[0.25, 1.0, 1.0, 1.0]);
        let plan = plan_llep_pool(&cfg(1.0, 10, 1.3), 8, 4, &loads, None, &pool);
        validate_plan(&plan, &loads).unwrap();
        let dl = plan.device_loads();
        assert_eq!(dl.iter().sum::<u64>(), 13_000);
        assert!(dl[0] <= 1_000, "straggler takes ~1/13: {dl:?}");
        for d in 1..4 {
            assert!(dl[d] >= 3_800 && dl[d] <= 4_200, "full-speed peers take ~4/13: {dl:?}");
        }
        // Normalized completion times are near-equal (the objective).
        let norm: Vec<f64> = dl
            .iter()
            .zip([0.25, 1.0, 1.0, 1.0])
            .map(|(&l, s)| l as f64 / s)
            .collect();
        let max = norm.iter().cloned().fold(0.0, f64::max);
        let min = norm.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 1.1, "normalized makespan balanced: {norm:?}");
    }

    #[test]
    fn dead_device_is_never_scheduled() {
        // Device 1 dead; experts 2 and 3 are native to it and must move
        // entirely — including tiny loads below the min-GEMM size.
        let loads = vec![400u64, 300, 200, 7];
        let pool = pool_with_speeds(&[1.0, 0.0]);
        let plan = plan_llep_pool(&cfg(1.0, 64, 1.3), 4, 2, &loads, None, &pool);
        validate_plan(&plan, &loads).unwrap();
        let dl = plan.device_loads();
        assert_eq!(dl[1], 0, "dead device holds nothing: {dl:?}");
        assert_eq!(dl[0], 907);
        assert!(
            plan.transfers.iter().all(|t| t.to != 1),
            "no weights shipped to a dead device: {:?}",
            plan.transfers
        );
    }

    #[test]
    fn pool_aware_trait_path_skips_guard_and_survives_all_dead() {
        let planner = Llep::new(cfg(1.0, 8, 1.3));
        // Balanced loads would normally hit the lambda guard; a straggler
        // pool must bypass it and rebalance anyway.
        let loads = vec![100u64; 8];
        let pool = pool_with_speeds(&[0.25, 1.0, 1.0, 1.0]);
        let plan = planner.plan_with_pool(4, &loads, &loads, None, Some(&pool));
        assert!(!plan.fallback_ep, "guard skipped under degradation");
        let dl = plan.device_loads();
        assert!(dl[0] < dl[1], "straggler relieved even on balanced routing: {dl:?}");
        // Healthy pool: identical to the plain path (guard applies).
        let healthy = planner.plan_with_pool(4, &loads, &loads, None, Some(&PoolState::healthy(4)));
        assert!(healthy.fallback_ep);
        // All-dead pool: total, degenerate native plan (strands later).
        let dead = pool_with_speeds(&[0.0, 0.0, 0.0, 0.0]);
        let plan = planner.plan_with_pool(4, &loads, &loads, None, Some(&dead));
        assert_eq!(plan.device_loads().iter().sum::<u64>(), 800);
    }
}
