//! Least-Loaded Assignment — the paper's Alg. 2 (LLA) and Alg. 3 (LLAS).
//!
//! For each expert, in decreasing-load order, assign its tokens to the
//! native device up to the capacity threshold
//! `m_alpha = alpha * total / P`, then spill the excess in chunks to the
//! least-loaded other devices. Chunks smaller than `m` tokens are not
//! worth a GEMM launch + weight transfer (paper §3.2 / Fig. 8), so they
//! are either kept local (native min-GEMM exception) or skipped for a
//! fuller device; if nobody can take a chunk within capacity, the
//! remainder is force-assigned to the least-loaded device (LLAS
//! fallback), which is the only way a device may exceed `m_alpha`.

use super::{plan_ep, Planner, RoutePlan, Segment, WeightTransfer};
use crate::config::LlepConfig;
use crate::routing::imbalance_ratio;
use crate::topology::Topology;

/// LLEP (paper Alg. 2-4) as a trait planner: the Alg. 4 lambda guard
/// reverts to standard EP when the routing is balanced enough, otherwise
/// runs the least-loaded assignment.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Llep {
    pub cfg: LlepConfig,
}

impl Llep {
    pub fn new(cfg: LlepConfig) -> Llep {
        Llep { cfg }
    }
}

impl Planner for Llep {
    fn plan_with_stats(
        &self,
        devices: usize,
        loads: &[u64],
        _stats: &[u64],
        topo: Option<&Topology>,
    ) -> RoutePlan {
        if imbalance_ratio(loads) < self.cfg.lambda {
            // Alg. 4 guard: balanced enough — standard EP.
            let mut p = plan_ep(loads.len(), devices, loads);
            p.fallback_ep = true;
            p
        } else {
            plan_llep(&self.cfg, loads.len(), devices, loads, topo)
        }
    }

    fn label(&self) -> String {
        format!(
            "LLEP(a={},m={},l={})",
            self.cfg.alpha, self.cfg.min_gemm_tokens, self.cfg.lambda
        )
    }

    fn spec(&self) -> String {
        format!(
            "llep:alpha={},m={},lambda={}",
            self.cfg.alpha, self.cfg.min_gemm_tokens, self.cfg.lambda
        )
    }
}

/// Build the LLEP plan. `topo`, when given, breaks least-loaded ties in
/// favour of intra-node devices (paper §4 "Implementation & Optimization"
/// — multi-node spill preference).
pub fn plan_llep(
    cfg: &LlepConfig,
    num_experts: usize,
    devices: usize,
    loads: &[u64],
    topo: Option<&Topology>,
) -> RoutePlan {
    assert_eq!(loads.len(), num_experts);
    assert!(devices > 0 && num_experts % devices == 0, "N must divide P");
    let m_per_dev = num_experts / devices;
    let total: u64 = loads.iter().sum();
    let mut plan = RoutePlan {
        num_experts,
        devices,
        assignments: vec![Vec::new(); num_experts],
        transfers: Vec::new(),
        fallback_ep: false,
    };
    if total == 0 {
        return plan;
    }

    // m_alpha: capacity threshold per device (tokens).
    let m_alpha = cfg.alpha * total as f64 / devices as f64;
    let min_chunk = cfg.min_gemm_tokens as u64;

    // Sorted expert order, decreasing load (stable on index for ties).
    let mut order: Vec<usize> = (0..num_experts).collect();
    order.sort_unstable_by_key(|&e| (std::cmp::Reverse(loads[e]), e));

    // Native (pending) and assigned load per device.
    let mut g_p: Vec<u64> = vec![0; devices];
    for (e, &l) in loads.iter().enumerate() {
        g_p[e / m_per_dev] += l;
    }
    let mut g_a: Vec<u64> = vec![0; devices];
    // Scratch reused across experts (perf: no per-expert allocs beyond
    // the segments that end up in the plan — see EXPERIMENTS.md §Perf).
    let mut seen: Vec<bool> = vec![false; devices];
    let mut others_scratch: Vec<usize> = Vec::with_capacity(devices);

    for &e in &order {
        let load = loads[e];
        let ng = e / m_per_dev;
        g_p[ng] -= load;
        if load == 0 {
            continue;
        }
        let mut segs: Vec<Segment> = Vec::new();

        // Available native capacity (may be negative).
        let occupied = (g_a[ng] + g_p[ng]) as f64;
        let na = (m_alpha - occupied).floor() as i64;

        if na >= load as i64 {
            // Case 1: native device takes everything. This is the common
            // case on balanced-ish loads — no spill machinery touched.
            segs.push(Segment { device: ng, start: 0, end: load, forced: false });
            g_a[ng] += load;
        } else if na > 0 {
            // Case 2: native takes what fits; spill the rest — unless the
            // remainder is below the min-GEMM size, in which case keeping
            // it local beats a weight transfer (paper §4 constraint 2).
            let nc = (na as u64).min(load);
            let remaining = load - nc;
            if remaining < min_chunk {
                segs.push(Segment { device: ng, start: 0, end: load, forced: true });
                g_a[ng] += load;
            } else {
                segs.push(Segment { device: ng, start: 0, end: nc, forced: false });
                g_a[ng] += nc;
                spill(
                    ng, remaining, nc, &mut segs, &mut g_a, &g_p, m_alpha, min_chunk, topo,
                    &mut others_scratch,
                );
            }
        } else {
            // Case 3: native is already at/over capacity — spill the whole
            // expert, except tiny loads which stay local.
            if load < min_chunk {
                segs.push(Segment { device: ng, start: 0, end: load, forced: true });
                g_a[ng] += load;
            } else {
                spill(
                    ng, load, 0, &mut segs, &mut g_a, &g_p, m_alpha, min_chunk, topo,
                    &mut others_scratch,
                );
            }
        }

        merge_adjacent(&mut segs);
        // Record weight transfers for foreign segments (scratch `seen` is
        // reused across experts and reset only where touched).
        for s in &segs {
            if s.device != ng && !seen[s.device] {
                seen[s.device] = true;
                plan.transfers.push(WeightTransfer { expert: e, from: ng, to: s.device });
            }
        }
        for s in &segs {
            seen[s.device] = false;
        }
        plan.assignments[e] = segs;
    }
    plan
}

/// Alg. 3 (LLAS): spill `r` remaining tokens of one expert, starting at
/// global token offset `to`, to the least-loaded non-native devices.
#[allow(clippy::too_many_arguments)]
fn spill(
    ng: usize,
    mut r: u64,
    mut to: u64,
    segs: &mut Vec<Segment>,
    g_a: &mut [u64],
    g_p: &[u64],
    m_alpha: f64,
    min_chunk: u64,
    topo: Option<&Topology>,
    others: &mut Vec<usize>,
) {
    let devices = g_a.len();
    while r > 0 {
        // Other devices ordered by current (assigned + pending) load,
        // intra-node peers preferred on ties when a topology is known.
        // (Perf: `others` is caller-provided scratch; a spill loop
        // iteration changes a single device's load, so the re-sort of a
        // nearly-sorted short vec is cheap — see EXPERIMENTS.md §Perf.)
        others.clear();
        others.extend((0..devices).filter(|&d| d != ng));
        if others.is_empty() {
            // P=1: there is no other device to spill to — keep the whole
            // remainder native, flagged forced (it exceeds m_alpha by
            // construction, which is the only legal way to exceed it).
            segs.push(Segment { device: ng, start: to, end: to + r, forced: true });
            g_a[ng] += r;
            return;
        }
        others.sort_by_key(|&d| {
            let inter = topo.map_or(0u8, |t| !t.same_node(ng, d) as u8);
            (g_a[d] + g_p[d], inter, d)
        });

        let mut assigned = false;
        for &o in others.iter() {
            let occupied = (g_a[o] + g_p[o]) as f64;
            let cap = (m_alpha - occupied).floor() as i64;
            if cap <= 0 {
                continue; // device full
            }
            let c = r.min(cap as u64);
            if c < min_chunk && r > c {
                // Chunk too small to justify a transfer + tiny GEMM, and
                // it would not even finish the expert — skip this device.
                continue;
            }
            segs.push(Segment { device: o, start: to, end: to + c, forced: false });
            g_a[o] += c;
            r -= c;
            to += c;
            assigned = true;
            break;
        }

        if !assigned {
            // Force-assign the entire remainder to the least-loaded other
            // device (it will exceed m_alpha — flagged as forced).
            let o = others[0];
            segs.push(Segment { device: o, start: to, end: to + r, forced: true });
            g_a[o] += r;
            return;
        }
    }
}

/// Merge adjacent segments that landed on the same device. Segments are
/// constructed in ascending token order (native first, spills at
/// increasing offsets), so no sort is needed — asserted in debug builds.
fn merge_adjacent(segs: &mut Vec<Segment>) {
    debug_assert!(segs.windows(2).all(|w| w[0].start <= w[1].start));
    let mut out: Vec<Segment> = Vec::with_capacity(segs.len());
    for s in segs.drain(..) {
        if let Some(last) = out.last_mut() {
            if last.device == s.device && last.end == s.start {
                last.end = s.end;
                last.forced |= s.forced;
                continue;
            }
        }
        out.push(s);
    }
    *segs = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::validate::validate_plan;

    fn cfg(alpha: f64, m: usize, lambda: f64) -> LlepConfig {
        LlepConfig { alpha, min_gemm_tokens: m, lambda }
    }

    #[test]
    fn balanced_loads_stay_native() {
        let loads = vec![100u64; 8];
        let plan = plan_llep(&cfg(1.0, 8, 1.3), 8, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        assert!(plan.is_pure_ep(), "{plan:?}");
        assert_eq!(plan.device_loads(), vec![200; 4]);
    }

    #[test]
    fn single_hot_expert_spreads_evenly() {
        // All 1000 tokens on expert 0; 4 devices; capacity = 250 each.
        let mut loads = vec![0u64; 8];
        loads[0] = 1000;
        let plan = plan_llep(&cfg(1.0, 10, 1.3), 8, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        let dl = plan.device_loads();
        assert_eq!(dl.iter().sum::<u64>(), 1000);
        assert_eq!(*dl.iter().max().unwrap(), 250, "{dl:?}");
        // expert 0's weights must reach the three foreign devices
        assert_eq!(plan.transfers.len(), 3);
        assert!(plan.transfers.iter().all(|t| t.expert == 0 && t.from == 0));
    }

    #[test]
    fn capacity_threshold_respected_without_force() {
        let loads = vec![600, 10, 10, 10, 10, 10, 10, 10]; // total 670, P=4
        let plan = plan_llep(&cfg(1.0, 1, 1.3), 8, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        let m_alpha: f64 = 670.0 / 4.0; // 167.5 -> 167 usable
        for (d, &l) in plan.device_loads().iter().enumerate() {
            assert!(
                l as f64 <= m_alpha.floor() + 0.0 || plan_has_forced_on(&plan, d),
                "device {d} over capacity: {l}"
            );
        }
    }

    fn plan_has_forced_on(plan: &RoutePlan, device: usize) -> bool {
        plan.assignments.iter().flatten().any(|s| s.device == device && s.forced)
    }

    #[test]
    fn min_chunk_keeps_small_excess_local() {
        // Native capacity 100 (alpha=1, total=400, P=4); expert 0 has 130:
        // the 30-token excess < m=64 stays local (forced).
        let loads = vec![130, 90, 90, 90];
        let plan = plan_llep(&cfg(1.0, 64, 1.3), 4, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        assert_eq!(plan.assignments[0].len(), 1);
        assert_eq!(plan.assignments[0][0].device, 0);
        assert!(plan.assignments[0][0].forced);
        assert!(plan.transfers.is_empty());
    }

    #[test]
    fn min_chunk_spills_when_excess_is_large() {
        let loads = vec![260, 90, 90, 40];
        // capacity = 120; excess of expert 0 = 140 >= m=64 -> spills.
        let plan = plan_llep(&cfg(1.0, 64, 1.3), 4, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        assert!(plan.assignments[0].len() >= 2, "{:?}", plan.assignments[0]);
        assert!(!plan.transfers.is_empty());
    }

    #[test]
    fn force_assign_when_all_full() {
        // alpha=1 with extreme skew: capacity 25*4=100 but one expert has
        // 100 and every other expert adds 0 load; devices can absorb it.
        // Harder: two experts of 100 each native to device 0; capacity 50.
        let loads = vec![100, 100, 0, 0, 0, 0, 0, 0]; // N=8, P=4 -> M=2, both on dev0
        let plan = plan_llep(&cfg(1.0, 1, 1.3), 8, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        let dl = plan.device_loads();
        assert_eq!(dl.iter().sum::<u64>(), 200);
        assert_eq!(*dl.iter().max().unwrap(), 50, "{dl:?}");
    }

    #[test]
    fn zero_total_yields_empty_plan() {
        let plan = plan_llep(&cfg(1.0, 16, 1.3), 4, 2, &[0, 0, 0, 0], None);
        assert_eq!(plan.device_loads(), vec![0, 0]);
        assert!(plan.transfers.is_empty());
    }

    #[test]
    fn alpha_two_allows_more_local() {
        let loads = vec![300, 50, 50, 0, 0, 0, 0, 0]; // total 400, P=4
        // alpha=2 -> capacity 200: expert 0 spills only 100.
        let plan = plan_llep(&cfg(2.0, 1, 1.3), 8, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        let native_part: u64 = plan.assignments[0]
            .iter()
            .filter(|s| s.device == 0)
            .map(|s| s.len())
            .sum();
        assert!(native_part >= 200 - 100, "native keeps most: {native_part}");
        let dl = plan.device_loads();
        assert!(dl[0] <= 200, "{dl:?}");
    }

    #[test]
    fn intra_node_preferred_on_ties() {
        use crate::config::{SystemConfig, SystemPreset};
        let topo = Topology::from_system(&SystemConfig::preset(SystemPreset::H200x16TwoNodes));
        // Expert 0 native to device 0 (node 0); everything else idle, so
        // all 15 other devices tie at load 0 — spill must pick node-0
        // peers first.
        let mut loads = vec![0u64; 16];
        loads[0] = 16_000;
        let plan = plan_llep(&cfg(1.0, 100, 1.3), 16, 16, &loads, Some(&topo));
        validate_plan(&plan, &loads).unwrap();
        // Check ordering: segments after the native one go to devices 1..8
        // before crossing the node boundary.
        let segs = &plan.assignments[0];
        let first_foreign: Vec<usize> =
            segs.iter().filter(|s| s.device != 0).map(|s| s.device).collect();
        assert!(first_foreign[..7].iter().all(|&d| d < 8), "{first_foreign:?}");
    }

    #[test]
    fn single_device_keeps_everything_native() {
        // Regression: with P=1 `spill` used to index `others[0]` on an
        // empty candidate list and panic. The remainder must stay on the
        // native (only) device instead, forced past m_alpha.
        let loads = vec![900u64, 50, 30, 20];
        // alpha < 1 is outside the validated config range but plan_llep is
        // a public building block and must stay total: it forces the
        // native capacity to overflow, exercising the old panic path.
        let plan = plan_llep(&cfg(0.5, 16, 1.0), 4, 1, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        assert_eq!(plan.device_loads(), vec![1000]);
        assert!(plan.transfers.is_empty());

        // In-range alpha on one device: trivially all-native, no panic.
        let plan = plan_llep(&cfg(1.0, 16, 1.0), 4, 1, &loads, None);
        validate_plan(&plan, &loads).unwrap();
        assert_eq!(plan.device_loads(), vec![1000]);
        assert!(plan.transfers.is_empty());
    }

    #[test]
    fn segments_are_contiguous_cover() {
        let loads = vec![977, 3, 250, 41, 0, 123, 77, 529];
        let plan = plan_llep(&cfg(1.0, 50, 1.3), 8, 4, &loads, None);
        validate_plan(&plan, &loads).unwrap();
    }
}
