//! Trial evaluation and search strategies (grid, random, successive
//! halving).
//!
//! ## Deterministic trials
//!
//! A trial prices one candidate spec on a fixed workload with the
//! engine's deterministic plan-cost model
//! ([`PlanCostModel`](crate::exec::PlanCostModel), installed by
//! [`Tuner::new`] when missing). Everything a trial reports is then a
//! pure function of `(spec, scenario, system, mode, seed, budget)`:
//! re-pricing the recommended spec under the tuner's settings
//! reproduces the metrics bit-identically ([`Tuner::verify`],
//! property-tested in `rust/tests/tune.rs`). Passing the spec back to
//! `run`/`serve` `--planner` reconstructs the identical planner and
//! plans (the registry round-trip); those commands charge *measured*
//! plan wall time, so only the microsecond `T_plan` component differs
//! from the tuner's modeled one.
//!
//! ## Budgets and the trial cache
//!
//! A trial's `budget` is its fidelity: engine steps priced in
//! [`Mode::Step`], requests simulated in [`Mode::Serve`]. Successive
//! halving starts every candidate at a small budget and re-evaluates
//! only the survivors at geometrically growing budgets; the final rung
//! always runs at the full budget. Results are cached keyed by
//! `(spec, scenario, system, budget)`, so rungs never re-price a point
//! they have already seen and [`Tuner::priced_units`] counts only real
//! work (the convergence bench reports it against full grid).

use super::pareto::pareto_front;
use super::space::SearchSpace;
use crate::chaos::FaultPlan;
use crate::coordinator::{run_continuous, uniform_profile, ContinuousBatchSim};
use crate::exec::{Engine, PlanCostModel};
use crate::planner::Registry;
use crate::routing::{DepthProfile, Scenario};
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// What one trial optimizes: a full-model training/prefill step, or a
/// decode-dominated continuous-batching horizon.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Mean full-model step latency ([`Engine::run_model`]) vs peak
    /// memory, over `budget` independently drawn batches.
    Step,
    /// p50 time-per-output-token in a continuous-batching simulation
    /// over `budget` requests, vs peak memory.
    Serve,
}

impl Mode {
    pub fn name(&self) -> &'static str {
        match self {
            Mode::Step => "step",
            Mode::Serve => "serve",
        }
    }

    pub fn from_name(name: &str) -> Option<Mode> {
        match name {
            "step" => Some(Mode::Step),
            "serve" => Some(Mode::Serve),
            _ => None,
        }
    }
}

/// Search strategy over the candidate set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Every candidate at full budget.
    Grid,
    /// A deterministic (seeded) subset of `trials` candidates at full
    /// budget.
    Random { trials: usize },
    /// Successive halving: all candidates at a small budget, keep the
    /// best `1/eta` per rung, multiply the budget by `eta`; the last
    /// rung runs at full budget.
    Halving { eta: usize },
}

/// The two tuning objectives (both minimized) plus the feasibility flags.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrialMetrics {
    /// Step mode: mean full-model step latency; serve mode: p50 TPOT
    /// (p50 TTFT when the horizon produced no decode steps).
    pub latency_s: f64,
    /// Max per-device peak bytes (Eq.-4 accounting) over the trial.
    pub peak_bytes: u64,
    /// Some device exceeded the profile's memory capacity.
    pub oom: bool,
    /// Under the trial's fault plan the candidate left work on a dead
    /// device (or the pool became unrecoverable): the configuration
    /// cannot serve this scenario at all. Like OOM, stranded trials are
    /// infeasible and never enter the Pareto front — this is how a fault
    /// dimension stress-hardens a recommendation.
    pub stranded: bool,
}

impl TrialMetrics {
    /// Infeasible on this profile/fault-plan (never recommended).
    pub fn infeasible(&self) -> bool {
        self.oom || self.stranded
    }
}

/// One evaluated candidate.
#[derive(Clone, Debug)]
pub struct Trial {
    pub spec: String,
    /// Fidelity the metrics were computed at (steps or requests).
    pub budget: usize,
    pub metrics: TrialMetrics,
}

/// Result of one [`Tuner::run`].
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    /// Strategy label (`grid`, `random(k)`, `halving(eta=2)`).
    pub strategy: String,
    /// Size of the candidate space the strategy drew from.
    pub specs_considered: usize,
    /// Budget units actually priced so far by this tuner (cache misses
    /// only, cumulative across rungs).
    pub priced_units: u64,
    /// The full-fidelity budget the final trials were evaluated at.
    pub final_budget: usize,
    /// Full-budget trials, ranked best-first.
    pub trials: Vec<Trial>,
    /// Latency/memory Pareto front over `trials` (non-OOM only),
    /// latency-ascending.
    pub front: Vec<Trial>,
    /// Lowest-latency feasible configuration (`front[0]`).
    pub recommended: Option<Trial>,
}

type TrialKey = (String, String, String, usize);

/// The autotuner: evaluates planner specs against one (scenario,
/// hardware profile) pair and searches spec space for the Pareto set.
pub struct Tuner {
    pub engine: Engine,
    pub registry: Registry,
    pub scenario: Scenario,
    pub mode: Mode,
    pub seed: u64,
    /// Step mode: tokens per device per priced batch. Serve mode: the
    /// continuous-batching prefill token budget per step.
    pub tokens_per_device: usize,
    /// Full-fidelity budget (steps or requests).
    pub full_budget: usize,
    /// Extra scenario dimension: every trial runs under this fault plan
    /// (step `k` of a trial sees `faults.state_at(k, ...)`), so the
    /// recommendation is stress-hardened against the injected
    /// degradation. None = always-healthy pool.
    pub faults: Option<FaultPlan>,
    cache: Mutex<BTreeMap<TrialKey, TrialMetrics>>,
    priced_units: AtomicU64,
}

impl Tuner {
    /// Build a tuner. Installs the default deterministic
    /// [`PlanCostModel`] when the engine does not already carry one —
    /// the bit-identical-trials contract requires it.
    pub fn new(engine: Engine, scenario: Scenario, mode: Mode, seed: u64) -> Tuner {
        let engine = if engine.plan_cost.is_some() {
            engine
        } else {
            engine.with_plan_cost(PlanCostModel::default())
        };
        Tuner {
            engine,
            registry: Registry::builtin(),
            scenario,
            mode,
            seed,
            tokens_per_device: 8192,
            full_budget: match mode {
                Mode::Step => 8,
                Mode::Serve => 24,
            },
            faults: None,
            cache: Mutex::new(BTreeMap::new()),
            priced_units: AtomicU64::new(0),
        }
    }

    /// Tune under a fault plan (chaos dimension). The plan joins the
    /// trial-cache key, so fault-free and faulted trials never mix.
    pub fn with_faults(mut self, faults: FaultPlan) -> Tuner {
        self.faults = Some(faults);
        self
    }

    /// Replace the registry (runtime-registered planners join the search).
    pub fn with_registry(mut self, registry: Registry) -> Tuner {
        self.registry = registry;
        self
    }

    /// Step-mode batch size (tokens per device); in serve mode the
    /// per-step prefill token budget.
    pub fn with_tokens(mut self, tokens_per_device: usize) -> Tuner {
        self.tokens_per_device = tokens_per_device.max(1);
        self
    }

    /// Full-fidelity budget (steps in step mode, requests in serve mode).
    pub fn with_full_budget(mut self, budget: usize) -> Tuner {
        self.full_budget = budget.max(1);
        self
    }

    /// Budget units priced so far (cache misses only).
    pub fn priced_units(&self) -> u64 {
        self.priced_units.load(Ordering::Relaxed)
    }

    fn key(&self, spec: &str, budget: usize) -> TrialKey {
        let faults = self.faults.as_ref().map(FaultPlan::label).unwrap_or_default();
        (
            spec.to_string(),
            self.scenario.label(),
            format!("{}/{}/{}", self.engine.system.name, self.mode.name(), faults),
            budget,
        )
    }

    /// Evaluate one spec at the given budget (served from the trial
    /// cache when already priced).
    pub fn evaluate(&self, spec: &str, budget: usize) -> Result<Trial, String> {
        let budget = budget.max(1);
        let key = self.key(spec, budget);
        if let Some(&metrics) = self.cache.lock().unwrap().get(&key) {
            return Ok(Trial { spec: spec.to_string(), budget, metrics });
        }
        let metrics = self.compute(spec, budget)?;
        self.priced_units.fetch_add(budget as u64, Ordering::Relaxed);
        self.cache.lock().unwrap().insert(key, metrics);
        Ok(Trial { spec: spec.to_string(), budget, metrics })
    }

    /// Recompute a trial from scratch, bypassing the cache, and check the
    /// result is bit-identical to what the trial reported.
    pub fn verify(&self, trial: &Trial) -> Result<bool, String> {
        let fresh = self.compute(&trial.spec, trial.budget)?;
        Ok(fresh.latency_s.to_bits() == trial.metrics.latency_s.to_bits()
            && fresh.peak_bytes == trial.metrics.peak_bytes
            && fresh.oom == trial.metrics.oom)
    }

    /// The actual pricing. Pure in `(spec, budget)` given the tuner's
    /// fixed scenario/system/mode/seed: the planner instance is fresh,
    /// per-batch RNG is derived from `seed` and the batch index, and the
    /// engine charges modeled plan time.
    fn compute(&self, spec: &str, budget: usize) -> Result<TrialMetrics, String> {
        let planner = self.registry.parse(spec)?;
        if let Some(f) = &self.faults {
            f.validate(self.engine.system.devices)?;
        }
        match self.mode {
            Mode::Step => {
                let layers = self.engine.model.num_moe_layers().max(1);
                let profile = DepthProfile::uniform(self.scenario.clone(), layers);
                let mut latency_sum = 0.0f64;
                let mut peak_bytes = 0u64;
                let mut oom = false;
                let mut stranded = false;
                let mut priced_batches = 0usize;
                for batch in 0..budget {
                    let mut rng = Rng::new(batch_seed(self.seed, batch));
                    // Under a fault plan, batch `k` prices on the pool at
                    // step `k` (the engine view is re-derived per batch).
                    let holder: Engine;
                    let engine: &Engine = match &self.faults {
                        Some(f) => {
                            let pool = f.state_at(batch, &self.engine.pool);
                            if pool.alive_count() == 0 {
                                stranded = true;
                                break;
                            }
                            holder = self.engine.for_pool(pool);
                            &holder
                        }
                        None => &self.engine,
                    };
                    let lms = profile.generate_loads(
                        &engine.model,
                        engine.system.devices,
                        self.tokens_per_device,
                        &mut rng,
                    );
                    let r = engine.run_model(&lms, &*planner)?;
                    latency_sum += r.latency_s;
                    peak_bytes = peak_bytes.max(r.max_peak_bytes());
                    oom |= r.oom;
                    stranded |= r.stranded;
                    priced_batches += 1;
                    // Trial evaluation runs on scoped worker threads, each
                    // with its own planning arena (thread-local): recycling
                    // every priced plan keeps all the batches after the
                    // first allocation-free on that worker.
                    for layer in r.layers {
                        crate::planner::recycle_plan(layer.plan);
                    }
                }
                // Mean over the batches actually priced: an all-dead pool
                // breaks the loop early and must not dilute the mean.
                Ok(TrialMetrics {
                    latency_s: latency_sum / priced_batches.max(1) as f64,
                    peak_bytes,
                    oom,
                    stranded,
                })
            }
            Mode::Serve => {
                // A dedicated arrivals stream, disjoint from the step-mode
                // per-batch streams (which use batch_seed) and identical on
                // every architecture.
                let mut arrivals = Rng::new(self.seed ^ 0xC0FF_EE00_5EED_5EED);
                let requests = ContinuousBatchSim::requests(
                    budget,
                    1e-4,
                    (64, 256),
                    (8, 32),
                    &mut arrivals,
                );
                // Trials run straight on the replica core (the same
                // driver `ContinuousBatchSim::try_run` wraps), skipping
                // the sim's owned engine/planner clones.
                let profile = uniform_profile(&self.engine, self.scenario.clone());
                match run_continuous(
                    &self.engine,
                    &*planner,
                    &profile,
                    self.tokens_per_device,
                    self.faults.as_ref(),
                    &requests,
                    &mut Rng::new(self.seed.wrapping_add(1)),
                ) {
                    Ok(r) => {
                        let latency_s = if r.tpot.n > 0 { r.tpot.p50 } else { r.ttft.p50 };
                        Ok(TrialMetrics {
                            latency_s,
                            peak_bytes: r.peak_bytes,
                            oom: r.oom_steps > 0,
                            stranded: false,
                        })
                    }
                    // The pool became unrecoverable under this candidate
                    // (e.g. a static planner met a failure): that is a
                    // *trial outcome*, not a tuner error — the candidate
                    // is infeasible on this fault plan.
                    Err(_) => Ok(TrialMetrics {
                        latency_s: f64::INFINITY,
                        peak_bytes: 0,
                        oom: false,
                        stranded: true,
                    }),
                }
            }
        }
    }

    /// Evaluate many specs at one budget, fanned out over scoped worker
    /// threads (candidates are independent).
    pub fn evaluate_all(&self, specs: &[String], budget: usize) -> Result<Vec<Trial>, String> {
        crate::util::par::parallel_map(specs, |spec| self.evaluate(spec, budget))
            .into_iter()
            .collect()
    }

    /// Run one search over `space` and assemble the Pareto front and the
    /// recommended spec.
    pub fn run(&self, space: &SearchSpace, strategy: Strategy) -> Result<TuneOutcome, String> {
        let full = self.full_budget.max(1);
        let (label, mut trials) = match strategy {
            Strategy::Grid => ("grid".to_string(), self.evaluate_all(&space.specs, full)?),
            Strategy::Random { trials } => {
                let k = trials.clamp(1, space.specs.len().max(1));
                let mut rng = Rng::new(self.seed);
                let mut idx = rng.sample_distinct(space.specs.len(), k.min(space.specs.len()));
                idx.sort_unstable();
                let subset: Vec<String> =
                    idx.into_iter().map(|i| space.specs[i].clone()).collect();
                (format!("random({k})"), self.evaluate_all(&subset, full)?)
            }
            Strategy::Halving { eta } => {
                let eta = eta.max(2);
                (format!("halving(eta={eta})"), self.run_halving(&space.specs, full, eta)?)
            }
        };
        rank(&mut trials);
        let front = pareto_front(&trials);
        let recommended = front.first().cloned();
        Ok(TuneOutcome {
            strategy: label,
            specs_considered: space.specs.len(),
            priced_units: self.priced_units(),
            final_budget: full,
            trials,
            front,
            recommended,
        })
    }

    /// Successive halving: rung budgets grow by `eta` up to `full`; the
    /// candidate set shrinks by `eta` per rung down to one survivor.
    fn run_halving(
        &self,
        specs: &[String],
        full: usize,
        eta: usize,
    ) -> Result<Vec<Trial>, String> {
        if specs.is_empty() {
            return Ok(Vec::new());
        }
        let mut levels = 1usize;
        let mut m = specs.len();
        while m > 1 {
            m = m.div_ceil(eta);
            levels += 1;
        }
        let mut rung_budgets: Vec<usize> = Vec::with_capacity(levels);
        let mut b = full;
        for _ in 0..levels {
            rung_budgets.push(b.max(1));
            b = b.div_ceil(eta);
        }
        rung_budgets.reverse(); // ascending; last == full

        let mut survivors: Vec<String> = specs.to_vec();
        let mut last: Vec<Trial> = Vec::new();
        for (i, &rung_budget) in rung_budgets.iter().enumerate() {
            let mut trials = self.evaluate_all(&survivors, rung_budget)?;
            rank(&mut trials);
            if i + 1 < rung_budgets.len() {
                let keep = survivors.len().div_ceil(eta).max(1);
                trials.truncate(keep);
                survivors = trials.iter().map(|t| t.spec.clone()).collect();
            }
            last = trials;
        }
        Ok(last)
    }
}

/// Per-batch RNG stream: independent of evaluation order, shared by
/// every candidate (all planners price the same workload).
fn batch_seed(seed: u64, batch: usize) -> u64 {
    seed ^ (batch as u64).wrapping_add(1).wrapping_mul(0x9E3779B97F4A7C15)
}

/// Rank trials best-first: feasible before OOM/stranded, then latency,
/// then peak memory, then spec (a total, deterministic order).
pub fn rank(trials: &mut [Trial]) {
    trials.sort_by(|a, b| {
        (a.metrics.infeasible() as u8)
            .cmp(&(b.metrics.infeasible() as u8))
            .then(a.metrics.latency_s.total_cmp(&b.metrics.latency_s))
            .then(a.metrics.peak_bytes.cmp(&b.metrics.peak_bytes))
            .then(a.spec.cmp(&b.spec))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
    use crate::tune::SpaceBudget;

    fn tuner(mode: Mode) -> Tuner {
        let engine = Engine::modeled(
            ModelConfig::preset(ModelPreset::Tiny),
            SystemConfig::preset(SystemPreset::CpuSim4),
        );
        Tuner::new(engine, Scenario::concentrated(0.9, 1), mode, 0)
            .with_tokens(512)
            .with_full_budget(4)
    }

    #[test]
    fn evaluate_caches_and_counts_priced_units() {
        let t = tuner(Mode::Step);
        let a = t.evaluate("llep", 2).unwrap();
        assert_eq!(t.priced_units(), 2);
        let b = t.evaluate("llep", 2).unwrap();
        assert_eq!(t.priced_units(), 2, "second lookup served from the cache");
        assert_eq!(a.metrics, b.metrics);
        let _ = t.evaluate("llep", 4).unwrap();
        assert_eq!(t.priced_units(), 6, "different budget is a different trial");
    }

    #[test]
    fn trials_reproduce_bit_identically() {
        for mode in [Mode::Step, Mode::Serve] {
            let t = tuner(mode);
            for spec in ["ep", "llep", "cached(llep):drift=0.15"] {
                let trial = t.evaluate(spec, 3).unwrap();
                assert!(
                    t.verify(&trial).unwrap(),
                    "{spec} must re-price bit-identically in {mode:?}"
                );
            }
        }
    }

    #[test]
    fn grid_run_produces_front_and_recommendation() {
        let t = tuner(Mode::Step);
        let space = SearchSpace::from_registry(&t.registry, SpaceBudget::Smoke).unwrap();
        let out = t.run(&space, Strategy::Grid).unwrap();
        assert_eq!(out.trials.len(), space.len());
        assert!(!out.front.is_empty());
        let rec = out.recommended.as_ref().expect("non-OOM candidates exist");
        assert_eq!(rec.spec, out.front[0].spec);
        // The recommendation parses back through the registry.
        t.registry.parse(&rec.spec).unwrap();
        // Ranked best-first: the recommended trial leads the table.
        assert_eq!(out.trials[0].spec, rec.spec);
    }

    #[test]
    fn random_strategy_is_a_deterministic_subset() {
        let t1 = tuner(Mode::Step);
        let space = SearchSpace::from_registry(&t1.registry, SpaceBudget::Smoke).unwrap();
        let a = t1.run(&space, Strategy::Random { trials: 5 }).unwrap();
        let t2 = tuner(Mode::Step);
        let b = t2.run(&space, Strategy::Random { trials: 5 }).unwrap();
        assert_eq!(a.trials.len(), 5);
        let specs_a: Vec<&str> = a.trials.iter().map(|t| t.spec.as_str()).collect();
        let specs_b: Vec<&str> = b.trials.iter().map(|t| t.spec.as_str()).collect();
        assert_eq!(specs_a, specs_b, "same seed, same subset");
    }

    #[test]
    fn fault_dimension_separates_cache_keys_and_strands_static_planners() {
        // Same spec, same budget: the faulted trial must not be served
        // from the fault-free cache entry (and vice versa).
        let healthy = tuner(Mode::Step);
        let clean = healthy.evaluate("llep:m=8", 2).unwrap();
        let faulted =
            tuner(Mode::Step).with_faults(FaultPlan::parse("slow:dev=0,x=4").unwrap());
        let slow = faulted.evaluate("llep:m=8", 2).unwrap();
        assert!(
            slow.metrics.latency_s > clean.metrics.latency_s,
            "a straggler costs latency even to an adaptive planner: {} vs {}",
            slow.metrics.latency_s,
            clean.metrics.latency_s
        );
        assert!(!slow.metrics.stranded);
        // A permanent failure strands static EP but not pool-aware LLEP,
        // in both modes — the stress-hardening signal.
        for mode in [Mode::Step, Mode::Serve] {
            let t = tuner(mode).with_faults(FaultPlan::parse("fail:dev=1,at=1").unwrap());
            let ep = t.evaluate("ep", 3).unwrap();
            assert!(ep.metrics.stranded, "{mode:?}: EP cannot adapt");
            assert!(ep.metrics.infeasible());
            let ll = t.evaluate("llep:m=8", 3).unwrap();
            assert!(!ll.metrics.stranded, "{mode:?}: LLEP replans around the hole");
        }
    }

    #[test]
    fn faulted_trials_reproduce_bit_identically() {
        for mode in [Mode::Step, Mode::Serve] {
            let t = tuner(mode)
                .with_faults(FaultPlan::parse("slow:dev=0,x=4;fail:dev=2,at=2").unwrap());
            let trial = t.evaluate("llep:m=8", 3).unwrap();
            assert!(
                t.verify(&trial).unwrap(),
                "faulted trial must re-price bit-identically in {mode:?}"
            );
        }
    }

    #[test]
    fn halving_prices_strictly_less_than_grid() {
        let grid_tuner = tuner(Mode::Step);
        let space = SearchSpace::from_registry(&grid_tuner.registry, SpaceBudget::Smoke).unwrap();
        let grid = grid_tuner.run(&space, Strategy::Grid).unwrap();
        let halving_tuner = tuner(Mode::Step);
        let halving = halving_tuner.run(&space, Strategy::Halving { eta: 2 }).unwrap();
        assert!(
            halving.priced_units < grid.priced_units,
            "halving {} vs grid {}",
            halving.priced_units,
            grid.priced_units
        );
        assert!(!halving.front.is_empty());
        assert_eq!(halving.trials[0].budget, grid_tuner.full_budget, "final rung at full budget");
    }
}
