//! Bi-objective (latency, peak memory) Pareto selection over trials.
//!
//! Both objectives are minimized. OOM trials are infeasible on the
//! profile's hardware — and stranded trials on its fault plan — so
//! neither ever enters the front. The front is returned
//! latency-ascending / memory-descending, so `front[0]` is the
//! lowest-latency feasible configuration (the tuner's recommendation)
//! and `front.last()` the most memory-frugal one.

use super::search::{Trial, TrialMetrics};

/// True when `a` is at least as good as `b` on both objectives and
/// strictly better on one (OOM-free metrics assumed).
pub fn dominates(a: &TrialMetrics, b: &TrialMetrics) -> bool {
    let le = a.latency_s <= b.latency_s && a.peak_bytes <= b.peak_bytes;
    let lt = a.latency_s < b.latency_s || a.peak_bytes < b.peak_bytes;
    le && lt
}

/// Non-dominated subset of the non-OOM trials, sorted by ascending
/// latency (ties broken toward lower memory, then spec — deterministic).
pub fn pareto_front(trials: &[Trial]) -> Vec<Trial> {
    let mut feasible: Vec<Trial> =
        trials.iter().filter(|t| !t.metrics.infeasible()).cloned().collect();
    feasible.sort_by(|a, b| {
        a.metrics
            .latency_s
            .total_cmp(&b.metrics.latency_s)
            .then(a.metrics.peak_bytes.cmp(&b.metrics.peak_bytes))
            .then(a.spec.cmp(&b.spec))
    });
    let mut front: Vec<Trial> = Vec::new();
    let mut best_mem = u64::MAX;
    for t in feasible {
        // Sorted by latency: a point joins the front iff it improves on
        // the best memory seen so far (equal-latency duplicates keep the
        // lower-memory representative).
        if t.metrics.peak_bytes < best_mem {
            best_mem = t.metrics.peak_bytes;
            front.push(t);
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trial(spec: &str, latency_s: f64, peak_bytes: u64, oom: bool) -> Trial {
        Trial {
            spec: spec.into(),
            budget: 1,
            metrics: TrialMetrics { latency_s, peak_bytes, oom, stranded: false },
        }
    }

    #[test]
    fn front_keeps_only_nondominated() {
        let trials = vec![
            trial("fast-fat", 1.0, 100, false),
            trial("slow-lean", 3.0, 10, false),
            trial("dominated", 2.0, 150, false), // slower and fatter than fast-fat
            trial("middle", 2.0, 50, false),
            trial("oom", 0.5, 400, true), // fastest but infeasible
        ];
        let front = pareto_front(&trials);
        let specs: Vec<&str> = front.iter().map(|t| t.spec.as_str()).collect();
        assert_eq!(specs, vec!["fast-fat", "middle", "slow-lean"]);
        // Pairwise non-domination.
        for a in &front {
            for b in &front {
                assert!(
                    a.spec == b.spec || !dominates(&a.metrics, &b.metrics),
                    "{} dominates {}",
                    a.spec,
                    b.spec
                );
            }
        }
    }

    #[test]
    fn every_feasible_trial_is_dominated_by_or_on_the_front() {
        let trials = vec![
            trial("a", 1.0, 90, false),
            trial("b", 1.5, 40, false),
            trial("c", 1.2, 95, false),
            trial("d", 2.0, 40, false),
        ];
        let front = pareto_front(&trials);
        for t in trials.iter().filter(|t| !t.metrics.oom) {
            let covered = front.iter().any(|f| {
                f.spec == t.spec || dominates(&f.metrics, &t.metrics)
            });
            assert!(covered, "{} neither on nor dominated by the front", t.spec);
        }
    }

    #[test]
    fn equal_latency_keeps_the_leaner_point() {
        let trials = vec![trial("fat", 1.0, 100, false), trial("lean", 1.0, 50, false)];
        let front = pareto_front(&trials);
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].spec, "lean");
    }

    #[test]
    fn all_oom_means_empty_front() {
        let trials = vec![trial("x", 1.0, 10, true), trial("y", 2.0, 20, true)];
        assert!(pareto_front(&trials).is_empty());
    }

    #[test]
    fn dominates_is_strict() {
        let a = TrialMetrics { latency_s: 1.0, peak_bytes: 10, oom: false, stranded: false };
        assert!(!dominates(&a, &a), "a point never dominates itself");
        let faster = TrialMetrics { latency_s: 0.5, peak_bytes: 10, oom: false, stranded: false };
        assert!(dominates(&faster, &a));
        assert!(!dominates(&a, &faster));
    }

    #[test]
    fn stranded_trials_never_enter_the_front() {
        let mut dead = trial("dead-fast", 0.1, 5, false);
        dead.metrics.stranded = true;
        let trials = vec![dead, trial("ok", 1.0, 50, false)];
        let front = pareto_front(&trials);
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].spec, "ok");
    }
}
