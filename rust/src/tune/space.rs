//! Search-space synthesis from the planner registry.
//!
//! The space is *derived*, not hand-listed: every [`PlannerEntry`] in the
//! registry contributes the cartesian product of its declared
//! [`ParamSpec`] grids, and the `cached(...)` decorator contributes its
//! own dimensions ([`CACHED_PARAMS`]) on top. Every synthesized point is
//! a valid `--planner` spec string (checked at construction by parsing
//! each one back through the registry), so whatever the tuner recommends
//! round-trips directly into `run`/`serve`/`replay`.
//!
//! Runtime-registered planners join automatically: register an entry
//! with `params` and the tuner searches it like any builtin.

use crate::fleet::OverloadConfig;
use crate::planner::{ParamSpec, Registry, CACHED_PARAMS, PLACED_PARAMS};

/// How much of the canonical grids to enumerate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpaceBudget {
    /// ≤ 2 values per parameter, no decorator dimensions (CI smoke).
    Smoke,
    /// Full per-planner grids, plus the decorator grid over each
    /// planner's default configuration.
    Default,
    /// Full grids with the decorator grid crossed against every base
    /// point.
    Full,
}

impl SpaceBudget {
    pub const ALL: [SpaceBudget; 3] =
        [SpaceBudget::Smoke, SpaceBudget::Default, SpaceBudget::Full];

    pub fn name(&self) -> &'static str {
        match self {
            SpaceBudget::Smoke => "smoke",
            SpaceBudget::Default => "default",
            SpaceBudget::Full => "full",
        }
    }

    pub fn from_name(name: &str) -> Option<SpaceBudget> {
        Self::ALL.iter().copied().find(|b| b.name() == name)
    }

    fn grid_cap(&self) -> usize {
        match self {
            SpaceBudget::Smoke => 2,
            _ => usize::MAX,
        }
    }
}

/// An enumerated candidate set of valid planner spec strings.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    pub specs: Vec<String>,
}

impl SearchSpace {
    /// Derive the space from `reg` at the given budget. Later
    /// registrations shadow earlier entries of the same name, matching
    /// [`Registry::parse`]. Errors if any synthesized spec fails to parse
    /// (a registry/grid inconsistency — loud, like the parser itself).
    pub fn from_registry(reg: &Registry, budget: SpaceBudget) -> Result<SearchSpace, String> {
        let cap = budget.grid_cap();
        let mut specs: Vec<String> = Vec::new();
        let mut base_specs: Vec<String> = Vec::new();
        let mut names: Vec<&str> = Vec::new();
        let mut seen: Vec<&str> = Vec::new();
        for entry in reg.entries().iter().rev() {
            if seen.contains(&entry.name) {
                continue; // shadowed by a later registration
            }
            seen.push(entry.name);
            names.push(entry.name);
            for assignment in grid_points(entry.params, cap) {
                base_specs.push(synthesize(entry.name, entry.params, &assignment));
            }
        }
        specs.extend(base_specs.iter().cloned());
        match budget {
            SpaceBudget::Smoke => {}
            SpaceBudget::Default => {
                // Decorator dims over each planner's default configuration.
                for name in &names {
                    for assignment in grid_points(CACHED_PARAMS, cap) {
                        specs.push(wrap_cached(name, &assignment));
                    }
                    for assignment in grid_points(PLACED_PARAMS, cap) {
                        specs.push(wrap_placed(name, &assignment));
                    }
                }
            }
            SpaceBudget::Full => {
                for base in &base_specs {
                    for assignment in grid_points(CACHED_PARAMS, cap) {
                        specs.push(wrap_cached(base, &assignment));
                    }
                    for assignment in grid_points(PLACED_PARAMS, cap) {
                        specs.push(wrap_placed(base, &assignment));
                    }
                }
            }
        }
        for spec in &specs {
            reg.parse(spec)
                .map_err(|e| format!("synthesized spec {spec:?} does not parse: {e}"))?;
        }
        Ok(SearchSpace { specs })
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }
}

/// Tunable dimensions of the fleet overload-protection config
/// ([`OverloadConfig`]): how tight the per-replica queue cap is and how
/// aggressively retries back off. The breaker/frontend knobs are
/// fault-tolerance policy, not throughput dimensions, so — like
/// `placed`'s `standby` — they stay out of the grid.
pub const OVERLOAD_PARAMS: &[ParamSpec] = &[
    ParamSpec { key: "queue-cap", grid: &[4.0, 8.0, 16.0], integer: true },
    ParamSpec { key: "backoff", grid: &[0.0005, 0.001, 0.004], integer: false },
];

/// Enumerate candidate overload configs at the given budget. Every point
/// is returned in [`OverloadConfig::spec`] canonical form (so it
/// round-trips through [`OverloadConfig::parse`] and compares stably as
/// a trial key); construction fails loudly on a grid/config mismatch.
pub fn overload_space(budget: SpaceBudget) -> Result<Vec<String>, String> {
    let cap = budget.grid_cap();
    let mut specs = Vec::new();
    for assignment in grid_points(OVERLOAD_PARAMS, cap) {
        let pairs: Vec<String> = OVERLOAD_PARAMS
            .iter()
            .zip(&assignment)
            .map(|(p, &v)| format!("{}={}", p.key, p.format_value(v)))
            .collect();
        let fragment = pairs.join(",");
        let cfg = OverloadConfig::parse(&fragment)
            .map_err(|e| format!("synthesized overload point {fragment:?} does not parse: {e}"))?;
        specs.push(cfg.spec());
    }
    Ok(specs)
}

/// Cartesian product of the first `cap` values of each parameter's grid;
/// a single empty assignment when there are no parameters.
fn grid_points(params: &[ParamSpec], cap: usize) -> Vec<Vec<f64>> {
    let grids: Vec<&[f64]> = params.iter().map(|p| &p.grid[..p.grid.len().min(cap)]).collect();
    let mut out: Vec<Vec<f64>> = vec![Vec::new()];
    for grid in grids {
        let mut next = Vec::with_capacity(out.len() * grid.len().max(1));
        for prefix in &out {
            for &v in grid {
                let mut p = prefix.clone();
                p.push(v);
                next.push(p);
            }
        }
        out = next;
    }
    out
}

/// Spell out one grid point as a registry spec string.
fn synthesize(name: &str, params: &[ParamSpec], assignment: &[f64]) -> String {
    if params.is_empty() {
        return name.to_string();
    }
    let pairs: Vec<String> = params
        .iter()
        .zip(assignment)
        .map(|(p, &v)| format!("{}={}", p.key, p.format_value(v)))
        .collect();
    format!("{name}:{}", pairs.join(","))
}

/// Wrap an inner spec in the `cached(...)` decorator at one grid point.
fn wrap_cached(inner: &str, assignment: &[f64]) -> String {
    let pairs: Vec<String> = CACHED_PARAMS
        .iter()
        .zip(assignment)
        .map(|(p, &v)| format!("{}={}", p.key, p.format_value(v)))
        .collect();
    if pairs.is_empty() {
        format!("cached({inner})")
    } else {
        format!("cached({inner}):{}", pairs.join(","))
    }
}

/// Wrap an inner spec in the `placed(...)` decorator at one grid point.
fn wrap_placed(inner: &str, assignment: &[f64]) -> String {
    let pairs: Vec<String> = PLACED_PARAMS
        .iter()
        .zip(assignment)
        .map(|(p, &v)| format!("{}={}", p.key, p.format_value(v)))
        .collect();
    if pairs.is_empty() {
        format!("placed({inner})")
    } else {
        format!("placed({inner}):{}", pairs.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{plan_ep, Planner, PlannerEntry, RoutePlan};
    use crate::topology::Topology;

    #[test]
    fn smoke_space_is_small_and_valid() {
        let reg = Registry::builtin();
        let space = SearchSpace::from_registry(&reg, SpaceBudget::Smoke).unwrap();
        // ep(1) + llep(2^3) + eplb(2) + chunked(2) + lpt(2) = 15
        assert_eq!(space.len(), 15, "{:?}", space.specs);
        assert!(space.specs.iter().all(|s| !s.starts_with("cached(")));
        assert!(space.specs.contains(&"ep".to_string()));
        assert!(space.specs.contains(&"llep:alpha=1,m=256,lambda=1.1".to_string()));
    }

    #[test]
    fn budgets_nest() {
        let reg = Registry::builtin();
        let smoke = SearchSpace::from_registry(&reg, SpaceBudget::Smoke).unwrap();
        let default = SearchSpace::from_registry(&reg, SpaceBudget::Default).unwrap();
        let full = SearchSpace::from_registry(&reg, SpaceBudget::Full).unwrap();
        assert!(smoke.len() < default.len());
        assert!(default.len() < full.len());
        assert!(default.specs.iter().any(|s| s.starts_with("cached(")));
        assert!(default.specs.iter().any(|s| s.starts_with("placed(")));
        // Full crosses the decorators against every base point.
        assert!(full.specs.iter().any(|s| s.contains("cached(llep:alpha=1.5")));
        assert!(full.specs.iter().any(|s| s.contains("placed(llep:alpha=1.5")));
    }

    #[test]
    fn runtime_registered_planner_joins_the_space() {
        struct Zero;
        impl Planner for Zero {
            fn plan_with_stats(
                &self,
                devices: usize,
                loads: &[u64],
                _stats: &[u64],
                _topo: Option<&Topology>,
            ) -> RoutePlan {
                plan_ep(loads.len(), devices, loads)
            }
            fn label(&self) -> String {
                "ZERO".into()
            }
            fn spec(&self) -> String {
                "zero".into()
            }
        }
        let mut reg = Registry::builtin();
        reg.register(PlannerEntry {
            name: "zero",
            help: "test-only",
            example: "zero",
            params: &[],
            build: |_| Ok(Box::new(Zero)),
        });
        let space = SearchSpace::from_registry(&reg, SpaceBudget::Default).unwrap();
        assert!(space.specs.contains(&"zero".to_string()));
        assert!(space.specs.iter().any(|s| s.starts_with("cached(zero)")));
    }

    #[test]
    fn shadowed_entries_enumerate_once() {
        let mut reg = Registry::builtin();
        // Shadow "ep" with an identical constructor; the space must not
        // list "ep" twice.
        reg.register(PlannerEntry {
            name: "ep",
            help: "shadowing test entry",
            example: "ep",
            params: &[],
            build: |_| Ok(Box::new(crate::planner::StandardEp)),
        });
        let space = SearchSpace::from_registry(&reg, SpaceBudget::Smoke).unwrap();
        assert_eq!(space.specs.iter().filter(|s| *s == "ep").count(), 1);
    }

    #[test]
    fn overload_space_scales_with_budget_and_is_canonical() {
        let smoke = overload_space(SpaceBudget::Smoke).unwrap();
        assert_eq!(smoke.len(), 4, "{smoke:?}"); // 2 queue caps x 2 backoffs
        let default = overload_space(SpaceBudget::Default).unwrap();
        assert_eq!(default.len(), 9, "{default:?}"); // full 3x3 grid
        assert_eq!(default, overload_space(SpaceBudget::Full).unwrap());
        for spec in &default {
            let cfg = OverloadConfig::parse(spec).unwrap();
            assert_eq!(&cfg.spec(), spec, "canonical form is a fixed point");
        }
        assert!(default.iter().any(|s| s.contains("queue-cap=16")));
        assert!(default.iter().any(|s| s.contains("backoff=0.004")));
        // smoke's truncated grids are a subset of the full grid
        for s in &smoke {
            assert!(default.contains(s), "{s}");
        }
    }

    #[test]
    fn every_spec_parses() {
        let reg = Registry::builtin();
        for budget in SpaceBudget::ALL {
            let space = SearchSpace::from_registry(&reg, budget).unwrap();
            for s in &space.specs {
                reg.parse(s).unwrap_or_else(|e| panic!("{s:?}: {e}"));
            }
        }
    }
}
