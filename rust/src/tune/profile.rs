//! Named hardware profiles for the autotuner.
//!
//! A profile is a complete [`SystemConfig`] — device count, node
//! topology, per-device HBM, GEMM throughput and interconnect bandwidth
//! tiers — addressed by name. The builtin names are the
//! [`SystemPreset`]s (`h200x8`, `h100x8`, `h200x16-2node`, `cpusim8`,
//! `cpusim4`); anything else is read as a path to a profile TOML file,
//! so site-specific hardware joins without recompiling:
//!
//! ```toml
//! [profile]
//! name = "a100x16-2node"
//! base = "h200x16-2node"     # optional preset to inherit from
//! devices = 16
//! devices_per_node = 8
//! mem_capacity_gb = 64.0
//!
//! [profile.gemm]
//! overhead_us = 6.0
//! peak_tflops = 200.0
//! tokens_half_eff = 384.0
//! dim_half_eff = 512.0
//!
//! [profile.comm]
//! latency_us = 12.0
//! intra_node_gbps = 300.0
//! inter_node_gbps = 25.0
//! ```
//!
//! All keys are optional (missing ones keep the base preset's values);
//! the resulting config must pass [`SystemConfig::validate`].

use crate::config::{SystemConfig, SystemPreset};
use crate::util::tomlmini::{self, Doc};

/// A named hardware configuration the tuner searches against.
#[derive(Clone, Debug, PartialEq)]
pub struct HardwareProfile {
    pub name: String,
    pub system: SystemConfig,
}

impl HardwareProfile {
    /// A builtin profile (one of the [`SystemPreset`] names).
    pub fn builtin(name: &str) -> Option<HardwareProfile> {
        let preset = SystemPreset::from_name(name)?;
        let system = SystemConfig::preset(preset);
        Some(HardwareProfile { name: system.name.clone(), system })
    }

    /// All builtin profiles, in preset order.
    pub fn all_builtin() -> Vec<HardwareProfile> {
        SystemPreset::ALL
            .iter()
            .map(|p| HardwareProfile::builtin(p.name()).expect("preset names resolve"))
            .collect()
    }

    /// Parse a profile TOML document (see the module docs for the schema).
    pub fn from_toml(text: &str) -> Result<HardwareProfile, String> {
        let doc = tomlmini::parse(text)?;
        let base = match doc.get("profile", "base") {
            Some(v) => {
                let name = v.as_str().ok_or("[profile] base must be a string")?;
                SystemPreset::from_name(name).ok_or_else(|| {
                    format!(
                        "unknown base preset {name:?}; known: {}",
                        SystemPreset::ALL.map(|p| p.name()).join(", ")
                    )
                })?
            }
            None => SystemPreset::H200x8,
        };
        let mut sys = SystemConfig::preset(base);
        if let Some(v) = doc.get("profile", "name") {
            sys.name = v.as_str().ok_or("[profile] name must be a string")?.to_string();
        }
        if let Some(d) = get_usize(&doc, "profile", "devices")? {
            sys = sys.with_devices(d);
        }
        if let Some(d) = get_usize(&doc, "profile", "devices_per_node")? {
            sys.devices_per_node = d;
        }
        if let Some(gb) = get_f64(&doc, "profile", "mem_capacity_gb")? {
            sys.mem_capacity_bytes = (gb * (1u64 << 30) as f64) as u64;
        }
        if let Some(us) = get_f64(&doc, "profile.gemm", "overhead_us")? {
            sys.gemm.overhead_s = us * 1e-6;
        }
        if let Some(tf) = get_f64(&doc, "profile.gemm", "peak_tflops")? {
            sys.gemm.peak_flops = tf * 1e12;
        }
        if let Some(x) = get_f64(&doc, "profile.gemm", "tokens_half_eff")? {
            sys.gemm.tokens_half_eff = x;
        }
        if let Some(x) = get_f64(&doc, "profile.gemm", "dim_half_eff")? {
            sys.gemm.dim_half_eff = x;
        }
        if let Some(us) = get_f64(&doc, "profile.comm", "latency_us")? {
            sys.comm.latency_s = us * 1e-6;
        }
        if let Some(g) = get_f64(&doc, "profile.comm", "intra_node_gbps")? {
            sys.comm.intra_node_bw = g * 1e9;
        }
        if let Some(g) = get_f64(&doc, "profile.comm", "inter_node_gbps")? {
            sys.comm.inter_node_bw = g * 1e9;
        }
        if let Some(v) = doc.get("profile", "device_speeds") {
            let arr =
                v.as_arr().ok_or("[profile] device_speeds must be an array of numbers")?;
            let mut speeds = Vec::with_capacity(arr.len());
            for x in arr {
                speeds
                    .push(x.as_f64().ok_or("[profile] device_speeds entries must be numbers")?);
            }
            sys.device_speeds = speeds;
        }
        sys.validate()?;
        Ok(HardwareProfile { name: sys.name.clone(), system: sys })
    }

    /// Resolve a `--profile` argument: builtin name first, then a path to
    /// a profile TOML file.
    pub fn resolve(arg: &str) -> Result<HardwareProfile, String> {
        if let Some(p) = HardwareProfile::builtin(arg) {
            return Ok(p);
        }
        match std::fs::read_to_string(arg) {
            Ok(text) => HardwareProfile::from_toml(&text)
                .map_err(|e| format!("profile file {arg:?}: {e}")),
            Err(_) => Err(format!(
                "unknown profile {arg:?} (builtin: {}; or pass a profile TOML path)",
                SystemPreset::ALL.map(|p| p.name()).join(", ")
            )),
        }
    }
}

fn get_usize(doc: &Doc, table: &str, key: &str) -> Result<Option<usize>, String> {
    match doc.get(table, key) {
        None => Ok(None),
        Some(v) => v
            .as_usize()
            .map(Some)
            .ok_or_else(|| format!("[{table}] {key} must be a non-negative integer")),
    }
}

fn get_f64(doc: &Doc, table: &str, key: &str) -> Result<Option<f64>, String> {
    match doc.get(table, key) {
        None => Ok(None),
        Some(v) => {
            v.as_f64().map(Some).ok_or_else(|| format!("[{table}] {key} must be a number"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_profiles_resolve_and_validate() {
        for p in HardwareProfile::all_builtin() {
            p.system.validate().unwrap();
            assert_eq!(HardwareProfile::resolve(&p.name).unwrap(), p);
        }
        assert!(HardwareProfile::builtin("h100x8").is_some());
    }

    #[test]
    fn toml_overrides_apply_over_base() {
        let p = HardwareProfile::from_toml(
            r#"
[profile]
name = "half-h200"
base = "h200x8"
mem_capacity_gb = 56.0

[profile.gemm]
peak_tflops = 325.0

[profile.comm]
intra_node_gbps = 225.0
"#,
        )
        .unwrap();
        assert_eq!(p.name, "half-h200");
        assert_eq!(p.system.devices, 8, "inherited from base");
        assert_eq!(p.system.mem_capacity_bytes, 56 * (1u64 << 30));
        assert_eq!(p.system.gemm.peak_flops, 325e12);
        assert_eq!(p.system.comm.intra_node_bw, 225e9);
        let base = SystemConfig::preset(SystemPreset::H200x8);
        assert_eq!(p.system.comm.inter_node_bw, base.comm.inter_node_bw, "untouched keys keep");
    }

    #[test]
    fn device_speeds_make_a_heterogeneous_profile() {
        let p = HardwareProfile::from_toml(
            r#"
[profile]
name = "site-mixed"
base = "cpusim4"
device_speeds = [1.0, 1.0, 0.5, 0.5]
"#,
        )
        .unwrap();
        assert_eq!(p.system.device_speeds, vec![1.0, 1.0, 0.5, 0.5]);
        // Wrong arity fails SystemConfig::validate.
        let bad = HardwareProfile::from_toml(
            "[profile]\nbase = \"cpusim4\"\ndevice_speeds = [1.0]\n",
        );
        assert!(bad.is_err(), "{bad:?}");
        // The builtin mixed preset resolves as a profile too.
        let mixed = HardwareProfile::resolve("mixed-h100-a100").unwrap();
        assert!(!mixed.system.device_speeds.is_empty());
    }

    #[test]
    fn invalid_profiles_are_rejected() {
        assert!(HardwareProfile::from_toml("[profile]\nbase = \"tpu\"\n").is_err());
        assert!(HardwareProfile::from_toml("[profile]\ndevices = \"eight\"\n").is_err());
        // 6 devices on 8-device nodes fails SystemConfig::validate.
        let r = HardwareProfile::from_toml(
            "[profile]\ndevices = 6\ndevices_per_node = 8\n",
        );
        assert!(r.is_err(), "{r:?}");
        assert!(HardwareProfile::resolve("no-such-profile").is_err());
    }

    #[test]
    fn empty_document_is_the_default_testbed() {
        let p = HardwareProfile::from_toml("").unwrap();
        assert_eq!(p.system, SystemConfig::preset(SystemPreset::H200x8));
    }
}
