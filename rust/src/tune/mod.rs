//! Hardware-profile autotuner: search planner-spec space, emit a
//! latency/memory Pareto front and a recommended `--planner` spec per
//! hardware profile.
//!
//! The paper closes by arguing its cost analysis "enables a principled
//! framework for hardware-specific hyper-parameter tuning". This module
//! is that framework, built on three existing pieces:
//!
//! * the **open planner registry** ([`crate::planner::registry`]) —
//!   every planner declares its tunable parameters
//!   ([`crate::planner::ParamSpec`] grids), so [`SearchSpace`]
//!   synthesizes candidate spec strings for all current *and future*
//!   planners, `cached(...)` decorator dimensions included;
//! * the **engine** ([`crate::exec`]) — trials price full-model steps
//!   (or a continuous-batching serve horizon) under the Eq. 3/4 cost
//!   models with a deterministic plan-cost model, so every trial is
//!   bit-reproducible under the tuner's settings and the winning spec
//!   round-trips into `run`/`serve`/`replay` (same planner, same
//!   plans; those commands charge measured plan wall time);
//! * **hardware profiles** ([`HardwareProfile`]) — builtin presets or
//!   site-specific TOML files supplying the bandwidth tiers, HBM
//!   capacity and node topology a configuration is tuned *for*.
//!
//! [`Tuner::run`] evaluates candidates in parallel
//! (`std::thread::scope`), caches trial results keyed by
//! `(spec, scenario, system, budget)`, supports grid / random /
//! successive-halving search ([`Strategy`]), and reduces the trials to a
//! Pareto front ([`pareto_front`]) plus a single recommendation. The
//! `llep tune` subcommand is a thin CLI over this module.

pub mod pareto;
pub mod profile;
pub mod search;
pub mod space;

pub use pareto::{dominates, pareto_front};
pub use profile::HardwareProfile;
pub use search::{Mode, Strategy, Trial, TrialMetrics, TuneOutcome, Tuner};
pub use space::{overload_space, SearchSpace, SpaceBudget, OVERLOAD_PARAMS};
