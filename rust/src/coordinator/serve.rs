//! Serving-style simulation: request queues feeding batched MoE steps.
//!
//! Requests carry token counts and arrive on a (virtual) timeline; each
//! simulator feeds them into a [`Replica`](super::Replica) — the shared
//! per-replica event loop in `coordinator/replica.rs` — which batches
//! whatever is queued (up to a token budget), prices one **full-model**
//! engine step per batch (all MoE layers of the model, each with its own
//! per-layer routing — see [`crate::exec::Engine::run_model`]), and
//! advances the virtual clock by the step latency. Per-request latency =
//! completion − arrival. This is the vLLM-router-shaped workload the
//! paper's "higher-throughput inference" claim is about.
//!
//! Both simulators run any trait [`Planner`] via `&dyn Planner` — in
//! particular the [`CachedPlanner`](crate::planner::CachedPlanner)
//! decorator, whose cross-step plan reuse takes `T_plan` off the decode
//! critical path; the per-run hit/miss/forced counters and per-step
//! planning-time summary are surfaced in the reports.
//!
//! Token accounting is exact: each batch's total token count is carried
//! into the priced load matrices via
//! [`Scenario::generate_loads_total`](crate::routing::Scenario::generate_loads_total)
//! (largest-remainder split across devices), and both reports carry a
//! [`TokenLedger`] whose admitted and priced sides must agree (asserted
//! by tests).

use super::replica::{uniform_profile, Replica, ReplicaRequest, ReplicaStepOutcome};
use super::{ChaosStats, TokenLedger};
use crate::chaos::FaultPlan;
use crate::exec::Engine;
use crate::placement::PlacementStats;
use crate::planner::{CacheStats, Planner, PlannerKind};
use crate::routing::{DepthProfile, Scenario};
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: usize,
    pub arrival_s: f64,
    pub tokens: usize,
}

/// Result of a serving simulation.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub planner: String,
    pub completed: usize,
    pub makespan_s: f64,
    pub request_latency: Summary,
    pub batches: usize,
    /// Admitted-vs-priced token accounting (equal by contract).
    pub tokens: TokenLedger,
    pub oom_batches: usize,
    /// Max per-device peak bytes over all steps (Eq.-4 accounting) — the
    /// memory side of the autotuner's latency/memory Pareto objectives.
    pub peak_bytes: u64,
    /// MoE layers priced per step.
    pub layers: usize,
    /// Plan-cache counters summed over all steps and layers.
    pub plan_cache: CacheStats,
    /// Persistent-placement activity summed over all steps and layers
    /// (all zero for stateless planners).
    pub placement: PlacementStats,
    /// Per-step planning wall time (sum across the step's layers).
    pub plan_time: Summary,
    /// Fault-injection accounting (all zero without a fault plan).
    pub chaos: ChaosStats,
}

impl ServeReport {
    pub fn throughput_tps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.tokens.admitted as f64 / self.makespan_s
        } else {
            0.0
        }
    }
}

/// Serving simulator over a fixed request list: each request is one
/// batchable unit of `tokens`, completing at the step that prices it (a
/// [`ReplicaRequest`] with zero decode steps).
pub struct ServeSim {
    pub engine: Engine,
    pub planner: Box<dyn Planner>,
    /// Per-layer routing scenarios for the full-model step.
    pub profile: DepthProfile,
    /// Max tokens per device per batch.
    pub max_tokens_per_device: usize,
    /// Per-step fault schedule (None = always-healthy pool).
    pub faults: Option<FaultPlan>,
}

impl ServeSim {
    /// Backward-compatible constructor from the [`PlannerKind`] enum.
    pub fn new(
        engine: Engine,
        planner: PlannerKind,
        scenario: Scenario,
        max_tokens_per_device: usize,
    ) -> ServeSim {
        ServeSim::with_planner(engine, planner.boxed(), scenario, max_tokens_per_device)
    }

    /// Constructor from any trait planner (spec-parsed, cached, custom).
    pub fn with_planner(
        engine: Engine,
        planner: Box<dyn Planner>,
        scenario: Scenario,
        max_tokens_per_device: usize,
    ) -> ServeSim {
        ServeSim {
            profile: uniform_profile(&engine, scenario),
            engine,
            planner,
            max_tokens_per_device,
            faults: None,
        }
    }

    /// Replace the depth profile (e.g. [`DepthProfile::varying`]).
    pub fn with_profile(mut self, profile: DepthProfile) -> ServeSim {
        self.profile = profile;
        self
    }

    /// Inject a fault schedule: each engine step `k` runs on
    /// `faults.state_at(k, ...)`. Use [`try_run`](Self::try_run) to
    /// observe unrecoverable pools as errors instead of panics.
    pub fn with_faults(mut self, faults: FaultPlan) -> ServeSim {
        self.faults = Some(faults);
        self
    }

    /// Generate a Poisson-ish arrival stream.
    pub fn poisson_requests(
        n: usize,
        mean_interarrival_s: f64,
        tokens_lo: usize,
        tokens_hi: usize,
        rng: &mut Rng,
    ) -> Vec<Request> {
        let mut t = 0.0;
        (0..n)
            .map(|id| {
                t += -mean_interarrival_s * (1.0 - rng.f64()).ln();
                Request { id, arrival_s: t, tokens: rng.range(tokens_lo, tokens_hi) }
            })
            .collect()
    }

    /// Run the simulation; requests must be sorted by arrival. Panics if
    /// the fault plan makes the pool unrecoverable — use
    /// [`try_run`](Self::try_run) when that is an expected outcome.
    pub fn run(&self, requests: &[Request], rng: &mut Rng) -> ServeReport {
        self.try_run(requests, rng).expect("serve simulation failed")
    }

    /// Run the simulation, surfacing chaos-unrecoverable pools (every
    /// device dead, or a planner that cannot adapt to a failure) as
    /// errors.
    pub fn try_run(&self, requests: &[Request], rng: &mut Rng) -> Result<ServeReport, String> {
        let budget = self.max_tokens_per_device * self.engine.system.devices;
        let mut replica = Replica::new(
            &self.engine,
            &*self.planner,
            &self.profile,
            budget,
            self.faults.as_ref(),
        )?;
        let mut next = 0usize;
        let mut latencies = Vec::with_capacity(requests.len());

        while next < requests.len() || replica.has_work() {
            // admit arrivals up to the clock; if idle, jump to next arrival
            if !replica.has_work()
                && next < requests.len()
                && requests[next].arrival_s > replica.now()
            {
                replica.advance_to(requests[next].arrival_s);
            }
            while next < requests.len() && requests[next].arrival_s <= replica.now() {
                let req = &requests[next];
                replica.submit(ReplicaRequest {
                    id: req.id,
                    arrival_s: req.arrival_s,
                    prompt_tokens: req.tokens,
                    decode_steps: 0,
                });
                next += 1;
            }
            if let ReplicaStepOutcome::Stepped(events) = replica.step(rng)? {
                let now = replica.now();
                for &(_, arrival_s) in &events.finished {
                    latencies.push(now - arrival_s);
                }
            }
        }

        Ok(ServeReport {
            planner: self.planner.label(),
            completed: latencies.len(),
            makespan_s: replica.now(),
            request_latency: Summary::of(&latencies),
            batches: replica.steps(),
            tokens: replica.ledger(),
            oom_batches: replica.oom_steps(),
            peak_bytes: replica.peak_bytes(),
            layers: self.profile.num_layers(),
            plan_cache: replica.plan_cache(),
            placement: replica.placement(),
            plan_time: replica.plan_time_summary(),
            chaos: replica.chaos_stats(),
        })
    }
}

/// A generation request for continuous batching: a prefill of
/// `prompt_tokens`, then `decode_steps` single-token steps.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: usize,
    pub arrival_s: f64,
    pub prompt_tokens: usize,
    pub decode_steps: usize,
}

/// Result of a continuous-batching run.
#[derive(Clone, Debug)]
pub struct ContinuousReport {
    pub planner: String,
    pub completed: usize,
    pub makespan_s: f64,
    /// Time to first token (prefill completion) per request.
    pub ttft: Summary,
    /// Per-decode-step latency across all requests: every step
    /// contributes one sample **per active decoding request** (weighting
    /// by `decode_tokens`), so the mean is the per-token latency a
    /// request actually experienced. A request's first token comes out of
    /// its prefill step (counted by `ttft`, not here), so `tpot.n` equals
    /// `sum(max(decode_steps - 1, 0))` over completed requests.
    pub tpot: Summary,
    pub steps: usize,
    /// Steps where every MoE layer's lambda guard reverted to EP.
    pub fallback_steps: usize,
    /// Steps where some device exceeded its memory capacity.
    pub oom_steps: usize,
    /// Max per-device peak bytes over all steps (Eq.-4 accounting).
    pub peak_bytes: u64,
    /// Admitted-vs-priced token accounting (equal by contract).
    pub tokens: TokenLedger,
    /// Plan-cache counters summed over all steps and layers.
    pub plan_cache: CacheStats,
    /// Persistent-placement activity summed over all steps and layers
    /// (all zero for stateless planners).
    pub placement: PlacementStats,
    /// Per-step planning wall time (sum across the step's layers).
    pub plan_time: Summary,
    /// Fault-injection accounting (all zero without a fault plan).
    pub chaos: ChaosStats,
}

/// Run a continuous-batching workload on one replica built from parts —
/// the shared driver behind [`ContinuousBatchSim::try_run`] and the
/// autotuner's serve-mode trial evaluation (which prices candidate
/// planner specs on the replica core without constructing a sim).
pub fn run_continuous(
    engine: &Engine,
    planner: &dyn Planner,
    profile: &DepthProfile,
    max_prefill_tokens: usize,
    faults: Option<&FaultPlan>,
    requests: &[GenRequest],
    rng: &mut Rng,
) -> Result<ContinuousReport, String> {
    let mut replica = Replica::new(engine, planner, profile, max_prefill_tokens, faults)?;
    let mut next = 0usize;
    let mut ttft = Vec::new();
    let mut tpot = Vec::new();
    let mut completed = 0usize;

    while completed < requests.len() {
        if !replica.has_work() {
            // idle: jump to next arrival
            replica.advance_to(requests[next].arrival_s);
        }
        while next < requests.len() && requests[next].arrival_s <= replica.now() {
            let req = &requests[next];
            replica.submit(ReplicaRequest {
                id: req.id,
                arrival_s: req.arrival_s,
                prompt_tokens: req.prompt_tokens,
                decode_steps: req.decode_steps,
            });
            next += 1;
        }
        if let ReplicaStepOutcome::Stepped(events) = replica.step(rng)? {
            let now = replica.now();
            // prefill completions = first token
            for &(_, arrival_s) in &events.prefilled {
                ttft.push(now - arrival_s);
            }
            // one decode token for every active request: one tpot sample
            // per (request, step) pair, so multi-request steps weigh more
            for _ in 0..events.decode_tokens {
                tpot.push(events.latency_s);
            }
            completed += events.finished.len();
        }
    }

    Ok(ContinuousReport {
        planner: planner.label(),
        completed,
        makespan_s: replica.now(),
        ttft: Summary::of(&ttft),
        tpot: Summary::of(&tpot),
        steps: replica.steps(),
        fallback_steps: replica.fallback_steps(),
        oom_steps: replica.oom_steps(),
        peak_bytes: replica.peak_bytes(),
        tokens: replica.ledger(),
        plan_cache: replica.plan_cache(),
        placement: replica.placement(),
        plan_time: replica.plan_time_summary(),
        chaos: replica.chaos_stats(),
    })
}

/// vLLM-style continuous batching: every engine step batches the newly
/// admitted requests' prefills together with one token from every active
/// decode, priced across **all** MoE layers of the model per step.
/// Decode-heavy steps are small and latency-bound — the regime where
/// LLEP's lambda guard, the fused-collective option, and cross-step plan
/// reuse matter.
pub struct ContinuousBatchSim {
    pub engine: Engine,
    pub planner: Box<dyn Planner>,
    pub profile: DepthProfile,
    pub max_prefill_tokens: usize,
    /// Per-step fault schedule (None = always-healthy pool).
    pub faults: Option<FaultPlan>,
}

impl ContinuousBatchSim {
    /// Backward-compatible constructor from the [`PlannerKind`] enum.
    pub fn new(
        engine: Engine,
        planner: PlannerKind,
        scenario: Scenario,
        max_prefill_tokens: usize,
    ) -> ContinuousBatchSim {
        ContinuousBatchSim::with_planner(engine, planner.boxed(), scenario, max_prefill_tokens)
    }

    /// Constructor from any trait planner (spec-parsed, cached, custom).
    pub fn with_planner(
        engine: Engine,
        planner: Box<dyn Planner>,
        scenario: Scenario,
        max_prefill_tokens: usize,
    ) -> ContinuousBatchSim {
        ContinuousBatchSim {
            profile: uniform_profile(&engine, scenario),
            engine,
            planner,
            max_prefill_tokens,
            faults: None,
        }
    }

    /// Replace the depth profile (e.g. [`DepthProfile::varying`]).
    pub fn with_profile(mut self, profile: DepthProfile) -> ContinuousBatchSim {
        self.profile = profile;
        self
    }

    /// Inject a fault schedule: each engine step `k` runs on
    /// `faults.state_at(k, ...)`. Use [`try_run`](Self::try_run) to
    /// observe unrecoverable pools as errors instead of panics.
    pub fn with_faults(mut self, faults: FaultPlan) -> ContinuousBatchSim {
        self.faults = Some(faults);
        self
    }

    /// Generate a request stream.
    pub fn requests(
        n: usize,
        mean_interarrival_s: f64,
        prompt: (usize, usize),
        decode: (usize, usize),
        rng: &mut Rng,
    ) -> Vec<GenRequest> {
        let mut t = 0.0;
        (0..n)
            .map(|id| {
                t += -mean_interarrival_s * (1.0 - rng.f64()).ln();
                GenRequest {
                    id,
                    arrival_s: t,
                    prompt_tokens: rng.range(prompt.0, prompt.1),
                    decode_steps: rng.range(decode.0, decode.1),
                }
            })
            .collect()
    }

    /// Run to completion. Panics if the fault plan makes the pool
    /// unrecoverable — use [`try_run`](Self::try_run) when that is an
    /// expected outcome.
    pub fn run(&self, requests: &[GenRequest], rng: &mut Rng) -> ContinuousReport {
        self.try_run(requests, rng).expect("continuous-batching simulation failed")
    }

    /// Run to completion, surfacing chaos-unrecoverable pools (every
    /// device dead, or a planner that cannot adapt to a failure) as
    /// errors.
    pub fn try_run(
        &self,
        requests: &[GenRequest],
        rng: &mut Rng,
    ) -> Result<ContinuousReport, String> {
        run_continuous(
            &self.engine,
            &*self.planner,
            &self.profile,
            self.max_prefill_tokens,
            self.faults.as_ref(),
            requests,
            rng,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
    use crate::planner::CachedPlanner;

    fn engine() -> Engine {
        Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        )
    }

    fn sim(planner: PlannerKind) -> ServeSim {
        ServeSim::new(engine(), planner, Scenario::concentrated(0.9, 1), 8192)
    }

    #[test]
    fn all_requests_complete() {
        let mut rng = Rng::new(1);
        let reqs = ServeSim::poisson_requests(50, 0.001, 64, 512, &mut rng);
        let report = sim(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(2));
        assert_eq!(report.completed, 50);
        assert!(report.makespan_s > 0.0);
        assert!(report.batches > 0);
        assert!(report.request_latency.mean > 0.0);
        assert!(report.peak_bytes > 0, "peak memory surfaces in the report");
        assert_eq!(report.oom_batches, 0);
        assert_eq!(report.plan_cache, CacheStats::default(), "uncached planner: zero counters");
    }

    #[test]
    fn arrivals_are_monotone() {
        let mut rng = Rng::new(3);
        let reqs = ServeSim::poisson_requests(20, 0.01, 10, 20, &mut rng);
        for w in reqs.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
    }

    #[test]
    fn batch_token_accounting_is_exact() {
        // 1001-token requests over 8 devices never divide evenly; the
        // priced work must still equal the admitted work exactly.
        let reqs: Vec<Request> =
            (0..7).map(|id| Request { id, arrival_s: 0.0, tokens: 1001 }).collect();
        let report = sim(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(9));
        assert_eq!(report.completed, 7);
        assert_eq!(report.tokens.admitted, 7 * 1001);
        assert!(report.tokens.is_exact(), "{:?}", report.tokens);
    }

    #[test]
    fn serve_prices_every_moe_layer() {
        // A 4-layer model's steps must cost ~4x a 1-layer model's on the
        // same workload (planning overlap makes it slightly cheaper).
        let reqs: Vec<Request> =
            (0..6).map(|id| Request { id, arrival_s: 0.0, tokens: 4096 }).collect();
        let mut model = ModelConfig::preset(ModelPreset::Fig1Layer);
        let one = sim(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(4));
        model.num_layers = 4;
        let engine4 = Engine::modeled(model, SystemConfig::preset(SystemPreset::H200x8));
        let sim4 =
            ServeSim::new(engine4, PlannerKind::StandardEp, Scenario::concentrated(0.9, 1), 8192);
        let four = sim4.run(&reqs, &mut Rng::new(4));
        assert_eq!(one.layers, 1);
        assert_eq!(four.layers, 4);
        assert!(
            four.makespan_s > one.makespan_s * 3.0,
            "4-layer steps must price all layers: {} vs {}",
            four.makespan_s,
            one.makespan_s
        );
    }

    #[test]
    fn llep_serves_faster_under_imbalance() {
        // arrival rate >> service rate so makespan is service-bound
        let mut rng = Rng::new(4);
        let reqs = ServeSim::poisson_requests(40, 0.00005, 1024, 4096, &mut rng);
        let ep = sim(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(5));
        let ll = sim(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(5));
        assert!(
            ll.makespan_s < ep.makespan_s,
            "LLEP {} vs EP {}",
            ll.makespan_s,
            ep.makespan_s
        );
        assert!(ll.request_latency.p50 <= ep.request_latency.p50 * 1.05);
        assert!(ll.throughput_tps() > ep.throughput_tps());
    }

    #[test]
    fn cached_planner_reuses_across_batches() {
        // Identical burst batches: after the first (miss), the cache
        // serves steady hits, accounting stays exact, and the counters
        // surface in the report.
        let reqs: Vec<Request> =
            (0..12).map(|id| Request { id, arrival_s: 0.0, tokens: 8192 * 8 }).collect();
        let cached = Box::new(
            CachedPlanner::new(PlannerKind::llep_default().boxed()).with_drift_threshold(0.1),
        );
        let s = ServeSim::with_planner(engine(), cached, Scenario::concentrated(0.9, 1), 8192);
        let report = s.run(&reqs, &mut Rng::new(7));
        assert_eq!(report.completed, 12);
        assert!(report.planner.starts_with("Cached["), "{}", report.planner);
        assert_eq!(report.plan_cache.lookups(), report.batches as u64);
        assert!(report.plan_cache.hits > 0, "steady load must reuse: {:?}", report.plan_cache);
        assert!(report.tokens.is_exact(), "{:?}", report.tokens);
    }

    #[test]
    fn repair_tier_counters_reconcile_in_serve_report() {
        // A repair-tier planner under batch-to-batch drift (the drifting
        // scenario re-draws its dominance per batch): a tight retarget
        // threshold with the widest repair ceiling routes every drifted
        // batch through the O(Δ) repair path, a periodic forced replan
        // exercises the fourth counter, and the four-way split must
        // account for every lookup exactly.
        let mut rng = Rng::new(21);
        let reqs = ServeSim::poisson_requests(16, 0.00005, 2048, 8192, &mut rng);
        let cached = Box::new(
            CachedPlanner::new(PlannerKind::llep_default().boxed())
                .with_drift_threshold(0.001)
                .with_repair_ceiling(2.0)
                .with_replan_every(5),
        );
        let s =
            ServeSim::with_planner(engine(), cached, Scenario::drifting(5, 0.6, 0.2), 8192);
        let report = s.run(&reqs, &mut Rng::new(22));
        assert_eq!(report.completed, 16);
        let c = &report.plan_cache;
        assert_eq!(
            c.hits + c.repairs + c.misses + c.forced,
            c.lookups(),
            "counters must reconcile: {c:?}"
        );
        assert_eq!(c.lookups(), report.batches as u64);
        assert!(c.repairs > 0, "drifted batches must take the repair path: {c:?}");
        assert!(c.forced > 0, "replan_every must force fresh plans: {c:?}");
        assert!(report.tokens.is_exact(), "{:?}", report.tokens);
    }

    fn continuous(planner: PlannerKind) -> ContinuousBatchSim {
        ContinuousBatchSim::new(engine(), planner, Scenario::concentrated(0.8, 4), 16_384)
    }

    #[test]
    fn continuous_batching_completes_all() {
        let mut rng = Rng::new(10);
        let reqs = ContinuousBatchSim::requests(24, 0.0005, (128, 1024), (4, 16), &mut rng);
        let r = continuous(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(11));
        assert_eq!(r.completed, 24);
        assert!(r.ttft.mean > 0.0);
        assert!(r.tpot.n > 0, "decode steps happened");
        assert!(r.steps >= 4, "multiple engine steps: {}", r.steps);
        assert!(r.peak_bytes > 0, "peak memory surfaces in the report");
        assert_eq!(r.oom_steps, 0);
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
    }

    #[test]
    fn tpot_weights_by_active_decodes() {
        // Regression for the old accounting, which pushed one sample per
        // step no matter how many requests were decoding: with per-active-
        // request samples, tpot.n must equal the total decode tokens.
        let reqs = vec![
            GenRequest { id: 0, arrival_s: 0.0, prompt_tokens: 64, decode_steps: 5 },
            GenRequest { id: 1, arrival_s: 0.0, prompt_tokens: 64, decode_steps: 2 },
            GenRequest { id: 2, arrival_s: 0.0, prompt_tokens: 64, decode_steps: 7 },
        ];
        let r = continuous(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(1));
        assert_eq!(r.completed, 3);
        // The first token of each request comes out of its prefill step
        // (ttft), so each request decodes for decode_steps - 1 further
        // steps: 4 + 1 + 6 samples, not 3 (one per step, the old bug).
        let expected: usize = reqs.iter().map(|q| q.decode_steps.saturating_sub(1)).sum();
        assert_eq!(r.tpot.n, expected, "one tpot sample per decode token per request");
        assert!(r.tpot.n > r.steps - 1, "weighted: more samples than decode steps");
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
    }

    #[test]
    fn continuous_llep_improves_prefill_heavy_phase() {
        let mut rng = Rng::new(12);
        // prefill-heavy burst: large prompts, few decodes
        let reqs = ContinuousBatchSim::requests(24, 0.00002, (2048, 8192), (1, 3), &mut rng);
        let ep = continuous(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(13));
        let ll = continuous(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(13));
        assert!(
            ll.makespan_s < ep.makespan_s,
            "LLEP {} vs EP {}",
            ll.makespan_s,
            ep.makespan_s
        );
        assert!(ll.ttft.p50 <= ep.ttft.p50 * 1.05);
    }

    #[test]
    fn continuous_decode_steps_fall_back_when_small() {
        // decode-only regime: tiny per-step batches are latency-bound and
        // often balanced enough that the lambda guard reverts to EP —
        // LLEP must not be slower there.
        let mut rng = Rng::new(14);
        let reqs = ContinuousBatchSim::requests(8, 0.00002, (64, 128), (32, 64), &mut rng);
        let ll = continuous(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(15));
        assert_eq!(ll.completed, 8);
        assert!(ll.tpot.n >= 32, "long decode phase");
    }

    #[test]
    fn chaos_failure_requeues_without_losing_tokens() {
        // A permanent failure mid-run: the chaos-aware LLEP serve sim
        // aborts the in-flight step, replans around the dead device, and
        // still completes every request with exact token accounting.
        // 30k-token requests against a 64k batch budget: two per batch,
        // so 10 requests take 5 engine steps and the failure at step 3
        // lands mid-run.
        let reqs: Vec<Request> =
            (0..10).map(|id| Request { id, arrival_s: 0.0, tokens: 30_000 }).collect();
        let faults = FaultPlan::parse("fail:dev=2,at=3").unwrap();
        let s = sim(PlannerKind::llep_default()).with_faults(faults);
        let r = s.try_run(&reqs, &mut Rng::new(21)).unwrap();
        assert_eq!(r.completed, 10);
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
        assert_eq!(r.chaos.failures, 1);
        assert_eq!(r.chaos.requeues, 1);
        assert!(r.chaos.requeued_tokens > 0);
        assert!(r.chaos.wasted_s > 0.0);
        assert!(r.chaos.max_recovery_steps <= 1, "bounded recovery");
        assert!(r.chaos.fault_steps > 0);
    }

    #[test]
    fn chaos_static_ep_cannot_adapt_to_failure() {
        let reqs: Vec<Request> =
            (0..10).map(|id| Request { id, arrival_s: 0.0, tokens: 30_000 }).collect();
        let faults = FaultPlan::parse("fail:dev=0,at=2").unwrap();
        let s = sim(PlannerKind::StandardEp).with_faults(faults);
        let err = s.try_run(&reqs, &mut Rng::new(22)).unwrap_err();
        assert!(err.contains("dead device"), "{err}");
    }

    #[test]
    fn chaos_no_faults_report_is_zero() {
        let mut rng = Rng::new(23);
        let reqs = ServeSim::poisson_requests(8, 0.001, 64, 256, &mut rng);
        let r = sim(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(24));
        assert_eq!(r.chaos, ChaosStats::default());
    }

    #[test]
    fn continuous_chaos_stall_recovers_on_its_own() {
        // A transient stall kills a device for two steps; the chaos-aware
        // planner routes around it and the device rejoins.
        let reqs = vec![
            GenRequest { id: 0, arrival_s: 0.0, prompt_tokens: 512, decode_steps: 12 },
            GenRequest { id: 1, arrival_s: 0.0, prompt_tokens: 512, decode_steps: 12 },
        ];
        let faults = FaultPlan::parse("stall:dev=1,at=2,steps=2").unwrap();
        let c = continuous(PlannerKind::llep_default()).with_faults(faults);
        let r = c.try_run(&reqs, &mut Rng::new(25)).unwrap();
        assert_eq!(r.completed, 2);
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
        assert_eq!(r.chaos.failures, 1);
        assert_eq!(r.chaos.recoveries, 1, "stall ends on its own");
        assert_eq!(r.chaos.fault_steps, 2);
    }

    #[test]
    fn queue_drains_even_with_bursts() {
        // all arrive at t=0 (burst)
        let reqs: Vec<Request> =
            (0..30).map(|id| Request { id, arrival_s: 0.0, tokens: 700 }).collect();
        let report = sim(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(6));
        assert_eq!(report.completed, 30);
        // batches bounded by budget: 8192*8 tokens per batch >= 9 requests
        assert!(report.batches >= 1);
    }
}
