//! Serving-style simulation: a request queue feeding batched MoE steps.
//!
//! Requests carry token counts and arrive on a (virtual) timeline; the
//! coordinator batches whatever is queued (up to a token budget), prices
//! one **full-model** engine step per batch (all MoE layers of the model,
//! each with its own per-layer routing — see
//! [`crate::exec::Engine::run_model`]), and advances the virtual clock by
//! the step latency. Per-request latency = completion − arrival. This is
//! the vLLM-router-shaped workload the paper's "higher-throughput
//! inference" claim is about.
//!
//! Token accounting is exact: each batch's total token count is carried
//! into the priced load matrices via
//! [`Scenario::generate_loads_total`](crate::routing::Scenario::generate_loads_total)
//! (largest-remainder split across devices), so reported throughput and
//! priced work always agree — the old `(batch / devices).max(1)` rounding
//! silently priced `per_device * devices != batch_tokens` loads.

use crate::exec::Engine;
use crate::planner::PlannerKind;
use crate::routing::{DepthProfile, Scenario};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use std::collections::VecDeque;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: usize,
    pub arrival_s: f64,
    pub tokens: usize,
}

/// Result of a serving simulation.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub planner: String,
    pub completed: usize,
    pub makespan_s: f64,
    pub request_latency: Summary,
    pub batches: usize,
    /// Tokens admitted from the request stream.
    pub total_tokens: u64,
    /// Tokens actually priced by the engine — equals `total_tokens` (the
    /// accounting contract; asserted by tests).
    pub priced_tokens: u64,
    pub oom_batches: usize,
    /// MoE layers priced per step.
    pub layers: usize,
}

impl ServeReport {
    pub fn throughput_tps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.total_tokens as f64 / self.makespan_s
        } else {
            0.0
        }
    }
}

/// Serving simulator over a fixed request list.
pub struct ServeSim {
    pub engine: Engine,
    pub planner: PlannerKind,
    /// Per-layer routing scenarios for the full-model step.
    pub profile: DepthProfile,
    /// Max tokens per device per batch.
    pub max_tokens_per_device: usize,
}

impl ServeSim {
    /// Every MoE layer of the engine's model routes with `scenario`.
    pub fn new(
        engine: Engine,
        planner: PlannerKind,
        scenario: Scenario,
        max_tokens_per_device: usize,
    ) -> ServeSim {
        let layers = engine.model.num_moe_layers().max(1);
        ServeSim {
            profile: DepthProfile::uniform(scenario, layers),
            engine,
            planner,
            max_tokens_per_device,
        }
    }

    /// Replace the depth profile (e.g. [`DepthProfile::varying`]).
    pub fn with_profile(mut self, profile: DepthProfile) -> ServeSim {
        self.profile = profile;
        self
    }

    /// Generate a Poisson-ish arrival stream.
    pub fn poisson_requests(
        n: usize,
        mean_interarrival_s: f64,
        tokens_lo: usize,
        tokens_hi: usize,
        rng: &mut Rng,
    ) -> Vec<Request> {
        let mut t = 0.0;
        (0..n)
            .map(|id| {
                t += -mean_interarrival_s * (1.0 - rng.f64()).ln();
                Request { id, arrival_s: t, tokens: rng.range(tokens_lo, tokens_hi) }
            })
            .collect()
    }

    /// Run the simulation; requests must be sorted by arrival.
    pub fn run(&self, requests: &[Request], rng: &mut Rng) -> ServeReport {
        let devices = self.engine.system.devices;
        let budget = self.max_tokens_per_device * devices;
        let mut clock = 0.0f64;
        let mut next = 0usize;
        let mut latencies = Vec::with_capacity(requests.len());
        let mut batches = 0usize;
        let mut total_tokens = 0u64;
        let mut priced_tokens = 0u64;
        let mut oom_batches = 0usize;
        let mut queue: VecDeque<&Request> = VecDeque::new();

        while next < requests.len() || !queue.is_empty() {
            // admit arrivals up to the clock; if idle, jump to next arrival
            if queue.is_empty() && next < requests.len() && requests[next].arrival_s > clock {
                clock = requests[next].arrival_s;
            }
            while next < requests.len() && requests[next].arrival_s <= clock {
                queue.push_back(&requests[next]);
                next += 1;
            }
            // form a batch under the token budget (FIFO)
            let mut batch: Vec<&Request> = Vec::new();
            let mut batch_tokens = 0usize;
            while let Some(&req) = queue.front() {
                if batch.is_empty() || batch_tokens + req.tokens <= budget {
                    batch_tokens += req.tokens;
                    batch.push(req);
                    queue.pop_front();
                } else {
                    break;
                }
            }
            if batch.is_empty() {
                continue;
            }
            // price a full-model step over the exact batch total
            let lms = self.profile.generate_loads_total(
                &self.engine.model,
                devices,
                batch_tokens,
                rng,
            );
            let report = self
                .engine
                .run_model(&lms, &self.planner)
                .expect("profile-generated loads are always consistent");
            clock += report.latency_s;
            batches += 1;
            total_tokens += batch_tokens as u64;
            priced_tokens += report.tokens;
            if report.oom {
                oom_batches += 1;
            }
            for req in batch {
                latencies.push(clock - req.arrival_s);
            }
        }

        ServeReport {
            planner: self.planner.label(),
            completed: latencies.len(),
            makespan_s: clock,
            request_latency: Summary::of(&latencies),
            batches,
            total_tokens,
            priced_tokens,
            oom_batches,
            layers: self.profile.num_layers(),
        }
    }
}

/// A generation request for continuous batching: a prefill of
/// `prompt_tokens`, then `decode_steps` single-token steps.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: usize,
    pub arrival_s: f64,
    pub prompt_tokens: usize,
    pub decode_steps: usize,
}

/// Result of a continuous-batching run.
#[derive(Clone, Debug)]
pub struct ContinuousReport {
    pub planner: String,
    pub completed: usize,
    pub makespan_s: f64,
    /// Time to first token (prefill completion) per request.
    pub ttft: Summary,
    /// Per-decode-step latency across all requests.
    pub tpot: Summary,
    pub steps: usize,
    /// Steps where every MoE layer's lambda guard reverted to EP.
    pub fallback_steps: usize,
}

/// vLLM-style continuous batching: every engine step batches the newly
/// admitted requests' prefills together with one token from every active
/// decode, priced across **all** MoE layers of the model per step.
/// Decode-heavy steps are small and latency-bound — the regime where
/// LLEP's lambda guard and the fused-collective option matter.
pub struct ContinuousBatchSim {
    pub engine: Engine,
    pub planner: PlannerKind,
    pub profile: DepthProfile,
    pub max_prefill_tokens: usize,
}

impl ContinuousBatchSim {
    /// Every MoE layer of the engine's model routes with `scenario`.
    pub fn new(
        engine: Engine,
        planner: PlannerKind,
        scenario: Scenario,
        max_prefill_tokens: usize,
    ) -> ContinuousBatchSim {
        let layers = engine.model.num_moe_layers().max(1);
        ContinuousBatchSim {
            profile: DepthProfile::uniform(scenario, layers),
            engine,
            planner,
            max_prefill_tokens,
        }
    }

    /// Replace the depth profile (e.g. [`DepthProfile::varying`]).
    pub fn with_profile(mut self, profile: DepthProfile) -> ContinuousBatchSim {
        self.profile = profile;
        self
    }

    /// Generate a request stream.
    pub fn requests(
        n: usize,
        mean_interarrival_s: f64,
        prompt: (usize, usize),
        decode: (usize, usize),
        rng: &mut Rng,
    ) -> Vec<GenRequest> {
        let mut t = 0.0;
        (0..n)
            .map(|id| {
                t += -mean_interarrival_s * (1.0 - rng.f64()).ln();
                GenRequest {
                    id,
                    arrival_s: t,
                    prompt_tokens: rng.range(prompt.0, prompt.1),
                    decode_steps: rng.range(decode.0, decode.1),
                }
            })
            .collect()
    }

    /// Run to completion.
    pub fn run(&self, requests: &[GenRequest], rng: &mut Rng) -> ContinuousReport {
        let devices = self.engine.system.devices;
        let mut clock = 0.0f64;
        let mut next = 0usize;
        let mut waiting: VecDeque<&GenRequest> = VecDeque::new();
        // (remaining decode steps, arrival)
        let mut active: Vec<(usize, f64)> = Vec::new();
        let mut ttft = Vec::new();
        let mut tpot = Vec::new();
        let mut completed = 0usize;
        let mut steps = 0usize;
        let mut fallback_steps = 0usize;

        while completed < requests.len() {
            if waiting.is_empty() && active.is_empty() {
                // idle: jump to next arrival
                clock = clock.max(requests[next].arrival_s);
            }
            while next < requests.len() && requests[next].arrival_s <= clock {
                waiting.push_back(&requests[next]);
                next += 1;
            }
            // admit prefills under the budget
            let mut prefill_tokens = 0usize;
            let mut admitted: Vec<&GenRequest> = Vec::new();
            while let Some(&req) = waiting.front() {
                if admitted.is_empty()
                    || prefill_tokens + req.prompt_tokens <= self.max_prefill_tokens
                {
                    prefill_tokens += req.prompt_tokens;
                    admitted.push(req);
                    waiting.pop_front();
                } else {
                    break;
                }
            }
            let decode_tokens = active.len();
            let step_tokens = prefill_tokens + decode_tokens;
            if step_tokens == 0 {
                continue;
            }
            // full-model step over the exact token total
            let lms = self.profile.generate_loads_total(
                &self.engine.model,
                devices,
                step_tokens,
                rng,
            );
            let report = self
                .engine
                .run_model(&lms, &self.planner)
                .expect("profile-generated loads are always consistent");
            clock += report.latency_s;
            steps += 1;
            fallback_steps += (report.fallback_layers == report.num_layers()) as usize;

            // prefill completions = first token
            for req in admitted {
                ttft.push(clock - req.arrival_s);
                if req.decode_steps > 0 {
                    active.push((req.decode_steps, req.arrival_s));
                } else {
                    completed += 1;
                }
            }
            // one decode step for everyone active
            if decode_tokens > 0 {
                tpot.push(report.latency_s);
            }
            active.retain_mut(|(left, _)| {
                *left -= 1;
                if *left == 0 {
                    completed += 1;
                    false
                } else {
                    true
                }
            });
        }

        ContinuousReport {
            planner: self.planner.label(),
            completed,
            makespan_s: clock,
            ttft: Summary::of(&ttft),
            tpot: Summary::of(&tpot),
            steps,
            fallback_steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};

    fn sim(planner: PlannerKind) -> ServeSim {
        let engine = Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        );
        ServeSim::new(engine, planner, Scenario::concentrated(0.9, 1), 8192)
    }

    #[test]
    fn all_requests_complete() {
        let mut rng = Rng::new(1);
        let reqs = ServeSim::poisson_requests(50, 0.001, 64, 512, &mut rng);
        let report = sim(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(2));
        assert_eq!(report.completed, 50);
        assert!(report.makespan_s > 0.0);
        assert!(report.batches > 0);
        assert!(report.request_latency.mean > 0.0);
    }

    #[test]
    fn arrivals_are_monotone() {
        let mut rng = Rng::new(3);
        let reqs = ServeSim::poisson_requests(20, 0.01, 10, 20, &mut rng);
        for w in reqs.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
    }

    #[test]
    fn batch_token_accounting_is_exact() {
        // 1001-token requests over 8 devices never divide evenly; the
        // priced work must still equal the admitted work exactly.
        let reqs: Vec<Request> =
            (0..7).map(|id| Request { id, arrival_s: 0.0, tokens: 1001 }).collect();
        let report = sim(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(9));
        assert_eq!(report.completed, 7);
        assert_eq!(report.total_tokens, 7 * 1001);
        assert_eq!(report.priced_tokens, report.total_tokens);
    }

    #[test]
    fn serve_prices_every_moe_layer() {
        // A 4-layer model's steps must cost ~4x a 1-layer model's on the
        // same workload (planning overlap makes it slightly cheaper).
        let reqs: Vec<Request> =
            (0..6).map(|id| Request { id, arrival_s: 0.0, tokens: 4096 }).collect();
        let mut model = ModelConfig::preset(ModelPreset::Fig1Layer);
        let one = sim(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(4));
        model.num_layers = 4;
        let engine4 = Engine::modeled(model, SystemConfig::preset(SystemPreset::H200x8));
        let sim4 =
            ServeSim::new(engine4, PlannerKind::StandardEp, Scenario::concentrated(0.9, 1), 8192);
        let four = sim4.run(&reqs, &mut Rng::new(4));
        assert_eq!(one.layers, 1);
        assert_eq!(four.layers, 4);
        assert!(
            four.makespan_s > one.makespan_s * 3.0,
            "4-layer steps must price all layers: {} vs {}",
            four.makespan_s,
            one.makespan_s
        );
    }

    #[test]
    fn llep_serves_faster_under_imbalance() {
        // arrival rate >> service rate so makespan is service-bound
        let mut rng = Rng::new(4);
        let reqs = ServeSim::poisson_requests(40, 0.00005, 1024, 4096, &mut rng);
        let ep = sim(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(5));
        let ll = sim(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(5));
        assert!(
            ll.makespan_s < ep.makespan_s,
            "LLEP {} vs EP {}",
            ll.makespan_s,
            ep.makespan_s
        );
        assert!(ll.request_latency.p50 <= ep.request_latency.p50 * 1.05);
        assert!(ll.throughput_tps() > ep.throughput_tps());
    }

    fn continuous(planner: PlannerKind) -> ContinuousBatchSim {
        let engine = Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        );
        ContinuousBatchSim::new(engine, planner, Scenario::concentrated(0.8, 4), 16_384)
    }

    #[test]
    fn continuous_batching_completes_all() {
        let mut rng = Rng::new(10);
        let reqs = ContinuousBatchSim::requests(24, 0.0005, (128, 1024), (4, 16), &mut rng);
        let r = continuous(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(11));
        assert_eq!(r.completed, 24);
        assert!(r.ttft.mean > 0.0);
        assert!(r.tpot.n > 0, "decode steps happened");
        assert!(r.steps >= 4, "multiple engine steps: {}", r.steps);
    }

    #[test]
    fn continuous_llep_improves_prefill_heavy_phase() {
        let mut rng = Rng::new(12);
        // prefill-heavy burst: large prompts, few decodes
        let reqs = ContinuousBatchSim::requests(24, 0.00002, (2048, 8192), (1, 3), &mut rng);
        let ep = continuous(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(13));
        let ll = continuous(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(13));
        assert!(
            ll.makespan_s < ep.makespan_s,
            "LLEP {} vs EP {}",
            ll.makespan_s,
            ep.makespan_s
        );
        assert!(ll.ttft.p50 <= ep.ttft.p50 * 1.05);
    }

    #[test]
    fn continuous_decode_steps_fall_back_when_small() {
        // decode-only regime: tiny per-step batches are latency-bound and
        // often balanced enough that the lambda guard reverts to EP —
        // LLEP must not be slower there.
        let mut rng = Rng::new(14);
        let reqs = ContinuousBatchSim::requests(8, 0.00002, (64, 128), (32, 64), &mut rng);
        let ll = continuous(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(15));
        assert_eq!(ll.completed, 8);
        assert!(ll.tpot.n >= 32, "long decode phase");
    }

    #[test]
    fn queue_drains_even_with_bursts() {
        // all arrive at t=0 (burst)
        let reqs: Vec<Request> =
            (0..30).map(|id| Request { id, arrival_s: 0.0, tokens: 700 }).collect();
        let report = sim(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(6));
        assert_eq!(report.completed, 30);
        // batches bounded by budget: 8192*8 tokens per batch >= 9 requests
        assert!(report.batches >= 1);
    }
}
