//! Serving-style simulation: a request queue feeding batched MoE steps.
//!
//! Requests carry token counts and arrive on a (virtual) timeline; the
//! coordinator batches whatever is queued (up to a token budget), prices
//! one **full-model** engine step per batch (all MoE layers of the model,
//! each with its own per-layer routing — see
//! [`crate::exec::Engine::run_model`]), and advances the virtual clock by
//! the step latency. Per-request latency = completion − arrival. This is
//! the vLLM-router-shaped workload the paper's "higher-throughput
//! inference" claim is about.
//!
//! Both simulators run any trait [`Planner`] — in particular the
//! [`CachedPlanner`](crate::planner::CachedPlanner) decorator, whose
//! cross-step plan reuse takes `T_plan` off the decode critical path; the
//! per-run hit/miss/forced counters and per-step planning-time summary
//! are surfaced in the reports.
//!
//! Token accounting is exact: each batch's total token count is carried
//! into the priced load matrices via
//! [`Scenario::generate_loads_total`](crate::routing::Scenario::generate_loads_total)
//! (largest-remainder split across devices), and both reports carry a
//! [`TokenLedger`] whose admitted and priced sides must agree (asserted
//! by tests).

use crate::chaos::{FaultPlan, PoolState};
use crate::exec::{Engine, ModelStepReport};
use crate::planner::{CacheStats, Planner, PlannerKind};
use crate::routing::{DepthProfile, Scenario};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use std::collections::VecDeque;

/// Admitted-vs-priced token accounting shared by both serving reports:
/// `admitted` tokens entered from the request stream, `priced` tokens
/// were charged by the engine. The contract is equality.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TokenLedger {
    pub admitted: u64,
    pub priced: u64,
}

impl TokenLedger {
    pub fn add(&mut self, admitted: u64, priced: u64) {
        self.admitted += admitted;
        self.priced += priced;
    }

    /// True when every admitted token was priced exactly once.
    pub fn is_exact(&self) -> bool {
        self.admitted == self.priced
    }
}

/// Chaos accounting for one serving run (all zero without a fault plan).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ChaosStats {
    /// Engine steps priced under a degraded pool view.
    pub fault_steps: usize,
    /// Devices observed transitioning alive -> dead during the run.
    pub failures: usize,
    /// Devices observed transitioning dead -> alive (elastic scale-up).
    pub recoveries: usize,
    /// Aborted in-flight steps whose batch was requeued after a failure.
    pub requeues: usize,
    /// Tokens those aborts requeued. The [`TokenLedger`] still counts
    /// every admitted token exactly once — only the successful retry
    /// prices them.
    pub requeued_tokens: u64,
    /// Virtual time burned by aborted attempts.
    pub wasted_s: f64,
    /// Max aborted attempts observed before a successful (elastically
    /// replanned) step completed — measured per failure event, so a
    /// regression that makes recovery loop shows up here. The
    /// bounded-recovery contract (`<= 1` under the current single-abort
    /// model) is asserted by `rust/tests/chaos.rs`.
    pub max_recovery_steps: usize,
}

/// Per-step chaos bookkeeping shared by both simulators: resolves the
/// fault plan into pool views, prices + discards the in-flight attempt a
/// fresh failure aborts, and hands the step an engine view of the
/// degraded pool.
struct ChaosDriver<'a> {
    plan: Option<&'a FaultPlan>,
    base: PoolState,
    stats: ChaosStats,
    /// Aborted attempts since the last successful step (resolved into
    /// `stats.max_recovery_steps` when a step completes).
    pending_aborts: usize,
    /// Cached engine view for the current degraded pool. Permanent
    /// degradations (a straggler, a failure, preset speeds under a fault
    /// plan) keep the same pool for many consecutive steps — rebuilding
    /// the engine (clone + topology re-derivation) per step would be
    /// pure waste.
    view: Option<(PoolState, Engine)>,
}

impl<'a> ChaosDriver<'a> {
    fn new(engine: &Engine, plan: Option<&'a FaultPlan>) -> Result<ChaosDriver<'a>, String> {
        if let Some(p) = plan {
            p.validate(engine.system.devices)?;
        }
        Ok(ChaosDriver {
            plan,
            base: engine.pool.clone(),
            stats: ChaosStats::default(),
            pending_aborts: 0,
            view: None,
        })
    }

    /// Engine to price the current step with (set by
    /// [`begin_step`](Self::begin_step)): the cached degraded view, or
    /// `base` while the pool is healthy.
    fn engine<'b>(&'b self, base: &'b Engine) -> &'b Engine {
        self.view.as_ref().map(|(_, e)| e).unwrap_or(base)
    }

    /// Advance to engine step `step` (called once per step, before the
    /// step is priced). When a device died since the previous step, the
    /// attempt that was in flight is priced against the *old* pool,
    /// charged to the clock as waste, and the batch requeues — the
    /// caller then prices the elastically replanned step against
    /// [`engine`](Self::engine).
    #[allow(clippy::too_many_arguments)]
    fn begin_step(
        &mut self,
        step: usize,
        engine: &Engine,
        profile: &DepthProfile,
        planner: &dyn Planner,
        batch_tokens: usize,
        rng: &mut Rng,
        clock: &mut f64,
    ) -> Result<(), String> {
        let Some(plan) = self.plan else { return Ok(()) };
        let pool = plan.state_at(step, &self.base);
        if pool.alive_count() == 0 {
            return Err(format!(
                "chaos: no alive devices left at step {step} ({}) — the pool cannot serve",
                pool.label()
            ));
        }
        let prev = if step == 0 { self.base.clone() } else { plan.state_at(step - 1, &self.base) };
        let newly_dead = (0..pool.len())
            .filter(|&d| prev.devices[d].alive && !pool.devices[d].alive)
            .count();
        self.stats.recoveries += (0..pool.len())
            .filter(|&d| !prev.devices[d].alive && pool.devices[d].alive)
            .count();
        if newly_dead > 0 {
            self.stats.failures += newly_dead;
            // The step in flight at the failure was planned against the
            // previous pool; its work is lost and the batch requeues. A
            // failure already active at step 0 has no in-flight work to
            // abort — serving simply starts on the degraded pool.
            if step > 0 {
                let holder: Engine;
                // The cached view still describes the previous step here.
                let attempt_engine: &Engine = match &self.view {
                    Some((p, e)) if *p == prev => e,
                    _ if prev.is_degraded() => {
                        holder = engine.for_pool(prev);
                        &holder
                    }
                    _ => engine,
                };
                let attempt = price_step(attempt_engine, profile, planner, batch_tokens, rng);
                *clock += attempt.latency_s;
                self.stats.wasted_s += attempt.latency_s;
                self.stats.requeues += 1;
                self.stats.requeued_tokens += batch_tokens as u64;
                self.pending_aborts += 1;
                recycle_report_plans(attempt);
            }
        }
        if pool.is_degraded() {
            self.stats.fault_steps += 1;
            let reusable = matches!(&self.view, Some((p, _)) if *p == pool);
            if !reusable {
                let view_engine = engine.for_pool(pool.clone());
                self.view = Some((pool, view_engine));
            }
        } else {
            self.view = None;
        }
        Ok(())
    }

    /// A stranded step is fatal: the planner cannot adapt to this pool.
    /// A successful step resolves any pending aborts into the measured
    /// recovery bound.
    fn check_step(
        &mut self,
        step: usize,
        planner_label: &str,
        report: &ModelStepReport,
    ) -> Result<(), String> {
        if report.stranded {
            return Err(format!(
                "chaos: planner {planner_label} left expert work on a dead device at step \
                 {step}; static placements cannot adapt — use a pool-aware planner (llep, lpt)"
            ));
        }
        self.stats.max_recovery_steps = self.stats.max_recovery_steps.max(self.pending_aborts);
        self.pending_aborts = 0;
        Ok(())
    }
}

/// Shared constructor boilerplate: every MoE layer of the engine's model
/// routes with `scenario` (single-layer models still get one layer).
fn uniform_profile(engine: &Engine, scenario: Scenario) -> DepthProfile {
    DepthProfile::uniform(scenario, engine.model.num_moe_layers().max(1))
}

/// Hand a consumed step report's routing plans back to this thread's
/// planning arena (see `planner::scratch`): the serving loops price one
/// report per step and drop it, so recycling here is what keeps the
/// decode regime's plan→price cycle allocation-free in steady state.
fn recycle_report_plans(report: ModelStepReport) {
    for layer in report.layers {
        crate::planner::recycle_plan(layer.plan);
    }
}

/// Shared step pricer for both simulators: one full-model engine step
/// over exactly `step_tokens` tokens drawn from `profile`.
fn price_step(
    engine: &Engine,
    profile: &DepthProfile,
    planner: &dyn Planner,
    step_tokens: usize,
    rng: &mut Rng,
) -> ModelStepReport {
    let lms =
        profile.generate_loads_total(&engine.model, engine.system.devices, step_tokens, rng);
    engine
        .run_model(&lms, planner)
        .expect("profile-generated loads are always consistent")
}

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: usize,
    pub arrival_s: f64,
    pub tokens: usize,
}

/// Result of a serving simulation.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub planner: String,
    pub completed: usize,
    pub makespan_s: f64,
    pub request_latency: Summary,
    pub batches: usize,
    /// Admitted-vs-priced token accounting (equal by contract).
    pub tokens: TokenLedger,
    pub oom_batches: usize,
    /// Max per-device peak bytes over all steps (Eq.-4 accounting) — the
    /// memory side of the autotuner's latency/memory Pareto objectives.
    pub peak_bytes: u64,
    /// MoE layers priced per step.
    pub layers: usize,
    /// Plan-cache counters summed over all steps and layers.
    pub plan_cache: CacheStats,
    /// Per-step planning wall time (sum across the step's layers).
    pub plan_time: Summary,
    /// Fault-injection accounting (all zero without a fault plan).
    pub chaos: ChaosStats,
}

impl ServeReport {
    pub fn throughput_tps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.tokens.admitted as f64 / self.makespan_s
        } else {
            0.0
        }
    }
}

/// Serving simulator over a fixed request list.
pub struct ServeSim {
    pub engine: Engine,
    pub planner: Box<dyn Planner>,
    /// Per-layer routing scenarios for the full-model step.
    pub profile: DepthProfile,
    /// Max tokens per device per batch.
    pub max_tokens_per_device: usize,
    /// Per-step fault schedule (None = always-healthy pool).
    pub faults: Option<FaultPlan>,
}

impl ServeSim {
    /// Backward-compatible constructor from the [`PlannerKind`] enum.
    pub fn new(
        engine: Engine,
        planner: PlannerKind,
        scenario: Scenario,
        max_tokens_per_device: usize,
    ) -> ServeSim {
        ServeSim::with_planner(engine, planner.boxed(), scenario, max_tokens_per_device)
    }

    /// Constructor from any trait planner (spec-parsed, cached, custom).
    pub fn with_planner(
        engine: Engine,
        planner: Box<dyn Planner>,
        scenario: Scenario,
        max_tokens_per_device: usize,
    ) -> ServeSim {
        ServeSim {
            profile: uniform_profile(&engine, scenario),
            engine,
            planner,
            max_tokens_per_device,
            faults: None,
        }
    }

    /// Replace the depth profile (e.g. [`DepthProfile::varying`]).
    pub fn with_profile(mut self, profile: DepthProfile) -> ServeSim {
        self.profile = profile;
        self
    }

    /// Inject a fault schedule: each engine step `k` runs on
    /// `faults.state_at(k, ...)`. Use [`try_run`](Self::try_run) to
    /// observe unrecoverable pools as errors instead of panics.
    pub fn with_faults(mut self, faults: FaultPlan) -> ServeSim {
        self.faults = Some(faults);
        self
    }

    /// Generate a Poisson-ish arrival stream.
    pub fn poisson_requests(
        n: usize,
        mean_interarrival_s: f64,
        tokens_lo: usize,
        tokens_hi: usize,
        rng: &mut Rng,
    ) -> Vec<Request> {
        let mut t = 0.0;
        (0..n)
            .map(|id| {
                t += -mean_interarrival_s * (1.0 - rng.f64()).ln();
                Request { id, arrival_s: t, tokens: rng.range(tokens_lo, tokens_hi) }
            })
            .collect()
    }

    /// Run the simulation; requests must be sorted by arrival. Panics if
    /// the fault plan makes the pool unrecoverable — use
    /// [`try_run`](Self::try_run) when that is an expected outcome.
    pub fn run(&self, requests: &[Request], rng: &mut Rng) -> ServeReport {
        self.try_run(requests, rng).expect("serve simulation failed")
    }

    /// Run the simulation, surfacing chaos-unrecoverable pools (every
    /// device dead, or a planner that cannot adapt to a failure) as
    /// errors.
    pub fn try_run(&self, requests: &[Request], rng: &mut Rng) -> Result<ServeReport, String> {
        let devices = self.engine.system.devices;
        let budget = self.max_tokens_per_device * devices;
        let mut clock = 0.0f64;
        let mut next = 0usize;
        let mut latencies = Vec::with_capacity(requests.len());
        let mut batches = 0usize;
        let mut tokens = TokenLedger::default();
        let mut oom_batches = 0usize;
        let mut peak_bytes = 0u64;
        let mut plan_cache = CacheStats::default();
        let mut plan_times: Vec<f64> = Vec::new();
        let mut queue: VecDeque<&Request> = VecDeque::new();
        let mut chaos = ChaosDriver::new(&self.engine, self.faults.as_ref())?;

        while next < requests.len() || !queue.is_empty() {
            // admit arrivals up to the clock; if idle, jump to next arrival
            if queue.is_empty() && next < requests.len() && requests[next].arrival_s > clock {
                clock = requests[next].arrival_s;
            }
            while next < requests.len() && requests[next].arrival_s <= clock {
                queue.push_back(&requests[next]);
                next += 1;
            }
            // form a batch under the token budget (FIFO)
            let mut batch: Vec<&Request> = Vec::new();
            let mut batch_tokens = 0usize;
            while let Some(&req) = queue.front() {
                if batch.is_empty() || batch_tokens + req.tokens <= budget {
                    batch_tokens += req.tokens;
                    batch.push(req);
                    queue.pop_front();
                } else {
                    break;
                }
            }
            if batch.is_empty() {
                continue;
            }
            // chaos: resolve this step's pool view; a fresh failure
            // aborts + requeues the in-flight attempt first
            chaos.begin_step(
                batches,
                &self.engine,
                &self.profile,
                &*self.planner,
                batch_tokens,
                rng,
                &mut clock,
            )?;
            // price a full-model step over the exact batch total
            let report = price_step(
                chaos.engine(&self.engine),
                &self.profile,
                &*self.planner,
                batch_tokens,
                rng,
            );
            chaos.check_step(batches, &report.planner, &report)?;
            clock += report.latency_s;
            batches += 1;
            tokens.add(batch_tokens as u64, report.tokens);
            plan_cache.absorb(&report.cache);
            plan_times.push(report.layers.iter().map(|l| l.report.phases.plan_s).sum::<f64>());
            peak_bytes = peak_bytes.max(report.max_peak_bytes());
            if report.oom {
                oom_batches += 1;
            }
            recycle_report_plans(report);
            for req in batch {
                latencies.push(clock - req.arrival_s);
            }
        }

        Ok(ServeReport {
            planner: self.planner.label(),
            completed: latencies.len(),
            makespan_s: clock,
            request_latency: Summary::of(&latencies),
            batches,
            tokens,
            oom_batches,
            peak_bytes,
            layers: self.profile.num_layers(),
            plan_cache,
            plan_time: Summary::of(&plan_times),
            chaos: chaos.stats,
        })
    }
}

/// A generation request for continuous batching: a prefill of
/// `prompt_tokens`, then `decode_steps` single-token steps.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: usize,
    pub arrival_s: f64,
    pub prompt_tokens: usize,
    pub decode_steps: usize,
}

/// Result of a continuous-batching run.
#[derive(Clone, Debug)]
pub struct ContinuousReport {
    pub planner: String,
    pub completed: usize,
    pub makespan_s: f64,
    /// Time to first token (prefill completion) per request.
    pub ttft: Summary,
    /// Per-decode-step latency across all requests: every step
    /// contributes one sample **per active decoding request** (weighting
    /// by `decode_tokens`), so the mean is the per-token latency a
    /// request actually experienced. A request's first token comes out of
    /// its prefill step (counted by `ttft`, not here), so `tpot.n` equals
    /// `sum(max(decode_steps - 1, 0))` over completed requests.
    pub tpot: Summary,
    pub steps: usize,
    /// Steps where every MoE layer's lambda guard reverted to EP.
    pub fallback_steps: usize,
    /// Steps where some device exceeded its memory capacity.
    pub oom_steps: usize,
    /// Max per-device peak bytes over all steps (Eq.-4 accounting).
    pub peak_bytes: u64,
    /// Admitted-vs-priced token accounting (equal by contract).
    pub tokens: TokenLedger,
    /// Plan-cache counters summed over all steps and layers.
    pub plan_cache: CacheStats,
    /// Per-step planning wall time (sum across the step's layers).
    pub plan_time: Summary,
    /// Fault-injection accounting (all zero without a fault plan).
    pub chaos: ChaosStats,
}

/// vLLM-style continuous batching: every engine step batches the newly
/// admitted requests' prefills together with one token from every active
/// decode, priced across **all** MoE layers of the model per step.
/// Decode-heavy steps are small and latency-bound — the regime where
/// LLEP's lambda guard, the fused-collective option, and cross-step plan
/// reuse matter.
pub struct ContinuousBatchSim {
    pub engine: Engine,
    pub planner: Box<dyn Planner>,
    pub profile: DepthProfile,
    pub max_prefill_tokens: usize,
    /// Per-step fault schedule (None = always-healthy pool).
    pub faults: Option<FaultPlan>,
}

impl ContinuousBatchSim {
    /// Backward-compatible constructor from the [`PlannerKind`] enum.
    pub fn new(
        engine: Engine,
        planner: PlannerKind,
        scenario: Scenario,
        max_prefill_tokens: usize,
    ) -> ContinuousBatchSim {
        ContinuousBatchSim::with_planner(engine, planner.boxed(), scenario, max_prefill_tokens)
    }

    /// Constructor from any trait planner (spec-parsed, cached, custom).
    pub fn with_planner(
        engine: Engine,
        planner: Box<dyn Planner>,
        scenario: Scenario,
        max_prefill_tokens: usize,
    ) -> ContinuousBatchSim {
        ContinuousBatchSim {
            profile: uniform_profile(&engine, scenario),
            engine,
            planner,
            max_prefill_tokens,
            faults: None,
        }
    }

    /// Replace the depth profile (e.g. [`DepthProfile::varying`]).
    pub fn with_profile(mut self, profile: DepthProfile) -> ContinuousBatchSim {
        self.profile = profile;
        self
    }

    /// Inject a fault schedule: each engine step `k` runs on
    /// `faults.state_at(k, ...)`. Use [`try_run`](Self::try_run) to
    /// observe unrecoverable pools as errors instead of panics.
    pub fn with_faults(mut self, faults: FaultPlan) -> ContinuousBatchSim {
        self.faults = Some(faults);
        self
    }

    /// Generate a request stream.
    pub fn requests(
        n: usize,
        mean_interarrival_s: f64,
        prompt: (usize, usize),
        decode: (usize, usize),
        rng: &mut Rng,
    ) -> Vec<GenRequest> {
        let mut t = 0.0;
        (0..n)
            .map(|id| {
                t += -mean_interarrival_s * (1.0 - rng.f64()).ln();
                GenRequest {
                    id,
                    arrival_s: t,
                    prompt_tokens: rng.range(prompt.0, prompt.1),
                    decode_steps: rng.range(decode.0, decode.1),
                }
            })
            .collect()
    }

    /// Run to completion. Panics if the fault plan makes the pool
    /// unrecoverable — use [`try_run`](Self::try_run) when that is an
    /// expected outcome.
    pub fn run(&self, requests: &[GenRequest], rng: &mut Rng) -> ContinuousReport {
        self.try_run(requests, rng).expect("continuous-batching simulation failed")
    }

    /// Run to completion, surfacing chaos-unrecoverable pools (every
    /// device dead, or a planner that cannot adapt to a failure) as
    /// errors.
    pub fn try_run(
        &self,
        requests: &[GenRequest],
        rng: &mut Rng,
    ) -> Result<ContinuousReport, String> {
        let mut clock = 0.0f64;
        let mut next = 0usize;
        let mut waiting: VecDeque<&GenRequest> = VecDeque::new();
        // (remaining decode steps, arrival)
        let mut active: Vec<(usize, f64)> = Vec::new();
        let mut ttft = Vec::new();
        let mut tpot = Vec::new();
        let mut completed = 0usize;
        let mut steps = 0usize;
        let mut fallback_steps = 0usize;
        let mut oom_steps = 0usize;
        let mut peak_bytes = 0u64;
        let mut tokens = TokenLedger::default();
        let mut plan_cache = CacheStats::default();
        let mut plan_times: Vec<f64> = Vec::new();
        let mut chaos = ChaosDriver::new(&self.engine, self.faults.as_ref())?;

        while completed < requests.len() {
            if waiting.is_empty() && active.is_empty() {
                // idle: jump to next arrival
                clock = clock.max(requests[next].arrival_s);
            }
            while next < requests.len() && requests[next].arrival_s <= clock {
                waiting.push_back(&requests[next]);
                next += 1;
            }
            // admit prefills under the budget
            let mut prefill_tokens = 0usize;
            let mut admitted: Vec<&GenRequest> = Vec::new();
            while let Some(&req) = waiting.front() {
                if admitted.is_empty()
                    || prefill_tokens + req.prompt_tokens <= self.max_prefill_tokens
                {
                    prefill_tokens += req.prompt_tokens;
                    admitted.push(req);
                    waiting.pop_front();
                } else {
                    break;
                }
            }
            let decode_tokens = active.len();
            let step_tokens = prefill_tokens + decode_tokens;
            if step_tokens == 0 {
                continue;
            }
            // chaos: resolve this step's pool view; a fresh failure
            // aborts + requeues the in-flight attempt first
            chaos.begin_step(
                steps,
                &self.engine,
                &self.profile,
                &*self.planner,
                step_tokens,
                rng,
                &mut clock,
            )?;
            // full-model step over the exact token total
            let report = price_step(
                chaos.engine(&self.engine),
                &self.profile,
                &*self.planner,
                step_tokens,
                rng,
            );
            chaos.check_step(steps, &report.planner, &report)?;
            clock += report.latency_s;
            steps += 1;
            fallback_steps += (report.fallback_layers == report.num_layers()) as usize;
            oom_steps += report.oom as usize;
            peak_bytes = peak_bytes.max(report.max_peak_bytes());
            tokens.add(step_tokens as u64, report.tokens);
            plan_cache.absorb(&report.cache);
            plan_times.push(report.layers.iter().map(|l| l.report.phases.plan_s).sum::<f64>());

            // prefill completions = first token
            for req in admitted {
                ttft.push(clock - req.arrival_s);
                if req.decode_steps > 0 {
                    active.push((req.decode_steps, req.arrival_s));
                } else {
                    completed += 1;
                }
            }
            // one decode token for every active request: one tpot sample
            // per (request, step) pair, so multi-request steps weigh more
            for _ in 0..decode_tokens {
                tpot.push(report.latency_s);
            }
            recycle_report_plans(report);
            active.retain_mut(|(left, _)| {
                *left -= 1;
                if *left == 0 {
                    completed += 1;
                    false
                } else {
                    true
                }
            });
        }

        Ok(ContinuousReport {
            planner: self.planner.label(),
            completed,
            makespan_s: clock,
            ttft: Summary::of(&ttft),
            tpot: Summary::of(&tpot),
            steps,
            fallback_steps,
            oom_steps,
            peak_bytes,
            tokens,
            plan_cache,
            plan_time: Summary::of(&plan_times),
            chaos: chaos.stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
    use crate::planner::CachedPlanner;

    fn engine() -> Engine {
        Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        )
    }

    fn sim(planner: PlannerKind) -> ServeSim {
        ServeSim::new(engine(), planner, Scenario::concentrated(0.9, 1), 8192)
    }

    #[test]
    fn all_requests_complete() {
        let mut rng = Rng::new(1);
        let reqs = ServeSim::poisson_requests(50, 0.001, 64, 512, &mut rng);
        let report = sim(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(2));
        assert_eq!(report.completed, 50);
        assert!(report.makespan_s > 0.0);
        assert!(report.batches > 0);
        assert!(report.request_latency.mean > 0.0);
        assert!(report.peak_bytes > 0, "peak memory surfaces in the report");
        assert_eq!(report.oom_batches, 0);
        assert_eq!(report.plan_cache, CacheStats::default(), "uncached planner: zero counters");
    }

    #[test]
    fn arrivals_are_monotone() {
        let mut rng = Rng::new(3);
        let reqs = ServeSim::poisson_requests(20, 0.01, 10, 20, &mut rng);
        for w in reqs.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
    }

    #[test]
    fn batch_token_accounting_is_exact() {
        // 1001-token requests over 8 devices never divide evenly; the
        // priced work must still equal the admitted work exactly.
        let reqs: Vec<Request> =
            (0..7).map(|id| Request { id, arrival_s: 0.0, tokens: 1001 }).collect();
        let report = sim(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(9));
        assert_eq!(report.completed, 7);
        assert_eq!(report.tokens.admitted, 7 * 1001);
        assert!(report.tokens.is_exact(), "{:?}", report.tokens);
    }

    #[test]
    fn serve_prices_every_moe_layer() {
        // A 4-layer model's steps must cost ~4x a 1-layer model's on the
        // same workload (planning overlap makes it slightly cheaper).
        let reqs: Vec<Request> =
            (0..6).map(|id| Request { id, arrival_s: 0.0, tokens: 4096 }).collect();
        let mut model = ModelConfig::preset(ModelPreset::Fig1Layer);
        let one = sim(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(4));
        model.num_layers = 4;
        let engine4 = Engine::modeled(model, SystemConfig::preset(SystemPreset::H200x8));
        let sim4 =
            ServeSim::new(engine4, PlannerKind::StandardEp, Scenario::concentrated(0.9, 1), 8192);
        let four = sim4.run(&reqs, &mut Rng::new(4));
        assert_eq!(one.layers, 1);
        assert_eq!(four.layers, 4);
        assert!(
            four.makespan_s > one.makespan_s * 3.0,
            "4-layer steps must price all layers: {} vs {}",
            four.makespan_s,
            one.makespan_s
        );
    }

    #[test]
    fn llep_serves_faster_under_imbalance() {
        // arrival rate >> service rate so makespan is service-bound
        let mut rng = Rng::new(4);
        let reqs = ServeSim::poisson_requests(40, 0.00005, 1024, 4096, &mut rng);
        let ep = sim(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(5));
        let ll = sim(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(5));
        assert!(
            ll.makespan_s < ep.makespan_s,
            "LLEP {} vs EP {}",
            ll.makespan_s,
            ep.makespan_s
        );
        assert!(ll.request_latency.p50 <= ep.request_latency.p50 * 1.05);
        assert!(ll.throughput_tps() > ep.throughput_tps());
    }

    #[test]
    fn cached_planner_reuses_across_batches() {
        // Identical burst batches: after the first (miss), the cache
        // serves steady hits, accounting stays exact, and the counters
        // surface in the report.
        let reqs: Vec<Request> =
            (0..12).map(|id| Request { id, arrival_s: 0.0, tokens: 8192 * 8 }).collect();
        let cached = Box::new(
            CachedPlanner::new(PlannerKind::llep_default().boxed()).with_drift_threshold(0.1),
        );
        let s = ServeSim::with_planner(engine(), cached, Scenario::concentrated(0.9, 1), 8192);
        let report = s.run(&reqs, &mut Rng::new(7));
        assert_eq!(report.completed, 12);
        assert!(report.planner.starts_with("Cached["), "{}", report.planner);
        assert_eq!(report.plan_cache.lookups(), report.batches as u64);
        assert!(report.plan_cache.hits > 0, "steady load must reuse: {:?}", report.plan_cache);
        assert!(report.tokens.is_exact(), "{:?}", report.tokens);
    }

    fn continuous(planner: PlannerKind) -> ContinuousBatchSim {
        ContinuousBatchSim::new(engine(), planner, Scenario::concentrated(0.8, 4), 16_384)
    }

    #[test]
    fn continuous_batching_completes_all() {
        let mut rng = Rng::new(10);
        let reqs = ContinuousBatchSim::requests(24, 0.0005, (128, 1024), (4, 16), &mut rng);
        let r = continuous(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(11));
        assert_eq!(r.completed, 24);
        assert!(r.ttft.mean > 0.0);
        assert!(r.tpot.n > 0, "decode steps happened");
        assert!(r.steps >= 4, "multiple engine steps: {}", r.steps);
        assert!(r.peak_bytes > 0, "peak memory surfaces in the report");
        assert_eq!(r.oom_steps, 0);
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
    }

    #[test]
    fn tpot_weights_by_active_decodes() {
        // Regression for the old accounting, which pushed one sample per
        // step no matter how many requests were decoding: with per-active-
        // request samples, tpot.n must equal the total decode tokens.
        let reqs = vec![
            GenRequest { id: 0, arrival_s: 0.0, prompt_tokens: 64, decode_steps: 5 },
            GenRequest { id: 1, arrival_s: 0.0, prompt_tokens: 64, decode_steps: 2 },
            GenRequest { id: 2, arrival_s: 0.0, prompt_tokens: 64, decode_steps: 7 },
        ];
        let r = continuous(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(1));
        assert_eq!(r.completed, 3);
        // The first token of each request comes out of its prefill step
        // (ttft), so each request decodes for decode_steps - 1 further
        // steps: 4 + 1 + 6 samples, not 3 (one per step, the old bug).
        let expected: usize = reqs.iter().map(|q| q.decode_steps.saturating_sub(1)).sum();
        assert_eq!(r.tpot.n, expected, "one tpot sample per decode token per request");
        assert!(r.tpot.n > r.steps - 1, "weighted: more samples than decode steps");
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
    }

    #[test]
    fn continuous_llep_improves_prefill_heavy_phase() {
        let mut rng = Rng::new(12);
        // prefill-heavy burst: large prompts, few decodes
        let reqs = ContinuousBatchSim::requests(24, 0.00002, (2048, 8192), (1, 3), &mut rng);
        let ep = continuous(PlannerKind::StandardEp).run(&reqs, &mut Rng::new(13));
        let ll = continuous(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(13));
        assert!(
            ll.makespan_s < ep.makespan_s,
            "LLEP {} vs EP {}",
            ll.makespan_s,
            ep.makespan_s
        );
        assert!(ll.ttft.p50 <= ep.ttft.p50 * 1.05);
    }

    #[test]
    fn continuous_decode_steps_fall_back_when_small() {
        // decode-only regime: tiny per-step batches are latency-bound and
        // often balanced enough that the lambda guard reverts to EP —
        // LLEP must not be slower there.
        let mut rng = Rng::new(14);
        let reqs = ContinuousBatchSim::requests(8, 0.00002, (64, 128), (32, 64), &mut rng);
        let ll = continuous(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(15));
        assert_eq!(ll.completed, 8);
        assert!(ll.tpot.n >= 32, "long decode phase");
    }

    #[test]
    fn chaos_failure_requeues_without_losing_tokens() {
        // A permanent failure mid-run: the chaos-aware LLEP serve sim
        // aborts the in-flight step, replans around the dead device, and
        // still completes every request with exact token accounting.
        // 30k-token requests against a 64k batch budget: two per batch,
        // so 10 requests take 5 engine steps and the failure at step 3
        // lands mid-run.
        let reqs: Vec<Request> =
            (0..10).map(|id| Request { id, arrival_s: 0.0, tokens: 30_000 }).collect();
        let faults = FaultPlan::parse("fail:dev=2,at=3").unwrap();
        let s = sim(PlannerKind::llep_default()).with_faults(faults);
        let r = s.try_run(&reqs, &mut Rng::new(21)).unwrap();
        assert_eq!(r.completed, 10);
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
        assert_eq!(r.chaos.failures, 1);
        assert_eq!(r.chaos.requeues, 1);
        assert!(r.chaos.requeued_tokens > 0);
        assert!(r.chaos.wasted_s > 0.0);
        assert!(r.chaos.max_recovery_steps <= 1, "bounded recovery");
        assert!(r.chaos.fault_steps > 0);
    }

    #[test]
    fn chaos_static_ep_cannot_adapt_to_failure() {
        let reqs: Vec<Request> =
            (0..10).map(|id| Request { id, arrival_s: 0.0, tokens: 30_000 }).collect();
        let faults = FaultPlan::parse("fail:dev=0,at=2").unwrap();
        let s = sim(PlannerKind::StandardEp).with_faults(faults);
        let err = s.try_run(&reqs, &mut Rng::new(22)).unwrap_err();
        assert!(err.contains("dead device"), "{err}");
    }

    #[test]
    fn chaos_no_faults_report_is_zero() {
        let mut rng = Rng::new(23);
        let reqs = ServeSim::poisson_requests(8, 0.001, 64, 256, &mut rng);
        let r = sim(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(24));
        assert_eq!(r.chaos, ChaosStats::default());
    }

    #[test]
    fn continuous_chaos_stall_recovers_on_its_own() {
        // A transient stall kills a device for two steps; the chaos-aware
        // planner routes around it and the device rejoins.
        let reqs = vec![
            GenRequest { id: 0, arrival_s: 0.0, prompt_tokens: 512, decode_steps: 12 },
            GenRequest { id: 1, arrival_s: 0.0, prompt_tokens: 512, decode_steps: 12 },
        ];
        let faults = FaultPlan::parse("stall:dev=1,at=2,steps=2").unwrap();
        let c = continuous(PlannerKind::llep_default()).with_faults(faults);
        let r = c.try_run(&reqs, &mut Rng::new(25)).unwrap();
        assert_eq!(r.completed, 2);
        assert!(r.tokens.is_exact(), "{:?}", r.tokens);
        assert_eq!(r.chaos.failures, 1);
        assert_eq!(r.chaos.recoveries, 1, "stall ends on its own");
        assert_eq!(r.chaos.fault_steps, 2);
    }

    #[test]
    fn queue_drains_even_with_bursts() {
        // all arrive at t=0 (burst)
        let reqs: Vec<Request> =
            (0..30).map(|id| Request { id, arrival_s: 0.0, tokens: 700 }).collect();
        let report = sim(PlannerKind::llep_default()).run(&reqs, &mut Rng::new(6));
        assert_eq!(report.completed, 30);
        // batches bounded by budget: 8192*8 tokens per batch >= 9 requests
        assert!(report.batches >= 1);
    }
}
