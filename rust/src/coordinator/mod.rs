//! Coordinator: multi-step runners gluing planner + engine, and the
//! replica serving core behind every queue-driven simulator.
//!
//! This is the process-level "leader" role: it owns the per-batch loop
//! (collect loads → plan → execute → report) that a real deployment runs
//! once per iteration, for both inference and training. All planner
//! policies flow through the trait [`Planner`](crate::planner::Planner)
//! object (`&dyn Planner`), so spec-parsed, cached, and custom planners
//! are interchangeable everywhere.
//!
//! The serving side is layered: [`Replica`] (in `replica.rs`) is the
//! single event loop — admission under a token budget, chaos pool
//! resolution, full-model step pricing, exact token ledgering — and
//! [`ServeSim`]/[`ContinuousBatchSim`] (in `serve.rs`), the autotuner's
//! serve-mode trials, and the [`fleet`](crate::fleet) cluster simulator
//! are thin drivers feeding requests into it.

mod mitigation;
mod replica;
mod serve;

pub use mitigation::{split_loads, BatchSplitPolicy, SplitOutcome};
pub use replica::{
    attention_overhead_s, uniform_profile, ChaosStats, Replica, ReplicaRequest,
    ReplicaStepOutcome, ServiceEstimate, StepEvents, TokenLedger,
};
pub use serve::{
    run_continuous, ContinuousBatchSim, ContinuousReport, GenRequest, Request, ServeReport,
    ServeSim,
};

use crate::exec::{Engine, StepReport};
use crate::planner::{Planner, PlannerKind};
use crate::routing::{LoadMatrix, RoutingTrace};
use crate::util::stats::Summary;

/// Multi-batch runner for one planner policy.
pub struct Runner {
    pub engine: Engine,
    pub planner: Box<dyn Planner>,
    /// Stats-driven planners (EPLB) place replicas from the previous
    /// batch's statistics (the time delay the paper criticizes); pure
    /// per-step planners ignore this.
    prev_loads: Option<LoadMatrix>,
}

impl Runner {
    pub fn new(engine: Engine, planner: PlannerKind) -> Runner {
        Runner::with_planner(engine, planner.boxed())
    }

    /// Build from any trait planner (e.g. a `--planner` spec or a
    /// [`CachedPlanner`](crate::planner::CachedPlanner)).
    pub fn with_planner(engine: Engine, planner: Box<dyn Planner>) -> Runner {
        Runner { engine, planner, prev_loads: None }
    }

    /// Run one batch; stale-stats planners (EPLB) use the previous
    /// batch's loads as placement statistics (first batch: balanced
    /// assumption = uniform stats).
    pub fn step(&mut self, lm: &LoadMatrix) -> StepReport {
        let report = if self.planner.wants_stale_stats() {
            match &self.prev_loads {
                Some(prev) => self.engine.run_step_loads_with_stats(lm, prev, &*self.planner),
                None => {
                    // no stats yet: uniform prior
                    let uniform = LoadMatrix {
                        counts: vec![vec![1; lm.num_experts()]; lm.devices()],
                        top_k: 1,
                    };
                    self.engine.run_step_loads_with_stats(lm, &uniform, &*self.planner)
                }
            }
        } else {
            self.engine.run_step_loads(lm, &*self.planner)
        };
        self.prev_loads = Some(lm.clone());
        report
    }

    /// Replay a recorded trace; returns per-batch reports.
    pub fn run_trace(&mut self, trace: &RoutingTrace) -> Vec<StepReport> {
        trace.batches.iter().map(|b| self.step(&b.load)).collect()
    }
}

/// Aggregate of a multi-batch run.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub planner: String,
    pub total_latency_s: f64,
    pub latency: Summary,
    pub peak_bytes: u64,
    pub total_tokens: u64,
    pub oom_batches: usize,
    pub fallback_batches: usize,
}

impl RunSummary {
    pub fn of(reports: &[StepReport]) -> RunSummary {
        let latencies: Vec<f64> = reports.iter().map(|r| r.latency_s).collect();
        RunSummary {
            planner: reports.first().map(|r| r.planner.clone()).unwrap_or_default(),
            total_latency_s: latencies.iter().sum(),
            latency: Summary::of(&latencies),
            peak_bytes: reports.iter().map(|r| r.max_peak_bytes()).max().unwrap_or(0),
            total_tokens: reports.iter().map(|r| r.tokens).sum(),
            oom_batches: reports.iter().filter(|r| r.oom).count(),
            fallback_batches: reports.iter().filter(|r| r.fallback_ep).count(),
        }
    }

    pub fn throughput(&self) -> f64 {
        if self.total_latency_s > 0.0 {
            self.total_tokens as f64 / self.total_latency_s
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
    use crate::routing::Scenario;
    use crate::util::rng::Rng;

    fn engine() -> Engine {
        Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        )
    }

    fn trace(batches: usize, scenario: Scenario, seed: u64) -> RoutingTrace {
        let model = ModelConfig::preset(ModelPreset::Fig1Layer);
        let mut rng = Rng::new(seed);
        let mut t = RoutingTrace::new("test", model.num_experts, model.top_k);
        for _ in 0..batches {
            t.push(scenario.generate_loads(&model, 8, 8192, &mut rng)).unwrap();
        }
        t
    }

    #[test]
    fn trace_replay_counts_batches() {
        let mut runner = Runner::new(engine(), PlannerKind::llep_default());
        let t = trace(5, Scenario::concentrated(0.8, 4), 1);
        let reports = runner.run_trace(&t);
        assert_eq!(reports.len(), 5);
        let s = RunSummary::of(&reports);
        assert_eq!(s.total_tokens, 5 * 8 * 8192);
        assert!(s.throughput() > 0.0);
        assert_eq!(s.oom_batches, 0);
    }

    #[test]
    fn llep_beats_ep_on_imbalanced_trace() {
        let t = trace(8, Scenario::concentrated(0.9, 1), 2);
        let mut ep = Runner::new(engine(), PlannerKind::StandardEp);
        let mut ll = Runner::new(engine(), PlannerKind::llep_default());
        let s_ep = RunSummary::of(&ep.run_trace(&t));
        let s_ll = RunSummary::of(&ll.run_trace(&t));
        assert!(s_ll.total_latency_s < s_ep.total_latency_s / 1.5);
        assert!(s_ll.peak_bytes < s_ep.peak_bytes);
    }

    #[test]
    fn eplb_suffers_under_drift() {
        // Drifting hotspot: EPLB's stale placement trails reality, LLEP
        // adapts per batch.
        let t = trace(10, Scenario::drifting(7, 0.5, 0.8), 3);
        let mut eplb = Runner::new(engine(), PlannerKind::Eplb { replicas: 8 });
        let mut ll = Runner::new(engine(), PlannerKind::llep_default());
        let s_eplb = RunSummary::of(&eplb.run_trace(&t));
        let s_ll = RunSummary::of(&ll.run_trace(&t));
        assert!(
            s_ll.total_latency_s < s_eplb.total_latency_s,
            "LLEP {} vs EPLB {}",
            s_ll.total_latency_s,
            s_eplb.total_latency_s
        );
    }

    #[test]
    fn balanced_trace_mostly_falls_back() {
        let t = trace(4, Scenario::balanced(), 4);
        let mut ll = Runner::new(engine(), PlannerKind::llep_default());
        let s = RunSummary::of(&ll.run_trace(&t));
        assert_eq!(s.fallback_batches, 4);
    }
}
