//! Naive OOM mitigation baselines the paper dismisses (§1, §3.1):
//! "lowering the batch size reduces throughput and increases latency."
//!
//! [`BatchSplitPolicy`] models the common practice: run the step; if the
//! planned peak memory would exceed capacity, split the batch in half
//! and run the halves sequentially (recursively). Memory is bounded, but
//! every split doubles fixed costs (dispatch latency, kernel launches)
//! and leaves the *imbalance* untouched — so latency grows, exactly the
//! trade-off LLEP avoids.

use crate::exec::{Engine, StepReport};
use crate::planner::Planner;
use crate::routing::LoadMatrix;

/// Result of running one logical batch under the splitting policy.
#[derive(Clone, Debug)]
pub struct SplitOutcome {
    /// Sub-step reports, in execution order.
    pub steps: Vec<StepReport>,
    /// Number of splits performed (0 = ran whole).
    pub splits: usize,
}

impl SplitOutcome {
    pub fn total_latency_s(&self) -> f64 {
        self.steps.iter().map(|r| r.latency_s).sum()
    }
    pub fn peak_bytes(&self) -> u64 {
        self.steps.iter().map(|r| r.max_peak_bytes()).max().unwrap_or(0)
    }
    pub fn tokens(&self) -> u64 {
        self.steps.iter().map(|r| r.tokens).sum()
    }
}

/// The batch-halving policy. Runs any trait [`Planner`] — the last
/// enum-dispatch call site migrated to `&dyn Planner`, so spec-parsed and
/// decorated planners work here too.
pub struct BatchSplitPolicy {
    pub engine: Engine,
    pub planner: Box<dyn Planner>,
    /// Refuse to split below this many tokens per device (avoids
    /// degenerate empty sub-batches).
    pub min_tokens_per_device: u64,
    /// Safety bound on recursion depth.
    pub max_splits: usize,
}

impl BatchSplitPolicy {
    pub fn new(engine: Engine, planner: Box<dyn Planner>) -> BatchSplitPolicy {
        BatchSplitPolicy { engine, planner, min_tokens_per_device: 64, max_splits: 6 }
    }

    /// Run `lm`, splitting in half while the step would OOM.
    pub fn run(&self, lm: &LoadMatrix) -> SplitOutcome {
        let mut outcome = SplitOutcome { steps: Vec::new(), splits: 0 };
        self.run_rec(lm, 0, &mut outcome);
        outcome
    }

    fn run_rec(&self, lm: &LoadMatrix, depth: usize, outcome: &mut SplitOutcome) {
        let report = self.engine.run_step_loads(lm, &*self.planner);
        let too_small = lm
            .tokens_per_device()
            .iter()
            .all(|&t| t / 2 < self.min_tokens_per_device);
        if !report.oom || depth >= self.max_splits || too_small {
            outcome.steps.push(report);
            return;
        }
        outcome.splits += 1;
        let (a, b) = split_loads(lm);
        self.run_rec(&a, depth + 1, outcome);
        self.run_rec(&b, depth + 1, outcome);
    }
}

/// Split a load matrix into two halves (per device, per expert; odd
/// remainders go to the first half), each padded to a K-multiple.
pub fn split_loads(lm: &LoadMatrix) -> (LoadMatrix, LoadMatrix) {
    let k = lm.top_k as u64;
    let halve = |which: usize| -> LoadMatrix {
        let counts: Vec<Vec<u64>> = lm
            .counts
            .iter()
            .map(|row| {
                let mut new_row: Vec<u64> = row
                    .iter()
                    .map(|&c| if which == 0 { c / 2 + c % 2 } else { c / 2 })
                    .collect();
                // pad expert 0 so the device total stays a K-multiple
                let total: u64 = new_row.iter().sum();
                let rem = total % k;
                if rem != 0 {
                    new_row[0] += k - rem;
                }
                new_row
            })
            .collect();
        LoadMatrix { counts, top_k: lm.top_k }
    };
    (halve(0), halve(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
    use crate::planner::{parse_planner, PlannerKind};
    use crate::routing::Scenario;
    use crate::util::rng::Rng;

    fn tight_engine() -> Engine {
        let model = ModelConfig::preset(ModelPreset::Fig1Layer);
        let mut sys = SystemConfig::preset(SystemPreset::H200x8);
        sys.mem_capacity_bytes = 4 << 30; // EP OOMs at B=64K under skew
        Engine::modeled(model, sys)
    }

    fn hot_loads(e: &Engine, tokens: usize, seed: u64) -> LoadMatrix {
        Scenario::concentrated(0.95, 1).generate_loads(&e.model, 8, tokens, &mut Rng::new(seed))
    }

    #[test]
    fn split_conserves_tokens_and_k_multiple() {
        let e = tight_engine();
        let lm = hot_loads(&e, 10_000, 1);
        let (a, b) = split_loads(&lm);
        a.validate().unwrap();
        b.validate().unwrap();
        // padding may add a few slots but never loses any
        assert!(a.total_load() + b.total_load() >= lm.total_load());
        assert!(a.total_load() + b.total_load() <= lm.total_load() + 8 * 4);
    }

    #[test]
    fn splitting_bounds_memory_but_costs_latency() {
        let e = tight_engine();
        let lm = hot_loads(&e, 65_536, 2);
        // Sanity: whole-batch EP OOMs.
        assert!(e.run_step_loads(&lm, &PlannerKind::StandardEp).oom);

        let policy = BatchSplitPolicy::new(e.clone(), PlannerKind::StandardEp.boxed());
        let outcome = policy.run(&lm);
        assert!(outcome.splits > 0, "must have split");
        assert!(outcome.steps.iter().all(|s| !s.oom), "all sub-steps fit");
        assert!(outcome.peak_bytes() <= e.system.mem_capacity_bytes);

        // ...but LLEP handles the whole batch in one step, faster.
        let llep = e.run_step_loads(&lm, &PlannerKind::llep_default());
        assert!(!llep.oom);
        assert!(
            llep.latency_s < outcome.total_latency_s(),
            "LLEP {} vs split-EP {}",
            llep.latency_s,
            outcome.total_latency_s()
        );
    }

    #[test]
    fn no_split_when_memory_fits() {
        let e = tight_engine();
        let lm = hot_loads(&e, 2048, 3);
        let policy = BatchSplitPolicy::new(e, PlannerKind::StandardEp.boxed());
        let outcome = policy.run(&lm);
        assert_eq!(outcome.splits, 0);
        assert_eq!(outcome.steps.len(), 1);
    }

    #[test]
    fn spec_parsed_planner_runs_the_policy() {
        // The migration off the PlannerKind enum means any registry spec
        // drives the policy directly.
        let e = tight_engine();
        let lm = hot_loads(&e, 2048, 7);
        let policy = BatchSplitPolicy::new(e, parse_planner("chunked:c=2048").unwrap());
        let outcome = policy.run(&lm);
        assert!(!outcome.steps.is_empty());
        assert!(outcome.steps[0].planner.contains("ChunkedEP"));
    }

    #[test]
    fn split_depth_bounded() {
        let e = {
            let model = ModelConfig::preset(ModelPreset::Fig1Layer);
            let mut sys = SystemConfig::preset(SystemPreset::H200x8);
            sys.mem_capacity_bytes = 1; // nothing ever fits
            Engine::modeled(model, sys)
        };
        let lm = hot_loads(&e, 8192, 4);
        let policy = BatchSplitPolicy::new(e, PlannerKind::StandardEp.boxed());
        let outcome = policy.run(&lm);
        // bounded by max_splits and min tokens; still returns reports
        assert!(!outcome.steps.is_empty());
        assert!(outcome.splits <= 64);
    }
}
