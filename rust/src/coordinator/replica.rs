//! The replica core: one serving engine's event loop, shared by every
//! simulator that prices batched MoE steps on a virtual clock.
//!
//! A [`Replica`] owns the per-replica serving state — the waiting queue,
//! the active decode set, the chaos pool view, the token ledger and the
//! step counters — and exposes a single [`step`](Replica::step) API:
//! admit waiting prefills under the token budget, join them with one
//! token per active decode, resolve this step's fault-plan pool view
//! (aborting + requeueing the in-flight attempt when a device died),
//! price one **full-model** engine step over the exact token total, and
//! advance the virtual clock by the step latency.
//!
//! [`ServeSim`](super::ServeSim), [`ContinuousBatchSim`](super::ContinuousBatchSim),
//! the autotuner's serve-mode trial evaluation and the
//! [`fleet`](crate::fleet) cluster simulator are all thin drivers over
//! this loop: they differ only in how requests are fed in and which
//! outcome events they aggregate. The loop's float and RNG operation
//! order is the bit-reproducibility contract — two runs with the same
//! (requests, engine, fault plan, seed) produce identical reports, and
//! the pre-refactor `ServeSim`/`ContinuousBatchSim` numbers are
//! preserved exactly.

use crate::chaos::{FaultPlan, PoolState};
use crate::exec::{Engine, ModelStepReport};
use crate::placement::PlacementStats;
use crate::planner::{CacheStats, Planner};
use crate::routing::{DepthProfile, Scenario};
use crate::trace::{ArgValue, COORD_TID};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use std::collections::VecDeque;

/// Admitted-vs-priced token accounting shared by all serving reports:
/// `admitted` tokens entered from the request stream, `priced` tokens
/// were charged by the engine. The contract is equality.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TokenLedger {
    pub admitted: u64,
    pub priced: u64,
}

impl TokenLedger {
    pub fn add(&mut self, admitted: u64, priced: u64) {
        self.admitted += admitted;
        self.priced += priced;
    }

    /// Merge another ledger (fleet reports sum their replicas' ledgers).
    pub fn absorb(&mut self, other: &TokenLedger) {
        self.admitted += other.admitted;
        self.priced += other.priced;
    }

    /// True when every admitted token was priced exactly once.
    pub fn is_exact(&self) -> bool {
        self.admitted == self.priced
    }
}

/// Chaos accounting for one serving run (all zero without a fault plan).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ChaosStats {
    /// Engine steps priced under a degraded pool view.
    pub fault_steps: usize,
    /// Devices observed transitioning alive -> dead during the run.
    pub failures: usize,
    /// Devices observed transitioning dead -> alive (elastic scale-up).
    pub recoveries: usize,
    /// Aborted in-flight steps whose batch was requeued after a failure.
    pub requeues: usize,
    /// Tokens those aborts requeued. The [`TokenLedger`] still counts
    /// every admitted token exactly once — only the successful retry
    /// prices them.
    pub requeued_tokens: u64,
    /// Virtual time burned by aborted attempts.
    pub wasted_s: f64,
    /// Max aborted attempts observed before a successful (elastically
    /// replanned) step completed — measured per failure event, so a
    /// regression that makes recovery loop shows up here. The
    /// bounded-recovery contract (`<= 1` under the current single-abort
    /// model) is asserted by `rust/tests/chaos.rs`.
    pub max_recovery_steps: usize,
}

impl ChaosStats {
    /// Merge another run's counters (fleet reports sum their replicas'
    /// device-level chaos accounting; the recovery bound is a max).
    pub fn absorb(&mut self, other: &ChaosStats) {
        self.fault_steps += other.fault_steps;
        self.failures += other.failures;
        self.recoveries += other.recoveries;
        self.requeues += other.requeues;
        self.requeued_tokens += other.requeued_tokens;
        self.wasted_s += other.wasted_s;
        self.max_recovery_steps = self.max_recovery_steps.max(other.max_recovery_steps);
    }
}

/// Per-step chaos bookkeeping for one replica: resolves the fault plan
/// into pool views, prices + discards the in-flight attempt a fresh
/// failure aborts, and hands the step an engine view of the degraded
/// pool.
struct ChaosDriver<'a> {
    plan: Option<&'a FaultPlan>,
    base: PoolState,
    stats: ChaosStats,
    /// Aborted attempts since the last successful step (resolved into
    /// `stats.max_recovery_steps` when a step completes).
    pending_aborts: usize,
    /// Cached engine view for the current degraded pool. Permanent
    /// degradations (a straggler, a failure, preset speeds under a fault
    /// plan) keep the same pool for many consecutive steps — rebuilding
    /// the engine (clone + topology re-derivation) per step would be
    /// pure waste.
    view: Option<(PoolState, Engine)>,
}

impl<'a> ChaosDriver<'a> {
    fn new(engine: &Engine, plan: Option<&'a FaultPlan>) -> Result<ChaosDriver<'a>, String> {
        if let Some(p) = plan {
            p.validate(engine.system.devices)?;
        }
        Ok(ChaosDriver {
            plan,
            base: engine.pool.clone(),
            stats: ChaosStats::default(),
            pending_aborts: 0,
            view: None,
        })
    }

    /// Engine to price the current step with (set by
    /// [`begin_step`](Self::begin_step)): the cached degraded view, or
    /// `base` while the pool is healthy.
    fn engine<'b>(&'b self, base: &'b Engine) -> &'b Engine {
        self.view.as_ref().map(|(_, e)| e).unwrap_or(base)
    }

    /// Advance to engine step `step` (called once per step, before the
    /// step is priced). When a device died since the previous step, the
    /// attempt that was in flight is priced against the *old* pool,
    /// charged to the clock as waste, and the batch requeues — the
    /// caller then prices the elastically replanned step against
    /// [`engine`](Self::engine).
    #[allow(clippy::too_many_arguments)]
    fn begin_step(
        &mut self,
        step: usize,
        engine: &Engine,
        profile: &DepthProfile,
        planner: &dyn Planner,
        batch_tokens: usize,
        rng: &mut Rng,
        clock: &mut f64,
    ) -> Result<(), String> {
        let Some(plan) = self.plan else { return Ok(()) };
        let pool = plan.state_at(step, &self.base);
        if pool.alive_count() == 0 {
            return Err(format!(
                "chaos: no alive devices left at step {step} ({}) — the pool cannot serve",
                pool.label()
            ));
        }
        let tracer = &engine.tracer;
        let prev = if step == 0 { self.base.clone() } else { plan.state_at(step - 1, &self.base) };
        let newly_dead = (0..pool.len())
            .filter(|&d| prev.devices[d].alive && !pool.devices[d].alive)
            .count();
        let recovered = (0..pool.len())
            .filter(|&d| !prev.devices[d].alive && pool.devices[d].alive)
            .count();
        self.stats.recoveries += recovered;
        if recovered > 0 && tracer.is_enabled() {
            tracer.instant_process(
                "device-recovery",
                "chaos",
                *clock,
                &[
                    ("recovered", ArgValue::Num(recovered as f64)),
                    ("pool", ArgValue::Text(pool.label())),
                ],
            );
            tracer.count("chaos/recoveries", recovered as u64);
        }
        if newly_dead > 0 {
            self.stats.failures += newly_dead;
            tracer.count("chaos/failures", newly_dead as u64);
            // The step in flight at the failure was planned against the
            // previous pool; its work is lost and the batch requeues. A
            // failure already active at step 0 has no in-flight work to
            // abort — serving simply starts on the degraded pool.
            if step > 0 {
                let holder: Engine;
                // The cached view still describes the previous step here.
                let attempt_engine: &Engine = match &self.view {
                    Some((p, e)) if *p == prev => e,
                    _ if prev.is_degraded() => {
                        holder = engine.for_pool(prev);
                        &holder
                    }
                    _ => engine,
                };
                let attempt = price_step(attempt_engine, profile, planner, batch_tokens, rng);
                let wasted_s = attempt.latency_s;
                *clock += wasted_s;
                self.stats.wasted_s += wasted_s;
                self.stats.requeues += 1;
                self.stats.requeued_tokens += batch_tokens as u64;
                self.pending_aborts += 1;
                recycle_report_plans(attempt);
                if tracer.is_enabled() {
                    tracer.instant_process(
                        "abort-requeue",
                        "chaos",
                        *clock,
                        &[
                            ("requeued_tokens", ArgValue::Num(batch_tokens as f64)),
                            ("wasted_s", ArgValue::Num(wasted_s)),
                        ],
                    );
                    tracer.count("chaos/requeues", 1);
                    tracer.count("chaos/requeued_tokens", batch_tokens as u64);
                }
            }
            if tracer.is_enabled() {
                tracer.instant_process(
                    "device-failure",
                    "chaos",
                    *clock,
                    &[
                        ("newly_dead", ArgValue::Num(newly_dead as f64)),
                        ("pool", ArgValue::Text(pool.label())),
                    ],
                );
            }
        }
        if pool.is_degraded() {
            self.stats.fault_steps += 1;
            if tracer.is_enabled() {
                // Track-spanning marker: this step prices under a
                // degraded pool (the fault window, one instant per step).
                tracer.instant_process(
                    "fault-window",
                    "chaos",
                    *clock,
                    &[("pool", ArgValue::Text(pool.label()))],
                );
                tracer.count("chaos/fault_steps", 1);
            }
            let reusable = matches!(&self.view, Some((p, _)) if *p == pool);
            if !reusable {
                let view_engine = engine.for_pool(pool.clone());
                self.view = Some((pool, view_engine));
            }
        } else {
            self.view = None;
        }
        Ok(())
    }

    /// A stranded step is fatal: the planner cannot adapt to this pool.
    /// A successful step resolves any pending aborts into the measured
    /// recovery bound.
    fn check_step(
        &mut self,
        step: usize,
        planner_label: &str,
        report: &ModelStepReport,
    ) -> Result<(), String> {
        if report.stranded {
            return Err(format!(
                "chaos: planner {planner_label} left expert work on a dead device at step \
                 {step}; static placements cannot adapt — use a pool-aware planner (llep, lpt)"
            ));
        }
        self.stats.max_recovery_steps = self.stats.max_recovery_steps.max(self.pending_aborts);
        self.pending_aborts = 0;
        Ok(())
    }
}

/// Shared constructor boilerplate: every MoE layer of the engine's model
/// routes with `scenario` (single-layer models still get one layer).
pub fn uniform_profile(engine: &Engine, scenario: Scenario) -> DepthProfile {
    DepthProfile::uniform(scenario, engine.model.num_moe_layers().max(1))
}

/// Hand a consumed step report's routing plans back to this thread's
/// planning arena (see `planner::scratch`): the serving loops price one
/// report per step and drop it, so recycling here is what keeps the
/// decode regime's plan→price cycle allocation-free in steady state.
pub(crate) fn recycle_report_plans(report: ModelStepReport) {
    for layer in report.layers {
        crate::planner::recycle_plan(layer.plan);
    }
}

/// Shared step pricer: one full-model engine step over exactly
/// `step_tokens` tokens drawn from `profile`.
pub(crate) fn price_step(
    engine: &Engine,
    profile: &DepthProfile,
    planner: &dyn Planner,
    step_tokens: usize,
    rng: &mut Rng,
) -> ModelStepReport {
    let lms =
        profile.generate_loads_total(&engine.model, engine.system.devices, step_tokens, rng);
    engine
        .run_model(&lms, planner)
        .expect("profile-generated loads are always consistent")
}

/// Per-token attention + dense FLOPs for one layer (rough transformer
/// accounting: 4 D^2 QKVO projections + 2 D^2-equivalent attention work).
fn attn_flops_per_token(d_model: usize) -> f64 {
    6.0 * (d_model as f64) * (d_model as f64)
}

/// Seconds per full forward step spent outside MoE layers (attention and
/// dense projections), spread across the engine's devices (data
/// parallel). Shared by the Fig.-1c harness and the layered full-model
/// simulator so both price the non-MoE part identically.
pub fn attention_overhead_s(engine: &Engine, total_tokens: f64) -> f64 {
    engine.model.num_layers as f64 * total_tokens * attn_flops_per_token(engine.model.d_model)
        / (engine.gemm.peak_flops * engine.system.devices as f64)
}

/// One request as the replica core sees it: a prefill of
/// `prompt_tokens`, then `decode_steps` single-token steps. Batch-style
/// requests (the [`ServeSim`](super::ServeSim) workload) set
/// `decode_steps = 0` and complete at their prefill step.
#[derive(Clone, Debug)]
pub struct ReplicaRequest {
    pub id: usize,
    pub arrival_s: f64,
    pub prompt_tokens: usize,
    pub decode_steps: usize,
}

/// An admitted request mid-decode.
#[derive(Clone, Debug)]
struct ActiveGen {
    req: ReplicaRequest,
    remaining: usize,
}

/// Observed service rates of a replica, derived from what it has
/// actually priced so far (see [`Replica::service_estimate`]). The
/// fleet admission controller uses these to estimate whether a replica
/// can still meet a deadline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServiceEstimate {
    /// Priced tokens per busy virtual second.
    pub tokens_per_s: f64,
    /// Mean priced step latency (busy time / steps).
    pub mean_step_s: f64,
}

/// Events produced by one successful [`Replica::step`].
#[derive(Clone, Debug, Default)]
pub struct StepEvents {
    /// Requests whose prefill completed this step: `(id, arrival_s)` in
    /// admission (FIFO) order. Time-to-first-token = `now() - arrival_s`.
    pub prefilled: Vec<(usize, f64)>,
    /// Requests that fully completed this step: `(id, arrival_s)`,
    /// prefill-only completions first (admission order), then decode
    /// completions (active-set order). Request latency =
    /// `now() - arrival_s`.
    pub finished: Vec<(usize, f64)>,
    /// Active decodes that contributed one token to this step — each is
    /// one per-token-latency sample at `latency_s`.
    pub decode_tokens: usize,
    /// Total tokens priced (prefill + decode).
    pub step_tokens: usize,
    /// Latency of the successful attempt (chaos waste excluded; the
    /// clock already carries both).
    pub latency_s: f64,
    /// Some device exceeded its memory capacity this step.
    pub oom: bool,
    /// Every MoE layer's lambda guard reverted to EP this step.
    pub fallback: bool,
}

/// Outcome of one [`Replica::step`] call.
#[derive(Clone, Debug)]
pub enum ReplicaStepOutcome {
    /// Nothing to do: no waiting prefills and no active decodes. The
    /// driver should advance the clock to the next arrival and resubmit.
    Idle,
    /// One engine step was priced; the clock advanced by its latency
    /// (plus any chaos-aborted attempt's waste).
    Stepped(StepEvents),
}

/// One serving replica: an engine + pool view + fault plan + queues,
/// stepped on a virtual clock. See the module docs for the event-loop
/// contract; construct with [`Replica::new`], feed requests with
/// [`submit`](Replica::submit), and drive with [`step`](Replica::step).
pub struct Replica<'a> {
    engine: &'a Engine,
    planner: &'a dyn Planner,
    profile: &'a DepthProfile,
    /// Max prefill tokens admitted per step (the first waiting request
    /// is always admitted, matching the FIFO budget rule).
    max_batch_tokens: usize,
    chaos: ChaosDriver<'a>,
    clock: f64,
    steps: usize,
    waiting: VecDeque<ReplicaRequest>,
    active: Vec<ActiveGen>,
    ledger: TokenLedger,
    peak_bytes: u64,
    oom_steps: usize,
    fallback_steps: usize,
    plan_cache: CacheStats,
    placement: PlacementStats,
    plan_times: Vec<f64>,
    /// Virtual time spent pricing steps (including chaos waste) — the
    /// numerator of fleet per-replica utilization.
    busy_s: f64,
}

impl<'a> Replica<'a> {
    /// Build a replica. Fails if the fault plan references devices the
    /// engine's system does not have.
    pub fn new(
        engine: &'a Engine,
        planner: &'a dyn Planner,
        profile: &'a DepthProfile,
        max_batch_tokens: usize,
        faults: Option<&'a FaultPlan>,
    ) -> Result<Replica<'a>, String> {
        Ok(Replica {
            chaos: ChaosDriver::new(engine, faults)?,
            engine,
            planner,
            profile,
            max_batch_tokens,
            clock: 0.0,
            steps: 0,
            waiting: VecDeque::new(),
            active: Vec::new(),
            ledger: TokenLedger::default(),
            peak_bytes: 0,
            oom_steps: 0,
            fallback_steps: 0,
            plan_cache: CacheStats::default(),
            placement: PlacementStats::default(),
            plan_times: Vec::new(),
            busy_s: 0.0,
        })
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.clock
    }

    /// Jump the clock forward to `t` (no-op if `t` is in the past).
    pub fn advance_to(&mut self, t: f64) {
        self.clock = self.clock.max(t);
    }

    /// True while any request is waiting or decoding.
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.active.is_empty()
    }

    /// Enqueue a request (FIFO).
    pub fn submit(&mut self, req: ReplicaRequest) {
        self.waiting.push_back(req);
    }

    /// Waiting + active request count (the least-queue router signal).
    pub fn queue_depth(&self) -> usize {
        self.waiting.len() + self.active.len()
    }

    /// Queued prompt tokens plus the active decode set (a KV-cache
    /// proxy) — the pressure router signal.
    pub fn pressure(&self) -> usize {
        self.waiting.iter().map(|r| r.prompt_tokens).sum::<usize>() + self.active.len()
    }

    /// Engine steps priced so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    pub fn ledger(&self) -> TokenLedger {
        self.ledger
    }

    pub fn chaos_stats(&self) -> ChaosStats {
        self.chaos.stats
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }

    pub fn oom_steps(&self) -> usize {
        self.oom_steps
    }

    pub fn fallback_steps(&self) -> usize {
        self.fallback_steps
    }

    pub fn plan_cache(&self) -> CacheStats {
        self.plan_cache
    }

    /// Placement activity (re-layouts, migrations, standby promotions)
    /// accumulated over the run — all zero for stateless planners.
    pub fn placement(&self) -> PlacementStats {
        self.placement
    }

    /// Per-step planning wall time (sum across each step's layers).
    pub fn plan_times(&self) -> &[f64] {
        &self.plan_times
    }

    /// Summary over [`plan_times`](Self::plan_times).
    pub fn plan_time_summary(&self) -> Summary {
        Summary::of(&self.plan_times)
    }

    /// Virtual time spent pricing steps (includes chaos waste).
    pub fn busy_s(&self) -> f64 {
        self.busy_s
    }

    /// Observed service rates, or `None` before the first priced step
    /// (a cold replica has no evidence yet — admission control then
    /// admits optimistically).
    pub fn service_estimate(&self) -> Option<ServiceEstimate> {
        if self.steps == 0 || !(self.busy_s > 0.0) {
            return None;
        }
        Some(ServiceEstimate {
            tokens_per_s: self.ledger.priced as f64 / self.busy_s,
            mean_step_s: self.busy_s / self.steps as f64,
        })
    }

    /// Crude earliest-finish estimate for a new request submitted at
    /// `now`: clear the currently queued work (pressure tokens at the
    /// observed priced-token rate), prefill the request's own prompt,
    /// then one mean step per decode token. Deliberately cheap — the
    /// same queue-depth x step-latency arithmetic a real frontend does
    /// from heartbeat metrics, and a pure function of replica state (no
    /// RNG), so admission decisions stay bit-reproducible.
    pub fn estimated_finish_s(&self, now: f64, prompt_tokens: usize, decode_steps: usize) -> f64 {
        let start = self.clock.max(now);
        match self.service_estimate() {
            // cold replica: optimistic (finish "immediately"); the
            // deadline still bounds how late it can start
            None => start,
            Some(est) => {
                start
                    + (self.pressure() + prompt_tokens) as f64 / est.tokens_per_s
                    + decode_steps as f64 * est.mean_step_s
            }
        }
    }

    /// True when a queue cap is set and this replica's outstanding
    /// requests have reached it (the backpressure signal).
    pub fn at_capacity(&self, queue_cap: Option<usize>) -> bool {
        queue_cap.is_some_and(|cap| self.queue_depth() >= cap)
    }

    /// MoE layers priced per step.
    pub fn layers(&self) -> usize {
        self.profile.num_layers()
    }

    /// Take every queued and in-flight request off this replica (waiting
    /// FIFO order first, then the active set in order) for re-routing
    /// after a whole-replica failure. In-flight decodes come back as
    /// fresh requests with their remaining decode steps — the receiving
    /// replica re-prices the prefill, and both ledgers stay exact
    /// because each replica prices exactly what it admits.
    pub fn drain(&mut self) -> Vec<ReplicaRequest> {
        let mut out: Vec<ReplicaRequest> = self.waiting.drain(..).collect();
        out.extend(self.active.drain(..).map(|a| ReplicaRequest {
            decode_steps: a.remaining,
            ..a.req
        }));
        out
    }

    /// Run one event-loop iteration: admit waiting prefills under the
    /// token budget (FIFO; the first waiting request always fits), add
    /// one token per active decode, and price one full-model engine
    /// step over the exact total. Errors are chaos-unrecoverable pools
    /// (every device dead, or a planner that strands work on one).
    pub fn step(&mut self, rng: &mut Rng) -> Result<ReplicaStepOutcome, String> {
        // admit prefills under the budget
        let mut prefill_tokens = 0usize;
        let mut admitted: Vec<ReplicaRequest> = Vec::new();
        while let Some(req) = self.waiting.front() {
            if admitted.is_empty() || prefill_tokens + req.prompt_tokens <= self.max_batch_tokens
            {
                prefill_tokens += req.prompt_tokens;
                admitted.push(self.waiting.pop_front().expect("front just matched"));
            } else {
                break;
            }
        }
        let decode_tokens = self.active.len();
        let step_tokens = prefill_tokens + decode_tokens;
        if step_tokens == 0 {
            return Ok(ReplicaStepOutcome::Idle);
        }
        let engine = self.engine;
        let profile = self.profile;
        let planner = self.planner;
        let clock_before = self.clock;
        let tracer = &engine.tracer;
        if tracer.is_enabled() {
            // Anchor engine emission (including a chaos-aborted attempt)
            // at this step's virtual start time.
            tracer.set_time_base(clock_before);
            for req in &admitted {
                tracer.instant(
                    COORD_TID,
                    "admit",
                    "serve",
                    clock_before,
                    &[
                        ("id", ArgValue::Num(req.id as f64)),
                        ("prompt_tokens", ArgValue::Num(req.prompt_tokens as f64)),
                    ],
                );
            }
            let depth = self.waiting.len() + self.active.len() + admitted.len();
            tracer.counter("queue depth", clock_before, depth as f64);
            tracer.observe("replica/queue_depth", depth as f64);
            tracer.count("serve/admitted_tokens", prefill_tokens as u64);
            tracer.count("serve/decode_tokens", decode_tokens as u64);
        }
        // chaos: resolve this step's pool view; a fresh failure aborts +
        // requeues the in-flight attempt first
        self.chaos.begin_step(
            self.steps,
            engine,
            profile,
            planner,
            step_tokens,
            rng,
            &mut self.clock,
        )?;
        // the successful attempt starts after any chaos waste
        tracer.set_time_base(self.clock);
        // price a full-model step over the exact token total
        let report =
            price_step(self.chaos.engine(engine), profile, planner, step_tokens, rng);
        self.chaos.check_step(self.steps, &report.planner, &report)?;
        self.clock += report.latency_s;
        self.steps += 1;
        self.busy_s += self.clock - clock_before;
        self.fallback_steps += (report.fallback_layers == report.num_layers()) as usize;
        self.oom_steps += report.oom as usize;
        self.peak_bytes = self.peak_bytes.max(report.max_peak_bytes());
        self.ledger.add(step_tokens as u64, report.tokens);
        self.plan_cache.absorb(&report.cache);
        self.placement.absorb(&report.placement);
        self.plan_times
            .push(report.layers.iter().map(|l| l.report.phases.plan_s).sum::<f64>());

        let mut events = StepEvents {
            decode_tokens,
            step_tokens,
            latency_s: report.latency_s,
            oom: report.oom,
            fallback: report.fallback_layers == report.num_layers(),
            ..StepEvents::default()
        };
        // prefill completions = first token; zero-decode requests finish
        for req in admitted {
            events.prefilled.push((req.id, req.arrival_s));
            if req.decode_steps > 0 {
                let remaining = req.decode_steps;
                self.active.push(ActiveGen { req, remaining });
            } else {
                events.finished.push((req.id, req.arrival_s));
            }
        }
        recycle_report_plans(report);
        self.active.retain_mut(|a| {
            a.remaining -= 1;
            if a.remaining == 0 {
                events.finished.push((a.req.id, a.req.arrival_s));
                false
            } else {
                true
            }
        });
        if tracer.is_enabled() {
            let now = self.clock;
            // coordinator-track summary span over the successful attempt
            // (chaos waste, if any, precedes it on the same track)
            tracer.span(
                COORD_TID,
                "serve-step",
                "serve",
                now - events.latency_s,
                events.latency_s,
                &[
                    ("prefill_tokens", ArgValue::Num(prefill_tokens as f64)),
                    ("decode_tokens", ArgValue::Num(decode_tokens as f64)),
                ],
            );
            for &(id, _arrival) in &events.prefilled {
                tracer.instant(
                    COORD_TID,
                    "prefill-done",
                    "serve",
                    now,
                    &[("id", ArgValue::Num(id as f64))],
                );
            }
            for &(id, arrival) in &events.finished {
                tracer.instant(
                    COORD_TID,
                    "request-finished",
                    "serve",
                    now,
                    &[
                        ("id", ArgValue::Num(id as f64)),
                        ("latency_s", ArgValue::Num(now - arrival)),
                    ],
                );
            }
            tracer.count("serve/prefills", events.prefilled.len() as u64);
            tracer.count("serve/finished", events.finished.len() as u64);
        }
        Ok(ReplicaStepOutcome::Stepped(events))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
    use crate::planner::PlannerKind;

    fn engine() -> Engine {
        Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        )
    }

    #[test]
    fn replica_idles_without_work() {
        let engine = engine();
        let planner = PlannerKind::llep_default().boxed();
        let profile = uniform_profile(&engine, Scenario::concentrated(0.9, 1));
        let mut rep = Replica::new(&engine, &*planner, &profile, 8192, None).unwrap();
        assert!(!rep.has_work());
        assert!(matches!(rep.step(&mut Rng::new(1)).unwrap(), ReplicaStepOutcome::Idle));
        assert_eq!(rep.steps(), 0);
        assert_eq!(rep.now(), 0.0);
    }

    #[test]
    fn replica_prefill_and_decode_lifecycle() {
        let engine = engine();
        let planner = PlannerKind::llep_default().boxed();
        let profile = uniform_profile(&engine, Scenario::concentrated(0.9, 1));
        let mut rep = Replica::new(&engine, &*planner, &profile, 8192, None).unwrap();
        rep.submit(ReplicaRequest { id: 0, arrival_s: 0.0, prompt_tokens: 512, decode_steps: 2 });
        rep.submit(ReplicaRequest { id: 1, arrival_s: 0.0, prompt_tokens: 256, decode_steps: 0 });
        let mut rng = Rng::new(2);
        // step 1: both prefill; request 1 (no decodes) finishes
        let ReplicaStepOutcome::Stepped(ev) = rep.step(&mut rng).unwrap() else {
            panic!("work was queued")
        };
        assert_eq!(ev.prefilled.len(), 2);
        assert_eq!(ev.finished, vec![(1, 0.0)]);
        assert_eq!(ev.step_tokens, 512 + 256);
        assert_eq!(ev.decode_tokens, 0);
        // steps 2-3: request 0 decodes out
        let ReplicaStepOutcome::Stepped(ev) = rep.step(&mut rng).unwrap() else {
            panic!("decode pending")
        };
        assert_eq!(ev.decode_tokens, 1);
        assert!(ev.finished.is_empty());
        let ReplicaStepOutcome::Stepped(ev) = rep.step(&mut rng).unwrap() else {
            panic!("decode pending")
        };
        assert_eq!(ev.finished, vec![(0, 0.0)]);
        assert!(!rep.has_work());
        assert_eq!(rep.steps(), 3);
        assert!(rep.ledger().is_exact());
        assert_eq!(rep.ledger().admitted, 512 + 256 + 2);
        assert!(rep.now() > 0.0);
        assert!((rep.busy_s() - rep.now()).abs() < 1e-12, "no idle time in this run");
    }

    #[test]
    fn replica_drain_returns_waiting_then_active_with_remaining_decodes() {
        let engine = engine();
        let planner = PlannerKind::llep_default().boxed();
        let profile = uniform_profile(&engine, Scenario::concentrated(0.9, 1));
        let mut rep = Replica::new(&engine, &*planner, &profile, 1024, None).unwrap();
        rep.submit(ReplicaRequest { id: 0, arrival_s: 0.0, prompt_tokens: 900, decode_steps: 5 });
        rep.submit(ReplicaRequest { id: 1, arrival_s: 0.0, prompt_tokens: 900, decode_steps: 3 });
        // one step: request 0 prefills (budget excludes request 1), one decode left pending
        rep.step(&mut Rng::new(3)).unwrap();
        assert_eq!(rep.queue_depth(), 2);
        assert!(rep.pressure() >= 900 + 1);
        let drained = rep.drain();
        assert!(!rep.has_work());
        assert_eq!(drained.len(), 2);
        // waiting first (untouched), then the in-flight decode with its
        // remaining steps (one of five consumed by the step above)
        assert_eq!(drained[0].id, 1);
        assert_eq!(drained[0].decode_steps, 3);
        assert_eq!(drained[1].id, 0);
        assert_eq!(drained[1].decode_steps, 4);
    }

    #[test]
    fn service_estimate_feeds_finish_time_and_capacity() {
        let engine = engine();
        let planner = PlannerKind::llep_default().boxed();
        let profile = uniform_profile(&engine, Scenario::concentrated(0.9, 1));
        let mut rep = Replica::new(&engine, &*planner, &profile, 8192, None).unwrap();
        // cold replica: no evidence yet, admission is optimistic
        assert_eq!(rep.service_estimate(), None);
        assert_eq!(rep.estimated_finish_s(0.25, 512, 4), 0.25, "cold estimate = start time");
        assert!(!rep.at_capacity(None));
        rep.submit(ReplicaRequest { id: 0, arrival_s: 0.0, prompt_tokens: 512, decode_steps: 2 });
        assert!(rep.at_capacity(Some(1)), "one outstanding request meets cap 1");
        assert!(!rep.at_capacity(Some(2)));
        let mut rng = Rng::new(4);
        while rep.has_work() {
            rep.step(&mut rng).unwrap();
        }
        let est = rep.service_estimate().expect("priced steps give an estimate");
        assert!(est.tokens_per_s > 0.0 && est.tokens_per_s.is_finite());
        assert!(est.mean_step_s > 0.0 && est.mean_step_s.is_finite());
        assert!((est.mean_step_s - rep.busy_s() / rep.steps() as f64).abs() < 1e-12);
        // a warm, empty replica still charges the request's own service
        // time; a queued one charges strictly more
        let empty_finish = rep.estimated_finish_s(rep.now(), 256, 4);
        assert!(empty_finish > rep.now());
        rep.submit(ReplicaRequest { id: 1, arrival_s: 0.0, prompt_tokens: 700, decode_steps: 8 });
        let queued_finish = rep.estimated_finish_s(rep.now(), 256, 4);
        assert!(queued_finish > empty_finish, "queued work pushes the estimate out");
    }
}
