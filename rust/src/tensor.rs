//! Dense row-major f32 matrices and the native GEMM used by the
//! [`crate::exec`] `Native` backend and the [`crate::moe`] reference.
//!
//! The native GEMM is a cache-blocked, 8-wide-unrolled kernel — not
//! cuBLAS, but fast enough to make measured-time experiments meaningful on
//! CPU, and deliberately exhibiting the same qualitative property the
//! paper's Eq. 3 models: small-`B` GEMMs amortize per-call overhead worse
//! than large-`B` ones.

/// Row-major 2-D matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Matrix filled from a generator called in row-major order.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Gaussian init scaled by `scale` (for synthetic expert weights).
    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut crate::util::rng::Rng) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * scale)
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Gather rows by index into a new matrix.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Number of bytes this matrix occupies (f32 payload only).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Frobenius-norm relative difference, for approx-equality checks.
    pub fn rel_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut num = 0f64;
        let mut den = 0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) * (a - b)) as f64;
            den += (a * a + b * b) as f64;
        }
        if den == 0.0 {
            0.0
        } else {
            (num / den).sqrt() as f32
        }
    }
}

/// `out += a @ b` for row-major matrices, cache-blocked.
///
/// The k-loop is outermost within a block so `b`'s rows stream linearly;
/// the innermost j-loop vectorizes. Accumulating into `out` lets callers
/// fuse the MoE gate-weighted combine without an extra pass.
pub fn matmul_acc(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols, b.rows, "inner dims: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    assert_eq!(out.rows, a.rows);
    assert_eq!(out.cols, b.cols);
    const MC: usize = 64; // rows of a per block
    const KC: usize = 128; // inner dim per block
    let (m, k, n) = (a.rows, a.cols, b.cols);
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for k0 in (0..k).step_by(KC) {
            let k1 = (k0 + KC).min(k);
            for i in i0..i1 {
                let a_row = a.row(i);
                let out_row = out.row_mut(i);
                // Unroll the k-loop 2x so each output chunk is loaded/
                // stored once per pair of b rows; chunks_exact gives the
                // compiler bound-check-free, vectorizable bodies.
                let mut kk = k0;
                while kk + 2 <= k1 {
                    let aik0 = a_row[kk];
                    let aik1 = a_row[kk + 1];
                    let b_row0 = &b.data[kk * n..kk * n + n];
                    let b_row1 = &b.data[(kk + 1) * n..(kk + 1) * n + n];
                    let out_c = out_row.chunks_exact_mut(8);
                    let rem = out_c.into_remainder().len();
                    for ((o, b0), b1) in out_row
                        .chunks_exact_mut(8)
                        .zip(b_row0.chunks_exact(8))
                        .zip(b_row1.chunks_exact(8))
                    {
                        for x in 0..8 {
                            o[x] += aik0 * b0[x] + aik1 * b1[x];
                        }
                    }
                    for j in n - rem..n {
                        out_row[j] += aik0 * b_row0[j] + aik1 * b_row1[j];
                    }
                    kk += 2;
                }
                if kk < k1 {
                    let aik = a_row[kk];
                    let b_row = &b.data[kk * n..kk * n + n];
                    for (o, bv) in out_row.iter_mut().zip(b_row) {
                        *o += aik * bv;
                    }
                }
            }
        }
    }
}

/// `a @ b` returning a fresh matrix.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows, b.cols);
    matmul_acc(a, b, &mut out);
    out
}

/// `a @ b^T` returning a fresh matrix (used in backward passes).
pub fn matmul_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "a@(b^T) inner dims");
    let mut out = Mat::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        let a_row = a.row(i);
        let out_row = out.row_mut(i);
        for j in 0..b.rows {
            let b_row = b.row(j);
            let mut acc = 0f32;
            for k in 0..a.cols {
                acc += a_row[k] * b_row[k];
            }
            out_row[j] = acc;
        }
    }
    out
}

/// `a^T @ b` accumulated into `out` (weight-gradient shape).
pub fn matmul_at_acc(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.rows, b.rows, "(a^T)@b inner dims");
    assert_eq!(out.rows, a.cols);
    assert_eq!(out.cols, b.cols);
    for r in 0..a.rows {
        let a_row = a.row(r);
        let b_row = b.row(r);
        for i in 0..a.cols {
            let ai = a_row[i];
            if ai == 0.0 {
                continue;
            }
            let out_row = out.row_mut(i);
            for j in 0..b.cols {
                out_row[j] += ai * b_row[j];
            }
        }
    }
}

/// SiLU activation x * sigmoid(x).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Derivative of SiLU.
#[inline]
pub fn silu_grad(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

/// Numerically-stable softmax over a slice, in place.
pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::MIN, f32::max);
    let mut sum = 0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0f32;
                for k in 0..a.cols {
                    acc += a.at(i, k) * b.at(k, j);
                }
                out.data[i * b.cols + j] = acc;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(5);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 128, 40), (70, 130, 65)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let fast = matmul(&a, &b);
            let slow = naive_matmul(&a, &b);
            assert!(fast.rel_diff(&slow) < 1e-5, "({m},{k},{n}): {}", fast.rel_diff(&slow));
        }
    }

    #[test]
    fn matmul_acc_accumulates() {
        let mut rng = Rng::new(6);
        let a = Mat::randn(4, 6, 1.0, &mut rng);
        let b = Mat::randn(6, 3, 1.0, &mut rng);
        let mut out = matmul(&a, &b);
        matmul_acc(&a, &b, &mut out); // out = 2 * a@b
        let twice = Mat::from_vec(4, 3, matmul(&a, &b).data.iter().map(|x| 2.0 * x).collect());
        assert!(out.rel_diff(&twice) < 1e-6);
    }

    #[test]
    fn matmul_bt_matches() {
        let mut rng = Rng::new(7);
        let a = Mat::randn(5, 8, 1.0, &mut rng);
        let b = Mat::randn(9, 8, 1.0, &mut rng);
        // a @ b^T == naive(a, transpose(b))
        let bt = Mat::from_fn(8, 9, |r, c| b.at(c, r));
        assert!(matmul_bt(&a, &b).rel_diff(&naive_matmul(&a, &bt)) < 1e-5);
    }

    #[test]
    fn matmul_at_matches() {
        let mut rng = Rng::new(8);
        let a = Mat::randn(10, 4, 1.0, &mut rng);
        let b = Mat::randn(10, 6, 1.0, &mut rng);
        let at = Mat::from_fn(4, 10, |r, c| a.at(c, r));
        let mut out = Mat::zeros(4, 6);
        matmul_at_acc(&a, &b, &mut out);
        assert!(out.rel_diff(&naive_matmul(&at, &b)) < 1e-5);
    }

    #[test]
    fn gather_rows_selects() {
        let m = Mat::from_fn(4, 2, |r, c| (r * 10 + c) as f32);
        let g = m.gather_rows(&[2, 0, 2]);
        assert_eq!(g.data, vec![20.0, 21.0, 0.0, 1.0, 20.0, 21.0]);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut xs = [1000.0f32, 1001.0, 1002.0];
        softmax_inplace(&mut xs);
        let sum: f32 = xs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
        assert!(xs.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn silu_and_grad_sane() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!(silu(5.0) > 4.9);
        // finite-difference check of silu_grad
        for &x in &[-2.0f32, -0.3, 0.0, 0.7, 3.0] {
            let eps = 1e-3;
            let fd = (silu(x + eps) - silu(x - eps)) / (2.0 * eps);
            assert!((fd - silu_grad(x)).abs() < 1e-3, "x={x}");
        }
    }
}
