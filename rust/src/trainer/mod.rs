//! Training loop driving the AOT-compiled `train_step` artifact — the
//! Fig.-5 experiment (EP vs LLEP wall-clock during fine-tuning) on the
//! tiny MoE transformer defined in `python/compile/model.py`.
//!
//! The JAX train step (fwd + bwd + SGD update, lowered once to HLO) is
//! executed from rust via PJRT; python is not involved at run time. The
//! step also returns per-expert routed-token counts, which feed the
//! EP/LLEP engines to compute each policy's virtual step latency — the
//! identical loss curve is then plotted against two different wall
//! clocks, exactly the comparison of paper Fig. 5.

use crate::exec::Engine;
use crate::planner::PlannerKind;
use crate::routing::LoadMatrix;
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use anyhow::{anyhow, Context, Result};

/// Output of one training step.
#[derive(Clone, Debug)]
pub struct TrainStepOut {
    pub loss: f32,
    /// Global per-expert routed token counts (summed over MoE layers).
    pub expert_counts: Vec<u64>,
}

/// One point of the Fig.-5 curve.
#[derive(Clone, Debug)]
pub struct CurvePoint {
    pub step: usize,
    pub loss: f32,
    /// Cumulative virtual wall-clock under standard EP.
    pub wall_ep_s: f64,
    /// Cumulative virtual wall-clock under LLEP.
    pub wall_llep_s: f64,
    /// Measured (real) per-step execution time of the PJRT train step.
    pub measured_step_s: f64,
}

/// Trainer state: parameters live in rust between steps.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    pub params: Vec<Vec<f32>>,
    param_shapes: Vec<Vec<usize>>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    pub num_experts: usize,
}

impl<'rt> Trainer<'rt> {
    /// Initialize from the artifact manifest: reads geometry metadata and
    /// runs the `init_params` artifact for the initial parameter values.
    pub fn new(rt: &'rt Runtime, seed: f32) -> Result<Trainer<'rt>> {
        let entry = rt
            .manifest
            .entries
            .get("train_step")
            .ok_or_else(|| anyhow!("train_step artifact missing — run `make artifacts`"))?;
        let meta = |k: &str| {
            entry
                .meta
                .get(k)
                .map(|&x| x as usize)
                .ok_or_else(|| anyhow!("train_step meta missing {k}"))
        };
        let num_params = meta("num_params")?;
        let batch = meta("batch")?;
        let seq = meta("seq")?;
        let vocab = meta("vocab")?;
        let num_experts = meta("num_experts")?;
        let param_shapes: Vec<Vec<usize>> = entry.inputs[..num_params].to_vec();

        let init = rt
            .execute_f32("init_params", &[(&[seed], &[])])
            .context("running init_params artifact")?;
        anyhow::ensure!(init.len() == num_params, "init_params arity mismatch");
        for (i, (p, s)) in init.iter().zip(&param_shapes).enumerate() {
            let want: usize = s.iter().product();
            anyhow::ensure!(p.len() == want, "param {i}: {} != {:?}", p.len(), s);
        }

        Ok(Trainer { rt, params: init, param_shapes, batch, seq, vocab, num_experts })
    }

    /// Synthetic next-token task: mostly-deterministic affine cycle over
    /// the vocabulary with 10% noise — learnable in a few hundred steps.
    pub fn make_batch(&self, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
        let mut x = Vec::with_capacity(self.batch * self.seq);
        let mut y = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let mut tok = rng.index(self.vocab);
            for _ in 0..self.seq {
                x.push(tok as f32);
                let next = if rng.f64() < 0.9 {
                    (3 * tok + 1) % self.vocab
                } else {
                    rng.index(self.vocab)
                };
                y.push(next as f32);
                tok = next;
            }
        }
        (x, y)
    }

    /// Execute one train step; updates parameters in place.
    pub fn step(&mut self, x: &[f32], y: &[f32]) -> Result<TrainStepOut> {
        anyhow::ensure!(x.len() == self.batch * self.seq, "x shape");
        anyhow::ensure!(y.len() == self.batch * self.seq, "y shape");
        let dims = [self.batch as i64, self.seq as i64];
        let mut inputs: Vec<(&[f32], &[i64])> = Vec::with_capacity(self.params.len() + 2);
        // own the i64 shape buffers for the params
        let shapes: Vec<Vec<i64>> = self
            .param_shapes
            .iter()
            .map(|s| s.iter().map(|&d| d as i64).collect())
            .collect();
        for (p, s) in self.params.iter().zip(&shapes) {
            inputs.push((p.as_slice(), s.as_slice()));
        }
        inputs.push((x, &dims));
        inputs.push((y, &dims));

        let mut outputs = self.rt.execute_f32("train_step", &inputs)?;
        anyhow::ensure!(
            outputs.len() == self.params.len() + 2,
            "train_step returned {} outputs, expected {}",
            outputs.len(),
            self.params.len() + 2
        );
        let counts_f = outputs.pop().unwrap();
        let loss = outputs[0][0];
        for (i, new_p) in outputs.drain(..).skip(1).enumerate() {
            self.params[i] = new_p;
        }
        let expert_counts: Vec<u64> = counts_f.iter().map(|&c| c.max(0.0) as u64).collect();
        anyhow::ensure!(expert_counts.len() == self.num_experts, "counts arity");
        Ok(TrainStepOut { loss, expert_counts })
    }

    /// See [`counts_to_load_matrix`].
    pub fn counts_to_loads(&self, counts: &[u64], devices: usize, top_k: usize) -> LoadMatrix {
        counts_to_load_matrix(counts, devices, top_k)
    }

    /// Run `steps` training steps, producing the Fig.-5 curve: identical
    /// losses, EP vs LLEP cumulative virtual wall-clock.
    pub fn run_curve(
        &mut self,
        steps: usize,
        engine: &Engine,
        rng: &mut Rng,
        mut on_step: impl FnMut(&CurvePoint),
    ) -> Result<Vec<CurvePoint>> {
        let mut curve = Vec::with_capacity(steps);
        let mut wall_ep = 0.0f64;
        let mut wall_llep = 0.0f64;
        let top_k = 2; // tiny model's K (see python/compile/model.py)
        for step in 0..steps {
            let (x, y) = self.make_batch(rng);
            let t0 = std::time::Instant::now();
            let out = self.step(&x, &y)?;
            let measured = t0.elapsed().as_secs_f64();
            let lm = self.counts_to_loads(&out.expert_counts, engine.system.devices, top_k);
            // fwd + bwd ~ 3x fwd FLOPs: scale the MoE-layer latency by 3.
            // min_gemm_tokens is tuned to the tiny workload (paper §4:
            // "tune these values for each use case") — the default m=1024
            // exceeds the whole per-expert load at this scale and would
            // disable spilling entirely.
            let llep_cfg = crate::config::LlepConfig {
                alpha: 1.0,
                min_gemm_tokens: 16,
                lambda: 1.3,
            };
            let ep = engine.run_step_loads(&lm, &PlannerKind::StandardEp);
            let ll = engine.run_step_loads(&lm, &PlannerKind::Llep(llep_cfg));
            wall_ep += 3.0 * ep.latency_s;
            wall_llep += 3.0 * ll.latency_s;
            let point = CurvePoint {
                step,
                loss: out.loss,
                wall_ep_s: wall_ep,
                wall_llep_s: wall_llep,
                measured_step_s: measured,
            };
            on_step(&point);
            curve.push(point);
        }
        Ok(curve)
    }
}

/// Turn global expert counts into a per-device load matrix (tokens
/// assumed evenly originated across devices; remainders land on device
/// 0), padded so each device's slot total is a K-multiple.
pub fn counts_to_load_matrix(counts: &[u64], devices: usize, top_k: usize) -> LoadMatrix {
    let per_dev: Vec<Vec<u64>> = (0..devices)
        .map(|p| {
            counts
                .iter()
                .map(|&c| c / devices as u64 + u64::from(p == 0) * (c % devices as u64))
                .collect()
        })
        .collect();
    // pad device 0 so each device's total is a K-multiple
    let mut counts = per_dev;
    for row in counts.iter_mut() {
        let total: u64 = row.iter().sum();
        let rem = total % top_k as u64;
        if rem != 0 {
            row[0] += top_k as u64 - rem;
        }
    }
    LoadMatrix { counts, top_k }
}

#[cfg(test)]
mod tests {
    // Runtime-dependent tests live in rust/tests/pjrt_integration.rs;
    // here we test the pure helpers.
    use super::*;

    #[test]
    fn counts_to_loads_rounds_to_k() {
        let lm = counts_to_load_matrix(&[10, 3, 0, 5], 4, 2);
        lm.validate().unwrap();
        assert!(lm.total_load() >= 18);
        assert_eq!(lm.total_load() % 2, 0);
        assert_eq!(lm.devices(), 4);
    }

    #[test]
    fn counts_remainders_on_device_zero() {
        // 10 = 4*2 + 2: device 0 gets 2 + 2 extra, others get 2 each.
        let lm = counts_to_load_matrix(&[10, 0], 4, 1);
        assert_eq!(lm.counts[0][0], 4);
        assert_eq!(lm.counts[1][0], 2);
        assert_eq!(lm.expert_loads(), vec![10, 0]);
    }

    #[test]
    fn counts_preserve_imbalance_ratio() {
        let lm = counts_to_load_matrix(&[800, 100, 60, 40], 4, 2);
        let l = lm.expert_loads();
        assert!(crate::routing::imbalance_ratio(&l) > 2.0);
    }
}
