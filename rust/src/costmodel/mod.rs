//! Analytic cost models: GEMM latency (paper Eq. 3), peak memory (paper
//! Eq. 4), and communication, plus a calibration harness that fits the
//! GEMM model to measured timings ([`calibrate`]).

pub mod calibrate;

use crate::config::{ModelConfig, SystemConfig};
use crate::topology::Topology;

/// GEMM latency model (paper Eq. 3):
///
/// `T(B) = T_overhead + B * t(B, D, H)` where the per-token time `t`
/// degrades at small `B` (poor MXU/SM occupancy) and small `D/H`. The
/// efficiency curve is `eff(B) = B / (B + b_half)` — the standard
/// saturation form; Fig. 8 of the paper is exactly the consequence of
/// this shape (same FLOPs split into more GEMMs take longer).
#[derive(Clone, Debug)]
pub struct GemmCostModel {
    pub overhead_s: f64,
    pub peak_flops: f64,
    pub tokens_half_eff: f64,
    pub dim_half_eff: f64,
}

impl GemmCostModel {
    pub fn from_system(sys: &SystemConfig) -> GemmCostModel {
        GemmCostModel {
            overhead_s: sys.gemm.overhead_s,
            peak_flops: sys.gemm.peak_flops,
            tokens_half_eff: sys.gemm.tokens_half_eff,
            dim_half_eff: sys.gemm.dim_half_eff,
        }
    }

    /// Efficiency in (0, 1] for a GEMM of `tokens` rows at dims `d x h`.
    pub fn efficiency(&self, tokens: u64, d: usize, h: usize) -> f64 {
        let b = tokens as f64;
        let eff_b = b / (b + self.tokens_half_eff);
        let dim = (d.min(h)) as f64;
        let eff_dim = dim / (dim + self.dim_half_eff);
        (eff_b * eff_dim).max(1e-9)
    }

    /// Latency of one expert GEMM over `tokens` tokens (seconds).
    pub fn gemm_time(&self, tokens: u64, model: &ModelConfig) -> f64 {
        if tokens == 0 {
            return 0.0;
        }
        let flops = tokens as f64 * model.flops_per_token();
        self.overhead_s
            + flops / (self.peak_flops * self.efficiency(tokens, model.d_model, model.d_ff))
    }

    /// Latency of a sequence of per-expert GEMMs on one device (paper
    /// Eq. 3's sum over local experts).
    pub fn device_compute_time(&self, per_expert_tokens: &[u64], model: &ModelConfig) -> f64 {
        per_expert_tokens.iter().map(|&b| self.gemm_time(b, model)).sum()
    }
}

/// Peak-memory model (paper Eq. 4): per expert computed on the device,
/// `B_i x D` activations in, `D x H` weights, `B_i x H` activations out.
#[derive(Clone, Debug)]
pub struct MemoryModel {
    pub dtype_bytes: usize,
}

impl MemoryModel {
    pub fn from_model(model: &ModelConfig) -> MemoryModel {
        MemoryModel { dtype_bytes: model.dtype_bytes }
    }

    /// Peak bytes on a device executing `work` = [(tokens, is_import)]
    /// with the model geometry. Resident native weights are counted once
    /// (`resident_experts`); imported expert weights add on top.
    pub fn device_peak_bytes(
        &self,
        model: &ModelConfig,
        work_tokens: &[u64],
        resident_experts: usize,
        imported_experts: usize,
    ) -> u64 {
        self.device_peak_bytes_iter(
            model,
            work_tokens.iter().copied(),
            resident_experts,
            imported_experts,
        )
    }

    /// [`device_peak_bytes`](Self::device_peak_bytes) folding straight
    /// over an iterator of per-expert token counts — the pricing hot
    /// path uses this to avoid collecting an intermediate `Vec<u64>` per
    /// device per step.
    pub fn device_peak_bytes_iter(
        &self,
        model: &ModelConfig,
        work_tokens: impl Iterator<Item = u64>,
        resident_experts: usize,
        imported_experts: usize,
    ) -> u64 {
        let d = model.d_model as u64;
        let h = model.d_ff as u64;
        let mats = model.mats_per_expert() as u64;
        let bytes = self.dtype_bytes as u64;
        let weights = (resident_experts + imported_experts) as u64 * mats * d * h * bytes;
        // Eq. 4 activation terms summed over the experts computed here.
        let acts: u64 = work_tokens.map(|b| b * (d + h) * bytes).sum();
        weights + acts
    }

    /// Peak bytes under chained gradient checkpointing (paper §3.1's
    /// chunked baseline): inputs for all `B_i` tokens must still be
    /// resident (they arrive via dispatch), but only one `chunk`-sized
    /// intermediate lives at a time — memory is reduced, not bounded,
    /// which is exactly the baseline's weakness.
    pub fn device_peak_bytes_chunked(
        &self,
        model: &ModelConfig,
        work_tokens: &[u64],
        resident_experts: usize,
        imported_experts: usize,
        chunk: u64,
    ) -> u64 {
        self.device_peak_bytes_chunked_iter(
            model,
            work_tokens.iter().copied(),
            resident_experts,
            imported_experts,
            chunk,
        )
    }

    /// Iterator form of
    /// [`device_peak_bytes_chunked`](Self::device_peak_bytes_chunked)
    /// (see [`device_peak_bytes_iter`](Self::device_peak_bytes_iter)).
    pub fn device_peak_bytes_chunked_iter(
        &self,
        model: &ModelConfig,
        work_tokens: impl Iterator<Item = u64>,
        resident_experts: usize,
        imported_experts: usize,
        chunk: u64,
    ) -> u64 {
        let d = model.d_model as u64;
        let h = model.d_ff as u64;
        let mats = model.mats_per_expert() as u64;
        let bytes = self.dtype_bytes as u64;
        let weights = (resident_experts + imported_experts) as u64 * mats * d * h * bytes;
        let inputs: u64 = work_tokens.map(|b| b * d * bytes).sum();
        let intermediate = chunk * h * bytes;
        weights + inputs + intermediate
    }
}

/// Communication cost model: All-to-All dispatch/combine plus P2P weight
/// transfers, on top of a [`Topology`].
#[derive(Clone, Debug)]
pub struct CommCostModel {
    pub topo: Topology,
    /// DeepEP-style fused collectives (paper §4 "Implementation &
    /// Optimization"): one fused kernel performs the whole All-to-All
    /// directly on unsorted tensors, so per-peer message launch latency
    /// collapses to a single launch per direction. Bandwidth terms are
    /// unchanged (the wire does not get faster).
    pub fused: bool,
    /// Per-device link divisors (>= 1.0) from the chaos layer's
    /// `link:dev=` fault: a message's bandwidth is divided by the worst
    /// divisor among its two endpoints. Empty = nominal, which keeps the
    /// integer accumulate-then-divide pricing path (and its exact f64
    /// results) bit-identical to the pre-chaos code.
    pub device_link: Vec<f64>,
}

impl CommCostModel {
    pub fn new(topo: Topology) -> CommCostModel {
        CommCostModel { topo, fused: false, device_link: Vec::new() }
    }

    /// Enable fused (DeepEP-like) collective launch accounting.
    pub fn fused(topo: Topology) -> CommCostModel {
        CommCostModel { topo, fused: true, device_link: Vec::new() }
    }

    /// Install per-device link divisors (empty = nominal links).
    pub fn with_device_link(mut self, device_link: Vec<f64>) -> CommCostModel {
        self.device_link = device_link;
        self
    }

    /// Bandwidth stretch for a message between `a` and `b`: the worst
    /// endpoint's link divisor (1.0 when nominal).
    fn link_stretch(&self, a: usize, b: usize) -> f64 {
        let f = |d: usize| self.device_link.get(d).copied().unwrap_or(1.0);
        f(a).max(f(b))
    }

    /// Time of an All-to-All phase given the per-(src, dst) byte matrix.
    /// Each device's phase time is `latency * messages + max(sent, recvd)
    /// / bw` (links are full-duplex); the caller takes the max across
    /// devices, mirroring a synchronous NCCL collective.
    pub fn all_to_all_times(&self, bytes: &[Vec<u64>]) -> Vec<f64> {
        let mut times = Vec::new();
        self.all_to_all_times_into(bytes, &mut times);
        times
    }

    /// [`all_to_all_times`](Self::all_to_all_times) into a reusable
    /// buffer (the pricing hot path).
    pub fn all_to_all_times_into(&self, bytes: &[Vec<u64>], times: &mut Vec<f64>) {
        let p = self.topo.devices;
        times.clear();
        times.resize(p, 0.0);
        if !self.device_link.is_empty() {
            // Per-device link degradation: each message's bandwidth is
            // divided by the worst endpoint's divisor, so bytes scale
            // per message instead of accumulating per tier.
            for (src, row) in bytes.iter().enumerate() {
                debug_assert_eq!(row.len(), p);
                let mut send_t = 0.0;
                let mut recv_t = 0.0;
                let mut msgs = 0u64;
                for (dst, &b) in row.iter().enumerate() {
                    if src == dst || b == 0 {
                        continue;
                    }
                    msgs += 1;
                    let bw = if self.topo.same_node(src, dst) {
                        self.topo.intra_node_bw
                    } else {
                        self.topo.inter_node_bw
                    };
                    send_t += b as f64 * self.link_stretch(src, dst) / bw;
                }
                for (other_src, other_row) in bytes.iter().enumerate() {
                    if other_src == src {
                        continue;
                    }
                    let b = other_row[src];
                    if b == 0 {
                        continue;
                    }
                    msgs += 1;
                    let bw = if self.topo.same_node(other_src, src) {
                        self.topo.intra_node_bw
                    } else {
                        self.topo.inter_node_bw
                    };
                    recv_t += b as f64 * self.link_stretch(other_src, src) / bw;
                }
                let launches = if self.fused { (msgs > 0) as u64 * 2 } else { msgs };
                times[src] = self.topo.latency_s * launches as f64 + send_t.max(recv_t);
            }
            return;
        }
        for (src, row) in bytes.iter().enumerate() {
            debug_assert_eq!(row.len(), p);
            let mut sent_intra = 0u64;
            let mut sent_inter = 0u64;
            let mut msgs = 0u64;
            for (dst, &b) in row.iter().enumerate() {
                if src == dst || b == 0 {
                    continue;
                }
                msgs += 1;
                if self.topo.same_node(src, dst) {
                    sent_intra += b;
                } else {
                    sent_inter += b;
                }
            }
            let mut recv_intra = 0u64;
            let mut recv_inter = 0u64;
            for (other_src, other_row) in bytes.iter().enumerate() {
                if other_src == src {
                    continue;
                }
                let b = other_row[src];
                if b == 0 {
                    continue;
                }
                msgs += 1;
                if self.topo.same_node(other_src, src) {
                    recv_intra += b;
                } else {
                    recv_inter += b;
                }
            }
            let send_t = sent_intra as f64 / self.topo.intra_node_bw
                + sent_inter as f64 / self.topo.inter_node_bw;
            let recv_t = recv_intra as f64 / self.topo.intra_node_bw
                + recv_inter as f64 / self.topo.inter_node_bw;
            let launches = if self.fused { (msgs > 0) as u64 * 2 } else { msgs };
            times[src] = self.topo.latency_s * launches as f64 + send_t.max(recv_t);
        }
    }

    /// Time for one P2P transfer. A per-device link divisor stretches
    /// the bandwidth term only — launch latency is endpoint compute, not
    /// wire time (matching [`Topology::degraded`]'s philosophy).
    pub fn p2p_time(&self, src: usize, dst: usize, bytes: u64) -> f64 {
        if self.device_link.is_empty() {
            return self.topo.transfer_time(src, dst, bytes);
        }
        self.topo.latency_s
            + bytes as f64 * self.link_stretch(src, dst) / self.topo.bandwidth(src, dst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelPreset, SystemPreset};

    fn model() -> ModelConfig {
        ModelConfig::preset(ModelPreset::Fig1Layer)
    }
    fn sys() -> SystemConfig {
        SystemConfig::preset(SystemPreset::H200x8)
    }

    #[test]
    fn gemm_time_monotone_in_tokens() {
        let g = GemmCostModel::from_system(&sys());
        let m = model();
        let t1 = g.gemm_time(100, &m);
        let t2 = g.gemm_time(1000, &m);
        let t3 = g.gemm_time(10_000, &m);
        assert!(t1 < t2 && t2 < t3);
        assert_eq!(g.gemm_time(0, &m), 0.0);
    }

    #[test]
    fn few_big_gemms_beat_many_small() {
        // Paper Fig. 8: same FLOPs, more experts -> slower.
        let g = GemmCostModel::from_system(&sys());
        let m = model();
        let total = 65_536u64;
        let one = g.device_compute_time(&[total], &m);
        let eight = g.device_compute_time(&vec![total / 8; 8], &m);
        let sixty_four = g.device_compute_time(&vec![total / 64; 64], &m);
        assert!(one < eight && eight < sixty_four, "{one} {eight} {sixty_four}");
    }

    #[test]
    fn efficiency_saturates() {
        let g = GemmCostModel::from_system(&sys());
        let e_small = g.efficiency(16, 2048, 2048);
        let e_big = g.efficiency(65_536, 2048, 2048);
        assert!(e_small < e_big);
        assert!(e_big <= 1.0);
        // At B = b_half, token efficiency is exactly 1/2 of the dim part.
        let b_half = g.tokens_half_eff as u64;
        let dim_eff = {
            let d = 2048f64;
            d / (d + g.dim_half_eff)
        };
        assert!((g.efficiency(b_half, 2048, 2048) - 0.5 * dim_eff).abs() < 1e-9);
    }

    #[test]
    fn memory_matches_eq4() {
        let m = model();
        let mm = MemoryModel::from_model(&m);
        // one expert of B=1000 tokens, 16 resident experts, no imports
        let bytes = mm.device_peak_bytes(&m, &[1000], 16, 0);
        let d = m.d_model as u64;
        let h = m.d_ff as u64;
        let expected_weights = 16 * 3 * d * h * 2;
        let expected_acts = 1000 * (d + h) * 2;
        assert_eq!(bytes, expected_weights + expected_acts);
    }

    #[test]
    fn imports_add_weight_memory() {
        let m = model();
        let mm = MemoryModel::from_model(&m);
        let without = mm.device_peak_bytes(&m, &[100], 16, 0);
        let with = mm.device_peak_bytes(&m, &[100], 16, 2);
        assert_eq!(with - without, 2 * m.expert_weight_bytes() as u64);
    }

    #[test]
    fn alltoall_balanced_symmetric() {
        let topo = Topology::from_system(&sys());
        let c = CommCostModel::new(topo);
        let p = 8;
        let bytes = vec![vec![1u64 << 20; p]; p];
        let times = c.all_to_all_times(&bytes);
        let t0 = times[0];
        assert!(times.iter().all(|&t| (t - t0).abs() < 1e-12), "{times:?}");
        assert!(t0 > 0.0);
    }

    #[test]
    fn device_link_stretches_only_touching_transfers() {
        let topo = Topology::from_system(&sys());
        let nominal = CommCostModel::new(topo.clone());
        let mut dlink = vec![1.0; 8];
        dlink[0] = 4.0;
        let degraded = CommCostModel::new(topo).with_device_link(dlink);
        let p = 8;
        // Big messages so the phase is bandwidth-bound, not launch-bound.
        let bytes = vec![vec![1u64 << 26; p]; p];
        let tn = nominal.all_to_all_times(&bytes);
        let td = degraded.all_to_all_times(&bytes);
        // Device 0's phase stretches; a device exchanging with 0 pays
        // only on that one message, so it stretches strictly less.
        assert!(td[0] > tn[0] * 2.0, "{} vs {}", td[0], tn[0]);
        assert!(td[1] > tn[1] && td[1] < td[0], "{} {} {}", tn[1], td[1], td[0]);
        // P2P: only transfers touching device 0 stretch, and only the
        // bandwidth term (latency is unchanged).
        let b = 1u64 << 26;
        assert!(degraded.p2p_time(0, 1, b) > nominal.p2p_time(0, 1, b) * 2.0);
        assert_eq!(degraded.p2p_time(2, 3, b), nominal.p2p_time(2, 3, b));
        let lat = degraded.topo.latency_s;
        let stretched = degraded.p2p_time(0, 1, b) - lat;
        let plain = nominal.p2p_time(0, 1, b) - lat;
        assert!((stretched - plain * 4.0).abs() < 1e-12 * stretched.max(1.0));
        // An all-1.0 profile prices exactly like the nominal path.
        let unit = CommCostModel::new(nominal.topo.clone()).with_device_link(vec![1.0; 8]);
        let tu = unit.all_to_all_times(&bytes);
        for (a, b) in tu.iter().zip(tn.iter()) {
            assert!((a - b).abs() < 1e-15 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn alltoall_hot_receiver_pays() {
        let topo = Topology::from_system(&sys());
        let c = CommCostModel::new(topo);
        let p = 8;
        // everyone sends 8 MiB to device 0 only
        let mut bytes = vec![vec![0u64; p]; p];
        for (src, row) in bytes.iter_mut().enumerate() {
            if src != 0 {
                row[0] = 8 << 20;
            }
        }
        let times = c.all_to_all_times(&bytes);
        assert!(times[0] > times[1] * 2.0, "{times:?}");
    }

    #[test]
    fn fused_collectives_cut_launch_latency_only() {
        let topo = Topology::from_system(&sys());
        let base = CommCostModel::new(topo.clone());
        let fused = CommCostModel::fused(topo);
        let p = 8;
        // tiny messages: latency-bound -> fused much faster
        let small = vec![vec![64u64; p]; p];
        let tb = base.all_to_all_times(&small)[0];
        let tf = fused.all_to_all_times(&small)[0];
        assert!(tf < tb / 3.0, "latency-bound: fused {tf} vs {tb}");
        // huge messages: bandwidth-bound -> nearly identical
        let big = vec![vec![1u64 << 30; p]; p];
        let tb = base.all_to_all_times(&big)[0];
        let tf = fused.all_to_all_times(&big)[0];
        assert!((tb - tf) / tb < 0.02, "bandwidth-bound: fused {tf} vs {tb}");
    }

    #[test]
    fn inter_node_alltoall_slower() {
        let two = SystemConfig::preset(SystemPreset::H200x16TwoNodes);
        let c = CommCostModel::new(Topology::from_system(&two));
        let p = 16;
        let mut intra = vec![vec![0u64; p]; p];
        intra[0][1] = 64 << 20;
        let mut inter = vec![vec![0u64; p]; p];
        inter[0][9] = 64 << 20;
        assert!(c.all_to_all_times(&inter)[0] > c.all_to_all_times(&intra)[0]);
    }
}
