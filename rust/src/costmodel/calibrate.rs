//! GEMM cost-model calibration.
//!
//! Fits the Eq.-3 model (`overhead_s`, `peak_flops`, `tokens_half_eff`)
//! to measured timings of the native rust GEMM, so the modeled engine's
//! relative numbers track what this machine actually does. Run via
//! `llep calibrate`; the fitted parameters can be pasted into a
//! `SystemConfig` or used directly.

use super::GemmCostModel;
use crate::config::ModelConfig;
use crate::tensor::{matmul, Mat};
use crate::util::rng::Rng;
use std::time::Instant;

/// One measured sample: a GEMM of `tokens x d @ d x h`.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub tokens: u64,
    pub d: usize,
    pub h: usize,
    pub seconds: f64,
}

/// Measure the native GEMM across a token sweep at fixed `d x h`.
pub fn measure_native(d: usize, h: usize, token_sweep: &[u64], reps: usize) -> Vec<Sample> {
    let mut rng = Rng::new(0xCA11B);
    let w = Mat::randn(d, h, 0.02, &mut rng);
    token_sweep
        .iter()
        .map(|&tokens| {
            let x = Mat::randn(tokens as usize, d, 0.1, &mut rng);
            // warmup
            let _ = matmul(&x, &w);
            let start = Instant::now();
            for _ in 0..reps {
                std::hint::black_box(matmul(&x, &w));
            }
            Sample { tokens, d, h, seconds: start.elapsed().as_secs_f64() / reps as f64 }
        })
        .collect()
}

/// Fit the cost model to samples by coordinate descent over
/// (overhead, peak_flops, tokens_half_eff), minimizing mean squared
/// relative error. Robust enough for the smooth 3-parameter surface.
pub fn fit(samples: &[Sample], dim_half_eff: f64) -> GemmCostModel {
    assert!(!samples.is_empty());
    // Initial guesses from the data.
    let biggest = samples.iter().max_by_key(|s| s.tokens).unwrap();
    let flops = |s: &Sample| 2.0 * s.tokens as f64 * s.d as f64 * s.h as f64;
    let mut model = GemmCostModel {
        overhead_s: samples.iter().map(|s| s.seconds).fold(f64::MAX, f64::min) * 0.1,
        peak_flops: flops(biggest) / biggest.seconds,
        tokens_half_eff: 32.0,
        dim_half_eff,
    };

    let err = |m: &GemmCostModel| -> f64 {
        samples
            .iter()
            .map(|s| {
                let fake = ModelConfig {
                    name: "cal".into(),
                    num_experts: 1,
                    top_k: 1,
                    d_model: s.d,
                    d_ff: s.h,
                    swiglu: false,
                    num_layers: 1,
                    dtype_bytes: 4,
                    num_shared_experts: 0,
                };
                let pred = m.gemm_time(s.tokens, &fake);
                let rel = (pred - s.seconds) / s.seconds;
                rel * rel
            })
            .sum::<f64>()
            / samples.len() as f64
    };

    let mut best = err(&model);
    for _ in 0..60 {
        let mut improved = false;
        for param in 0..3 {
            for &factor in &[0.5, 0.8, 0.95, 1.05, 1.25, 2.0] {
                let mut cand = model.clone();
                match param {
                    0 => cand.overhead_s *= factor,
                    1 => cand.peak_flops *= factor,
                    _ => cand.tokens_half_eff *= factor,
                }
                let e = err(&cand);
                if e < best {
                    best = e;
                    model = cand;
                    improved = true;
                }
            }
        }
        if !improved {
            break;
        }
    }
    model
}

/// Root-mean-square relative error of a model against samples.
pub fn rms_rel_error(model: &GemmCostModel, samples: &[Sample]) -> f64 {
    let se: f64 = samples
        .iter()
        .map(|s| {
            let fake = ModelConfig {
                name: "cal".into(),
                num_experts: 1,
                top_k: 1,
                d_model: s.d,
                d_ff: s.h,
                swiglu: false,
                num_layers: 1,
                dtype_bytes: 4,
                num_shared_experts: 0,
            };
            let pred = model.gemm_time(s.tokens, &fake);
            let rel = (pred - s.seconds) / s.seconds;
            rel * rel
        })
        .sum();
    (se / samples.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic "measurements" drawn from a known model must be
    /// recovered with small error.
    #[test]
    fn fit_recovers_known_model() {
        let truth = GemmCostModel {
            overhead_s: 5e-6,
            peak_flops: 2e10,
            tokens_half_eff: 24.0,
            dim_half_eff: 48.0,
        };
        let fake_cfg = |d: usize, h: usize| ModelConfig {
            name: "cal".into(),
            num_experts: 1,
            top_k: 1,
            d_model: d,
            d_ff: h,
            swiglu: false,
            num_layers: 1,
            dtype_bytes: 4,
            num_shared_experts: 0,
        };
        let samples: Vec<Sample> = [4u64, 16, 64, 256, 1024, 4096]
            .iter()
            .map(|&tokens| Sample {
                tokens,
                d: 256,
                h: 256,
                seconds: truth.gemm_time(tokens, &fake_cfg(256, 256)),
            })
            .collect();
        let fitted = fit(&samples, truth.dim_half_eff);
        let rms = rms_rel_error(&fitted, &samples);
        assert!(rms < 0.05, "rms={rms}");
    }

    /// Calibration against the real native GEMM should fit reasonably.
    #[test]
    fn fit_real_measurements() {
        let samples = measure_native(64, 64, &[8, 32, 128, 512], 3);
        assert_eq!(samples.len(), 4);
        assert!(samples.iter().all(|s| s.seconds > 0.0));
        let fitted = fit(&samples, 48.0);
        let rms = rms_rel_error(&fitted, &samples);
        // Real timer noise on a busy 1-core box: accept a loose fit.
        assert!(rms < 0.8, "rms={rms}");
        // Bigger GEMMs must take longer in both data and fit.
        assert!(samples[3].seconds > samples[0].seconds);
    }
}
