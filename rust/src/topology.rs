//! Device topology: node placement and link bandwidths.
//!
//! Encodes which devices share a node (fast links) and provides transfer
//! time estimates between any pair, used by the communication cost model
//! and by the multi-node spill preference (paper §4 "Implementation &
//! Optimization": prefer spilling to intra-node devices).

use crate::config::SystemConfig;

/// Topology derived from a [`SystemConfig`].
#[derive(Clone, Debug)]
pub struct Topology {
    pub devices: usize,
    pub devices_per_node: usize,
    pub latency_s: f64,
    pub intra_node_bw: f64,
    pub inter_node_bw: f64,
}

impl Topology {
    pub fn from_system(sys: &SystemConfig) -> Topology {
        Topology {
            devices: sys.devices,
            devices_per_node: sys.devices_per_node,
            latency_s: sys.comm.latency_s,
            intra_node_bw: sys.comm.intra_node_bw,
            inter_node_bw: sys.comm.inter_node_bw,
        }
    }

    pub fn node_of(&self, device: usize) -> usize {
        device / self.devices_per_node
    }

    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Point-to-point bandwidth between two devices, bytes/second.
    pub fn bandwidth(&self, src: usize, dst: usize) -> f64 {
        if src == dst {
            // Local "transfer" is a no-op; model as effectively infinite.
            f64::INFINITY
        } else if self.same_node(src, dst) {
            self.intra_node_bw
        } else {
            self.inter_node_bw
        }
    }

    /// Time to move `bytes` from `src` to `dst`.
    pub fn transfer_time(&self, src: usize, dst: usize, bytes: u64) -> f64 {
        if src == dst || bytes == 0 {
            return 0.0;
        }
        self.latency_s + bytes as f64 / self.bandwidth(src, dst)
    }

    /// Devices ordered by "closeness" to `from` for spill preference:
    /// same-node devices first, then remote nodes (stable within groups).
    pub fn spill_order(&self, from: usize) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.devices).filter(|&d| d != from).collect();
        order.sort_by_key(|&d| (!self.same_node(from, d) as usize, d));
        order
    }

    pub fn num_nodes(&self) -> usize {
        // div_ceil: a topology with a partially-filled last node (devices
        // not divisible by devices_per_node — constructible directly,
        // even though SystemConfig::validate rejects it) still counts
        // that node, consistently with `node_of`.
        self.devices.div_ceil(self.devices_per_node)
    }

    /// A copy with both bandwidth tiers divided by `factor` (the chaos
    /// layer's link degradation; per-message latency is unchanged — the
    /// wire got slower, not the NCCL launch path). `factor <= 1.0` is a
    /// no-op so recovery steps restore nominal bandwidth exactly.
    pub fn degraded(&self, factor: f64) -> Topology {
        let mut t = self.clone();
        if factor > 1.0 {
            t.intra_node_bw /= factor;
            t.inter_node_bw /= factor;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SystemConfig, SystemPreset};

    fn two_node() -> Topology {
        Topology::from_system(&SystemConfig::preset(SystemPreset::H200x16TwoNodes))
    }

    #[test]
    fn node_membership() {
        let t = two_node();
        assert_eq!(t.num_nodes(), 2);
        assert!(t.same_node(0, 7));
        assert!(!t.same_node(0, 8));
    }

    #[test]
    fn bandwidth_tiers() {
        let t = two_node();
        assert!(t.bandwidth(0, 1) > t.bandwidth(0, 9));
        assert_eq!(t.bandwidth(3, 3), f64::INFINITY);
    }

    #[test]
    fn transfer_time_monotone_in_bytes() {
        let t = two_node();
        let small = t.transfer_time(0, 1, 1 << 20);
        let big = t.transfer_time(0, 1, 1 << 24);
        assert!(big > small && small > 0.0);
        assert_eq!(t.transfer_time(0, 0, 1 << 20), 0.0);
        assert_eq!(t.transfer_time(0, 1, 0), 0.0);
    }

    #[test]
    fn inter_node_slower() {
        let t = two_node();
        assert!(t.transfer_time(0, 9, 1 << 24) > t.transfer_time(0, 1, 1 << 24));
    }

    #[test]
    fn spill_order_prefers_intra_node() {
        let t = two_node();
        let order = t.spill_order(2);
        assert_eq!(order.len(), 15);
        assert!(!order.contains(&2));
        // first 7 entries are node-0 peers
        assert!(order[..7].iter().all(|&d| t.same_node(2, d)));
        assert!(order[7..].iter().all(|&d| !t.same_node(2, d)));
    }

    #[test]
    fn spill_order_from_second_node_is_symmetric() {
        // The preference is relative to the source device, not node 0.
        let t = two_node();
        let order = t.spill_order(12);
        assert!(order[..7].iter().all(|&d| t.same_node(12, d)), "{order:?}");
        assert!(order[7..].iter().all(|&d| !t.same_node(12, d)), "{order:?}");
        // stable (ascending) within each group
        assert!(order[..7].windows(2).all(|w| w[0] < w[1]));
        assert!(order[7..].windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn transfer_time_selects_bandwidth_tier_exactly() {
        let t = two_node();
        let bytes = 1u64 << 24;
        let intra = t.transfer_time(0, 1, bytes);
        let inter = t.transfer_time(0, 9, bytes);
        assert_eq!(intra, t.latency_s + bytes as f64 / t.intra_node_bw);
        assert_eq!(inter, t.latency_s + bytes as f64 / t.inter_node_bw);
        // Both directions of a link price the same.
        assert_eq!(t.transfer_time(9, 0, bytes), inter);
        assert_eq!(t.transfer_time(1, 0, bytes), intra);
    }

    #[test]
    fn single_device_topology_is_total() {
        // P=1: one node, no spill candidates, self-transfer free.
        let t = Topology::from_system(
            &SystemConfig::preset(SystemPreset::CpuSim8).with_devices(1),
        );
        assert_eq!(t.num_nodes(), 1);
        assert!(t.spill_order(0).is_empty());
        assert_eq!(t.transfer_time(0, 0, 1 << 20), 0.0);
    }

    #[test]
    fn degraded_links_slow_transfers_proportionally() {
        let t = two_node();
        let d = t.degraded(2.0);
        assert_eq!(d.intra_node_bw, t.intra_node_bw / 2.0);
        assert_eq!(d.inter_node_bw, t.inter_node_bw / 2.0);
        assert_eq!(d.latency_s, t.latency_s, "launch latency unchanged");
        let bytes = 1u64 << 24;
        assert!(d.transfer_time(0, 1, bytes) > t.transfer_time(0, 1, bytes));
        // factor <= 1 is the identity (recovery path).
        assert_eq!(t.degraded(1.0).intra_node_bw, t.intra_node_bw);
        assert_eq!(t.degraded(0.5).inter_node_bw, t.inter_node_bw);
    }

    #[test]
    fn uneven_node_count_rounds_up() {
        // Constructed directly (SystemConfig::validate would reject the
        // division): 6 devices on 4-device nodes occupy 2 nodes, and
        // node_of agrees with num_nodes.
        let t = Topology {
            devices: 6,
            devices_per_node: 4,
            latency_s: 1e-6,
            intra_node_bw: 1e9,
            inter_node_bw: 1e8,
        };
        assert_eq!(t.num_nodes(), 2);
        assert_eq!(t.node_of(5), 1);
        assert!(t.node_of(5) < t.num_nodes(), "node_of stays within num_nodes");
        assert!(!t.same_node(3, 4));
        let order = t.spill_order(4);
        assert_eq!(order[0], 5, "the one same-node peer comes first: {order:?}");
    }
}
