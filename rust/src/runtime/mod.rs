//! PJRT runtime: loads AOT-compiled HLO **text** artifacts (produced by
//! `python/compile/aot.py` from the JAX/Pallas layers) and executes them
//! on the CPU PJRT client via the `xla` crate.
//!
//! Interchange is HLO text, not serialized protos — jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md).
//!
//! Python never runs at request time: once `make artifacts` has produced
//! `artifacts/*.hlo.txt` + `manifest.json`, this module is self-contained.

mod manifest;

pub use manifest::{ArtifactEntry, Manifest};

use crate::exec::ExpertCompute;
use crate::moe::ExpertWeights;
use crate::tensor::Mat;
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A PJRT CPU runtime bound to an artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open `dir` (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let manifest = Manifest::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Default artifacts directory: `$LLEP_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("LLEP_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by manifest name (cached).
    pub fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let entry = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on f32 inputs; returns all tuple outputs as
    /// flat f32 vectors (artifacts are lowered with `return_tuple=True`).
    pub fn execute_f32(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let expected: i64 = dims.iter().product();
                anyhow::ensure!(
                    expected as usize == data.len(),
                    "input length {} != shape {:?}",
                    data.len(),
                    dims
                );
                Ok(xla::Literal::vec1(data).reshape(dims)?)
            })
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(Into::into))
            .collect()
    }

    /// How many artifacts are registered.
    pub fn len(&self) -> usize {
        self.manifest.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.manifest.entries.is_empty()
    }
}

/// [`ExpertCompute`] backend running the Pallas expert-FFN artifact.
///
/// Artifacts are shape-specialized, so token counts are padded up to the
/// nearest available bucket; padded rows multiply into padded outputs
/// that are sliced away (gates are applied downstream, so padding rows
/// never contaminate results).
pub struct PjrtCompute<'rt> {
    rt: &'rt Runtime,
    /// Sorted (bucket, artifact-name) pairs for the expert FFN.
    buckets: Vec<(usize, String)>,
}

impl<'rt> PjrtCompute<'rt> {
    /// Collect `expert_ffn_b{N}` artifacts from the manifest.
    pub fn new(rt: &'rt Runtime) -> Result<PjrtCompute<'rt>> {
        let mut buckets: Vec<(usize, String)> = rt
            .manifest
            .entries
            .iter()
            .filter_map(|(name, e)| {
                name.strip_prefix("expert_ffn_b")
                    .and_then(|b| b.parse::<usize>().ok())
                    .map(|b| {
                        let _ = e;
                        (b, name.clone())
                    })
            })
            .collect();
        buckets.sort();
        anyhow::ensure!(!buckets.is_empty(), "no expert_ffn_b* artifacts in manifest");
        Ok(PjrtCompute { rt, buckets })
    }

    fn bucket_for(&self, rows: usize) -> &(usize, String) {
        self.buckets
            .iter()
            .find(|(b, _)| *b >= rows)
            .unwrap_or_else(|| self.buckets.last().unwrap())
    }

    /// The FFN for arbitrary row counts: split into bucket-sized pieces.
    fn ffn_result(&self, x: &Mat, w: &ExpertWeights) -> Result<Mat> {
        let d = x.cols;
        let h = w.w_gate.cols;
        let mut out = Mat::zeros(x.rows, d);
        let mut row = 0usize;
        while row < x.rows {
            let (bucket, name) = self.bucket_for(x.rows - row);
            let take = (*bucket).min(x.rows - row);
            // pad chunk to bucket rows
            let mut chunk = vec![0f32; bucket * d];
            for r in 0..take {
                chunk[r * d..(r + 1) * d].copy_from_slice(x.row(row + r));
            }
            let outputs = self.rt.execute_f32(
                name,
                &[
                    (&chunk, &[*bucket as i64, d as i64]),
                    (&w.w_gate.data, &[d as i64, h as i64]),
                    (&w.w_up.data, &[d as i64, h as i64]),
                    (&w.w_down.data, &[h as i64, d as i64]),
                ],
            )?;
            let y = &outputs[0];
            anyhow::ensure!(y.len() == bucket * d, "unexpected output size");
            for r in 0..take {
                out.row_mut(row + r).copy_from_slice(&y[r * d..(r + 1) * d]);
            }
            row += take;
        }
        Ok(out)
    }
}

impl ExpertCompute for PjrtCompute<'_> {
    fn ffn(&self, x: &Mat, w: &ExpertWeights) -> Mat {
        self.ffn_result(x, w).expect("PJRT expert FFN failed")
    }
    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/pjrt_integration.rs so they
    // can be skipped cleanly when artifacts have not been built.
    use super::*;

    #[test]
    fn default_dir_env_override() {
        std::env::set_var("LLEP_ARTIFACTS", "/tmp/llep_artifacts_test");
        assert_eq!(Runtime::default_dir(), PathBuf::from("/tmp/llep_artifacts_test"));
        std::env::remove_var("LLEP_ARTIFACTS");
        assert_eq!(Runtime::default_dir(), PathBuf::from("artifacts"));
    }

    #[test]
    fn open_missing_dir_fails_helpfully() {
        let err = match Runtime::open(Path::new("/nonexistent/llep")) {
            Err(e) => e,
            Ok(_) => panic!("expected failure"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
