//! Artifact manifest (`artifacts/manifest.json`), written by
//! `python/compile/aot.py` and read by [`super::Runtime`].

use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// One AOT artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    /// HLO text file, relative to the artifacts directory.
    pub file: String,
    /// Input shapes (row-major dims), for documentation/validation.
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes.
    pub outputs: Vec<Vec<usize>>,
    /// Free-form metadata (e.g. d_model, d_ff, bucket).
    pub meta: BTreeMap<String, f64>,
}

/// The full manifest.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Manifest {
    pub entries: BTreeMap<String, ArtifactEntry>,
}

fn shapes_of(v: Option<&Json>) -> Result<Vec<Vec<usize>>, String> {
    let Some(arr) = v.and_then(Json::as_arr) else {
        return Ok(Vec::new());
    };
    arr.iter()
        .map(|shape| {
            shape
                .as_arr()
                .ok_or_else(|| "shape must be an array".to_string())
                .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let v = json::parse(text)?;
        let Json::Obj(map) = &v else {
            return Err("manifest root must be an object".into());
        };
        let artifacts = map
            .get("artifacts")
            .ok_or("manifest missing \"artifacts\"")?;
        let Json::Obj(arts) = artifacts else {
            return Err("\"artifacts\" must be an object".into());
        };
        let mut entries = BTreeMap::new();
        for (name, e) in arts {
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("artifact {name}: missing file"))?
                .to_string();
            let inputs = shapes_of(e.get("inputs"))?;
            let outputs = shapes_of(e.get("outputs"))?;
            let mut meta = BTreeMap::new();
            if let Some(Json::Obj(m)) = e.get("meta") {
                for (k, val) in m {
                    if let Some(x) = val.as_f64() {
                        meta.insert(k.clone(), x);
                    }
                }
            }
            entries.insert(name.clone(), ArtifactEntry { file, inputs, outputs, meta });
        }
        Ok(Manifest { entries })
    }

    pub fn meta_usize(&self, artifact: &str, key: &str) -> Option<usize> {
        self.entries.get(artifact)?.meta.get(key).map(|&x| x as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "expert_ffn_b64": {
          "file": "expert_ffn_b64.hlo.txt",
          "inputs": [[64, 32], [32, 48], [32, 48], [48, 32]],
          "outputs": [[64, 32]],
          "meta": {"bucket": 64, "d_model": 32, "d_ff": 48}
        },
        "train_step": {
          "file": "train_step.hlo.txt",
          "inputs": [],
          "outputs": [[1]],
          "meta": {"batch": 8}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = &m.entries["expert_ffn_b64"];
        assert_eq!(e.file, "expert_ffn_b64.hlo.txt");
        assert_eq!(e.inputs[0], vec![64, 32]);
        assert_eq!(e.outputs[0], vec![64, 32]);
        assert_eq!(m.meta_usize("expert_ffn_b64", "bucket"), Some(64));
        assert_eq!(m.meta_usize("train_step", "batch"), Some(8));
        assert_eq!(m.meta_usize("train_step", "missing"), None);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("[]").is_err());
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts": {"x": {}}}"#).is_err());
    }
}
