//! Stateful expert re-layout & migration across steps.
//!
//! LLEP reroutes *excess tokens* per step, which means a persistently hot
//! expert's spill transfers are re-bought every step. The related-work
//! line (LAER-MoE; EPLB's replica movement) amortizes that cost by
//! adapting the expert *layout* to observed load instead. This subsystem
//! implements the stateful hybrid: a [`PlacementManager`] owns a mutable
//! [`ExpertMap`] across steps, tracks per-expert load with an EMA fed
//! from the routing statistics every plan call sees, and between steps
//! decides migrate / replicate (warm standby) / evict actions for hot
//! experts against a weight-transfer budget amortized over a predicted
//! horizon:
//!
//! > move iff `expected_imbalance_savings x horizon > migration_cost`
//!
//! where the savings proxy is the per-step spill transfer a token-level
//! planner keeps re-buying while the layout stays wrong, and both sides
//! are priced through the same [`Topology`] P2P path the engine's
//! `CommCostModel` charges (migrations from a dead device take the
//! host-checkpoint path, exactly like stranded spill transfers).
//!
//! The whole thing is surfaced as the registry decorator
//! `placed(<inner>):ema=,budget=,horizon=,standby=` ([`Placed`]): any
//! planner — EP, LLEP, EPLB — plans *against the current layout*. The
//! decorator relabels loads into layout space, runs the inner planner,
//! relabels the plan back (in place, allocation-free), and attaches the
//! step's migration transfers to [`RoutePlan::migrations`]; the engine
//! charges those into step latency unconditionally, even for planners
//! whose spill transfers are amortized away.
//!
//! Chaos interaction: migration targets are restricted to alive devices
//! at no less than half the fastest alive speed (never migrate onto dead
//! or badly slowed devices), and a warm standby of a hot expert turns a
//! device failure into a free *promotion* — the standby device already
//! holds the weights, so no stranded transfers and no forced-fresh plans
//! — instead of the per-step host-checkpoint recovery EPLB-style static
//! layouts are stuck with.
//!
//! [`RoutePlan::migrations`]: crate::planner::RoutePlan::migrations
//! [`Topology`]: crate::topology::Topology

mod decorator;
mod manager;

pub use decorator::Placed;
pub use manager::PlacementManager;

use crate::planner::{RoutePlan, WeightTransfer};

/// Hyperparameters of the placement layer (the `placed(...)` spec knobs
/// plus fixed internals).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlacementConfig {
    /// EMA weight of the newest load observation, in `(0, 1]`. Higher
    /// adapts faster; lower smooths per-batch noise.
    pub ema: f64,
    /// Maximum paid expert weight moves per plan call (a swap costs two:
    /// the hot expert in, the displaced cold expert out).
    pub budget: usize,
    /// Predicted number of steps the new layout persists — the
    /// amortization window of the decision rule. `horizon <= 2`
    /// effectively disables paid migration (a swap's two legs can never
    /// amortize).
    pub horizon: f64,
    /// Warm-standby replicas kept for this many of the hottest experts
    /// (0 = none). A standby turns the owner device's death into a free
    /// promotion instead of per-step host-checkpoint recovery.
    pub standby: usize,
    /// Hysteresis: only re-layout while the EMA native imbalance
    /// (max/mean device share) exceeds `1 + margin`.
    pub margin: f64,
    /// Expert weight bytes used by the *decision rule* only. The charged
    /// price always uses the engine's real model bytes; the decision is
    /// insensitive to the absolute value because it appears on both
    /// sides of the inequality (savings and cost are both one weight
    /// transfer), so a nominal constant keeps planning engine-free.
    pub nominal_weight_bytes: u64,
}

impl Default for PlacementConfig {
    fn default() -> PlacementConfig {
        PlacementConfig {
            ema: 0.25,
            budget: 4,
            horizon: 32.0,
            standby: 0,
            margin: 0.15,
            nominal_weight_bytes: 64 << 20,
        }
    }
}

/// Placement activity of one plan call (step/layer), absorbed upward
/// into model / serve / fleet reports.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PlacementStats {
    /// Decision rounds that changed the layout (at most 1 per plan call).
    pub relayouts: u64,
    /// Paid expert weight moves: migration legs plus standby placements.
    pub migrations: u64,
    /// Cold experts displaced to make room for an incoming hot expert.
    pub evictions: u64,
    /// Free failovers: a dead device's hot expert flipped onto its warm
    /// standby (weights already resident — no transfer charged).
    pub standby_promotions: u64,
    /// Bytes moved by the paid migrations (filled in by pricing, which
    /// knows the real per-expert weight size).
    pub migration_bytes: u64,
    /// Wall time charged into step latency for those moves (pricing).
    pub migration_s: f64,
}

impl PlacementStats {
    /// Accumulate another report's placement activity into this one.
    pub fn absorb(&mut self, other: &PlacementStats) {
        self.relayouts += other.relayouts;
        self.migrations += other.migrations;
        self.evictions += other.evictions;
        self.standby_promotions += other.standby_promotions;
        self.migration_bytes += other.migration_bytes;
        self.migration_s += other.migration_s;
    }

    /// True when any placement action was recorded.
    pub fn any(&self) -> bool {
        self.relayouts + self.migrations + self.evictions + self.standby_promotions > 0
    }
}

/// A mutable expert layout: a bijective relabeling of experts onto slots
/// (device of expert `e` = `slot_of[e] / M`, the block rule in slot
/// space) plus the warm-standby table. The dynamic counterpart of the
/// static [`crate::planner::Placement`]; kept separate because it must
/// mutate in place across steps and relabel plans without allocating.
#[derive(Clone, Debug, PartialEq)]
pub struct ExpertMap {
    /// `slot_of[e]` = slot of expert `e`; device is `slot_of[e] / M`.
    slot_of: Vec<usize>,
    /// Inverse: `expert_at[slot]` = expert occupying that slot.
    expert_at: Vec<usize>,
    /// Per expert: device holding a warm standby copy, if any.
    standby_of: Vec<Option<usize>>,
    devices: usize,
}

impl ExpertMap {
    /// The block-native layout (generation 0 of every manager).
    pub fn identity(num_experts: usize, devices: usize) -> ExpertMap {
        assert!(devices > 0 && num_experts % devices == 0, "N must divide P");
        ExpertMap {
            slot_of: (0..num_experts).collect(),
            expert_at: (0..num_experts).collect(),
            standby_of: vec![None; num_experts],
            devices,
        }
    }

    pub fn num_experts(&self) -> usize {
        self.slot_of.len()
    }

    pub fn devices(&self) -> usize {
        self.devices
    }

    fn experts_per_device(&self) -> usize {
        self.slot_of.len() / self.devices
    }

    /// Device currently owning expert `e`'s weights.
    pub fn device_of(&self, e: usize) -> usize {
        self.slot_of[e] / self.experts_per_device()
    }

    /// Expert ids resident on `device`, in slot order.
    pub fn experts_on(&self, device: usize) -> &[usize] {
        let m = self.experts_per_device();
        &self.expert_at[device * m..(device + 1) * m]
    }

    /// Warm-standby device of expert `e`, if one is kept.
    pub fn standby_of(&self, e: usize) -> Option<usize> {
        self.standby_of[e]
    }

    pub fn set_standby(&mut self, e: usize, device: Option<usize>) {
        self.standby_of[e] = device;
    }

    /// Exchange the slots (and therefore devices) of two experts —
    /// preserves the equal-fill invariant by construction.
    pub fn swap_experts(&mut self, a: usize, b: usize) {
        let (sa, sb) = (self.slot_of[a], self.slot_of[b]);
        self.slot_of.swap(a, b);
        self.expert_at[sa] = b;
        self.expert_at[sb] = a;
    }

    /// True when the map is the block-native layout.
    pub fn is_identity(&self) -> bool {
        self.slot_of.iter().enumerate().all(|(e, &s)| e == s)
    }

    /// Relabel per-expert values into layout (slot) space, reusing `out`.
    pub fn permute_into(&self, values: &[u64], out: &mut Vec<u64>) {
        out.clear();
        out.resize(values.len(), 0);
        for (e, &slot) in self.slot_of.iter().enumerate() {
            out[slot] = values[e];
        }
    }

    /// Map a plan computed in slot space back to real expert ids, in
    /// place and allocation-free: assignment rows move along permutation
    /// cycles (`visited` is a reusable mark buffer), transfer expert ids
    /// remap through the inverse table, and transfers are re-sorted into
    /// canonical order (relabeling can break it).
    pub fn unpermute_plan_in_place(&self, plan: &mut RoutePlan, visited: &mut Vec<bool>) {
        let n = self.slot_of.len();
        debug_assert_eq!(plan.assignments.len(), n);
        visited.clear();
        visited.resize(n, false);
        // Row `e` must end up holding the row planned for slot_of[e].
        for start in 0..n {
            if visited[start] || self.slot_of[start] == start {
                visited[start] = true;
                continue;
            }
            let saved = std::mem::take(&mut plan.assignments[start]);
            let mut pos = start;
            loop {
                visited[pos] = true;
                let src = self.slot_of[pos];
                if src == start {
                    plan.assignments[pos] = saved;
                    break;
                }
                plan.assignments[pos] = std::mem::take(&mut plan.assignments[src]);
                pos = src;
            }
        }
        for t in &mut plan.transfers {
            t.expert = self.expert_at[t.expert];
        }
        plan.canonicalize_transfers();
        for t in &mut plan.migrations {
            t.expert = self.expert_at[t.expert];
        }
    }
}

/// Like [`crate::planner::validate::validate_plan`] but for plans built
/// against an explicit layout: weight transfers must originate from the
/// expert's *current owner* (`home[e]`) instead of the block-native
/// device. With the identity home map this is exactly the standard
/// validator contract.
pub fn validate_plan_on_layout(
    plan: &RoutePlan,
    loads: &[u64],
    home: &[usize],
) -> Result<(), String> {
    if home.len() != plan.num_experts || loads.len() != plan.num_experts {
        return Err("home/loads/plan expert count mismatch".into());
    }
    // Coverage + segment invariants are layout-independent: check them by
    // relabeling nothing and comparing transfers against `home` directly.
    for (e, segs) in plan.assignments.iter().enumerate() {
        let mut cursor = 0u64;
        for s in segs {
            if s.device >= plan.devices {
                return Err(format!("expert {e}: device {} out of range", s.device));
            }
            if s.start != cursor || s.end <= s.start {
                return Err(format!("expert {e}: bad segment {s:?} at cursor {cursor}"));
            }
            cursor = s.end;
        }
        if cursor != loads[e] {
            return Err(format!("expert {e}: covers {cursor} of {} tokens", loads[e]));
        }
    }
    let mut want: Vec<WeightTransfer> = Vec::new();
    for (e, segs) in plan.assignments.iter().enumerate() {
        let mut seen = Vec::new();
        for s in segs {
            if s.device != home[e] && !seen.contains(&s.device) {
                seen.push(s.device);
                want.push(WeightTransfer { expert: e, from: home[e], to: s.device });
            }
        }
    }
    let mut have = plan.transfers.clone();
    have.sort_by_key(|t| (t.expert, t.from, t.to));
    want.sort_by_key(|t| (t.expert, t.from, t.to));
    if have != want {
        return Err(format!("transfer mismatch on layout:\n  plan: {have:?}\n  need: {want:?}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{plan_ep, PlannerKind};

    #[test]
    fn identity_map_is_noop() {
        let map = ExpertMap::identity(8, 4);
        assert!(map.is_identity());
        assert_eq!(map.device_of(5), 2);
        assert_eq!(map.experts_on(1), &[2, 3]);
        let mut out = Vec::new();
        map.permute_into(&[5, 4, 3, 2, 1, 0, 7, 6], &mut out);
        assert_eq!(out, vec![5, 4, 3, 2, 1, 0, 7, 6]);
    }

    #[test]
    fn swap_updates_both_directions() {
        let mut map = ExpertMap::identity(8, 4);
        map.swap_experts(0, 7); // expert 0 -> device 3, expert 7 -> device 0
        assert_eq!(map.device_of(0), 3);
        assert_eq!(map.device_of(7), 0);
        assert_eq!(map.experts_on(0), &[7, 1]);
        assert_eq!(map.experts_on(3), &[6, 0]);
        assert!(!map.is_identity());
    }

    #[test]
    fn unpermute_round_trips_a_planned_step() {
        let mut map = ExpertMap::identity(8, 4);
        map.swap_experts(0, 6);
        map.swap_experts(3, 4);
        let loads = vec![70u64, 13, 2, 9, 4, 4, 8, 3];
        let mut permuted = Vec::new();
        map.permute_into(&loads, &mut permuted);
        let mut plan = plan_ep(8, 4, &permuted);
        let mut visited = Vec::new();
        map.unpermute_plan_in_place(&mut plan, &mut visited);
        let home: Vec<usize> = (0..8).map(|e| map.device_of(e)).collect();
        validate_plan_on_layout(&plan, &loads, &home).unwrap();
        for (e, segs) in plan.assignments.iter().enumerate() {
            let covered: u64 = segs.iter().map(|s| s.len()).sum();
            assert_eq!(covered, loads[e], "expert {e}");
            for s in segs {
                assert_eq!(s.device, map.device_of(e));
            }
        }
    }

    #[test]
    fn unpermute_remaps_spill_transfers_to_current_owner() {
        let mut map = ExpertMap::identity(8, 2);
        map.swap_experts(0, 5); // hot expert 0 now lives on device 1
        let loads = vec![100_000u64, 10, 10, 10, 10, 10, 10, 10];
        let mut permuted = Vec::new();
        map.permute_into(&loads, &mut permuted);
        let mut plan = PlannerKind::llep_default().plan(2, &permuted, None);
        let mut visited = Vec::new();
        map.unpermute_plan_in_place(&mut plan, &mut visited);
        assert!(plan.transfers_canonical());
        let home: Vec<usize> = (0..8).map(|e| map.device_of(e)).collect();
        validate_plan_on_layout(&plan, &loads, &home).unwrap();
        // The spilled hot expert's transfer originates from its *new* home.
        for t in &plan.transfers {
            assert_eq!(t.from, map.device_of(t.expert), "{t:?}");
        }
    }

    #[test]
    fn stats_absorb_sums_every_counter() {
        let mut a = PlacementStats {
            relayouts: 1,
            migrations: 2,
            evictions: 1,
            standby_promotions: 0,
            migration_bytes: 128,
            migration_s: 0.5,
        };
        let b = PlacementStats {
            relayouts: 0,
            migrations: 1,
            evictions: 0,
            standby_promotions: 3,
            migration_bytes: 64,
            migration_s: 0.25,
        };
        a.absorb(&b);
        assert_eq!(a.migrations, 3);
        assert_eq!(a.standby_promotions, 3);
        assert_eq!(a.migration_bytes, 192);
        assert!((a.migration_s - 0.75).abs() < 1e-12);
        assert!(a.any());
        assert!(!PlacementStats::default().any());
    }
}
