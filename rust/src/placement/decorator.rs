//! [`Placed`]: the `placed(<inner>):ema=,budget=,horizon=,standby=`
//! registry decorator. Wraps any planner so it plans *against the
//! current layout* owned by a shared [`PlacementManager`].

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::{PlacementConfig, PlacementManager, PlacementStats};
use crate::chaos::PoolState;
use crate::planner::{CacheOutcome, Planner, RepairParams, RoutePlan};
use crate::topology::Topology;

static NEXT_PLACED_ID: AtomicUsize = AtomicUsize::new(1);

thread_local! {
    /// Per-thread (placed id -> last round stats) table, mirroring the
    /// plan cache's last-outcome idiom: the engine prices the plan on
    /// the thread that requested it, so the hook stays lock-free.
    static LAST_STATS: RefCell<Vec<(usize, PlacementStats)>> = const { RefCell::new(Vec::new()) };
}

/// A planner decorator owning persistent placement state: every plan
/// call first runs the placement decision round (EMA update, standby
/// promotion, amortized migration, standby refresh), then lets the
/// inner planner plan in layout space, and finally relabels the plan
/// back and attaches the round's migration transfers to
/// [`RoutePlan::migrations`].
///
/// Stateful: `replay_safe()` is false — the engine times a single plan
/// call and multi-layer runners plan layers sequentially in depth order,
/// so the observation sequence (and therefore the layout evolution) is a
/// deterministic function of (spec, scenario, seed).
pub struct Placed {
    inner: Box<dyn Planner>,
    cfg: PlacementConfig,
    id: usize,
    mgr: Mutex<PlacementManager>,
}

impl Placed {
    pub fn new(inner: Box<dyn Planner>) -> Placed {
        Placed::with_config(inner, PlacementConfig::default())
    }

    pub fn with_config(inner: Box<dyn Planner>, cfg: PlacementConfig) -> Placed {
        Placed {
            inner,
            cfg,
            id: NEXT_PLACED_ID.fetch_add(1, Ordering::Relaxed),
            mgr: Mutex::new(PlacementManager::new(cfg)),
        }
    }

    pub fn config(&self) -> PlacementConfig {
        self.cfg
    }

    fn record(&self, stats: PlacementStats) {
        LAST_STATS.with(|slot| {
            let mut v = slot.borrow_mut();
            match v.iter_mut().find(|(id, _)| *id == self.id) {
                Some(entry) => entry.1 = stats,
                None => v.push((self.id, stats)),
            }
        });
    }
}

impl Planner for Placed {
    fn plan_with_stats(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
    ) -> RoutePlan {
        self.plan_with_pool(devices, loads, stats, topo, None)
    }

    fn plan_with_pool(
        &self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
        pool: Option<&PoolState>,
    ) -> RoutePlan {
        let mut mgr = self.mgr.lock().expect("placement state mutex");
        let gi = mgr.begin_round(devices, loads, stats, topo, pool);
        let mut plan = {
            let (pl, ps) = mgr.layout_inputs();
            self.inner.plan_with_pool(devices, pl, ps, topo, pool)
        };
        mgr.finish_round(gi, &mut plan);
        let round = mgr.round_stats();
        drop(mgr);
        self.record(round);
        plan
    }

    fn label(&self) -> String {
        format!("Placed[{}]", self.inner.label())
    }

    fn spec(&self) -> String {
        format!(
            "placed({}):ema={},budget={},horizon={},standby={}",
            self.inner.spec(),
            self.cfg.ema,
            self.cfg.budget,
            self.cfg.horizon,
            self.cfg.standby
        )
    }

    fn chunk_tokens(&self) -> Option<u64> {
        self.inner.chunk_tokens()
    }

    fn charges_weight_transfers(&self) -> bool {
        self.inner.charges_weight_transfers()
    }

    fn wants_stale_stats(&self) -> bool {
        self.inner.wants_stale_stats()
    }

    /// Stateful: every plan call mutates the EMA (and possibly the
    /// layout), so it must be observed exactly once.
    fn replay_safe(&self) -> bool {
        false
    }

    fn last_cache_outcome(&self) -> Option<CacheOutcome> {
        self.inner.last_cache_outcome()
    }

    fn last_repair_peeled(&self) -> u64 {
        self.inner.last_repair_peeled()
    }

    /// Deliberately `None`: the cache's delta-repair tier re-spills
    /// against the block-native capacity model (`native(e) = e / M`),
    /// which is exactly the assumption a re-layout breaks. A cache
    /// wrapped around `placed(...)` therefore only hits or replans —
    /// never repairs across an evolved layout.
    fn repair_params(&self) -> Option<RepairParams> {
        None
    }

    fn layout_generation(&self) -> u64 {
        self.mgr.lock().expect("placement state mutex").generation()
    }

    fn last_placement_stats(&self) -> Option<PlacementStats> {
        LAST_STATS.with(|slot| {
            slot.borrow().iter().find(|(id, _)| *id == self.id).map(|(_, s)| *s)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::validate_plan_on_layout;
    use crate::planner::{Llep, PlannerKind};

    fn hot_loads() -> Vec<u64> {
        let mut loads = vec![100u64; 16];
        for l in loads.iter_mut().take(4) {
            *l = 4_000;
        }
        loads
    }

    #[test]
    fn placed_llep_plans_against_the_evolved_layout() {
        let p = Placed::with_config(
            Box::new(Llep::new(crate::config::LlepConfig::default())),
            PlacementConfig { budget: 8, ..PlacementConfig::default() },
        );
        let loads = hot_loads();
        let gen0 = p.layout_generation();
        let first = p.plan(4, &loads, None);
        assert!(!first.migrations.is_empty(), "colliding hotspot must trigger migration");
        assert!(p.layout_generation() > gen0);
        let stats = p.last_placement_stats().expect("stats recorded");
        assert!(stats.migrations > 0 && stats.relayouts == 1);

        // Steady state: the layout absorbed the hotspot, so LLEP no
        // longer needs per-step spill transfers.
        let mut settled = first;
        for _ in 0..6 {
            settled = p.plan(4, &loads, None);
        }
        assert!(settled.migrations.is_empty(), "layout settled: no further migration");
        assert!(
            settled.transfers.len() < 2,
            "re-layout should absorb the spills: {:?}",
            settled.transfers
        );
    }

    #[test]
    fn plans_validate_against_the_current_layout() {
        let p = Placed::new(PlannerKind::llep_default().boxed());
        let loads = hot_loads();
        for _ in 0..5 {
            let plan = p.plan(4, &loads, None);
            let mgr = p.mgr.lock().unwrap();
            let home: Vec<usize> = (0..16).map(|e| mgr.group_map(0).device_of(e)).collect();
            drop(mgr);
            validate_plan_on_layout(&plan, &loads, &home).unwrap();
        }
    }

    #[test]
    fn settled_placement_rounds_allocate_nothing() {
        // The steady-state contract: once the layout has absorbed the
        // hotspot and no migration fires, a full plan round (EMA update,
        // decision scan, permute, inner plan, unpermute) touches only the
        // manager's held buffers and the planner scratch arena.
        let p = Placed::with_config(
            PlannerKind::llep_default().boxed(),
            PlacementConfig { budget: 8, ..PlacementConfig::default() },
        );
        let loads = hot_loads();
        let mut last = None;
        for _ in 0..8 {
            let plan = p.plan(4, &loads, None);
            last = Some(plan.migrations.len());
            crate::planner::recycle_plan(plan);
        }
        assert_eq!(last, Some(0), "layout must settle before measuring");
        let before = crate::util::alloc_count::allocations_on_this_thread();
        for _ in 0..16 {
            let plan = p.plan(4, &loads, None);
            crate::planner::recycle_plan(plan);
        }
        let after = crate::util::alloc_count::allocations_on_this_thread();
        assert_eq!(after - before, 0, "settled rounds must not allocate");
    }

    #[test]
    fn spec_round_trip_shape() {
        let p = Placed::with_config(
            PlannerKind::llep_default().boxed(),
            PlacementConfig {
                ema: 0.5,
                budget: 2,
                horizon: 16.0,
                standby: 1,
                ..PlacementConfig::default()
            },
        );
        assert_eq!(
            p.spec(),
            format!("placed({}):ema=0.5,budget=2,horizon=16,standby=1", p.inner.spec())
        );
        assert!(p.label().starts_with("Placed[LLEP"));
        assert!(!p.replay_safe());
    }
}
