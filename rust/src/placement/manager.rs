//! The stateful placement engine: EMA load tracking, the amortized
//! migrate/replicate/evict decision rule, and the in-place plan
//! relabeling machinery. See the module docs in [`super`] for the model.

use super::{ExpertMap, PlacementConfig, PlacementStats};
use crate::chaos::PoolState;
use crate::planner::{RoutePlan, WeightTransfer};
use crate::topology::Topology;

/// Layout groups kept before the least-recently-used one is dropped. A
/// group forms per distinct load-signature regime — in practice one per
/// MoE layer (depth-varying hotspots) plus one per drift epoch.
const GROUP_CAP: usize = 64;

/// Maximum L1 share distance for an observation to join an existing
/// group (total share mass is 1, so 2.0 is the theoretical maximum).
/// New regimes beyond this inherit the most recent layout and track
/// their own EMA from scratch.
const GROUP_MATCH: f64 = 0.6;

/// Migration targets must run at no less than this fraction of the
/// fastest alive device — never migrate onto dead or badly slowed
/// devices (the chaos contract).
const TARGET_SPEED_FLOOR: f64 = 0.5;

/// One load-signature regime: its EMA of per-expert shares and the
/// expert layout evolved for it.
#[derive(Clone, Debug)]
struct Group {
    ema: Vec<f64>,
    map: ExpertMap,
    last_used: u64,
}

/// Owns the mutable expert layout across steps. All decision state and
/// working buffers live here, so a warmed manager performs no heap
/// allocation on rounds where no placement action fires.
///
/// Every decision is a deterministic function of the observation
/// sequence (index-ordered scans, sequential float accumulation), so
/// placement state evolves bit-reproducibly from (spec, scenario, seed).
#[derive(Debug)]
pub struct PlacementManager {
    cfg: PlacementConfig,
    groups: Vec<Group>,
    generation: u64,
    clock: u64,
    // Reusable buffers (steady state allocates nothing).
    shares: Vec<f64>,
    dev_share: Vec<f64>,
    permuted_loads: Vec<u64>,
    permuted_stats: Vec<u64>,
    visited: Vec<bool>,
    moves: Vec<WeightTransfer>,
    topk: Vec<usize>,
    round: PlacementStats,
}

impl PlacementManager {
    pub fn new(cfg: PlacementConfig) -> PlacementManager {
        PlacementManager {
            cfg,
            groups: Vec::new(),
            generation: 0,
            clock: 0,
            shares: Vec::new(),
            dev_share: Vec::new(),
            permuted_loads: Vec::new(),
            permuted_stats: Vec::new(),
            visited: Vec::new(),
            moves: Vec::new(),
            topk: Vec::new(),
            round: PlacementStats::default(),
        }
    }

    /// Monotone layout-generation counter: bumps whenever any group's
    /// primary layout changes (migration or standby promotion). The plan
    /// cache keys entries on it so re-layouts invalidate stale plans.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Placement activity of the most recent round.
    pub fn round_stats(&self) -> PlacementStats {
        self.round
    }

    /// The layout-space load/stat views produced by the most recent
    /// [`begin_round`](Self::begin_round).
    pub fn layout_inputs(&self) -> (&[u64], &[u64]) {
        (&self.permuted_loads, &self.permuted_stats)
    }

    /// The layout a group currently plans against (test/debug view).
    pub fn group_map(&self, gi: usize) -> &ExpertMap {
        &self.groups[gi].map
    }

    /// Observe one step's statistics and run the between-steps decision
    /// round: match the load regime to a group, update its EMA, promote
    /// standbys of experts stranded on dead devices, perform paid
    /// migration swaps under the budget/horizon rule, and refresh warm
    /// standbys. Fills the layout-space input buffers for the inner
    /// planner and returns the group index for
    /// [`finish_round`](Self::finish_round).
    pub fn begin_round(
        &mut self,
        devices: usize,
        loads: &[u64],
        stats: &[u64],
        topo: Option<&Topology>,
        pool: Option<&PoolState>,
    ) -> usize {
        self.clock += 1;
        self.round = PlacementStats::default();
        self.moves.clear();

        let n = stats.len();
        self.shares.clear();
        self.shares.resize(n, 0.0);
        let total: u64 = stats.iter().sum();
        if total > 0 {
            let inv = 1.0 / total as f64;
            for (s, &l) in self.shares.iter_mut().zip(stats) {
                *s = l as f64 * inv;
            }
        }

        let gi = self.match_group(devices, n);
        self.groups[gi].last_used = self.clock;

        if total > 0 && devices > 1 {
            let a = self.cfg.ema.clamp(1e-6, 1.0);
            let g = &mut self.groups[gi];
            for (m, &s) in g.ema.iter_mut().zip(&self.shares) {
                *m += a * (s - *m);
            }
            let moved = self.promote_standbys(gi, pool);
            let migrated = self.migrate(gi, topo, pool);
            self.refresh_standbys(gi, topo, pool);
            if moved || migrated {
                self.generation += 1;
                self.round.relayouts += 1;
            }
        }

        let g = &self.groups[gi];
        g.map.permute_into(loads, &mut self.permuted_loads);
        g.map.permute_into(stats, &mut self.permuted_stats);
        gi
    }

    /// Relabel the inner planner's slot-space plan back to real expert
    /// ids (in place) and attach this round's migration transfers in
    /// canonical order.
    pub fn finish_round(&mut self, gi: usize, plan: &mut RoutePlan) {
        self.groups[gi].map.unpermute_plan_in_place(plan, &mut self.visited);
        if !self.moves.is_empty() {
            self.moves.sort_unstable_by_key(|t| (t.to, t.from, t.expert));
            plan.migrations.extend_from_slice(&self.moves);
        }
    }

    /// Nearest group by L1 share distance, or a freshly spawned one that
    /// inherits the most recently used same-shape layout (placement is a
    /// property of the physical pool; a new traffic regime starts from
    /// the layout the previous regime evolved).
    fn match_group(&mut self, devices: usize, n: usize) -> usize {
        let mut best: Option<(usize, f64)> = None;
        for (i, g) in self.groups.iter().enumerate() {
            if g.map.devices() != devices || g.ema.len() != n {
                continue;
            }
            let dist: f64 =
                g.ema.iter().zip(&self.shares).map(|(a, b)| (a - b).abs()).sum();
            if best.is_none_or(|(_, d)| dist < d) {
                best = Some((i, dist));
            }
        }
        if let Some((i, d)) = best {
            if d <= GROUP_MATCH {
                return i;
            }
        }
        let map = self
            .groups
            .iter()
            .filter(|g| g.map.devices() == devices && g.ema.len() == n)
            .max_by_key(|g| g.last_used)
            .map(|g| g.map.clone())
            .unwrap_or_else(|| ExpertMap::identity(n, devices));
        if self.groups.len() >= GROUP_CAP {
            let oldest = self
                .groups
                .iter()
                .enumerate()
                .min_by_key(|(_, g)| g.last_used)
                .map(|(i, _)| i)
                .expect("cap > 0");
            self.groups.swap_remove(oldest);
        }
        self.groups.push(Group { ema: self.shares.clone(), map, last_used: self.clock });
        self.groups.len() - 1
    }

    /// Free failover: every expert whose owner device died and which has
    /// an alive warm standby swaps places with the coldest expert on the
    /// standby device. The weights are already resident there, so no
    /// transfer is emitted — the displaced cold expert is evicted onto
    /// the dead device and will be host-checkpointed per step by a
    /// pool-aware inner planner if it still receives tokens.
    fn promote_standbys(&mut self, gi: usize, pool: Option<&PoolState>) -> bool {
        let Some(pool) = pool else { return false };
        if pool.devices.iter().all(|d| d.alive) {
            return false;
        }
        let g = &mut self.groups[gi];
        let n = g.map.num_experts();
        let mut changed = false;
        for e in 0..n {
            let home = g.map.device_of(e);
            if pool.devices.get(home).is_none_or(|d| d.alive) {
                continue;
            }
            let Some(sb) = g.map.standby_of(e) else { continue };
            if sb == home || pool.devices.get(sb).is_some_and(|d| !d.alive) {
                g.map.set_standby(e, None);
                continue;
            }
            let victim = g
                .map
                .experts_on(sb)
                .iter()
                .copied()
                .filter(|&v| v != e)
                .min_by(|&a, &b| {
                    g.ema[a].partial_cmp(&g.ema[b]).expect("finite ema").then(a.cmp(&b))
                });
            let Some(victim) = victim else { continue };
            g.map.swap_experts(e, victim);
            g.map.set_standby(e, None);
            self.round.standby_promotions += 1;
            self.round.evictions += 1;
            changed = true;
        }
        changed
    }

    /// Paid migration: greedy hottest-device/coldest-device expert swaps
    /// under the leg budget, each gated by the amortization rule
    /// `savings_per_step x horizon > migration_cost` where the savings
    /// proxy is the one weight transfer per step a token-level planner
    /// keeps re-buying for a misplaced hot expert, and both sides price
    /// through the topology's P2P path (unit costs without a topology).
    fn migrate(
        &mut self,
        gi: usize,
        topo: Option<&Topology>,
        pool: Option<&PoolState>,
    ) -> bool {
        let g = &mut self.groups[gi];
        let n = g.map.num_experts();
        let p = g.map.devices();
        self.dev_share.clear();
        self.dev_share.resize(p, 0.0);
        for e in 0..n {
            self.dev_share[g.map.device_of(e)] += g.ema[e];
        }
        let mean = self.dev_share.iter().sum::<f64>() / p as f64;
        if mean <= 0.0 {
            return false;
        }
        let max_alive_speed = pool.map_or(1.0, |ps| {
            ps.devices
                .iter()
                .filter(|d| d.alive)
                .map(|d| d.speed)
                .fold(0.0, f64::max)
        });
        let alive = |d: usize| pool.is_none_or(|ps| ps.devices.get(d).is_none_or(|s| s.alive));
        let eligible_target = |d: usize| {
            pool.is_none_or(|ps| {
                ps.devices
                    .get(d)
                    .is_none_or(|s| s.alive && s.speed >= TARGET_SPEED_FLOOR * max_alive_speed)
            })
        };

        let mut legs = 0usize;
        let mut changed = false;
        while legs + 2 <= self.cfg.budget {
            // Hottest alive device (migrating off a dead device is the
            // standby path's job, not a paid swap that would evict a
            // victim onto dead hardware).
            let mut d_hot = usize::MAX;
            for d in 0..p {
                if alive(d) && (d_hot == usize::MAX || self.dev_share[d] > self.dev_share[d_hot]) {
                    d_hot = d;
                }
            }
            if d_hot == usize::MAX || self.dev_share[d_hot] <= mean * (1.0 + self.cfg.margin) {
                break;
            }
            let mut d_cold = usize::MAX;
            for d in 0..p {
                if d != d_hot
                    && eligible_target(d)
                    && (d_cold == usize::MAX || self.dev_share[d] < self.dev_share[d_cold])
                {
                    d_cold = d;
                }
            }
            if d_cold == usize::MAX {
                break;
            }
            let e_hot = g
                .map
                .experts_on(d_hot)
                .iter()
                .copied()
                .max_by(|&a, &b| {
                    g.ema[a].partial_cmp(&g.ema[b]).expect("finite ema").then(b.cmp(&a))
                })
                .expect("device hosts M >= 1 experts");
            let e_cold = g
                .map
                .experts_on(d_cold)
                .iter()
                .copied()
                .min_by(|&a, &b| {
                    g.ema[a].partial_cmp(&g.ema[b]).expect("finite ema").then(a.cmp(&b))
                })
                .expect("device hosts M >= 1 experts");
            let delta = g.ema[e_hot] - g.ema[e_cold];
            if delta <= 0.0 {
                break;
            }
            let new_hot = self.dev_share[d_hot] - delta;
            let new_cold = self.dev_share[d_cold] + delta;
            if new_hot.max(new_cold) >= self.dev_share[d_hot] {
                break; // the swap would not lower the hot device's share
            }
            let (save_per_step, cost) = match topo {
                Some(t) => {
                    let w = self.cfg.nominal_weight_bytes;
                    (
                        t.transfer_time(d_hot, d_cold, w),
                        t.transfer_time(d_hot, d_cold, w) + t.transfer_time(d_cold, d_hot, w),
                    )
                }
                None => (1.0, 2.0),
            };
            if save_per_step * self.cfg.horizon <= cost {
                break;
            }
            g.map.swap_experts(e_hot, e_cold);
            // A standby that now coincides with the expert's new home is
            // redundant — drop it.
            if g.map.standby_of(e_hot) == Some(d_cold) {
                g.map.set_standby(e_hot, None);
            }
            if g.map.standby_of(e_cold) == Some(d_hot) {
                g.map.set_standby(e_cold, None);
            }
            self.moves.push(WeightTransfer { expert: e_hot, from: d_hot, to: d_cold });
            self.moves.push(WeightTransfer { expert: e_cold, from: d_cold, to: d_hot });
            self.dev_share[d_hot] = new_hot;
            self.dev_share[d_cold] = new_cold;
            self.round.migrations += 2;
            self.round.evictions += 1;
            legs += 2;
            changed = true;
        }
        changed
    }

    /// Keep warm standby copies for the `standby` hottest experts on the
    /// least-loaded eligible device that is not their home. Placing or
    /// moving a standby is a paid weight transfer; standbys of experts
    /// that left the hot set are dropped for free (memory eviction).
    fn refresh_standbys(
        &mut self,
        gi: usize,
        _topo: Option<&Topology>,
        pool: Option<&PoolState>,
    ) {
        if self.cfg.standby == 0 {
            return;
        }
        let g = &mut self.groups[gi];
        let n = g.map.num_experts();
        let p = g.map.devices();
        let k = self.cfg.standby.min(n);
        // Top-k experts by EMA (desc, ties to the lowest id), via bounded
        // insertion into the reusable buffer.
        self.topk.clear();
        for e in 0..n {
            let mut i = self.topk.len();
            while i > 0 {
                let o = self.topk[i - 1];
                if g.ema[o] > g.ema[e] || (g.ema[o] == g.ema[e] && o < e) {
                    break;
                }
                i -= 1;
            }
            if i < k {
                self.topk.insert(i, e);
                self.topk.truncate(k);
            }
        }
        // dev_share reflects post-migration EMA loads (recompute: the
        // migrate pass may not have run).
        self.dev_share.clear();
        self.dev_share.resize(p, 0.0);
        for e in 0..n {
            self.dev_share[g.map.device_of(e)] += g.ema[e];
        }
        let max_alive_speed = pool.map_or(1.0, |ps| {
            ps.devices
                .iter()
                .filter(|d| d.alive)
                .map(|d| d.speed)
                .fold(0.0, f64::max)
        });
        let eligible = |d: usize| {
            pool.is_none_or(|ps| {
                ps.devices
                    .get(d)
                    .is_none_or(|s| s.alive && s.speed >= TARGET_SPEED_FLOOR * max_alive_speed)
            })
        };
        for idx in 0..self.topk.len() {
            let e = self.topk[idx];
            let home = g.map.device_of(e);
            if g.map.standby_of(e).is_some_and(|d| d != home && eligible(d)) {
                continue; // current standby is still good — no churn
            }
            let mut target = usize::MAX;
            for d in 0..p {
                if d != home
                    && eligible(d)
                    && (target == usize::MAX || self.dev_share[d] < self.dev_share[target])
                {
                    target = d;
                }
            }
            if target == usize::MAX {
                if g.map.standby_of(e).is_some() {
                    g.map.set_standby(e, None);
                }
                continue;
            }
            g.map.set_standby(e, Some(target));
            self.moves.push(WeightTransfer { expert: e, from: home, to: target });
            self.round.migrations += 1;
        }
        for e in 0..n {
            if g.map.standby_of(e).is_some() && !self.topk.contains(&e) {
                g.map.set_standby(e, None);
                self.round.evictions += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::DeviceState;

    fn mgr(cfg: PlacementConfig) -> PlacementManager {
        PlacementManager::new(cfg)
    }

    /// 16 experts on 4 devices: experts 0..4 hot and all native to
    /// device 0 under block layout — the case token-level rerouting
    /// re-buys transfers for every step but one swap round fixes.
    fn colliding_loads() -> Vec<u64> {
        let mut loads = vec![100u64; 16];
        for l in loads.iter_mut().take(4) {
            *l = 4_000;
        }
        loads
    }

    #[test]
    fn migrates_colliding_hot_experts_apart() {
        let mut m = mgr(PlacementConfig { budget: 8, ..PlacementConfig::default() });
        let loads = colliding_loads();
        for _ in 0..4 {
            let gi = m.begin_round(4, &loads, &loads, None, None);
            let mut plan = crate::planner::plan_ep(16, 4, m.layout_inputs().0);
            m.finish_round(gi, &mut plan);
        }
        let map = m.group_map(0);
        // The four hot experts must no longer collide on one device.
        let homes: Vec<usize> = (0..4).map(|e| map.device_of(e)).collect();
        let mut distinct = homes.clone();
        distinct.sort_unstable();
        distinct.dedup();
        assert!(distinct.len() >= 3, "hot experts still collide: {homes:?}");
        assert!(m.generation() > 0);
    }

    #[test]
    fn horizon_below_amortization_bound_disables_migration() {
        // A swap costs two legs; with unit costs the rule fires only when
        // horizon * 1 > 2.
        let mut m = mgr(PlacementConfig { horizon: 2.0, ..PlacementConfig::default() });
        let loads = colliding_loads();
        for _ in 0..8 {
            let gi = m.begin_round(4, &loads, &loads, None, None);
            let mut plan = crate::planner::plan_ep(16, 4, m.layout_inputs().0);
            m.finish_round(gi, &mut plan);
            assert!(plan.migrations.is_empty(), "horizon=2 must never amortize a swap");
        }
        assert_eq!(m.generation(), 0);
    }

    #[test]
    fn never_migrates_onto_dead_or_slow_devices() {
        let mut m = mgr(PlacementConfig { budget: 16, ..PlacementConfig::default() });
        let loads = colliding_loads();
        let mut pool = PoolState::healthy(4);
        pool.devices[2] = DeviceState { speed: 1.0, alive: false };
        pool.devices[3] = DeviceState { speed: 0.2, alive: true };
        for _ in 0..6 {
            let gi = m.begin_round(4, &loads, &loads, None, Some(&pool));
            let mut plan = crate::planner::plan_ep(16, 4, m.layout_inputs().0);
            m.finish_round(gi, &mut plan);
            for t in &plan.migrations {
                assert_ne!(t.to, 2, "migrated onto a dead device: {t:?}");
                assert_ne!(t.to, 3, "migrated onto a 5x straggler: {t:?}");
            }
        }
    }

    #[test]
    fn standby_promotion_is_free_and_counted() {
        let mut m = mgr(PlacementConfig { standby: 1, budget: 0, ..PlacementConfig::default() });
        let loads = colliding_loads();
        // Healthy rounds: the hottest expert gets a warm standby (a paid
        // placement transfer).
        let gi = m.begin_round(4, &loads, &loads, None, None);
        let mut plan = crate::planner::plan_ep(16, 4, m.layout_inputs().0);
        m.finish_round(gi, &mut plan);
        assert_eq!(plan.migrations.len(), 1, "standby placement is a paid transfer");
        let hot = plan.migrations[0].expert;
        let sb = plan.migrations[0].to;
        assert_eq!(m.group_map(0).standby_of(hot), Some(sb));

        // Kill the hot expert's home: promotion fires, free.
        let home = m.group_map(0).device_of(hot);
        let mut pool = PoolState::healthy(4);
        pool.devices[home] = DeviceState { speed: 1.0, alive: false };
        let gi = m.begin_round(4, &loads, &loads, None, Some(&pool));
        let mut plan = crate::planner::plan_ep(16, 4, m.layout_inputs().0);
        m.finish_round(gi, &mut plan);
        let stats = m.round_stats();
        assert_eq!(stats.standby_promotions, 1);
        assert_eq!(m.group_map(0).device_of(hot), sb, "hot expert now lives on its standby");
        assert!(
            plan.migrations.iter().all(|t| t.expert != hot),
            "promotion must not emit a transfer for the promoted expert"
        );
    }

    #[test]
    fn evolution_is_deterministic() {
        let run = || {
            let mut m = mgr(PlacementConfig { standby: 2, ..PlacementConfig::default() });
            let mut trace = Vec::new();
            for step in 0..12u64 {
                let mut loads = vec![100u64; 16];
                let hot = ((step / 4) as usize * 3) % 16;
                loads[hot] = 5_000;
                loads[(hot + 1) % 16] = 3_000;
                let gi = m.begin_round(4, &loads, &loads, None, None);
                let mut plan = crate::planner::plan_ep(16, 4, m.layout_inputs().0);
                m.finish_round(gi, &mut plan);
                trace.push((m.generation(), plan.migrations.clone()));
            }
            trace
        };
        assert_eq!(run(), run());
    }
}
