//! Routing trace record / replay.
//!
//! The paper measures real gpt-oss routing over batches of math data
//! (Fig. 3). Without the real model we record load matrices from the
//! synthetic generators (or, in principle, from any external harness via
//! the JSON format) and replay them deterministically into the engines.

use super::LoadMatrix;
use crate::util::json::{self, Json};

/// One recorded batch.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceBatch {
    pub load: LoadMatrix,
}

/// A sequence of recorded batches plus metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct RoutingTrace {
    pub name: String,
    pub num_experts: usize,
    pub top_k: usize,
    pub batches: Vec<TraceBatch>,
}

impl RoutingTrace {
    pub fn new(name: &str, num_experts: usize, top_k: usize) -> RoutingTrace {
        RoutingTrace { name: name.into(), num_experts, top_k, batches: Vec::new() }
    }

    pub fn push(&mut self, load: LoadMatrix) -> Result<(), String> {
        if load.num_experts() != self.num_experts {
            return Err(format!(
                "batch has {} experts, trace expects {}",
                load.num_experts(),
                self.num_experts
            ));
        }
        if load.top_k != self.top_k {
            return Err("top_k mismatch".into());
        }
        load.validate()?;
        self.batches.push(TraceBatch { load });
        Ok(())
    }

    /// Serialize to JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("num_experts", Json::num(self.num_experts as f64)),
            ("top_k", Json::num(self.top_k as f64)),
            (
                "batches",
                Json::arr(self.batches.iter().map(|b| {
                    Json::arr(b.load.counts.iter().map(|row| {
                        Json::arr(row.iter().map(|&c| Json::num(c as f64)))
                    }))
                })),
            ),
        ])
    }

    /// Parse from JSON text.
    pub fn from_json_text(text: &str) -> Result<RoutingTrace, String> {
        let v = json::parse(text)?;
        let name = v.get("name").and_then(Json::as_str).unwrap_or("trace").to_string();
        let num_experts =
            v.get("num_experts").and_then(Json::as_usize).ok_or("missing num_experts")?;
        let top_k = v.get("top_k").and_then(Json::as_usize).ok_or("missing top_k")?;
        let mut trace = RoutingTrace::new(&name, num_experts, top_k);
        for batch in v.get("batches").and_then(Json::as_arr).ok_or("missing batches")? {
            let counts: Vec<Vec<u64>> = batch
                .as_arr()
                .ok_or("batch must be an array")?
                .iter()
                .map(|row| {
                    row.as_arr()
                        .ok_or_else(|| "row must be an array".to_string())
                        .map(|cells| {
                            cells.iter().map(|c| c.as_f64().unwrap_or(0.0) as u64).collect()
                        })
                })
                .collect::<Result<_, String>>()?;
            trace.push(LoadMatrix { counts, top_k })?;
        }
        Ok(trace)
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }

    pub fn load(path: &std::path::Path) -> Result<RoutingTrace, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        RoutingTrace::from_json_text(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset};
    use crate::routing::Scenario;
    use crate::util::rng::Rng;

    fn sample_trace() -> RoutingTrace {
        let model = ModelConfig::preset(ModelPreset::Tiny);
        let mut rng = Rng::new(3);
        let mut t = RoutingTrace::new("unit", model.num_experts, model.top_k);
        for _ in 0..5 {
            let lm = Scenario::drifting(2, 0.3, 0.2).generate_loads(&model, 4, 128, &mut rng);
            t.push(lm).unwrap();
        }
        t
    }

    #[test]
    fn json_roundtrip() {
        let t = sample_trace();
        let text = t.to_json().to_string_pretty();
        let back = RoutingTrace::from_json_text(&text).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn file_roundtrip() {
        let t = sample_trace();
        let dir = std::env::temp_dir().join("llep_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        t.save(&path).unwrap();
        let back = RoutingTrace::load(&path).unwrap();
        assert_eq!(t, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn push_validates_shape() {
        let mut t = RoutingTrace::new("x", 8, 2);
        let bad = LoadMatrix { counts: vec![vec![1; 4]], top_k: 2 };
        assert!(t.push(bad).is_err());
        let wrong_k = LoadMatrix { counts: vec![vec![1; 8]], top_k: 4 };
        assert!(t.push(wrong_k).is_err());
    }

    #[test]
    fn rejects_malformed_json() {
        assert!(RoutingTrace::from_json_text("{}").is_err());
        assert!(RoutingTrace::from_json_text("not json").is_err());
    }
}
