//! Imbalance statistics over routings (paper §3.1, Fig. 3).

use super::LoadMatrix;

/// The paper's imbalance ratio `max(l) / mean(l)` (Alg. 4 guard).
/// Allocation-free (it runs on every LLEP planning call): same fold
/// order and arithmetic as [`crate::util::stats::max_over_mean`] over
/// the converted loads, so results are bit-identical to the historical
/// collect-based implementation.
pub fn imbalance_ratio(expert_loads: &[u64]) -> f64 {
    if expert_loads.is_empty() {
        return 0.0;
    }
    let sum: f64 = expert_loads.iter().map(|&x| x as f64).sum();
    let mean = sum / expert_loads.len() as f64;
    if mean == 0.0 {
        return 0.0;
    }
    expert_loads.iter().map(|&x| x as f64).fold(f64::MIN, f64::max) / mean
}

/// Per-device share of the global load under the block layout
/// (Fig. 3b: "GPU 0 has 30-35% vs ~12.5% balanced").
pub fn gpu_load_shares(lm: &LoadMatrix, devices: usize) -> Vec<f64> {
    let native = lm.native_device_loads(devices);
    let total: u64 = native.iter().sum();
    if total == 0 {
        return vec![0.0; devices];
    }
    native.iter().map(|&x| x as f64 / total as f64).collect()
}

/// Aggregated statistics across a sequence of batches.
#[derive(Clone, Debug, Default)]
pub struct RoutingStats {
    /// Per-expert max share across batches (Fig. 3a plots maxima).
    pub expert_max_share: Vec<f64>,
    /// Per-device max share across batches (Fig. 3b).
    pub gpu_max_share: Vec<f64>,
    /// Imbalance ratio per batch.
    pub ratios: Vec<f64>,
    batches: usize,
}

impl RoutingStats {
    pub fn new() -> RoutingStats {
        RoutingStats::default()
    }

    pub fn observe(&mut self, lm: &LoadMatrix, devices: usize) {
        let l = lm.expert_loads();
        let total: u64 = l.iter().sum();
        if self.expert_max_share.is_empty() {
            self.expert_max_share = vec![0.0; l.len()];
            self.gpu_max_share = vec![0.0; devices];
        }
        if total > 0 {
            for (e, &x) in l.iter().enumerate() {
                let share = x as f64 / total as f64;
                if share > self.expert_max_share[e] {
                    self.expert_max_share[e] = share;
                }
            }
        }
        for (p, share) in gpu_load_shares(lm, devices).into_iter().enumerate() {
            if share > self.gpu_max_share[p] {
                self.gpu_max_share[p] = share;
            }
        }
        self.ratios.push(imbalance_ratio(&l));
        self.batches += 1;
    }

    pub fn batches(&self) -> usize {
        self.batches
    }

    /// Expert index with the highest max-share (the "E11" of Fig. 3a).
    pub fn dominant_expert(&self) -> Option<usize> {
        self.expert_max_share
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
    }

    /// Device with the highest max-share (the "gpu-0" of Fig. 3b).
    pub fn dominant_device(&self) -> Option<usize> {
        self.gpu_max_share
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset};
    use crate::routing::Scenario;
    use crate::util::rng::Rng;

    #[test]
    fn ratio_balanced_is_one() {
        assert!((imbalance_ratio(&[5, 5, 5, 5]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ratio_skewed() {
        assert!((imbalance_ratio(&[8, 0, 0, 0]) - 4.0).abs() < 1e-12);
        assert_eq!(imbalance_ratio(&[]), 0.0);
        assert_eq!(imbalance_ratio(&[0, 0]), 0.0);
    }

    #[test]
    fn gpu_shares_sum_to_one() {
        let mut rng = Rng::new(1);
        let model = ModelConfig::preset(ModelPreset::Tiny);
        let lm = Scenario::concentrated(0.9, 1).generate_loads(&model, 4, 512, &mut rng);
        let shares = gpu_load_shares(&lm, 4);
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // hot experts live on device 0
        assert!(shares[0] > 0.3, "{shares:?}");
    }

    #[test]
    fn stats_track_maxima_and_dominants() {
        let model = ModelConfig::preset(ModelPreset::Tiny);
        let sc = Scenario::drifting(3, 0.35, 0.1);
        let mut rng = Rng::new(2);
        let mut st = RoutingStats::new();
        for _ in 0..20 {
            let lm = sc.generate_loads(&model, 4, 512, &mut rng);
            st.observe(&lm, 4);
        }
        assert_eq!(st.batches(), 20);
        assert_eq!(st.dominant_expert(), Some(3));
        // expert 3 is on device 1 (M = 2)
        assert_eq!(st.dominant_device(), Some(1));
        assert!(st.expert_max_share[3] > 0.2);
        assert!(st.ratios.iter().all(|&r| r >= 1.0));
    }
}
