//! Routing substrate: token→expert assignments, synthetic imbalance
//! scenarios, recorded traces, and imbalance statistics.
//!
//! Two representations coexist:
//!
//! * [`Routing`] — full token-level assignments (expert ids + gates per
//!   (token, k) slot, grouped by origin device). Used wherever numerics
//!   must be exact (the `Native`/`Pjrt` engine backends, the tests).
//! * [`LoadMatrix`] — per-(origin device, expert) token counts. This is
//!   all the planner and the cost models need, so the paper-scale
//!   benchmarks (millions of token slots) use it directly.

mod scenario;
mod stats;
mod trace;

pub use scenario::{DepthProfile, Scenario};
pub use stats::{gpu_load_shares, imbalance_ratio, RoutingStats};
pub use trace::{RoutingTrace, TraceBatch};

/// Token-level routing for one global batch.
///
/// `experts[p]` and `gates[p]` are flat `B_p * K` arrays for origin device
/// `p`, laid out token-major (slots of token `t` occupy
/// `[t*K, (t+1)*K)`). Expert ids are global (`0..N`).
#[derive(Clone, Debug, PartialEq)]
pub struct Routing {
    pub num_experts: usize,
    pub top_k: usize,
    pub experts: Vec<Vec<u32>>,
    pub gates: Vec<Vec<f32>>,
}

impl Routing {
    /// Number of origin devices.
    pub fn devices(&self) -> usize {
        self.experts.len()
    }

    /// Tokens on origin device `p`.
    pub fn tokens_on(&self, p: usize) -> usize {
        self.experts[p].len() / self.top_k
    }

    /// Total tokens across devices.
    pub fn total_tokens(&self) -> usize {
        (0..self.devices()).map(|p| self.tokens_on(p)).sum()
    }

    /// Collapse to per-(device, expert) counts.
    pub fn load_matrix(&self) -> LoadMatrix {
        let mut counts = vec![vec![0u64; self.num_experts]; self.devices()];
        for (p, ids) in self.experts.iter().enumerate() {
            for &e in ids {
                counts[p][e as usize] += 1;
            }
        }
        LoadMatrix { counts, top_k: self.top_k }
    }

    /// Validate structural invariants (ids in range, gate/expert lengths
    /// match). Duplicate experts within one token are allowed: synthetic
    /// scenarios sample slots i.i.d. (see [`Scenario`]); the engines treat
    /// slots independently so exactness is unaffected.
    pub fn validate(&self) -> Result<(), String> {
        if self.experts.len() != self.gates.len() {
            return Err("experts/gates device count mismatch".into());
        }
        for (p, (ids, gs)) in self.experts.iter().zip(&self.gates).enumerate() {
            if ids.len() != gs.len() {
                return Err(format!("device {p}: ids/gates length mismatch"));
            }
            if ids.len() % self.top_k != 0 {
                return Err(format!("device {p}: length not divisible by K"));
            }
            if let Some(&e) = ids.iter().find(|&&e| e as usize >= self.num_experts) {
                return Err(format!("device {p}: expert id {e} out of range"));
            }
        }
        Ok(())
    }
}

/// Per-(origin device, expert) token-slot counts.
#[derive(Clone, Debug, PartialEq)]
pub struct LoadMatrix {
    /// `counts[p][e]` = number of (token, slot) pairs on device `p` routed
    /// to expert `e`.
    pub counts: Vec<Vec<u64>>,
    pub top_k: usize,
}

impl LoadMatrix {
    pub fn devices(&self) -> usize {
        self.counts.len()
    }

    pub fn num_experts(&self) -> usize {
        self.counts.first().map_or(0, |c| c.len())
    }

    /// Global per-expert loads `l` (paper Alg. 2 input).
    pub fn expert_loads(&self) -> Vec<u64> {
        let n = self.num_experts();
        let mut l = vec![0u64; n];
        for row in &self.counts {
            for (e, &c) in row.iter().enumerate() {
                l[e] += c;
            }
        }
        l
    }

    /// Total token-slot assignments.
    pub fn total_load(&self) -> u64 {
        self.counts.iter().map(|r| r.iter().sum::<u64>()).sum()
    }

    /// Tokens per origin device (slots / K).
    pub fn tokens_per_device(&self) -> Vec<u64> {
        self.counts
            .iter()
            .map(|r| r.iter().sum::<u64>() / self.top_k as u64)
            .collect()
    }

    /// Load native to each device under the block expert layout
    /// (`M = N/P` consecutive experts per device).
    pub fn native_device_loads(&self, devices: usize) -> Vec<u64> {
        let n = self.num_experts();
        let m = n / devices;
        let l = self.expert_loads();
        (0..devices)
            .map(|p| l[p * m..(p + 1) * m].iter().sum())
            .collect()
    }

    pub fn validate(&self) -> Result<(), String> {
        let n = self.num_experts();
        if self.counts.iter().any(|r| r.len() != n) {
            return Err("ragged load matrix".into());
        }
        if self.top_k == 0 {
            return Err("top_k must be positive".into());
        }
        for (p, row) in self.counts.iter().enumerate() {
            let total: u64 = row.iter().sum();
            if total % self.top_k as u64 != 0 {
                return Err(format!("device {p}: slot count {total} not divisible by K"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_routing() -> Routing {
        // 2 devices, 2 tokens each, K=2, N=4.
        Routing {
            num_experts: 4,
            top_k: 2,
            experts: vec![vec![0, 1, 2, 3], vec![0, 2, 0, 1]],
            gates: vec![vec![0.5, 0.5, 0.7, 0.3], vec![0.6, 0.4, 0.9, 0.1]],
        }
    }

    #[test]
    fn routing_accessors() {
        let r = small_routing();
        r.validate().unwrap();
        assert_eq!(r.devices(), 2);
        assert_eq!(r.tokens_on(0), 2);
        assert_eq!(r.total_tokens(), 4);
    }

    #[test]
    fn load_matrix_counts() {
        let lm = small_routing().load_matrix();
        assert_eq!(lm.counts[0], vec![1, 1, 1, 1]);
        assert_eq!(lm.counts[1], vec![2, 1, 1, 0]);
        assert_eq!(lm.expert_loads(), vec![3, 2, 2, 1]);
        assert_eq!(lm.total_load(), 8);
        assert_eq!(lm.tokens_per_device(), vec![2, 2]);
        lm.validate().unwrap();
    }

    #[test]
    fn native_loads_block_layout() {
        let lm = small_routing().load_matrix();
        // 2 devices, M=2: device0 hosts experts {0,1}, device1 {2,3}.
        assert_eq!(lm.native_device_loads(2), vec![5, 3]);
    }

    #[test]
    fn validate_catches_range_and_shape() {
        let mut r = small_routing();
        r.experts[0][1] = 0; // duplicate within a token is ALLOWED
        r.validate().unwrap();

        let mut r2 = small_routing();
        r2.experts[1][0] = 9; // out of range
        assert!(r2.validate().is_err());

        let mut r3 = small_routing();
        r3.gates[0].pop();
        assert!(r3.validate().is_err());
    }
}
