//! Synthetic imbalance scenarios (paper §5.1 and Fig. 1/4 sweeps).
//!
//! The paper *simulates* "X% of tokens evenly concentrated into k
//! imbalanced experts": routing slots are sampled i.i.d. from a skewed
//! distribution placing mass `concentration` on the hot set (experts
//! `0..hot_experts`, i.e. concentrated on device 0 under the block layout
//! — the paper's observed worst case, §3.1). Unlike a real top-K router,
//! a token's K slots may repeat an expert — the engines treat slots
//! independently, so exactness is unaffected (the real router in
//! [`crate::moe::route`] does produce distinct experts).

use super::{LoadMatrix, Routing};
use crate::config::ModelConfig;
use crate::util::rng::Rng;

/// A routing workload generator.
#[derive(Clone, Debug, PartialEq)]
pub enum Scenario {
    /// Statistically uniform routing (the pre-training assumption).
    Balanced,
    /// Fraction `concentration` of all routed load lands on
    /// `hot_experts` experts (evenly within the hot set).
    Concentrated { concentration: f64, hot_experts: usize },
    /// Zipf-like decay: expert `i` has weight `(i+1)^-exponent`.
    PowerLaw { exponent: f64 },
    /// Fig.-3-style drift: a dominant expert takes `dominance` of the
    /// load on average, with per-batch multiplicative noise of `drift`,
    /// and with probability `drift` the dominant position moves.
    Drifting { dominant: usize, dominance: f64, drift: f64 },
}

impl Scenario {
    pub fn balanced() -> Scenario {
        Scenario::Balanced
    }
    pub fn concentrated(concentration: f64, hot_experts: usize) -> Scenario {
        assert!((0.0..=1.0).contains(&concentration));
        assert!(hot_experts >= 1);
        Scenario::Concentrated { concentration, hot_experts }
    }
    pub fn power_law(exponent: f64) -> Scenario {
        Scenario::PowerLaw { exponent }
    }
    pub fn drifting(dominant: usize, dominance: f64, drift: f64) -> Scenario {
        Scenario::Drifting { dominant, dominance, drift }
    }

    pub fn label(&self) -> String {
        match self {
            Scenario::Balanced => "balanced".into(),
            Scenario::Concentrated { concentration, hot_experts } => {
                format!("{:.0}% into {}", concentration * 100.0, hot_experts)
            }
            Scenario::PowerLaw { exponent } => format!("powerlaw({exponent})"),
            Scenario::Drifting { dominant, dominance, .. } => {
                format!("drift(E{dominant}@{:.0}%)", dominance * 100.0)
            }
        }
    }

    /// Per-slot expert sampling weights for this scenario (normalized by
    /// the caller). Drifting scenarios re-draw per batch via `rng`.
    fn slot_weights(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        match *self {
            Scenario::Balanced => vec![1.0; n],
            Scenario::Concentrated { concentration, hot_experts } => {
                let hot = hot_experts.min(n);
                let cold = n - hot;
                (0..n)
                    .map(|e| {
                        if e < hot {
                            concentration / hot as f64
                        } else if cold > 0 {
                            (1.0 - concentration) / cold as f64
                        } else {
                            0.0
                        }
                    })
                    .collect()
            }
            Scenario::PowerLaw { exponent } => {
                (0..n).map(|i| ((i + 1) as f64).powf(-exponent)).collect()
            }
            Scenario::Drifting { dominant, dominance, drift } => {
                let dom = if rng.f64() < drift {
                    rng.index(n)
                } else {
                    dominant.min(n - 1)
                };
                let noise = 1.0 + drift * (rng.f64() * 2.0 - 1.0);
                let d = (dominance * noise).clamp(0.0, 0.95);
                let mut w = vec![(1.0 - d) / (n - 1).max(1) as f64; n];
                w[dom] = d;
                w
            }
        }
    }

    /// Generate full token-level routing for `devices` origin devices with
    /// `tokens_per_device` tokens each.
    pub fn generate(
        &self,
        model: &ModelConfig,
        devices: usize,
        tokens_per_device: usize,
        rng: &mut Rng,
    ) -> Routing {
        let n = model.num_experts;
        let k = model.top_k;
        assert!(k <= n);
        let w = self.slot_weights(n, rng);
        let mut experts = Vec::with_capacity(devices);
        let mut gates = Vec::with_capacity(devices);
        for _ in 0..devices {
            let mut ids = Vec::with_capacity(tokens_per_device * k);
            let mut gts = Vec::with_capacity(tokens_per_device * k);
            for _ in 0..tokens_per_device {
                for _ in 0..k {
                    ids.push(rng.weighted(&w) as u32);
                }
                // gates: normalized positive weights, slot-0 heaviest
                // (mimicking softmax top-k ordering)
                let mut raw: Vec<f32> = (0..k).map(|_| 0.05 + rng.f32()).collect();
                raw.sort_by(|a, b| b.partial_cmp(a).unwrap());
                let sum: f32 = raw.iter().sum();
                for g in raw {
                    gts.push(g / sum);
                }
            }
            experts.push(ids);
            gates.push(gts);
        }
        Routing { num_experts: n, top_k: k, experts, gates }
    }

    /// Generate only the load matrix (deterministic expectation rounding;
    /// used by the paper-scale modeled benchmarks where token identities
    /// do not matter).
    pub fn generate_loads(
        &self,
        model: &ModelConfig,
        devices: usize,
        tokens_per_device: usize,
        rng: &mut Rng,
    ) -> LoadMatrix {
        let n = model.num_experts;
        let k = model.top_k;
        let w = self.slot_weights(n, rng);
        let w_total: f64 = w.iter().sum();
        let slots = (tokens_per_device * k) as f64;
        let expected: Vec<f64> = w.iter().map(|&wi| slots * wi / w_total).collect();

        let mut counts = Vec::with_capacity(devices);
        for _ in 0..devices {
            counts.push(round_to_total(&expected, (tokens_per_device * k) as u64));
        }
        LoadMatrix { counts, top_k: k }
    }

    /// Like [`generate_loads`](Self::generate_loads) but distributes an
    /// exact *total* token count across devices (largest-remainder: the
    /// first `total % devices` devices carry one extra token). The serving
    /// simulators use this so priced work always equals admitted work —
    /// `(total / devices).max(1)` rounding silently dropped or invented
    /// tokens whenever a batch did not divide evenly.
    pub fn generate_loads_total(
        &self,
        model: &ModelConfig,
        devices: usize,
        total_tokens: usize,
        rng: &mut Rng,
    ) -> LoadMatrix {
        let n = model.num_experts;
        let k = model.top_k;
        let w = self.slot_weights(n, rng);
        let w_total: f64 = w.iter().sum();
        let base = total_tokens / devices;
        let extra = total_tokens % devices;
        let mut counts = Vec::with_capacity(devices);
        for p in 0..devices {
            let tokens = base + if p < extra { 1 } else { 0 };
            let slots = (tokens * k) as f64;
            let expected: Vec<f64> = w.iter().map(|&wi| slots * wi / w_total).collect();
            counts.push(round_to_total(&expected, (tokens * k) as u64));
        }
        LoadMatrix { counts, top_k: k }
    }
}

/// Per-layer routing scenarios for one full forward step — different MoE
/// layers specialize on different experts (paper Fig. 3a is a per-layer
/// maximum), so the imbalance degree and hotspot location vary across
/// depth. [`crate::exec::Engine::run_model`] draws one [`LoadMatrix`] per
/// layer from a profile.
#[derive(Clone, Debug, PartialEq)]
pub struct DepthProfile {
    layers: Vec<Scenario>,
}

impl DepthProfile {
    /// Every layer routes with the same scenario.
    pub fn uniform(scenario: Scenario, layers: usize) -> DepthProfile {
        assert!(layers >= 1, "a model has at least one MoE layer");
        DepthProfile { layers: vec![scenario; layers] }
    }

    /// Explicit per-layer scenarios.
    pub fn from_scenarios(layers: Vec<Scenario>) -> DepthProfile {
        assert!(!layers.is_empty(), "a model has at least one MoE layer");
        DepthProfile { layers }
    }

    /// Depth-varying imbalance over all of `model`'s MoE layers: layer `i`
    /// favours expert `(7 i + 11) mod N` with the given average dominance
    /// and per-batch drift — each depth has its own hotspot, as observed
    /// in paper §3.1.
    pub fn varying(model: &ModelConfig, dominance: f64, drift: f64) -> DepthProfile {
        let n = model.num_experts;
        let layers = model.num_moe_layers().max(1);
        DepthProfile {
            layers: (0..layers)
                .map(|i| Scenario::drifting((7 * i + 11) % n, dominance, drift))
                .collect(),
        }
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn scenarios(&self) -> &[Scenario] {
        &self.layers
    }

    pub fn label(&self) -> String {
        let first = &self.layers[0];
        if self.layers.iter().all(|s| s == first) {
            format!("{} x{} layers", first.label(), self.layers.len())
        } else {
            format!("depth-varying x{} layers", self.layers.len())
        }
    }

    /// One load matrix per layer, `tokens_per_device` tokens on each
    /// origin device.
    pub fn generate_loads(
        &self,
        model: &ModelConfig,
        devices: usize,
        tokens_per_device: usize,
        rng: &mut Rng,
    ) -> Vec<LoadMatrix> {
        self.layers
            .iter()
            .map(|sc| sc.generate_loads(model, devices, tokens_per_device, rng))
            .collect()
    }

    /// One load matrix per layer carrying an exact batch total (see
    /// [`Scenario::generate_loads_total`]).
    pub fn generate_loads_total(
        &self,
        model: &ModelConfig,
        devices: usize,
        total_tokens: usize,
        rng: &mut Rng,
    ) -> Vec<LoadMatrix> {
        self.layers
            .iter()
            .map(|sc| sc.generate_loads_total(model, devices, total_tokens, rng))
            .collect()
    }
}

/// Round expectations to integers preserving the exact total
/// (largest-remainder method).
fn round_to_total(expected: &[f64], total: u64) -> Vec<u64> {
    let mut out: Vec<u64> = expected.iter().map(|&x| x.floor() as u64).collect();
    let assigned: u64 = out.iter().sum();
    debug_assert!(assigned <= total);
    let mut remainder: Vec<(usize, f64)> = expected
        .iter()
        .enumerate()
        .map(|(i, &x)| (i, x - x.floor()))
        .collect();
    remainder.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut left = total - assigned;
    let mut i = 0;
    while left > 0 {
        out[remainder[i % remainder.len()].0] += 1;
        left -= 1;
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelPreset};

    fn tiny() -> ModelConfig {
        ModelConfig::preset(ModelPreset::Tiny) // N=8, K=2
    }

    #[test]
    fn generate_structure_valid() {
        let mut rng = Rng::new(1);
        for sc in [
            Scenario::balanced(),
            Scenario::concentrated(0.8, 2),
            Scenario::power_law(1.2),
            Scenario::drifting(3, 0.2, 0.1),
        ] {
            let r = sc.generate(&tiny(), 4, 64, &mut rng);
            r.validate().unwrap();
            assert_eq!(r.devices(), 4);
            assert_eq!(r.total_tokens(), 256);
        }
    }

    #[test]
    fn balanced_is_roughly_uniform() {
        let mut rng = Rng::new(2);
        let r = Scenario::balanced().generate(&tiny(), 4, 2000, &mut rng);
        let l = r.load_matrix().expert_loads();
        let mean = l.iter().sum::<u64>() as f64 / l.len() as f64;
        for &x in &l {
            assert!((x as f64) < 1.25 * mean && (x as f64) > 0.75 * mean, "{l:?}");
        }
    }

    #[test]
    fn concentrated_owns_the_stated_share() {
        let mut rng = Rng::new(3);
        let r = Scenario::concentrated(0.9, 1).generate(&tiny(), 4, 2000, &mut rng);
        let l = r.load_matrix().expert_loads();
        let total: u64 = l.iter().sum();
        let share = l[0] as f64 / total as f64;
        assert!((share - 0.9).abs() < 0.03, "hot share {share}, loads {l:?}");
    }

    #[test]
    fn loads_match_token_level_in_expectation() {
        let mut rng = Rng::new(4);
        let model = tiny();
        let sc = Scenario::concentrated(0.8, 2);
        let lm = sc.generate_loads(&model, 4, 4096, &mut rng);
        lm.validate().unwrap();
        let full = sc.generate(&model, 4, 4096, &mut rng).load_matrix();
        let a = lm.expert_loads();
        let b = full.expert_loads();
        let total: u64 = a.iter().sum();
        assert_eq!(total, b.iter().sum::<u64>());
        for e in 0..model.num_experts {
            let pa = a[e] as f64 / total as f64;
            let pb = b[e] as f64 / total as f64;
            assert!((pa - pb).abs() < 0.03, "expert {e}: {pa} vs {pb}");
        }
    }

    #[test]
    fn loads_exact_total() {
        let mut rng = Rng::new(5);
        let lm = Scenario::power_law(1.5).generate_loads(&tiny(), 8, 1000, &mut rng);
        assert_eq!(lm.total_load(), 8 * 1000 * 2);
        assert_eq!(lm.tokens_per_device(), vec![1000; 8]);
    }

    #[test]
    fn round_to_total_preserves_total() {
        let out = round_to_total(&[1.4, 2.7, 0.9], 5);
        assert_eq!(out.iter().sum::<u64>(), 5);
    }

    #[test]
    fn drifting_moves_the_hotspot_sometimes() {
        let model = tiny();
        let sc = Scenario::drifting(3, 0.4, 0.5);
        let mut rng = Rng::new(6);
        let mut dominants = std::collections::BTreeSet::new();
        for _ in 0..40 {
            let lm = sc.generate_loads(&model, 2, 512, &mut rng);
            let l = lm.expert_loads();
            let argmax = (0..l.len()).max_by_key(|&i| l[i]).unwrap();
            dominants.insert(argmax);
        }
        assert!(dominants.contains(&3), "usually E3 dominates: {dominants:?}");
        assert!(dominants.len() > 1, "drift relocates the hotspot: {dominants:?}");
    }

    #[test]
    fn drifting_dominance_is_load_share() {
        let model = tiny();
        let mut rng = Rng::new(7);
        let lm = Scenario::drifting(3, 0.3, 0.0).generate_loads(&model, 4, 4000, &mut rng);
        let l = lm.expert_loads();
        let total: u64 = l.iter().sum();
        let share = l[3] as f64 / total as f64;
        assert!((share - 0.3).abs() < 0.02, "share {share}");
    }

    #[test]
    fn labels_are_descriptive() {
        assert_eq!(Scenario::concentrated(0.95, 1).label(), "95% into 1");
        assert_eq!(Scenario::balanced().label(), "balanced");
    }

    #[test]
    fn loads_total_carries_exact_batch() {
        let model = tiny(); // K = 2
        let mut rng = Rng::new(8);
        // 1003 tokens over 4 devices: 251, 251, 251, 250.
        let lm = Scenario::concentrated(0.8, 2).generate_loads_total(&model, 4, 1003, &mut rng);
        lm.validate().unwrap();
        assert_eq!(lm.total_load(), 1003 * 2);
        assert_eq!(lm.tokens_per_device(), vec![251, 251, 251, 250]);
        // fewer tokens than devices: the first ones get a token each
        let lm = Scenario::balanced().generate_loads_total(&model, 4, 3, &mut rng);
        lm.validate().unwrap();
        assert_eq!(lm.tokens_per_device(), vec![1, 1, 1, 0]);
    }

    #[test]
    fn depth_profile_shapes_and_labels() {
        let model = tiny();
        let uniform = DepthProfile::uniform(Scenario::balanced(), 3);
        assert_eq!(uniform.num_layers(), 3);
        assert_eq!(uniform.label(), "balanced x3 layers");

        let varying = DepthProfile::varying(&model, 0.4, 0.0);
        assert_eq!(varying.num_layers(), model.num_moe_layers());
        assert!(varying.label().contains("layers"));

        let mut rng = Rng::new(9);
        let lms = varying.generate_loads(&model, 4, 256, &mut rng);
        assert_eq!(lms.len(), model.num_moe_layers());
        for lm in &lms {
            lm.validate().unwrap();
            assert_eq!(lm.total_load(), 4 * 256 * model.top_k as u64);
        }
    }

    #[test]
    fn depth_varying_hotspots_differ_across_layers() {
        // dominance with zero drift: layer i's argmax is (7i+11) mod N.
        let mut model = tiny();
        model.num_layers = 4;
        let profile = DepthProfile::varying(&model, 0.5, 0.0);
        let mut rng = Rng::new(10);
        let lms = profile.generate_loads(&model, 2, 2048, &mut rng);
        let argmax = |lm: &LoadMatrix| {
            let l = lm.expert_loads();
            (0..l.len()).max_by_key(|&i| l[i]).unwrap()
        };
        let hot: Vec<usize> = lms.iter().map(argmax).collect();
        assert_eq!(hot, vec![11 % 8, (7 + 11) % 8, (14 + 11) % 8, (21 + 11) % 8]);
    }
}
