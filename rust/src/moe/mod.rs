//! Pure-rust MoE reference: router, SwiGLU expert FFN, and a
//! single-device forward/backward oracle.
//!
//! The execution engine's distributed dispatch-compute-combine must be
//! *exactly* this computation (paper: "LLEP is an **exact** MoE
//! computation algorithm") — the integration tests compare both forward
//! outputs and accumulated expert-weight gradients against this module.

use crate::config::ModelConfig;
use crate::routing::Routing;
use crate::tensor::{matmul, matmul_at_acc, matmul_bt, silu, silu_grad, softmax_inplace, Mat};
use crate::util::rng::Rng;

/// SwiGLU expert weights: `y = (silu(x Wg) * (x Wu)) Wd`.
#[derive(Clone, Debug)]
pub struct ExpertWeights {
    pub w_gate: Mat, // D x H
    pub w_up: Mat,   // D x H
    pub w_down: Mat, // H x D
}

impl ExpertWeights {
    pub fn random(model: &ModelConfig, rng: &mut Rng) -> ExpertWeights {
        let d = model.d_model;
        let h = model.d_ff;
        let scale = 1.0 / (d as f32).sqrt();
        ExpertWeights {
            w_gate: Mat::randn(d, h, scale, rng),
            w_up: Mat::randn(d, h, scale, rng),
            w_down: Mat::randn(h, d, scale, rng),
        }
    }

    pub fn zeros_like(&self) -> ExpertWeights {
        ExpertWeights {
            w_gate: Mat::zeros(self.w_gate.rows, self.w_gate.cols),
            w_up: Mat::zeros(self.w_up.rows, self.w_up.cols),
            w_down: Mat::zeros(self.w_down.rows, self.w_down.cols),
        }
    }

    /// Accumulate another gradient set into this one.
    pub fn add_assign(&mut self, other: &ExpertWeights) {
        for (a, b) in self.w_gate.data.iter_mut().zip(&other.w_gate.data) {
            *a += b;
        }
        for (a, b) in self.w_up.data.iter_mut().zip(&other.w_up.data) {
            *a += b;
        }
        for (a, b) in self.w_down.data.iter_mut().zip(&other.w_down.data) {
            *a += b;
        }
    }

    pub fn max_abs_diff(&self, other: &ExpertWeights) -> f32 {
        let d = |a: &Mat, b: &Mat| {
            a.data
                .iter()
                .zip(&b.data)
                .map(|(x, y)| (x - y).abs())
                .fold(0f32, f32::max)
        };
        d(&self.w_gate, &other.w_gate)
            .max(d(&self.w_up, &other.w_up))
            .max(d(&self.w_down, &other.w_down))
    }
}

/// An MoE layer: router weights + `N` experts.
#[derive(Clone, Debug)]
pub struct MoeLayer {
    pub model: ModelConfig,
    pub router: Mat, // D x N
    pub experts: Vec<ExpertWeights>,
}

impl MoeLayer {
    pub fn random(model: &ModelConfig, rng: &mut Rng) -> MoeLayer {
        let router = Mat::randn(model.d_model, model.num_experts, 0.2, rng);
        let experts = (0..model.num_experts).map(|_| ExpertWeights::random(model, rng)).collect();
        MoeLayer { model: model.clone(), router, experts }
    }
}

/// SwiGLU FFN forward: `(silu(x Wg) * (x Wu)) Wd`.
pub fn ffn_forward(x: &Mat, w: &ExpertWeights) -> Mat {
    let g = matmul(x, &w.w_gate); // B x H
    let u = matmul(x, &w.w_up); // B x H
    let mut a = Mat::zeros(g.rows, g.cols);
    for i in 0..g.data.len() {
        a.data[i] = silu(g.data[i]) * u.data[i];
    }
    matmul(&a, &w.w_down) // B x D
}

/// Gradients of the SwiGLU FFN.
pub struct FfnGrads {
    pub d_weights: ExpertWeights,
    pub d_x: Mat,
}

/// SwiGLU FFN backward for upstream gradient `dy` (B x D).
pub fn ffn_backward(x: &Mat, w: &ExpertWeights, dy: &Mat) -> FfnGrads {
    let g = matmul(x, &w.w_gate); // B x H (pre-activation)
    let u = matmul(x, &w.w_up); // B x H
    let mut a = Mat::zeros(g.rows, g.cols); // silu(g) * u
    for i in 0..g.data.len() {
        a.data[i] = silu(g.data[i]) * u.data[i];
    }
    // d_a = dy @ Wd^T ; dWd = a^T @ dy
    let d_a = matmul_bt(dy, &w.w_down); // B x H (w_down is H x D; dy (BxD) @ (Wd^T: DxH))
    let mut d_w_down = Mat::zeros(w.w_down.rows, w.w_down.cols);
    matmul_at_acc(&a, dy, &mut d_w_down);

    // d_g = d_a * u * silu'(g); d_u = d_a * silu(g)
    let mut d_g = Mat::zeros(g.rows, g.cols);
    let mut d_u = Mat::zeros(g.rows, g.cols);
    for i in 0..g.data.len() {
        d_g.data[i] = d_a.data[i] * u.data[i] * silu_grad(g.data[i]);
        d_u.data[i] = d_a.data[i] * silu(g.data[i]);
    }
    let mut d_w_gate = Mat::zeros(w.w_gate.rows, w.w_gate.cols);
    matmul_at_acc(x, &d_g, &mut d_w_gate);
    let mut d_w_up = Mat::zeros(w.w_up.rows, w.w_up.cols);
    matmul_at_acc(x, &d_u, &mut d_w_up);

    // d_x = d_g @ Wg^T + d_u @ Wu^T
    let mut d_x = matmul_bt(&d_g, &w.w_gate);
    let d_x2 = matmul_bt(&d_u, &w.w_up);
    for (a, b) in d_x.data.iter_mut().zip(&d_x2.data) {
        *a += b;
    }

    FfnGrads { d_weights: ExpertWeights { w_gate: d_w_gate, w_up: d_w_up, w_down: d_w_down }, d_x }
}

/// Top-K softmax routing of per-device token batches (paper Eq. 1-2):
/// scores = softmax(x W_r); keep the K largest as gates.
pub fn route(layer: &MoeLayer, xs: &[Mat]) -> Routing {
    let n = layer.model.num_experts;
    let k = layer.model.top_k;
    let mut experts = Vec::with_capacity(xs.len());
    let mut gates = Vec::with_capacity(xs.len());
    for x in xs {
        let logits = matmul(x, &layer.router); // B x N
        let mut ids = Vec::with_capacity(x.rows * k);
        let mut gts = Vec::with_capacity(x.rows * k);
        for t in 0..x.rows {
            let mut scores = logits.row(t).to_vec();
            softmax_inplace(&mut scores);
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
            for &e in order.iter().take(k) {
                ids.push(e as u32);
                gts.push(scores[e]);
            }
        }
        experts.push(ids);
        gates.push(gts);
    }
    Routing { num_experts: n, top_k: k, experts, gates }
}

/// Bias-adjusted routing — the *parameter-altering* load-balancing family
/// the paper argues against for post-training (§1, §3.1: DeepSeek-V3's
/// moving-average routing bias, auxiliary losses). A per-expert bias is
/// added to the router scores before top-K selection, steering tokens
/// away from overloaded experts. This balances loads but **changes which
/// experts process which tokens**, i.e. it alters model outputs — unlike
/// LLEP, which is exact. `tests::biased_routing_balances_but_is_not_exact`
/// quantifies both effects.
pub fn route_biased(layer: &MoeLayer, xs: &[Mat], bias: &[f32]) -> Routing {
    let n = layer.model.num_experts;
    let k = layer.model.top_k;
    assert_eq!(bias.len(), n);
    let mut experts = Vec::with_capacity(xs.len());
    let mut gates = Vec::with_capacity(xs.len());
    for x in xs {
        let logits = matmul(x, &layer.router);
        let mut ids = Vec::with_capacity(x.rows * k);
        let mut gts = Vec::with_capacity(x.rows * k);
        for t in 0..x.rows {
            let mut scores = logits.row(t).to_vec();
            softmax_inplace(&mut scores);
            // bias applies to SELECTION only; the gate values stay the
            // original affinities (DeepSeek-V3 semantics).
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| {
                (scores[b] + bias[b])
                    .partial_cmp(&(scores[a] + bias[a]))
                    .unwrap()
                    .then(a.cmp(&b))
            });
            for &e in order.iter().take(k) {
                ids.push(e as u32);
                gts.push(scores[e]);
            }
        }
        experts.push(ids);
        gates.push(gts);
    }
    Routing { num_experts: n, top_k: k, experts, gates }
}

/// One moving-average bias update step (DeepSeek-V3-style): experts above
/// the mean load get pushed down, below-mean experts pulled up.
pub fn update_routing_bias(bias: &mut [f32], loads: &[u64], rate: f32) {
    let mean = (loads.iter().sum::<u64>() as f32 / loads.len() as f32).max(1.0);
    for (b, &l) in bias.iter_mut().zip(loads) {
        // proportional variant of DeepSeek-V3's auxiliary-loss-free
        // update (sign-based in the original; proportional converges in
        // fewer batches, which suits the unit-test horizon)
        *b -= rate * (l as f32 - mean) / mean;
    }
}

/// Single-device reference MoE forward: per device `p`, output row `t` is
/// `sum_k gate[t,k] * FFN_{expert[t,k]}(x[t])`.
pub fn forward_reference(layer: &MoeLayer, xs: &[Mat], routing: &Routing) -> Vec<Mat> {
    let k = routing.top_k;
    xs.iter()
        .enumerate()
        .map(|(p, x)| {
            let mut out = Mat::zeros(x.rows, layer.model.d_model);
            for t in 0..x.rows {
                let xt = Mat::from_vec(1, x.cols, x.row(t).to_vec());
                for slot in 0..k {
                    let e = routing.experts[p][t * k + slot] as usize;
                    let gate = routing.gates[p][t * k + slot];
                    let y = ffn_forward(&xt, &layer.experts[e]);
                    for (o, v) in out.row_mut(t).iter_mut().zip(&y.data) {
                        *o += gate * v;
                    }
                }
            }
            out
        })
        .collect()
}

/// Reference expert-weight gradients for upstream grads `dys` (per
/// device), accumulated across all tokens that touched each expert.
pub fn backward_reference(
    layer: &MoeLayer,
    xs: &[Mat],
    routing: &Routing,
    dys: &[Mat],
) -> Vec<ExpertWeights> {
    let k = routing.top_k;
    let mut grads: Vec<ExpertWeights> =
        layer.experts.iter().map(|w| w.zeros_like()).collect();
    for (p, x) in xs.iter().enumerate() {
        for t in 0..x.rows {
            let xt = Mat::from_vec(1, x.cols, x.row(t).to_vec());
            for slot in 0..k {
                let e = routing.experts[p][t * k + slot] as usize;
                let gate = routing.gates[p][t * k + slot];
                let mut dy = Mat::from_vec(1, layer.model.d_model, dys[p].row(t).to_vec());
                for v in dy.data.iter_mut() {
                    *v *= gate;
                }
                let g = ffn_backward(&xt, &layer.experts[e], &dy);
                grads[e].add_assign(&g.d_weights);
            }
        }
    }
    grads
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelPreset;

    fn tiny_layer(seed: u64) -> MoeLayer {
        let model = ModelConfig::preset(ModelPreset::Tiny);
        MoeLayer::random(&model, &mut Rng::new(seed))
    }

    #[test]
    fn ffn_forward_shape_and_determinism() {
        let layer = tiny_layer(1);
        let mut rng = Rng::new(2);
        let x = Mat::randn(5, 64, 0.1, &mut rng);
        let y1 = ffn_forward(&x, &layer.experts[0]);
        let y2 = ffn_forward(&x, &layer.experts[0]);
        assert_eq!(y1.rows, 5);
        assert_eq!(y1.cols, 64);
        assert_eq!(y1, y2);
    }

    #[test]
    fn ffn_backward_matches_finite_differences() {
        let model = ModelConfig::preset(ModelPreset::Tiny);
        let mut rng = Rng::new(3);
        // Small dims for FD stability.
        let small = ModelConfig { d_model: 6, d_ff: 5, ..model };
        let mut w = ExpertWeights::random(&small, &mut rng);
        let x = Mat::randn(3, 6, 0.5, &mut rng);
        let dy = Mat::randn(3, 6, 0.5, &mut rng);

        let grads = ffn_backward(&x, &w, &dy);
        let loss = |w: &ExpertWeights, x: &Mat| -> f32 {
            let y = ffn_forward(x, w);
            y.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-3f32;
        // check a scattering of weight coordinates in each matrix
        for (mat_idx, (get_grad, len)) in [
            (&grads.d_weights.w_gate, w.w_gate.data.len()),
            (&grads.d_weights.w_up, w.w_up.data.len()),
            (&grads.d_weights.w_down, w.w_down.data.len()),
        ]
        .iter()
        .enumerate()
        {
            for &i in &[0usize, len / 2, len - 1] {
                let orig = match mat_idx {
                    0 => w.w_gate.data[i],
                    1 => w.w_up.data[i],
                    _ => w.w_down.data[i],
                };
                let set = |w: &mut ExpertWeights, v: f32| match mat_idx {
                    0 => w.w_gate.data[i] = v,
                    1 => w.w_up.data[i] = v,
                    _ => w.w_down.data[i] = v,
                };
                set(&mut w, orig + eps);
                let up = loss(&w, &x);
                set(&mut w, orig - eps);
                let down = loss(&w, &x);
                set(&mut w, orig);
                let fd = (up - down) / (2.0 * eps);
                let an = get_grad.data[i];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + fd.abs()),
                    "mat {mat_idx} idx {i}: fd={fd} analytic={an}"
                );
            }
        }
        // and d_x
        let x_orig = x.clone();
        for &i in &[0usize, 7, 17] {
            let mut xp = x_orig.clone();
            xp.data[i] += eps;
            let mut xm = x_orig.clone();
            xm.data[i] -= eps;
            let fd = (loss(&w, &xp) - loss(&w, &xm)) / (2.0 * eps);
            let an = grads.d_x.data[i];
            assert!((fd - an).abs() < 2e-2 * (1.0 + fd.abs()), "d_x idx {i}: fd={fd} an={an}");
        }
    }

    #[test]
    fn route_produces_valid_topk() {
        let layer = tiny_layer(4);
        let mut rng = Rng::new(5);
        let xs = vec![Mat::randn(10, 64, 0.5, &mut rng), Mat::randn(7, 64, 0.5, &mut rng)];
        let r = route(&layer, &xs);
        r.validate().unwrap();
        assert_eq!(r.tokens_on(0), 10);
        assert_eq!(r.tokens_on(1), 7);
        // gates descend within each token (top-k of softmax)
        for p in 0..2 {
            for t in 0..r.tokens_on(p) {
                let g0 = r.gates[p][t * 2];
                let g1 = r.gates[p][t * 2 + 1];
                assert!(g0 >= g1);
                assert!(g0 > 0.0 && g0 <= 1.0);
            }
        }
    }

    #[test]
    fn forward_reference_uses_gates() {
        let layer = tiny_layer(6);
        let mut rng = Rng::new(7);
        let xs = vec![Mat::randn(4, 64, 0.5, &mut rng)];
        let mut routing = route(&layer, &xs);
        let y = forward_reference(&layer, &xs, &routing);
        // zeroing the gates must zero the output
        for g in routing.gates[0].iter_mut() {
            *g = 0.0;
        }
        let y0 = forward_reference(&layer, &xs, &routing);
        assert!(y[0].data.iter().any(|&v| v != 0.0));
        assert!(y0[0].data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn biased_routing_balances_but_is_not_exact() {
        // Build a layer whose router is skewed toward expert 0, then let
        // the DeepSeek-style bias equalize it over a few updates. Loads
        // get balanced — but the routing (and thus the model output)
        // CHANGES, which is exactly why the paper rejects this for
        // post-training and builds LLEP instead.
        let model = ModelConfig::preset(ModelPreset::Tiny);
        let mut rng = Rng::new(42);
        let mut layer = MoeLayer::random(&model, &mut rng);
        for r in 0..model.d_model {
            layer.router.data[r * model.num_experts] += 3.0; // skew to E0
        }
        let xs: Vec<Mat> = (0..2).map(|_| Mat::randn(200, model.d_model, 0.5, &mut rng)).collect();

        let unbiased = route(&layer, &xs);
        let l0 = unbiased.load_matrix().expert_loads();
        let ratio0 = crate::routing::imbalance_ratio(&l0);
        assert!(ratio0 > 1.8, "skewed router must be imbalanced: {ratio0}");

        let mut bias = vec![0f32; model.num_experts];
        let mut routing = unbiased.clone();
        for _ in 0..60 {
            update_routing_bias(&mut bias, &routing.load_matrix().expert_loads(), 0.05);
            routing = route_biased(&layer, &xs, &bias);
        }
        let l1 = routing.load_matrix().expert_loads();
        // the hot expert demonstrably sheds load (cold-expert ties make
        // the instantaneous max oscillate, as bias-chasing schemes do)
        assert!(
            l1[0] * 3 < l0[0] * 2,
            "bias must shed hot-expert load: {} -> {}",
            l0[0],
            l1[0]
        );

        // ...but the computation is no longer the same model:
        let y_unbiased = forward_reference(&layer, &xs, &unbiased);
        let y_biased = forward_reference(&layer, &xs, &routing);
        let diff = y_unbiased
            .iter()
            .zip(&y_biased)
            .map(|(a, b)| a.rel_diff(b))
            .fold(0f32, f32::max);
        assert!(diff > 1e-3, "biased routing must alter outputs (diff {diff})");
    }

    #[test]
    fn zero_bias_routing_matches_unbiased() {
        let model = ModelConfig::preset(ModelPreset::Tiny);
        let mut rng = Rng::new(43);
        let layer = MoeLayer::random(&model, &mut rng);
        let xs = vec![Mat::randn(20, model.d_model, 0.5, &mut rng)];
        let a = route(&layer, &xs);
        let b = route_biased(&layer, &xs, &vec![0.0; model.num_experts]);
        assert_eq!(a, b);
    }

    #[test]
    fn bias_update_pushes_toward_mean() {
        let mut bias = vec![0f32; 4];
        update_routing_bias(&mut bias, &[100, 10, 10, 10], 0.1);
        assert!(bias[0] < 0.0, "overloaded expert pushed down");
        assert!(bias[1] > 0.0 && bias[2] > 0.0 && bias[3] > 0.0);
    }

    #[test]
    fn backward_reference_zero_dy_zero_grads() {
        let layer = tiny_layer(8);
        let mut rng = Rng::new(9);
        let xs = vec![Mat::randn(3, 64, 0.5, &mut rng)];
        let routing = route(&layer, &xs);
        let dys = vec![Mat::zeros(3, 64)];
        let grads = backward_reference(&layer, &xs, &routing, &dys);
        assert!(grads.iter().all(|g| g.w_gate.data.iter().all(|&v| v == 0.0)));
    }
}
