//! Criterion-style micro-benchmark kit (criterion is unavailable offline).
//!
//! Measures wall-clock time of a closure with warmup, adaptive iteration
//! counts, and outlier-robust statistics. Used by every `rust/benches/`
//! target (all declared with `harness = false`).

use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub stddev_ns: f64,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.mean_ns / 1e9
    }
    /// Human-readable time with adaptive unit.
    pub fn pretty_mean(&self) -> String {
        format_ns(self.mean_ns)
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn format_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a fixed measurement budget per case.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Keep budgets modest: the suite runs on one CPU core.
        Bencher {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(600),
            min_samples: 10,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode bencher for CI-style smoke runs.
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            min_samples: 5,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which should return a value dependent on its work
    /// (it is black-boxed here to stop the optimizer eliding it).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup + estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters < 3 {
            black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters as f64;

        // Pick a batch size so each sample takes ~ measure/samples.
        let target_sample_ns = self.measure.as_nanos() as f64 / self.min_samples as f64;
        let batch = ((target_sample_ns / per_iter.max(1.0)).ceil() as u64).clamp(1, 1_000_000);

        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.min_samples * 2);
        let total_start = Instant::now();
        let mut iters = 0u64;
        while total_start.elapsed() < self.measure || samples_ns.len() < self.min_samples {
            let t = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / batch as f64);
            iters += batch;
            if samples_ns.len() >= 10_000 {
                break;
            }
        }

        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let mean = samples_ns.iter().sum::<f64>() / n as f64;
        let var = samples_ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            median_ns: samples_ns[n / 2],
            min_ns: samples_ns[0],
            stddev_ns: var.sqrt(),
        };
        println!(
            "bench {:<52} {:>12} (median {:>12}, min {:>12}, {} iters)",
            result.name,
            format_ns(result.mean_ns),
            format_ns(result.median_ns),
            format_ns(result.min_ns),
            result.iters
        );
        self.results.push(result.clone());
        result
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// True when `--quick` was passed or `LLEP_BENCH_QUICK` is set — benches use
/// this to shrink sweeps on slow machines.
pub fn quick_requested() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("LLEP_BENCH_QUICK").is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::quick();
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters >= 3);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn format_units() {
        assert!(format_ns(5.0).ends_with("ns"));
        assert!(format_ns(5_000.0).ends_with("µs"));
        assert!(format_ns(5_000_000.0).ends_with("ms"));
        assert!(format_ns(5e9).ends_with(" s"));
    }
}
