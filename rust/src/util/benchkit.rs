//! Criterion-style micro-benchmark kit (criterion is unavailable offline).
//!
//! Measures wall-clock time of a closure with warmup, adaptive iteration
//! counts, and outlier-robust statistics. Used by every `rust/benches/`
//! target (all declared with `harness = false`).
//!
//! [`BenchSuite`] adds the rebar-style regression harness on top: a
//! named set of results serialized to JSON (`BENCH_<suite>.json`: case
//! name, median/mean/min ns, iteration count, git revision) and a
//! median-vs-pin comparison with a tolerance band. `llep bench --suite
//! hotpath --out/--check` drives it; CI fails on regressions beyond the
//! band, so speedups are locked in rather than anecdotal.

use crate::util::json::{self, Json};
use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub stddev_ns: f64,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.mean_ns / 1e9
    }
    /// Human-readable time with adaptive unit.
    pub fn pretty_mean(&self) -> String {
        format_ns(self.mean_ns)
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn format_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a fixed measurement budget per case.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Keep budgets modest: the suite runs on one CPU core.
        Bencher {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(600),
            min_samples: 10,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode bencher for CI-style smoke runs.
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            min_samples: 5,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which should return a value dependent on its work
    /// (it is black-boxed here to stop the optimizer eliding it).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup + estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters < 3 {
            black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters as f64;

        // Pick a batch size so each sample takes ~ measure/samples.
        let target_sample_ns = self.measure.as_nanos() as f64 / self.min_samples as f64;
        let batch = ((target_sample_ns / per_iter.max(1.0)).ceil() as u64).clamp(1, 1_000_000);

        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.min_samples * 2);
        let total_start = Instant::now();
        let mut iters = 0u64;
        while total_start.elapsed() < self.measure || samples_ns.len() < self.min_samples {
            let t = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / batch as f64);
            iters += batch;
            if samples_ns.len() >= 10_000 {
                break;
            }
        }

        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let mean = samples_ns.iter().sum::<f64>() / n as f64;
        let var = samples_ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            median_ns: samples_ns[n / 2],
            min_ns: samples_ns[0],
            stddev_ns: var.sqrt(),
        };
        println!(
            "bench {:<52} {:>12} (median {:>12}, min {:>12}, {} iters)",
            result.name,
            format_ns(result.mean_ns),
            format_ns(result.median_ns),
            format_ns(result.min_ns),
            result.iters
        );
        self.results.push(result.clone());
        result
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// True when `--quick` was passed or `LLEP_BENCH_QUICK` is set — benches use
/// this to shrink sweeps on slow machines.
pub fn quick_requested() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("LLEP_BENCH_QUICK").is_ok()
}

/// Best-effort current git revision (short), read straight from `.git`
/// so no subprocess is spawned; `"unknown"` outside a repository.
pub fn git_rev() -> String {
    let read = |p: std::path::PathBuf| std::fs::read_to_string(p).ok();
    let Some(head) = read(std::path::PathBuf::from(".git/HEAD")) else {
        return "unknown".into();
    };
    let head = head.trim();
    let full = match head.strip_prefix("ref: ") {
        Some(r) => match read(std::path::Path::new(".git").join(r.trim())) {
            Some(h) => h.trim().to_string(),
            None => return "unknown".into(),
        },
        None => head.to_string(),
    };
    full.chars().take(12).collect()
}

/// A named set of bench results with JSON round-trip and pinned-baseline
/// comparison (see the module docs).
#[derive(Clone, Debug)]
pub struct BenchSuite {
    pub name: String,
    pub git_rev: String,
    pub results: Vec<BenchResult>,
}

/// One case's current-vs-pinned medians.
#[derive(Clone, Debug)]
pub struct BenchDelta {
    pub name: String,
    pub pinned_ns: f64,
    pub current_ns: f64,
}

impl BenchDelta {
    /// `current / pinned` — above 1.0 is slower than the pin.
    pub fn ratio(&self) -> f64 {
        self.current_ns / self.pinned_ns.max(1e-9)
    }

    /// Regression beyond the tolerance band (e.g. 0.25 = 25% slower).
    pub fn regressed(&self, tolerance: f64) -> bool {
        self.ratio() > 1.0 + tolerance
    }
}

/// Result of comparing a fresh run against a pinned suite.
#[derive(Clone, Debug, Default)]
pub struct BenchComparison {
    /// Cases present in both suites, in pin order.
    pub deltas: Vec<BenchDelta>,
    /// Pinned cases the current run no longer produces (renames count as
    /// failures: a silently vanished case is an unguarded hot path).
    pub missing: Vec<String>,
}

impl BenchComparison {
    /// Deltas beyond the tolerance band, worst first.
    pub fn regressions(&self, tolerance: f64) -> Vec<&BenchDelta> {
        let mut out: Vec<&BenchDelta> =
            self.deltas.iter().filter(|d| d.regressed(tolerance)).collect();
        out.sort_by(|a, b| b.ratio().total_cmp(&a.ratio()));
        out
    }

    pub fn passes(&self, tolerance: f64) -> bool {
        self.missing.is_empty() && self.regressions(tolerance).is_empty()
    }
}

impl BenchSuite {
    pub fn new(name: &str) -> BenchSuite {
        BenchSuite { name: name.to_string(), git_rev: git_rev(), results: Vec::new() }
    }

    /// Move a bencher's accumulated results into the suite.
    pub fn absorb(&mut self, bencher: &Bencher) {
        self.results.extend_from_slice(bencher.results());
    }

    pub fn get(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("suite", Json::str(&self.name)),
            ("git_rev", Json::str(&self.git_rev)),
            (
                "results",
                Json::arr(self.results.iter().map(|r| {
                    Json::obj(vec![
                        ("name", Json::str(&r.name)),
                        ("median_ns", Json::num(r.median_ns)),
                        ("mean_ns", Json::num(r.mean_ns)),
                        ("min_ns", Json::num(r.min_ns)),
                        ("stddev_ns", Json::num(r.stddev_ns)),
                        ("iters", Json::num(r.iters as f64)),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<BenchSuite, String> {
        let name = j.get("suite").and_then(Json::as_str).ok_or("missing suite field")?;
        let git_rev = j.get("git_rev").and_then(Json::as_str).unwrap_or("unknown");
        let results = j
            .get("results")
            .and_then(Json::as_arr)
            .ok_or("missing results array")?
            .iter()
            .map(|r| {
                let name = r.get("name").and_then(Json::as_str).ok_or("result missing name")?;
                let median_ns = r
                    .get("median_ns")
                    .and_then(Json::as_f64)
                    .ok_or("result missing median_ns")?;
                Ok(BenchResult {
                    name: name.to_string(),
                    median_ns,
                    mean_ns: r.get("mean_ns").and_then(Json::as_f64).unwrap_or(median_ns),
                    min_ns: r.get("min_ns").and_then(Json::as_f64).unwrap_or(median_ns),
                    stddev_ns: r.get("stddev_ns").and_then(Json::as_f64).unwrap_or(0.0),
                    iters: r.get("iters").and_then(Json::as_f64).unwrap_or(0.0) as u64,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(BenchSuite { name: name.to_string(), git_rev: git_rev.to_string(), results })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<(), String> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .map_err(|e| format!("{}: {e}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> Result<BenchSuite, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let j = json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        BenchSuite::from_json(&j)
    }

    /// Compare this (current) run's medians against a pinned suite.
    pub fn compare(&self, pin: &BenchSuite) -> BenchComparison {
        let mut cmp = BenchComparison::default();
        for pinned in &pin.results {
            match self.get(&pinned.name) {
                Some(cur) => cmp.deltas.push(BenchDelta {
                    name: pinned.name.clone(),
                    pinned_ns: pinned.median_ns,
                    current_ns: cur.median_ns,
                }),
                None => cmp.missing.push(pinned.name.clone()),
            }
        }
        cmp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::quick();
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters >= 3);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn format_units() {
        assert!(format_ns(5.0).ends_with("ns"));
        assert!(format_ns(5_000.0).ends_with("µs"));
        assert!(format_ns(5_000_000.0).ends_with("ms"));
        assert!(format_ns(5e9).ends_with(" s"));
    }

    fn result(name: &str, median_ns: f64) -> BenchResult {
        BenchResult {
            name: name.into(),
            iters: 100,
            mean_ns: median_ns * 1.1,
            median_ns,
            min_ns: median_ns * 0.9,
            stddev_ns: 1.0,
        }
    }

    #[test]
    fn suite_json_round_trips() {
        let mut s = BenchSuite::new("hotpath");
        s.results.push(result("a", 123.0));
        s.results.push(result("b", 4.5e6));
        let j = s.to_json();
        let back = BenchSuite::from_json(&j).unwrap();
        assert_eq!(back.name, "hotpath");
        assert_eq!(back.results.len(), 2);
        assert_eq!(back.get("b").unwrap().median_ns, 4.5e6);
        // Text round-trip through the parser too.
        let re = crate::util::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(BenchSuite::from_json(&re).unwrap().results.len(), 2);
    }

    #[test]
    fn compare_flags_regressions_and_missing_cases() {
        let mut pin = BenchSuite::new("hotpath");
        pin.results.push(result("fast", 100.0));
        pin.results.push(result("slow", 100.0));
        pin.results.push(result("gone", 100.0));
        let mut cur = BenchSuite::new("hotpath");
        cur.results.push(result("fast", 90.0)); // improved
        cur.results.push(result("slow", 140.0)); // 40% regression
        let cmp = cur.compare(&pin);
        assert_eq!(cmp.missing, vec!["gone".to_string()]);
        let regs = cmp.regressions(0.25);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].name, "slow");
        assert!((regs[0].ratio() - 1.4).abs() < 1e-12);
        assert!(!cmp.passes(0.25), "missing case fails the gate");
        // Within the band everything passes.
        let mut ok = BenchSuite::new("hotpath");
        ok.results.push(result("fast", 110.0));
        ok.results.push(result("slow", 110.0));
        ok.results.push(result("gone", 80.0));
        assert!(ok.compare(&pin).passes(0.25));
    }

    #[test]
    fn git_rev_is_short_or_unknown() {
        let r = git_rev();
        assert!(r == "unknown" || (!r.is_empty() && r.len() <= 12), "{r}");
    }
}
