//! Minimal JSON reader/writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`), routing
//! trace record/replay, and machine-readable bench reports. Supports the
//! full JSON value grammar minus `\u` surrogate pairs (escapes decode to
//! the BMP scalar directly).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    item.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() && x == x.trunc() && x.abs() < 9e15 {
        let _ = write!(out, "{}", x as i64);
    } else if x.is_finite() {
        let _ = write!(out, "{x}");
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }
    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }
    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }
    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Advance over one UTF-8 scalar.
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let text =
                        std::str::from_utf8(&self.bytes[start..end]).map_err(|e| e.to_string())?;
                    s.push_str(text);
                    self.pos = end;
                }
            }
        }
    }
    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c)
                if c.is_ascii_digit()
                    || c == b'.'
                    || c == b'e'
                    || c == b'E'
                    || c == b'+'
                    || c == b'-'
        )
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v, "{src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "s": "x", "a": [1,2], "b": false}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""café \t ok""#).unwrap();
        assert_eq!(v.as_str(), Some("café \t ok"));
        let s = Json::Str("tab\tand \"quote\"".into()).to_string();
        assert_eq!(parse(&s).unwrap().as_str(), Some("tab\tand \"quote\""));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }
}
