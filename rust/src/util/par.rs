//! Chunked scoped-thread parallel map (rayon is unavailable offline).
//!
//! One shared implementation of the "slots + `std::thread::scope` over
//! contiguous chunks" fan-out used by the engine's per-layer planning
//! ([`crate::exec::Engine::run_model`]) and the autotuner's trial
//! evaluation ([`crate::tune::Tuner`]): results land in input order
//! regardless of completion order, and short inputs (or single-core
//! hosts) run inline with no threads spawned.

/// Map `f` over `items` on up to `available_parallelism()` scoped
/// worker threads, preserving input order.
pub fn parallel_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let workers = std::thread::available_parallelism().map(|w| w.get()).unwrap_or(1).min(n);
    if workers <= 1 {
        return items.iter().map(f).collect();
    }
    let mut slots: Vec<Option<U>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for (slot_chunk, item_chunk) in slots.chunks_mut(chunk).zip(items.chunks(chunk)) {
            let f = &f;
            scope.spawn(move || {
                for (slot, item) in slot_chunk.iter_mut().zip(item_chunk) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    slots.into_iter().map(|s| s.expect("every item mapped")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_covers_all_items() {
        let items: Vec<usize> = (0..103).collect();
        let out = parallel_map(&items, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single_inputs() {
        let none: Vec<u32> = Vec::new();
        assert!(parallel_map(&none, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], |&x| x + 1), vec![8]);
    }

    #[test]
    fn results_can_carry_errors() {
        let items = ["1", "2", "x"];
        let out = parallel_map(&items, |s| s.parse::<i32>());
        assert_eq!(out[0], Ok(1));
        assert!(out[2].is_err());
    }
}
