//! In-tree substrates that would normally come from crates.io.
//!
//! This build environment is offline (the `xla`/`anyhow` dependency tree
//! exists only behind the optional `pjrt` feature), so the crate carries
//! its own implementations of the small utility layers it needs: a
//! deterministic PRNG ([`rng`]), a CLI argument
//! parser ([`cli`]), a TOML-subset parser ([`tomlmini`]), a JSON
//! reader/writer ([`json`]), summary statistics ([`stats`]), a
//! criterion-style benchmark kit ([`benchkit`]), a property-testing
//! driver ([`prop`]) and a scoped-thread parallel map ([`par`]).

#[cfg(test)]
pub mod alloc_count;
pub mod benchkit;
pub mod cli;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tomlmini;
