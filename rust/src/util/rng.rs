//! Deterministic pseudo-random number generation.
//!
//! Implements xoshiro256++ seeded via SplitMix64 — the same construction
//! `rand_xoshiro` uses. Every example, bench and test in the crate seeds
//! explicitly so runs are reproducible.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded through SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's unbiased multiply-shift method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply rejection sampling (unbiased).
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.index(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.index(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork an independent generator (for per-device / per-batch streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(11);
        for _ in 0..50 {
            let s = r.sample_distinct(20, 8);
            let mut u = s.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), 8);
            assert!(s.iter().all(|&x| x < 20));
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(13);
        let w = [0.05, 0.9, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..2000 {
            counts[r.weighted(&w)] += 1;
        }
        assert!(counts[1] > counts[0] * 5 && counts[1] > counts[2] * 5);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
