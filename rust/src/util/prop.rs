//! Property-testing driver (proptest is unavailable offline).
//!
//! [`check`] runs a property over `cases` randomly generated inputs and, on
//! failure, greedily shrinks the failing input via a user-supplied
//! shrinker before reporting. Inputs are produced from a seeded [`Rng`] so
//! failures are reproducible: the failing seed is printed and can be
//! replayed with `check_seeded`.

use crate::util::rng::Rng;

/// Outcome of a property run.
#[derive(Debug)]
pub struct Failure<T: std::fmt::Debug> {
    pub seed: u64,
    pub case: usize,
    pub input: T,
    pub message: String,
}

/// Run `property` over `cases` inputs drawn by `gen`. Returns the first
/// (shrunk) failure, or `None` if all cases pass.
pub fn check<T, G, P, S>(
    base_seed: u64,
    cases: usize,
    mut gen: G,
    mut property: P,
    mut shrink: S,
) -> Option<Failure<T>>
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: FnMut(&T) -> Vec<T>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = property(&input) {
            // Greedy shrink: repeatedly take the first smaller input that
            // still fails, up to a budget.
            let mut best = input;
            let mut best_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in shrink(&best) {
                    budget -= 1;
                    if budget == 0 {
                        break 'outer;
                    }
                    if let Err(m) = property(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            return Some(Failure { seed, case, input: best, message: best_msg });
        }
    }
    None
}

/// Assert-style wrapper: panics with a reproducible report on failure.
pub fn assert_property<T, G, P, S>(
    name: &str,
    base_seed: u64,
    cases: usize,
    gen: G,
    property: P,
    shrink: S,
) where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: FnMut(&T) -> Vec<T>,
{
    if let Some(f) = check(base_seed, cases, gen, property, shrink) {
        panic!(
            "property {name:?} failed (case {} seed {:#x}):\n  input: {:?}\n  error: {}",
            f.case, f.seed, f.input, f.message
        );
    }
}

/// No-op shrinker for inputs that are cheap enough to debug raw.
pub fn no_shrink<T: Clone>(_: &T) -> Vec<T> {
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_returns_none() {
        let r = check(
            1,
            100,
            |rng| rng.index(1000),
            |&x| if x < 1000 { Ok(()) } else { Err("out of range".into()) },
            no_shrink,
        );
        assert!(r.is_none());
    }

    #[test]
    fn failing_property_is_caught_and_shrunk() {
        // Property: x < 50. Generator can produce up to 999. Shrinker
        // halves. The shrunk counterexample should land near the boundary.
        let r = check(
            2,
            200,
            |rng| rng.index(1000),
            |&x| if x < 50 { Ok(()) } else { Err(format!("{x} >= 50")) },
            |&x| if x > 0 { vec![x / 2, x - 1] } else { vec![] },
        )
        .expect("must fail");
        assert!(r.input >= 50, "shrunk input still fails: {}", r.input);
        assert!(r.input <= 60, "shrunk close to boundary: {}", r.input);
    }

    #[test]
    fn seeds_are_deterministic() {
        let gen = |rng: &mut Rng| rng.index(1_000_000);
        let prop = |&x: &usize| if x % 3 != 0 { Ok(()) } else { Err("div3".into()) };
        let f1 = check(7, 50, gen, prop, no_shrink);
        let f2 = check(7, 50, gen, prop, no_shrink);
        assert_eq!(f1.map(|f| f.input), f2.map(|f| f.input));
    }
}
