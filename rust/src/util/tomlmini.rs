//! TOML-subset parser for configuration files.
//!
//! Supports the subset the config system needs: `[table]` and
//! `[table.subtable]` headers, `key = value` with string / integer / float
//! / bool / homogeneous-array values, comments, and bare or quoted keys.
//! Not supported (rejected, never silently misparsed): inline tables,
//! arrays-of-tables, multi-line strings, datetimes.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }
    /// Floats accept integer literals too (`alpha = 1` parses as 1.0).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// A parsed document: dotted-path table name -> key -> value. The root
/// table is the empty string.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    pub tables: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    /// Look up `key` in `table` ("" for root).
    pub fn get(&self, table: &str, key: &str) -> Option<&Value> {
        self.tables.get(table).and_then(|t| t.get(key))
    }

    /// All keys of a table, if present.
    pub fn table(&self, table: &str) -> Option<&BTreeMap<String, Value>> {
        self.tables.get(table)
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc, String> {
    let mut doc = Doc::default();
    let mut current = String::new();
    doc.tables.entry(current.clone()).or_default();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            if line.starts_with("[[") {
                return Err(format!("line {}: arrays of tables unsupported", lineno + 1));
            }
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated table header", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty table name", lineno + 1));
            }
            current = name.to_string();
            doc.tables.entry(current.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim().trim_matches('"').to_string();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
        doc.tables.get_mut(&current).unwrap().insert(key, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside of a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        if body.contains('"') {
            return Err("embedded quotes unsupported".into());
        }
        return Ok(Value::Str(body.replace("\\n", "\n").replace("\\t", "\t")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?.trim();
        if body.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            body.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    let cleaned = s.replace('_', "");
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = cleaned.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_and_types() {
        let doc = parse(
            r#"
# top comment
title = "llep"   # trailing comment
[model]
num_experts = 128
top_k = 4
[llep]
alpha = 1.0
lambda = 1.3
min_gemm_tokens = 1_024
adaptive = true
buckets = [64, 256, 1024]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "title").unwrap().as_str(), Some("llep"));
        assert_eq!(doc.get("model", "num_experts").unwrap().as_usize(), Some(128));
        assert_eq!(doc.get("llep", "alpha").unwrap().as_f64(), Some(1.0));
        assert_eq!(doc.get("llep", "lambda").unwrap().as_f64(), Some(1.3));
        assert_eq!(doc.get("llep", "min_gemm_tokens").unwrap().as_usize(), Some(1024));
        assert_eq!(doc.get("llep", "adaptive").unwrap().as_bool(), Some(true));
        let arr = doc.get("llep", "buckets").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_usize(), Some(1024));
    }

    #[test]
    fn dotted_table_names() {
        let doc = parse("[system.comm]\nintra_gbps = 450.0\n").unwrap();
        assert_eq!(doc.get("system.comm", "intra_gbps").unwrap().as_f64(), Some(450.0));
    }

    #[test]
    fn int_vs_float() {
        let doc = parse("a = 3\nb = 3.0\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap(), &Value::Int(3));
        assert_eq!(doc.get("", "b").unwrap(), &Value::Float(3.0));
        // as_f64 accepts both
        assert_eq!(doc.get("", "a").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("novalue =\n").is_err());
        assert!(parse("= 3\n").is_err());
        assert!(parse("[[aot]]\n").is_err());
        assert!(parse("x = \"unterminated\n").is_err());
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = parse("x = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn negative_and_scientific() {
        let doc = parse("a = -5\nb = 2.5e-3\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_i64(), Some(-5));
        assert!((doc.get("", "b").unwrap().as_f64().unwrap() - 2.5e-3).abs() < 1e-12);
    }
}
