//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [--key=value]
//! [positional...]`. Unknown flags are an error so typos do not silently
//! change experiment parameters.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Specification of accepted options/flags for validation + help.
#[derive(Clone, Debug, Default)]
pub struct Spec {
    /// (name, takes_value, help)
    pub opts: Vec<(&'static str, bool, &'static str)>,
}

impl Spec {
    pub fn new() -> Spec {
        Spec::default()
    }
    pub fn opt(mut self, name: &'static str, help: &'static str) -> Spec {
        self.opts.push((name, true, help));
        self
    }
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Spec {
        self.opts.push((name, false, help));
        self
    }
    pub fn help(&self) -> String {
        let mut s = String::new();
        for (name, takes, help) in &self.opts {
            s.push_str(&format!(
                "  --{}{}\n      {}\n",
                name,
                if *takes { " <value>" } else { "" },
                help
            ));
        }
        s
    }

    /// Parse `argv` (without the program name) against this spec. The first
    /// non-flag token becomes the subcommand if `with_subcommand`.
    pub fn parse(&self, argv: &[String], with_subcommand: bool) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|(n, _, _)| *n == name)
                    .ok_or_else(|| format!("unknown option --{name}"))?;
                if spec.1 {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    out.options.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    out.flags.push(name);
                }
            } else if with_subcommand && out.subcommand.is_none() {
                out.subcommand = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| format!("--{name} expects a number, got {v:?}")),
        }
    }
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn spec() -> Spec {
        Spec::new()
            .opt("fig", "figure id")
            .opt("devices", "EP world size")
            .opt("alpha", "capacity factor")
            .flag("verbose", "chatty output")
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = spec()
            .parse(&argv(&["figures", "--fig", "1a", "--devices=8", "--verbose", "extra"]), true)
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("figures"));
        assert_eq!(a.get("fig"), Some("1a"));
        assert_eq!(a.get_usize("devices", 4).unwrap(), 8);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(&argv(&["run"]), true).unwrap();
        assert_eq!(a.get_usize("devices", 8).unwrap(), 8);
        assert_eq!(a.get_f64("alpha", 1.0).unwrap(), 1.0);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(spec().parse(&argv(&["--bogus"]), false).is_err());
        assert!(spec().parse(&argv(&["--fig"]), false).is_err());
        assert!(spec().parse(&argv(&["--verbose=yes"]), false).is_err());
        let parsed = spec().parse(&argv(&["--devices", "x"]), false).unwrap();
        assert!(parsed.get_usize("devices", 1).is_err());
    }

    #[test]
    fn help_lists_options() {
        let h = spec().help();
        assert!(h.contains("--fig <value>"));
        assert!(h.contains("--verbose\n"));
    }
}
