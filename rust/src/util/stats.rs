//! Summary statistics over timing / load samples.

/// Summary of a sample set (times, loads, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; `samples` need not be sorted. Empty input yields
    /// an all-zero summary.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut s: Vec<f64> = samples.to_vec();
        // total_cmp: NaN samples sort to the ends instead of panicking
        // (partial_cmp().unwrap() would abort on the first NaN).
        s.sort_by(f64::total_cmp);
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            p50: percentile_sorted(&s, 0.50),
            p90: percentile_sorted(&s, 0.90),
            p99: percentile_sorted(&s, 0.99),
            max: s[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Maximum / mean ratio — the paper's imbalance statistic (Alg. 4 guard).
pub fn max_over_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if mean == 0.0 {
        return 0.0;
    }
    xs.iter().cloned().fold(f64::MIN, f64::max) / mean
}

/// Shannon entropy of a (possibly unnormalized) non-negative distribution,
/// in nats. Used as an auxiliary imbalance diagnostic.
pub fn entropy(xs: &[f64]) -> f64 {
    let total: f64 = xs.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    -xs.iter()
        .filter(|&&x| x > 0.0)
        .map(|&x| {
            let p = x / total;
            p * p.ln()
        })
        .sum::<f64>()
}

/// Coefficient of variation (std / mean).
pub fn cv(xs: &[f64]) -> f64 {
    let s = Summary::of(xs);
    if s.mean == 0.0 { 0.0 } else { s.std / s.mean }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn summary_handles_nan_without_panicking() {
        // Regression: sort_by(partial_cmp().unwrap()) panicked on NaN.
        let s = Summary::of(&[1.0, f64::NAN, 3.0]);
        assert_eq!(s.n, 3);
        // Positive NaN sorts after every number under the total order.
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan());
        // All-NaN input must also survive.
        let s = Summary::of(&[f64::NAN, f64::NAN]);
        assert_eq!(s.n, 2);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert!((percentile_sorted(&s, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&s, 0.0), 0.0);
        assert_eq!(percentile_sorted(&s, 1.0), 10.0);
    }

    #[test]
    fn max_over_mean_balanced_is_one() {
        assert!((max_over_mean(&[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn max_over_mean_skewed() {
        // one element has everything: max/mean = n
        assert!((max_over_mean(&[4.0, 0.0, 0.0, 0.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_uniform_is_ln_n() {
        let e = entropy(&[1.0; 8]);
        assert!((e - (8f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn entropy_delta_is_zero() {
        assert_eq!(entropy(&[5.0, 0.0, 0.0]), 0.0);
    }
}
