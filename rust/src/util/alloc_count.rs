//! Per-thread allocation counting (test builds only).
//!
//! The lib test binary installs [`CountingAlloc`] as the global
//! allocator (see `lib.rs`); it delegates to the system allocator and
//! bumps a thread-local counter on every `alloc`/`realloc`, so a test
//! can assert a code path performs zero heap allocations on *its own*
//! thread without interference from concurrently running tests.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static COUNT: Cell<u64> = const { Cell::new(0) };
}

fn bump() {
    // `try_with`: TLS may be mid-teardown when a destructor allocates.
    let _ = COUNT.try_with(|c| c.set(c.get() + 1));
}

/// Allocations performed by the calling thread since it started.
pub fn allocations_on_this_thread() -> u64 {
    COUNT.with(|c| c.get())
}

/// System allocator wrapper that counts per-thread allocations.
pub struct CountingAlloc;

// SAFETY: pure delegation to `System`; the TLS bump has no effect on
// the returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_this_threads_allocations() {
        let before = allocations_on_this_thread();
        let v: Vec<u64> = Vec::with_capacity(32);
        let after = allocations_on_this_thread();
        assert!(after > before, "an allocation was counted");
        drop(v);
        // Pure arithmetic allocates nothing.
        let before = allocations_on_this_thread();
        let x = std::hint::black_box(3u64) * 7;
        assert_eq!(std::hint::black_box(x), 21);
        assert_eq!(allocations_on_this_thread(), before);
    }
}
