//! ASCII bar charts for the `llep figures` output — the terminal
//! equivalent of the paper's bar/line figures.

/// A horizontal bar chart.
#[derive(Clone, Debug, Default)]
pub struct BarChart {
    pub title: String,
    /// (label, value, annotation)
    rows: Vec<(String, f64, String)>,
    /// Width of the bar area in characters.
    pub width: usize,
}

impl BarChart {
    pub fn new(title: &str) -> BarChart {
        BarChart { title: title.to_string(), rows: Vec::new(), width: 46 }
    }

    pub fn bar(&mut self, label: &str, value: f64, annotation: &str) {
        assert!(value.is_finite() && value >= 0.0, "bar value must be finite/non-negative");
        self.rows.push((label.to_string(), value, annotation.to_string()));
    }

    /// Render with bars scaled to the maximum value.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.title);
        out.push('\n');
        if self.rows.is_empty() {
            out.push_str("  (no data)\n");
            return out;
        }
        let max = self.rows.iter().map(|r| r.1).fold(0.0f64, f64::max).max(1e-300);
        let label_w = self.rows.iter().map(|r| r.0.chars().count()).max().unwrap_or(0);
        for (label, value, ann) in &self.rows {
            let filled = ((value / max) * self.width as f64).round() as usize;
            let filled = filled.min(self.width);
            out.push_str(&format!(
                "  {:<lw$} |{}{}| {}\n",
                label,
                "█".repeat(filled),
                " ".repeat(self.width - filled),
                ann,
                lw = label_w
            ));
        }
        out
    }
}

/// A line/series plot rendered as rows of scaled dots (for the Fig.-5
/// loss-vs-wall-clock curve).
#[derive(Clone, Debug)]
pub struct SeriesPlot {
    pub title: String,
    pub height: usize,
    pub width: usize,
    series: Vec<(char, Vec<(f64, f64)>)>,
}

impl SeriesPlot {
    pub fn new(title: &str) -> SeriesPlot {
        SeriesPlot { title: title.to_string(), height: 12, width: 64, series: Vec::new() }
    }

    pub fn series(&mut self, marker: char, points: Vec<(f64, f64)>) {
        self.series.push((marker, points));
    }

    /// Render all series on shared axes.
    pub fn render(&self) -> String {
        let mut out = format!("{}\n", self.title);
        let all: Vec<(f64, f64)> =
            self.series.iter().flat_map(|(_, pts)| pts.iter().copied()).collect();
        if all.is_empty() {
            out.push_str("  (no data)\n");
            return out;
        }
        let (mut x0, mut x1, mut y0, mut y1) = (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
        for &(x, y) in &all {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
        let xr = (x1 - x0).max(1e-12);
        let yr = (y1 - y0).max(1e-12);
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (marker, pts) in &self.series {
            for &(x, y) in pts {
                let col = (((x - x0) / xr) * (self.width - 1) as f64).round() as usize;
                let row = (((y1 - y) / yr) * (self.height - 1) as f64).round() as usize;
                grid[row.min(self.height - 1)][col.min(self.width - 1)] = *marker;
            }
        }
        for (i, row) in grid.iter().enumerate() {
            let y_label = if i == 0 {
                format!("{y1:>9.3}")
            } else if i == self.height - 1 {
                format!("{y0:>9.3}")
            } else {
                " ".repeat(9)
            };
            out.push_str(&format!("{y_label} |{}\n", row.iter().collect::<String>()));
        }
        out.push_str(&format!(
            "{} +{}\n{: >11}{x0:<.3} .. {x1:.3}\n",
            " ".repeat(9),
            "-".repeat(self.width),
            ""
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bars_scale_to_max() {
        let mut c = BarChart::new("speedup");
        c.bar("balanced", 1.0, "1.0x");
        c.bar("95% into 1", 5.0, "5.0x");
        let s = c.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        let count = |l: &str| l.matches('█').count();
        assert_eq!(count(lines[2]), 46, "max bar fills the width");
        assert!((count(lines[1]) as f64 - 46.0 / 5.0).abs() <= 1.0);
        assert!(lines[1].contains("1.0x"));
    }

    #[test]
    fn empty_chart_renders() {
        assert!(BarChart::new("x").render().contains("no data"));
    }

    #[test]
    #[should_panic]
    fn rejects_negative() {
        BarChart::new("x").bar("bad", -1.0, "");
    }

    #[test]
    fn series_plot_places_extremes() {
        let mut p = SeriesPlot::new("loss");
        p.series('o', vec![(0.0, 1.0), (10.0, 0.0)]);
        p.series('x', vec![(5.0, 0.5)]);
        let s = p.render();
        let lines: Vec<&str> = s.lines().collect();
        // first grid row holds the max-y point, last grid row the min-y
        assert!(lines[1].contains('o'));
        assert!(lines[p.height].contains('o'));
        assert!(s.contains('x'));
        assert!(s.contains("0.000 .. 10.000"));
    }

    #[test]
    fn labels_aligned() {
        let mut c = BarChart::new("t");
        c.bar("a", 1.0, "");
        c.bar("long label", 2.0, "");
        let s = c.render();
        let bars: Vec<usize> = s.lines().skip(1).map(|l| l.find('|').unwrap()).collect();
        assert_eq!(bars[0], bars[1], "bar columns aligned");
    }
}
