//! Reporting helpers: text tables, ASCII charts and JSON export of step
//! reports.

pub mod chart;

use crate::coordinator::ChaosStats;
use crate::exec::{ModelStepReport, StepReport};
use crate::placement::PlacementStats;
use crate::util::json::Json;

pub use crate::planner::CacheStats;

pub use crate::util::stats::Summary;

/// Version stamp on every top-level JSON report this crate emits
/// (`report_to_json`, `model_report_to_json`, `tune_report_to_json`,
/// `fleet_report_to_json`, and the `llep chaos --out` payload). Bump on
/// any backwards-incompatible change to a report's shape so downstream
/// consumers can detect payloads they don't understand.
pub const SCHEMA_VERSION: u64 = 1;

/// Format bytes with adaptive unit. Total: output width stays bounded
/// all the way to `u64::MAX` (16 EiB).
pub fn format_bytes(bytes: u64) -> String {
    const EIB: f64 = (1u64 << 60) as f64;
    const PIB: f64 = (1u64 << 50) as f64;
    const TIB: f64 = (1u64 << 40) as f64;
    const GIB: f64 = (1u64 << 30) as f64;
    const MIB: f64 = (1u64 << 20) as f64;
    const KIB: f64 = 1024.0;
    let b = bytes as f64;
    if b >= EIB {
        format!("{:.2} EiB", b / EIB)
    } else if b >= PIB {
        format!("{:.2} PiB", b / PIB)
    } else if b >= TIB {
        format!("{:.2} TiB", b / TIB)
    } else if b >= GIB {
        format!("{:.2} GiB", b / GIB)
    } else if b >= MIB {
        format!("{:.2} MiB", b / MIB)
    } else if b >= KIB {
        format!("{:.1} KiB", b / KIB)
    } else {
        format!("{bytes} B")
    }
}

/// Format seconds with adaptive unit. Total over all of `f64`: NaN and
/// ±inf render as-is (`"NaN s"`), negative durations keep their sign
/// with the unit their magnitude selects, and absurdly large values
/// switch to scientific notation so the output width stays bounded.
pub fn format_secs(s: f64) -> String {
    if !s.is_finite() {
        return format!("{s} s");
    }
    let a = s.abs();
    if a >= 1e6 {
        format!("{s:.3e} s")
    } else if a >= 1.0 {
        format!("{s:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if a >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        let ns = s * 1e9;
        // sub-rounding dust (incl. exact ±0) prints as plain "0 ns"
        // rather than "-0 ns"
        if ns.round() == 0.0 {
            "0 ns".into()
        } else {
            format!("{ns:.0} ns")
        }
    }
}

/// A simple aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                // left-align first column, right-align the rest
                if i == 0 {
                    line.push_str(&format!("{:<w$}", cell, w = widths[i]));
                } else {
                    line.push_str(&format!("{:>w$}", cell, w = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// One comparison line: EP vs LLEP on the same workload.
#[derive(Clone, Debug)]
pub struct Comparison {
    pub label: String,
    pub ep: StepReport,
    pub llep: StepReport,
}

impl Comparison {
    pub fn speedup(&self) -> f64 {
        self.ep.latency_s / self.llep.latency_s
    }
    pub fn memory_ratio(&self) -> f64 {
        self.ep.max_peak_bytes() as f64 / self.llep.max_peak_bytes().max(1) as f64
    }
}

/// JSON export of a step report (for machine-readable bench logs).
pub fn report_to_json(r: &StepReport) -> Json {
    Json::obj(vec![
        ("schema_version", Json::num(SCHEMA_VERSION as f64)),
        ("planner", Json::str(&r.planner)),
        ("latency_s", Json::num(r.latency_s)),
        ("plan_s", Json::num(r.phases.plan_s)),
        ("dispatch_s", Json::num(r.phases.dispatch_s)),
        ("weights_s", Json::num(r.phases.weights_s)),
        ("compute_s", Json::num(r.phases.compute_s)),
        ("combine_s", Json::num(r.phases.combine_s)),
        ("peak_bytes", Json::num(r.max_peak_bytes() as f64)),
        ("bytes_dispatch", Json::num(r.bytes_dispatch as f64)),
        ("bytes_weights", Json::num(r.bytes_weights as f64)),
        ("gemm_calls", Json::num(r.gemm_calls as f64)),
        ("weight_transfers", Json::num(r.weight_transfers as f64)),
        ("oom", Json::Bool(r.oom)),
        ("stranded", Json::Bool(r.stranded)),
        ("fallback_ep", Json::Bool(r.fallback_ep)),
        ("tokens", Json::num(r.tokens as f64)),
        ("throughput_tps", Json::num(r.throughput())),
        ("cache_hits", Json::num(r.cache.hits as f64)),
        ("cache_repairs", Json::num(r.cache.repairs as f64)),
        ("cache_misses", Json::num(r.cache.misses as f64)),
        ("cache_forced", Json::num(r.cache.forced as f64)),
        ("placement", placement_to_json(&r.placement)),
    ])
}

/// JSON export of persistent-placement counters (all zero for
/// stateless planners).
pub fn placement_to_json(p: &PlacementStats) -> Json {
    Json::obj(vec![
        ("relayouts", Json::num(p.relayouts as f64)),
        ("migrations", Json::num(p.migrations as f64)),
        ("evictions", Json::num(p.evictions as f64)),
        ("standby_promotions", Json::num(p.standby_promotions as f64)),
        ("migration_bytes", Json::num(p.migration_bytes as f64)),
        ("migration_s", Json::num(p.migration_s)),
    ])
}

/// Compact placement cell for serving tables: `-` when the planner
/// never touched the layout.
pub fn format_placement(p: &PlacementStats) -> String {
    if !p.any() {
        "-".into()
    } else {
        let mut s = format!("{} mig / {}", p.migrations, format_bytes(p.migration_bytes));
        if p.standby_promotions > 0 {
            s.push_str(&format!(" / {} promo", p.standby_promotions));
        }
        s
    }
}

/// Format plan-cache counters as `hits/lookups (rate)` — with a `+Nr`
/// repair term when the delta-repair tier fired — or `-` when the
/// planner has no cache.
pub fn format_cache(c: &CacheStats) -> String {
    if c.lookups() == 0 {
        "-".into()
    } else if c.repairs > 0 {
        format!("{}+{}r/{} ({:.0}%)", c.hits, c.repairs, c.lookups(), c.hit_rate() * 100.0)
    } else {
        format!("{}/{} ({:.0}%)", c.hits, c.lookups(), c.hit_rate() * 100.0)
    }
}

/// Planner-comparison rows over the same workload: one full-model report
/// per planner, speedup measured against the first row (the baseline).
pub fn planner_comparison_table(reports: &[ModelStepReport]) -> Table {
    let mut t = Table::new(&["planner", "latency", "speedup", "peak mem", "plan cache"]);
    let base = reports.first().map(|r| r.latency_s).unwrap_or(0.0);
    for r in reports {
        let speedup =
            if r.latency_s > 0.0 { format!("{:.2}x", base / r.latency_s) } else { "-".into() };
        t.row(vec![
            r.planner.clone(),
            format_secs(r.latency_s),
            speedup,
            format_bytes(r.max_peak_bytes()),
            format_cache(&r.cache),
        ]);
    }
    t
}

/// Ranked tuner trials (best first): one row per evaluated spec.
pub fn tune_trials_table(trials: &[crate::tune::Trial]) -> Table {
    let mut t = Table::new(&["spec", "latency", "peak mem", "budget", "status"]);
    for trial in trials {
        let status = if trial.metrics.oom {
            "OOM"
        } else if trial.metrics.stranded {
            "STRANDED"
        } else {
            "-"
        };
        t.row(vec![
            trial.spec.clone(),
            format_secs(trial.metrics.latency_s),
            format_bytes(trial.metrics.peak_bytes),
            trial.budget.to_string(),
            status.into(),
        ]);
    }
    t
}

/// The tuner's Pareto front, latency-ascending, with the recommended
/// spec (`front[0]`) marked.
pub fn tune_front_table(outcome: &crate::tune::TuneOutcome) -> Table {
    let mut t = Table::new(&["spec", "latency", "peak mem", ""]);
    let recommended = outcome.recommended.as_ref().map(|r| r.spec.as_str());
    for trial in &outcome.front {
        let mark = if Some(trial.spec.as_str()) == recommended {
            "<- recommended".to_string()
        } else {
            String::new()
        };
        t.row(vec![
            trial.spec.clone(),
            format_secs(trial.metrics.latency_s),
            format_bytes(trial.metrics.peak_bytes),
            mark,
        ]);
    }
    t
}

/// JSON export of a tune run (trial list, front, recommendation).
pub fn tune_report_to_json(
    outcome: &crate::tune::TuneOutcome,
    profile: &str,
    scenario: &str,
) -> Json {
    let trial_json = |t: &crate::tune::Trial| {
        Json::obj(vec![
            ("spec", Json::str(&t.spec)),
            ("latency_s", Json::num(t.metrics.latency_s)),
            ("peak_bytes", Json::num(t.metrics.peak_bytes as f64)),
            ("budget", Json::num(t.budget as f64)),
            ("oom", Json::Bool(t.metrics.oom)),
            ("stranded", Json::Bool(t.metrics.stranded)),
        ])
    };
    Json::obj(vec![
        ("schema_version", Json::num(SCHEMA_VERSION as f64)),
        ("profile", Json::str(profile)),
        ("scenario", Json::str(scenario)),
        ("strategy", Json::str(&outcome.strategy)),
        ("specs_considered", Json::num(outcome.specs_considered as f64)),
        ("priced_units", Json::num(outcome.priced_units as f64)),
        ("final_budget", Json::num(outcome.final_budget as f64)),
        ("trials", Json::arr(outcome.trials.iter().map(trial_json))),
        ("front", Json::arr(outcome.front.iter().map(trial_json))),
        (
            "recommended",
            outcome
                .recommended
                .as_ref()
                .map(trial_json)
                .unwrap_or(Json::Null),
        ),
    ])
}

/// JSON export of a serving run's chaos accounting.
pub fn chaos_stats_to_json(c: &ChaosStats) -> Json {
    Json::obj(vec![
        ("fault_steps", Json::num(c.fault_steps as f64)),
        ("failures", Json::num(c.failures as f64)),
        ("recoveries", Json::num(c.recoveries as f64)),
        ("requeues", Json::num(c.requeues as f64)),
        ("requeued_tokens", Json::num(c.requeued_tokens as f64)),
        ("wasted_s", Json::num(c.wasted_s)),
        ("max_recovery_steps", Json::num(c.max_recovery_steps as f64)),
    ])
}

/// Compact chaos-counter cell for serving tables: `-` when the run saw
/// no degradation at all.
pub fn format_chaos(c: &ChaosStats) -> String {
    if *c == ChaosStats::default() {
        "-".into()
    } else {
        format!(
            "{} fail / {} requeue / {} wasted",
            c.failures,
            c.requeues,
            format_secs(c.wasted_s)
        )
    }
}

/// JSON export of a latency summary (seconds).
pub fn summary_to_json(s: &Summary) -> Json {
    Json::obj(vec![
        ("n", Json::num(s.n as f64)),
        ("mean", Json::num(s.mean)),
        ("min", Json::num(s.min)),
        ("p50", Json::num(s.p50)),
        ("p99", Json::num(s.p99)),
        ("max", Json::num(s.max)),
    ])
}

/// Per-replica breakdown table of a fleet run.
pub fn fleet_replica_table(r: &crate::fleet::FleetReport) -> Table {
    let mut t = Table::new(&[
        "replica", "planner", "speed", "routed", "done", "steps", "util", "peak mem", "ledger",
        "brk", "chaos",
    ]);
    for (i, p) in r.replicas.iter().enumerate() {
        t.row(vec![
            format!("R{i}"),
            p.planner.clone(),
            format!("{:.2}x", p.speed),
            p.routed.to_string(),
            p.completed.to_string(),
            p.steps.to_string(),
            format!("{:.0}%", p.utilization * 100.0),
            format_bytes(p.peak_bytes),
            if p.tokens.is_exact() {
                format!("{} ok", p.tokens.admitted)
            } else {
                format!("{}!={} BROKEN", p.tokens.admitted, p.tokens.priced)
            },
            if p.breaker_opens == 0 { "-".into() } else { format!("{} open", p.breaker_opens) },
            format_chaos(&p.chaos),
        ]);
    }
    t
}

/// JSON export of a fleet run (SLO summaries, summed ledger, per-replica
/// slices) — the `llep fleet --out` payload.
pub fn fleet_report_to_json(r: &crate::fleet::FleetReport) -> Json {
    Json::obj(vec![
        ("schema_version", Json::num(SCHEMA_VERSION as f64)),
        ("router", Json::str(&r.router)),
        ("workload", Json::str(&r.workload)),
        ("requests", Json::num(r.requests as f64)),
        ("completed", Json::num(r.completed as f64)),
        ("shed", Json::num(r.shed as f64)),
        ("protected", Json::Bool(r.protected)),
        ("makespan_s", Json::num(r.makespan_s)),
        ("ttft", summary_to_json(&r.ttft)),
        ("tpot", summary_to_json(&r.tpot)),
        ("request_latency", summary_to_json(&r.request_latency)),
        (
            "deadline_s",
            r.deadline_s.map(Json::num).unwrap_or(Json::Null),
        ),
        ("on_time", Json::num(r.on_time as f64)),
        ("goodput_tps", Json::num(r.goodput_tps)),
        ("throughput_tps", Json::num(r.throughput_tps)),
        ("tokens_admitted", Json::num(r.tokens.admitted as f64)),
        ("tokens_priced", Json::num(r.tokens.priced as f64)),
        ("ledger_exact", Json::Bool(r.tokens.is_exact())),
        ("chaos", chaos_stats_to_json(&r.chaos)),
        ("replica_failures", Json::num(r.replica_failures as f64)),
        ("replica_recoveries", Json::num(r.replica_recoveries as f64)),
        ("requeued_requests", Json::num(r.requeued_requests as f64)),
        ("max_requeues", Json::num(r.max_requeues as f64)),
        (
            "overload",
            Json::obj(vec![
                ("shed_deadline", Json::num(r.overload.shed_deadline as f64)),
                ("shed_frontend", Json::num(r.overload.shed_frontend as f64)),
                ("shed_retries", Json::num(r.overload.shed_retries as f64)),
                ("retries", Json::num(r.overload.retries as f64)),
                ("breaker_opens", Json::num(r.overload.breaker_opens as f64)),
                ("breaker_probes", Json::num(r.overload.breaker_probes as f64)),
                ("backoff_total_s", Json::num(r.overload.backoff_total_s)),
                (
                    "frontend_peak_depth",
                    Json::num(r.overload.frontend_peak_depth as f64),
                ),
            ]),
        ),
        (
            "replicas",
            Json::arr(r.replicas.iter().map(|p| {
                Json::obj(vec![
                    ("planner", Json::str(&p.planner)),
                    ("speed", Json::num(p.speed)),
                    ("routed", Json::num(p.routed as f64)),
                    ("completed", Json::num(p.completed as f64)),
                    ("steps", Json::num(p.steps as f64)),
                    ("utilization", Json::num(p.utilization)),
                    ("peak_bytes", Json::num(p.peak_bytes as f64)),
                    ("oom_steps", Json::num(p.oom_steps as f64)),
                    ("fallback_steps", Json::num(p.fallback_steps as f64)),
                    ("tokens_admitted", Json::num(p.tokens.admitted as f64)),
                    ("tokens_priced", Json::num(p.tokens.priced as f64)),
                    ("ledger_exact", Json::Bool(p.tokens.is_exact())),
                    ("cache_hits", Json::num(p.plan_cache.hits as f64)),
                    ("cache_repairs", Json::num(p.plan_cache.repairs as f64)),
                    ("cache_misses", Json::num(p.plan_cache.misses as f64)),
                    ("cache_forced", Json::num(p.plan_cache.forced as f64)),
                    ("breaker_opens", Json::num(p.breaker_opens as f64)),
                    ("placement", placement_to_json(&p.placement)),
                    ("chaos", chaos_stats_to_json(&p.chaos)),
                ])
            })),
        ),
    ])
}

/// Per-layer latency/memory breakdown of a full-model step.
pub fn model_report_table(r: &ModelStepReport) -> Table {
    let mut t = Table::new(&[
        "layer", "latency", "plan", "dispatch", "weights", "compute", "combine", "peak mem",
        "xfers", "mode",
    ]);
    for (i, layer) in r.layers.iter().enumerate() {
        let rep = &layer.report;
        t.row(vec![
            format!("L{i}"),
            format_secs(rep.latency_s),
            format_secs(rep.phases.plan_s),
            format_secs(rep.phases.dispatch_s),
            format_secs(rep.phases.weights_s),
            format_secs(rep.phases.compute_s),
            format_secs(rep.phases.combine_s),
            format_bytes(rep.max_peak_bytes()),
            rep.weight_transfers.to_string(),
            if rep.fallback_ep { "EP-fallback".into() } else { "LLA".into() },
        ]);
    }
    t
}

/// JSON export of a full-model step report, including the per-layer
/// latency and memory series (for machine-readable bench logs).
pub fn model_report_to_json(r: &ModelStepReport) -> Json {
    Json::obj(vec![
        ("schema_version", Json::num(SCHEMA_VERSION as f64)),
        ("planner", Json::str(&r.planner)),
        ("layers", Json::num(r.num_layers() as f64)),
        ("latency_s", Json::num(r.latency_s)),
        ("serial_latency_s", Json::num(r.serial_latency_s)),
        ("overlap_saved_s", Json::num(r.overlap_saved_s)),
        ("peak_bytes", Json::num(r.max_peak_bytes() as f64)),
        ("tokens", Json::num(r.tokens as f64)),
        ("throughput_tps", Json::num(r.throughput())),
        ("oom", Json::Bool(r.oom)),
        ("stranded", Json::Bool(r.stranded)),
        ("fallback_layers", Json::num(r.fallback_layers as f64)),
        ("cache_hits", Json::num(r.cache.hits as f64)),
        ("cache_repairs", Json::num(r.cache.repairs as f64)),
        ("cache_misses", Json::num(r.cache.misses as f64)),
        ("cache_forced", Json::num(r.cache.forced as f64)),
        ("cache_hit_rate", Json::num(r.cache.hit_rate())),
        ("placement", placement_to_json(&r.placement)),
        (
            "layer_latencies_s",
            Json::arr(r.layers.iter().map(|l| Json::num(l.report.latency_s))),
        ),
        (
            "layer_peak_bytes",
            Json::arr(r.layers.iter().map(|l| Json::num(l.report.max_peak_bytes() as f64))),
        ),
        (
            "layer_weight_transfers",
            Json::arr(r.layers.iter().map(|l| Json::num(l.report.weight_transfers as f64))),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(format_bytes(512), "512 B");
        assert_eq!(format_bytes(2048), "2.0 KiB");
        assert!(format_bytes(3 << 20).contains("MiB"));
        assert!(format_bytes(5 << 30).contains("GiB"));
    }

    #[test]
    fn secs_formatting() {
        assert!(format_secs(2.5).contains(" s"));
        assert!(format_secs(2.5e-3).contains("ms"));
        assert!(format_secs(2.5e-6).contains("µs"));
        assert!(format_secs(2.5e-9).contains("ns"));
    }

    #[test]
    fn secs_formatting_is_total() {
        // degenerate inputs render without panicking and keep a unit
        assert_eq!(format_secs(f64::NAN), "NaN s");
        assert_eq!(format_secs(f64::INFINITY), "inf s");
        assert_eq!(format_secs(f64::NEG_INFINITY), "-inf s");
        assert_eq!(format_secs(0.0), "0 ns");
        assert_eq!(format_secs(-0.0), "0 ns");
        assert_eq!(format_secs(1e-15), "0 ns");
        assert_eq!(format_secs(-1e-15), "0 ns");
        // negatives keep their sign and magnitude-selected unit
        assert_eq!(format_secs(-2.5e-3), "-2.500 ms");
        assert_eq!(format_secs(-3.0), "-3.000 s");
        // huge magnitudes stay bounded-width via scientific notation
        let huge = format_secs(1e30);
        assert!(huge.ends_with(" s") && huge.len() < 16, "{huge}");
        assert!(format_secs(f64::MAX).ends_with(" s"));
    }

    #[test]
    fn bytes_formatting_covers_large_tiers() {
        assert!(format_bytes(3 << 40).contains("TiB"));
        assert!(format_bytes(3 << 50).contains("PiB"));
        let max = format_bytes(u64::MAX);
        assert!(max.contains("EiB") && max.len() < 12, "{max}");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["scenario", "speedup"]);
        t.row(vec!["balanced".into(), "1.00x".into()]);
        t.row(vec!["95% into 1".into(), "4.61x".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("scenario"));
        assert!(lines[3].contains("4.61x"));
        // all data lines equal width
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn model_report_breakdown_lists_every_layer() {
        use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
        use crate::exec::Engine;
        use crate::planner::PlannerKind;
        use crate::routing::DepthProfile;
        use crate::util::rng::Rng;

        let mut model = ModelConfig::preset(ModelPreset::Fig1Layer);
        model.num_layers = 3;
        let engine = Engine::modeled(model.clone(), SystemConfig::preset(SystemPreset::H200x8));
        let profile = DepthProfile::varying(&model, 0.5, 0.0);
        let mut rng = Rng::new(1);
        let r = engine.run_model_profile(&profile, &PlannerKind::llep_default(), 4096, &mut rng);

        let table = model_report_table(&r);
        assert_eq!(table.rows.len(), 3);
        assert!(table.render().contains("L2"));

        let json = model_report_to_json(&r).to_string();
        assert!(json.contains("\"layers\""));
        assert!(json.contains("layer_latencies_s"));
        assert!(json.contains("cache_hit_rate"));
    }

    #[test]
    fn planner_comparison_includes_cache_column() {
        use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
        use crate::exec::Engine;
        use crate::planner::{CachedPlanner, PlannerKind};
        use crate::routing::{DepthProfile, Scenario};
        use crate::util::rng::Rng;

        let engine = Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        );
        let profile = DepthProfile::uniform(Scenario::concentrated(0.9, 1), 1);
        let mut rng = Rng::new(2);
        let ep = engine.run_model_profile(&profile, &PlannerKind::StandardEp, 4096, &mut rng);
        let cached = CachedPlanner::new(PlannerKind::llep_default().boxed());
        let warm = engine.run_model_profile(&profile, &cached, 4096, &mut Rng::new(2));
        let hit = engine.run_model_profile(&profile, &cached, 4096, &mut Rng::new(2));
        assert_eq!(warm.cache.misses, 1);
        assert_eq!(hit.cache.hits, 1);

        let t = planner_comparison_table(&[ep, warm, hit]);
        assert_eq!(t.rows.len(), 3);
        let rendered = t.render();
        assert!(rendered.contains("plan cache"), "{rendered}");
        assert!(rendered.contains("1/1 (100%)"), "{rendered}");
        assert!(rendered.contains("EP"), "{rendered}");
    }

    #[test]
    fn tune_tables_and_json_render() {
        use crate::tune::{Trial, TrialMetrics, TuneOutcome};
        let trial = |spec: &str, lat: f64, mem: u64, oom: bool| Trial {
            spec: spec.into(),
            budget: 4,
            metrics: TrialMetrics { latency_s: lat, peak_bytes: mem, oom, stranded: false },
        };
        let trials =
            vec![trial("llep", 1e-3, 1 << 30, false), trial("ep", 2e-3, 2 << 30, false)];
        let front = trials.clone();
        let outcome = TuneOutcome {
            strategy: "grid".into(),
            specs_considered: 2,
            priced_units: 8,
            final_budget: 4,
            recommended: Some(trials[0].clone()),
            trials,
            front,
        };
        let t = tune_trials_table(&outcome.trials);
        assert_eq!(t.rows.len(), 2);
        let f = tune_front_table(&outcome).render();
        assert!(f.contains("<- recommended"), "{f}");
        assert!(f.contains("llep"), "{f}");
        let json = tune_report_to_json(&outcome, "h200x8", "95% into 1").to_string();
        assert!(json.contains("\"recommended\""), "{json}");
        assert!(json.contains("\"priced_units\":8"), "{json}");
    }

    #[test]
    fn fleet_table_and_json_render() {
        use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
        use crate::exec::Engine;
        use crate::fleet::{FleetSim, ReplicaConfig, Workload};
        use crate::routing::Scenario;

        let engine = Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        );
        let sim = FleetSim::new(
            engine,
            Scenario::concentrated(0.8, 4),
            vec![ReplicaConfig::default(), ReplicaConfig::default().with_speed(0.5)],
            16_384,
        )
        .with_workload(Workload::parse("poisson:n=8,ia=0.001,prompt=64-256,decode=2-4").unwrap());
        let r = sim.try_run(1).unwrap();

        let table = fleet_replica_table(&r).render();
        assert!(table.contains("R0"), "{table}");
        assert!(table.contains("0.50x"), "{table}");
        assert!(table.contains("ok"), "{table}");

        let json = fleet_report_to_json(&r).to_string();
        assert!(json.contains("\"router\""), "{json}");
        assert!(json.contains("\"goodput_tps\""), "{json}");
        assert!(json.contains("\"ledger_exact\":true"), "{json}");
        assert!(json.contains("\"deadline_s\":null"), "{json}");
        assert!(json.contains("\"replicas\":["), "{json}");
        assert!(json.contains("\"shed\":0"), "{json}");
        assert!(json.contains("\"protected\":false"), "{json}");
        assert!(json.contains("\"overload\":{"), "{json}");
        assert!(json.contains("\"shed_frontend\":0"), "{json}");
        assert!(json.contains("\"breaker_opens\":0"), "{json}");
    }

    #[test]
    fn fleet_json_reports_protected_overload_counters() {
        use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
        use crate::exec::Engine;
        use crate::fleet::{FleetSim, OverloadConfig, ReplicaConfig, Workload};
        use crate::routing::Scenario;

        let engine = Engine::modeled(
            ModelConfig::preset(ModelPreset::Fig1Layer),
            SystemConfig::preset(SystemPreset::H200x8),
        );
        let sim = FleetSim::new(
            engine,
            Scenario::concentrated(0.8, 4),
            vec![ReplicaConfig::default(), ReplicaConfig::default()],
            16_384,
        )
        .with_workload(
            Workload::parse("bursty:n=12,ia=0.0002,burst=12,every=12,prompt=64-256,decode=2-4")
                .unwrap(),
        )
        .with_overload(
            OverloadConfig::parse("queue-cap=1,frontend-cap=1,retries=1").unwrap(),
        );
        let r = sim.try_run(2).unwrap();
        assert_eq!(r.completed + r.shed, r.requests);
        assert!(r.shed > 0);

        let json = fleet_report_to_json(&r).to_string();
        assert!(json.contains("\"protected\":true"), "{json}");
        assert!(json.contains(&format!("\"shed\":{}", r.shed)), "{json}");
        assert!(
            json.contains(&format!("\"shed_frontend\":{}", r.overload.shed_frontend)),
            "{json}"
        );
        assert!(json.contains("\"frontend_peak_depth\":1"), "{json}");
    }

    #[test]
    fn cache_formatting() {
        assert_eq!(format_cache(&CacheStats::default()), "-");
        let c = CacheStats { hits: 3, repairs: 0, misses: 1, forced: 0 };
        assert_eq!(format_cache(&c), "3/4 (75%)");
        let r = CacheStats { hits: 3, repairs: 2, misses: 1, forced: 0 };
        assert_eq!(format_cache(&r), "3+2r/6 (83%)");
    }

    #[test]
    fn placement_formatting_and_json() {
        assert_eq!(format_placement(&PlacementStats::default()), "-");
        let p = PlacementStats {
            relayouts: 2,
            migrations: 3,
            evictions: 0,
            standby_promotions: 1,
            migration_bytes: 3 << 20,
            migration_s: 1e-3,
        };
        let cell = format_placement(&p);
        assert!(cell.contains("3 mig"), "{cell}");
        assert!(cell.contains("1 promo"), "{cell}");
        let json = placement_to_json(&p).to_string();
        assert!(json.contains("\"migrations\":3"), "{json}");
        assert!(json.contains("\"standby_promotions\":1"), "{json}");
        assert!(json.contains("\"migration_s\""), "{json}");
    }

    #[test]
    fn chaos_formatting_and_json() {
        assert_eq!(format_chaos(&ChaosStats::default()), "-");
        let c = ChaosStats {
            fault_steps: 5,
            failures: 1,
            recoveries: 0,
            requeues: 1,
            requeued_tokens: 4096,
            wasted_s: 0.25,
            max_recovery_steps: 1,
        };
        let cell = format_chaos(&c);
        assert!(cell.contains("1 fail"), "{cell}");
        assert!(cell.contains("1 requeue"), "{cell}");
        let json = chaos_stats_to_json(&c).to_string();
        assert!(json.contains("\"failures\":1"), "{json}");
        assert!(json.contains("\"requeued_tokens\":4096"), "{json}");
        assert!(json.contains("\"max_recovery_steps\":1"), "{json}");
    }
}
