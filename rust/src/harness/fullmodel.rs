//! Full-model throughput estimation (paper Fig. 1c / §5.2).
//!
//! A full forward step = per-layer (attention + dense overhead) + the MoE
//! layer's dispatch-compute-combine. The attention/dense part is a fixed,
//! parallelism-agnostic per-token cost (the paper: "full model throughput
//! is impacted by other irrelevant factors and fixed overheads"); only
//! the MoE part differs between EP and LLEP, so full-model speedup is a
//! damped version of the MoE-layer speedup — exactly the Fig.-1c shape.

use crate::config::{ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use crate::coordinator::attention_overhead_s;
use crate::exec::{Engine, ModelStepReport};
use crate::planner::{Planner, PlannerKind};
use crate::routing::{DepthProfile, Scenario};
use crate::util::rng::Rng;

/// One Fig.-1c row.
#[derive(Clone, Debug)]
pub struct ThroughputRow {
    pub model: String,
    pub devices: usize,
    pub ep_tps: f64,
    pub llep_tps: f64,
    /// Seconds per step spent outside MoE layers (attention etc.).
    pub overhead_s: f64,
}

impl ThroughputRow {
    pub fn speedup(&self) -> f64 {
        self.llep_tps / self.ep_tps
    }
}

/// Estimate full-model EP vs LLEP throughput on the in-the-wild routing
/// distribution (drifting dominant expert, as measured in paper §3.1).
pub fn throughput_row(
    preset: ModelPreset,
    devices: usize,
    tokens_per_device: usize,
    seed: u64,
) -> ThroughputRow {
    let model = ModelConfig::preset(preset);
    let system = SystemConfig::preset(SystemPreset::H200x8).with_devices(devices);
    let engine = Engine::modeled(model.clone(), system);
    let mut rng = Rng::new(seed);

    // In-the-wild imbalance: a dominant expert near 20% of tokens with
    // per-batch drift (paper Fig. 3 on the math dataset).
    let scenario = Scenario::drifting(model.num_experts / 3, 0.20, 0.25);

    let total_tokens = (tokens_per_device * devices) as f64;
    // attention/dense time per step, spread across devices (data parallel)
    // — priced by the replica core's shared helper.
    let attn_s = attention_overhead_s(&engine, total_tokens);

    let mut ep_moe = 0.0;
    let mut llep_moe = 0.0;
    let batches = 4;
    for _ in 0..batches {
        let lm = scenario.generate_loads(&model, devices, tokens_per_device, &mut rng);
        ep_moe += engine.run_step_loads(&lm, &PlannerKind::StandardEp).latency_s;
        llep_moe += engine.run_step_loads(&lm, &PlannerKind::llep_default()).latency_s;
    }
    let layers = model.num_layers as f64;
    let ep_step = attn_s + layers * ep_moe / batches as f64;
    let llep_step = attn_s + layers * llep_moe / batches as f64;

    ThroughputRow {
        model: model.name,
        devices,
        ep_tps: total_tokens / ep_step,
        llep_tps: total_tokens / llep_step,
        overhead_s: attn_s,
    }
}

/// Layer-by-layer full-model simulation: each MoE layer carries its own
/// routing distribution (different layers specialize on different
/// experts — paper Fig. 3a is a per-layer maximum), so per-batch the
/// imbalance degree varies across depth exactly as observed in §3.1.
/// Steps are priced with the pipelined multi-layer engine
/// ([`Engine::run_model`]): one plan per layer, planning for layer `L+1`
/// overlapped with execution of layer `L`.
pub struct FullModelSim {
    pub engine: Engine,
    /// Per-layer routing scenarios (layer i favours a different expert).
    pub profile: DepthProfile,
}

/// Per-step result of the layered simulation.
#[derive(Clone, Debug)]
pub struct FullModelStep {
    pub moe_s: f64,
    pub attn_s: f64,
    pub peak_bytes: u64,
    pub fallback_layers: usize,
    /// Full per-layer breakdown of the MoE part.
    pub report: ModelStepReport,
}

impl FullModelStep {
    pub fn total_s(&self) -> f64 {
        self.moe_s + self.attn_s
    }
}

impl FullModelSim {
    pub fn new(preset: ModelPreset, devices: usize, dominance: f64, drift: f64) -> FullModelSim {
        let model = ModelConfig::preset(preset);
        let system = SystemConfig::preset(SystemPreset::H200x8).with_devices(devices);
        let profile = DepthProfile::varying(&model, dominance, drift);
        FullModelSim { engine: Engine::modeled(model, system), profile }
    }

    /// Simulate one full forward step under `planner`.
    pub fn step(
        &self,
        planner: &dyn Planner,
        tokens_per_device: usize,
        rng: &mut Rng,
    ) -> FullModelStep {
        let devices = self.engine.system.devices;
        let total_tokens = (tokens_per_device * devices) as f64;
        let attn_s = attention_overhead_s(&self.engine, total_tokens);
        let report = self.engine.run_model_profile(&self.profile, planner, tokens_per_device, rng);
        FullModelStep {
            moe_s: report.latency_s,
            attn_s,
            peak_bytes: report.max_peak_bytes(),
            fallback_layers: report.fallback_layers,
            report,
        }
    }

    /// Throughput (tokens/s) averaged over `batches` steps.
    pub fn throughput(
        &self,
        planner: &dyn Planner,
        tokens_per_device: usize,
        batches: usize,
        seed: u64,
    ) -> f64 {
        let mut rng = Rng::new(seed);
        let total: f64 = (0..batches)
            .map(|_| self.step(planner, tokens_per_device, &mut rng).total_s())
            .sum();
        (tokens_per_device * self.engine.system.devices * batches) as f64 / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layered_sim_matches_analytic_shape() {
        let sim = FullModelSim::new(ModelPreset::GptOss20b, 8, 0.20, 0.25);
        let ep = sim.throughput(&PlannerKind::StandardEp, 8192, 3, 1);
        let ll = sim.throughput(&PlannerKind::llep_default(), 8192, 3, 1);
        let speedup = ll / ep;
        assert!(speedup > 1.05 && speedup < 4.0, "layered speedup {speedup:.2}");
    }

    #[test]
    fn per_layer_imbalance_varies() {
        let sim = FullModelSim::new(ModelPreset::GptOss20b, 8, 0.20, 0.5);
        let mut rng = Rng::new(2);
        let step = sim.step(&PlannerKind::llep_default(), 8192, &mut rng);
        // with drift=0.5 some layers are balanced enough to fall back,
        // others are not — both behaviours appear in one step
        assert!(step.fallback_layers < sim.engine.model.num_layers);
        assert!(step.moe_s > 0.0 && step.attn_s > 0.0);
    }

    #[test]
    fn pipelined_step_reports_per_layer_breakdown() {
        let sim = FullModelSim::new(ModelPreset::GptOss20b, 8, 0.3, 0.2);
        let mut rng = Rng::new(5);
        let step = sim.step(&PlannerKind::llep_default(), 8192, &mut rng);
        assert_eq!(step.report.num_layers(), sim.engine.model.num_moe_layers());
        // ms-scale execution always hides the µs-scale planning of the
        // next layer, so pipelining must save something real.
        assert!(step.report.overlap_saved_s > 0.0);
        assert!(step.moe_s < step.report.serial_latency_s);
        assert_eq!(step.report.layer_latencies_s().len(), step.report.num_layers());
    }

    #[test]
    fn llep_full_model_speedup_damped_but_real() {
        let row = throughput_row(ModelPreset::GptOss120b, 8, 32_768, 1);
        let s = row.speedup();
        assert!(s > 1.1, "full-model speedup too small: {s:.2}");
        assert!(s < 4.0, "full-model speedup should be damped by attention: {s:.2}");
    }

    #[test]
    fn more_devices_more_relative_speedup() {
        // Paper §5.2: "better scaling efficiency with greater relative
        // speedups the more GPUs are used".
        let s4 = throughput_row(ModelPreset::GptOss20b, 4, 32_768, 2).speedup();
        let s8 = throughput_row(ModelPreset::GptOss20b, 8, 32_768, 2).speedup();
        assert!(s8 > s4 * 0.95, "P=8 {s8:.2} vs P=4 {s4:.2}");
    }

    #[test]
    fn throughput_positive_and_ordered() {
        let row = throughput_row(ModelPreset::GptOss20b, 8, 16_384, 3);
        assert!(row.ep_tps > 0.0 && row.llep_tps > row.ep_tps);
        assert!(row.overhead_s > 0.0);
    }
}
