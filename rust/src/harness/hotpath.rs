//! The `hotpath` bench suite behind `llep bench --suite hotpath`.
//!
//! One callable definition of the planning/pricing micro-benchmarks the
//! perf-regression gate pins (`BENCH_planner.json`): the CLI, CI, and
//! `cargo bench --bench planner` all run the same cases, so a pinned
//! median means the same thing everywhere.
//!
//! The headline case is the **skewed-scenario planner microbench**
//! (`plan/llep/skewed/...`): 90% of the load into one hot expert on the
//! Fig-1 layer — the regime where LLEP's spill loop does real work. It
//! is measured twice: `alloc` plans with a fresh arena every call (the
//! historical allocating path) and `scratch` reuses one arena with plan
//! recycling (the steady-state engine path); the ratio between them is
//! the zero-allocation win, and the pin keeps both from regressing.

use crate::config::{LlepConfig, ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use crate::exec::{price_plan, Engine};
use crate::planner::{
    plan_llep_scratch, plan_lpt_scratch, CachedPlanner, PlanScratch, Planner, PlannerKind,
};
use crate::routing::Scenario;
use crate::util::benchkit::{bb, BenchSuite, Bencher};
use crate::util::rng::Rng;

/// Tolerance band the `--check` gate defaults to: medians more than 25%
/// above the pin fail CI.
pub const DEFAULT_TOLERANCE: f64 = 0.25;

/// Run the hotpath suite and collect its results.
pub fn hotpath_suite(quick: bool) -> BenchSuite {
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let mut suite = BenchSuite::new("hotpath");

    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer), // N=128 experts
        SystemConfig::preset(SystemPreset::H200x8),
    );
    let mut rng = Rng::new(7);
    let skewed =
        Scenario::concentrated(0.9, 1).generate_loads(&engine.model, 8, 32_768, &mut rng);
    let loads = skewed.expert_loads();
    let balanced = Scenario::balanced().generate_loads(&engine.model, 8, 32_768, &mut rng);
    let balanced_loads = balanced.expert_loads();
    let cfg = LlepConfig::default();
    let llep = PlannerKind::llep_default();

    // --- the skewed-scenario planner microbench (pinned headline) ---
    let mut scratch = PlanScratch::new();
    b.bench("plan/llep/skewed/scratch/N=128/P=8", || {
        let p = plan_llep_scratch(&cfg, 128, 8, &loads, None, None, &mut scratch);
        let k = p.transfers.len();
        scratch.recycle(p);
        k
    });
    b.bench("plan/llep/skewed/alloc/N=128/P=8", || {
        let mut fresh = PlanScratch::new();
        let p = plan_llep_scratch(&cfg, 128, 8, &loads, None, None, &mut fresh);
        p.transfers.len()
    });
    b.bench("plan/llep/balanced/guard/N=128/P=8", || {
        let p = llep.plan_with_stats(8, &balanced_loads, &balanced_loads, None);
        let k = p.fallback_ep as usize;
        crate::planner::recycle_plan(p);
        k
    });

    // --- LPT rebalancer on the same skew ---
    b.bench("plan/lpt/skewed/scratch/N=128/P=8", || {
        let p = plan_lpt_scratch(1024, 128, 8, &loads, None, &mut scratch);
        let k = p.transfers.len();
        scratch.recycle(p);
        k
    });

    // --- plan-cache hit (retarget path) ---
    let cached = CachedPlanner::new(PlannerKind::llep_default().boxed());
    let _ = cached.plan(8, &loads, None); // prime: one miss
    b.bench("plan/cached-hit/skewed/N=128/P=8", || {
        let p = cached.plan(8, &loads, None);
        let k = p.transfers.len();
        crate::planner::recycle_plan(p);
        k
    });

    // --- delta repair vs. fresh replan under decode-style drift ---
    // ~3% of total load oscillates off the hot expert: past the
    // retarget threshold (drift ≈ 0.0625 > 0.05) but inside the repair
    // ceiling, so every lookup takes the O(Δ) repair path. The fresh
    // case plans the same alternating loads from scratch — the cost a
    // drift miss would pay — and the pin holds repair well under it.
    let drifted = {
        let mut d = loads.clone();
        let hot = (0..d.len()).max_by_key(|&e| d[e]).unwrap();
        let moved = d.iter().sum::<u64>() / 32;
        d[hot] -= moved;
        d[(hot + 1) % d.len()] += moved;
        d
    };
    let repairing =
        CachedPlanner::new(PlannerKind::llep_default().boxed()).with_repair_ceiling(0.2);
    let _ = repairing.plan(8, &loads, None); // prime: one miss
    let mut flip = false;
    b.bench("plan/cached-repair/drift/N=128/P=8", || {
        flip = !flip;
        let p = repairing.plan(8, if flip { &drifted } else { &loads }, None);
        let k = p.transfers.len();
        crate::planner::recycle_plan(p);
        k
    });
    let mut flip = false;
    b.bench("plan/drift-fresh-replan/drift/N=128/P=8", || {
        flip = !flip;
        let l = if flip { &drifted } else { &loads };
        let p = plan_llep_scratch(&cfg, 128, 8, l, None, None, &mut scratch);
        let k = p.transfers.len();
        scratch.recycle(p);
        k
    });

    // --- pricing a fixed plan (canonical transfers, SoA folds) ---
    let plan = crate::planner::plan_llep(&cfg, 128, 8, &loads, None);
    b.bench("price/llep/skewed/N=128/P=8", || {
        bb(price_plan(&engine, &plan, &skewed, &llep, 0.0, None).latency_s)
    });

    // --- full modeled step: plan + price ---
    b.bench("step/llep/skewed/N=128/P=8", || bb(engine.run_step_loads(&skewed, &llep).latency_s));

    suite.absorb(&b);
    suite
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_runs_and_names_are_stable() {
        // Quick mode keeps this a smoke test; the case names are the pin
        // contract — renaming one orphans the checked-in baseline, so
        // assert the headline set explicitly.
        let suite = hotpath_suite(true);
        for name in [
            "plan/llep/skewed/scratch/N=128/P=8",
            "plan/llep/skewed/alloc/N=128/P=8",
            "plan/llep/balanced/guard/N=128/P=8",
            "plan/lpt/skewed/scratch/N=128/P=8",
            "plan/cached-hit/skewed/N=128/P=8",
            "plan/cached-repair/drift/N=128/P=8",
            "plan/drift-fresh-replan/drift/N=128/P=8",
            "price/llep/skewed/N=128/P=8",
            "step/llep/skewed/N=128/P=8",
        ] {
            let r = suite.get(name).unwrap_or_else(|| panic!("case {name} missing"));
            assert!(r.median_ns > 0.0, "{name} measured nothing");
        }
        assert_eq!(suite.name, "hotpath");
    }
}
