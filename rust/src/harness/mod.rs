//! Figure/benchmark harness: regenerates every table and figure of the
//! paper's evaluation (see DESIGN.md per-experiment index).
//!
//! Each `fig_*` function runs the corresponding sweep and returns a
//! [`Table`] whose rows mirror what the paper plots; `llep figures
//! --fig <id>` prints them, and the `rust/benches/*` targets time the
//! same sweeps.

pub mod fullmodel;
pub mod hotpath;

use crate::config::{LlepConfig, ModelConfig, ModelPreset, SystemConfig, SystemPreset};
use crate::exec::Engine;
use crate::metrics::{format_bytes, Table};
use crate::planner::PlannerKind;
use crate::routing::{RoutingStats, Scenario};
use crate::util::rng::Rng;

/// The paper's imbalance grid: balanced + {30, 50, 80, 95}% into
/// {16, 4, 1} experts (Fig. 1 / Fig. 4).
pub fn paper_scenarios(num_experts: usize) -> Vec<Scenario> {
    let mut out = vec![Scenario::balanced()];
    for &conc in &[0.30, 0.50, 0.80, 0.95] {
        for &hot in &[16usize, 4, 1] {
            if hot <= num_experts {
                out.push(Scenario::concentrated(conc, hot));
            }
        }
    }
    out
}

/// EP-vs-LLEP comparison for one scenario; returns (speedup, ep, llep).
pub fn compare(
    engine: &Engine,
    scenario: &Scenario,
    tokens_per_device: usize,
    llep: &LlepConfig,
    seed: u64,
) -> (f64, crate::exec::StepReport, crate::exec::StepReport) {
    let mut rng = Rng::new(seed);
    let lm =
        scenario.generate_loads(&engine.model, engine.system.devices, tokens_per_device, &mut rng);
    let ep = engine.run_step_loads(&lm, &PlannerKind::StandardEp);
    let ll = engine.run_step_loads(&lm, &PlannerKind::Llep(*llep));
    (ep.latency_s / ll.latency_s, ep, ll)
}

/// Fig. 1a — speedup of LLEP over EP, 128-expert layer, P=8, B=32K.
pub fn fig_1a() -> Table {
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    );
    let llep = LlepConfig::default();
    let mut t = Table::new(&["scenario", "EP latency", "LLEP latency", "speedup"]);
    for sc in paper_scenarios(engine.model.num_experts) {
        let (speedup, ep, ll) = compare(&engine, &sc, 32_768, &llep, 1);
        t.row(vec![
            sc.label(),
            crate::metrics::format_secs(ep.latency_s),
            crate::metrics::format_secs(ll.latency_s),
            format!("{speedup:.2}x"),
        ]);
    }
    t
}

/// Fig. 1a as an ASCII bar chart (the paper's visual form).
pub fn fig_1a_chart() -> crate::metrics::chart::BarChart {
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    );
    let llep = LlepConfig::default();
    let mut chart =
        crate::metrics::chart::BarChart::new("LLEP speedup over EP (128E/top4/D2048, P=8, B=32K)");
    for sc in paper_scenarios(engine.model.num_experts) {
        let (speedup, _, _) = compare(&engine, &sc, 32_768, &llep, 1);
        chart.bar(&sc.label(), speedup, &format!("{speedup:.2}x"));
    }
    chart
}

/// Fig. 1b — peak memory per GPU for the same sweep.
pub fn fig_1b() -> Table {
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    );
    let llep = LlepConfig::default();
    let mut t = Table::new(&["scenario", "EP peak mem", "LLEP peak mem", "ratio", "EP OOM?"]);
    for sc in paper_scenarios(engine.model.num_experts) {
        let (_, ep, ll) = compare(&engine, &sc, 32_768, &llep, 1);
        t.row(vec![
            sc.label(),
            format_bytes(ep.max_peak_bytes()),
            format_bytes(ll.max_peak_bytes()),
            format!("{:.2}x", ep.max_peak_bytes() as f64 / ll.max_peak_bytes().max(1) as f64),
            if ep.oom { "OOM".into() } else { "ok".into() },
        ]);
    }
    t
}

/// Fig. 1c — end-to-end full-model throughput, gpt-oss-20b and -120b.
pub fn fig_1c() -> Table {
    let mut t = Table::new(&["model", "devices", "EP tok/s", "LLEP tok/s", "speedup"]);
    for (preset, devices) in [
        (ModelPreset::GptOss20b, 4),
        (ModelPreset::GptOss20b, 8),
        (ModelPreset::GptOss120b, 8),
    ] {
        let row = fullmodel::throughput_row(preset, devices, 32_768, 7);
        t.row(vec![
            format!("{} (P={devices})", ModelConfig::preset(preset).name),
            devices.to_string(),
            format!("{:.0}", row.ep_tps),
            format!("{:.0}", row.llep_tps),
            format!("{:.2}x", row.speedup()),
        ]);
    }
    t
}

/// Fig. 3 — routing imbalance statistics over batches (drifting trace
/// replicating the paper's gpt-oss-20b observations).
pub fn fig_3() -> (Table, Table) {
    let model = ModelConfig::preset(ModelPreset::GptOss20b); // 32 experts
    let devices = 8;
    // E11 dominates at ~20% with per-batch drift (paper Fig. 3a).
    let sc = Scenario::drifting(11, 0.20, 0.25);
    let mut rng = Rng::new(11);
    let mut stats = RoutingStats::new();
    for _ in 0..64 {
        let lm = sc.generate_loads(&model, devices, 8192, &mut rng);
        stats.observe(&lm, devices);
    }
    let mut per_expert = Table::new(&["expert", "max load share", "balanced share"]);
    let balanced = 1.0 / model.num_experts as f64;
    let mut order: Vec<usize> = (0..model.num_experts).collect();
    order.sort_by(|&a, &b| {
        stats.expert_max_share[b].partial_cmp(&stats.expert_max_share[a]).unwrap()
    });
    for &e in order.iter().take(8) {
        per_expert.row(vec![
            format!("E{e}"),
            format!("{:.1}%", stats.expert_max_share[e] * 100.0),
            format!("{:.1}%", balanced * 100.0),
        ]);
    }
    let mut per_gpu = Table::new(&["gpu", "max load share", "balanced share"]);
    for (p, &share) in stats.gpu_max_share.iter().enumerate() {
        per_gpu.row(vec![
            format!("gpu-{p}"),
            format!("{:.1}%", share * 100.0),
            format!("{:.1}%", 100.0 / devices as f64),
        ]);
    }
    (per_expert, per_gpu)
}

/// Fig. 4 — speedup and peak memory across the three MoE architectures.
pub fn fig_4() -> Table {
    let mut t = Table::new(&[
        "model", "scenario", "speedup", "EP peak", "LLEP peak",
    ]);
    for (preset, tokens) in [
        (ModelPreset::GptOss120b, 32_768usize),
        (ModelPreset::DeepSeekV3, 16_384),
        (ModelPreset::KimiK2, 16_384),
    ] {
        let model = ModelConfig::preset(preset);
        let engine =
            Engine::modeled(model.clone(), SystemConfig::preset(SystemPreset::H200x8));
        let llep = LlepConfig::default(); // lambda=1.3, alpha=1, m=1024 (§5.1)
        for sc in paper_scenarios(model.num_experts) {
            let (speedup, ep, ll) = compare(&engine, &sc, tokens, &llep, 4);
            t.row(vec![
                model.name.clone(),
                sc.label(),
                format!("{speedup:.2}x"),
                format_bytes(ep.max_peak_bytes()),
                format_bytes(ll.max_peak_bytes()),
            ]);
        }
    }
    t
}

/// Fig. 6a — speedup vs batch size (4 hot experts).
pub fn fig_6a() -> Table {
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    );
    let llep = LlepConfig::default();
    let mut t =
        Table::new(&["tokens/device", "30% speedup", "50% speedup", "80% speedup", "95% speedup"]);
    for &b in &[2048usize, 4096, 8192, 16_384, 32_768, 65_536] {
        let mut cells = vec![format!("{b}")];
        for &conc in &[0.30, 0.50, 0.80, 0.95] {
            let (s, _, _) = compare(&engine, &Scenario::concentrated(conc, 4), b, &llep, 6);
            cells.push(format!("{s:.2}x"));
        }
        t.row(cells);
    }
    t
}

/// Fig. 6b — speedup vs alpha (4 hot experts, 80% concentration).
pub fn fig_6b() -> Table {
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    );
    let mut t = Table::new(&["alpha", "speedup (80% into 4)", "speedup (95% into 4)"]);
    for &alpha in &[1.0, 1.25, 1.5, 2.0, 3.0] {
        let llep = LlepConfig::default().with_alpha(alpha);
        let (s80, _, _) = compare(&engine, &Scenario::concentrated(0.80, 4), 32_768, &llep, 6);
        let (s95, _, _) = compare(&engine, &Scenario::concentrated(0.95, 4), 32_768, &llep, 6);
        t.row(vec![format!("{alpha}"), format!("{s80:.2}x"), format!("{s95:.2}x")]);
    }
    t
}

/// Fig. 7a — speedup vs lambda at low batch (B=8K) and low/high imbalance.
pub fn fig_7a() -> Table {
    let engine = Engine::modeled(
        ModelConfig::preset(ModelPreset::Fig1Layer),
        SystemConfig::preset(SystemPreset::H200x8),
    );
    let mut t = Table::new(&["lambda", "speedup (15% into 4)", "speedup (80% into 4)"]);
    for &lambda in &[1.0, 1.2, 1.5, 2.0, 3.0, 5.0] {
        let llep = LlepConfig::default().with_lambda(lambda);
        let (lo, _, _) = compare(&engine, &Scenario::concentrated(0.15, 4), 8192, &llep, 7);
        let (hi, _, _) = compare(&engine, &Scenario::concentrated(0.80, 4), 8192, &llep, 7);
        t.row(vec![format!("{lambda}"), format!("{lo:.3}x"), format!("{hi:.2}x")]);
    }
    t
}

/// Fig. 7b — speedup vs hidden size (80% into 4 experts).
pub fn fig_7b() -> Table {
    let mut t = Table::new(&["hidden size", "speedup (80% into 4)"]);
    for &d in &[512usize, 1024, 2048, 4096, 8192] {
        let mut model = ModelConfig::preset(ModelPreset::Fig1Layer);
        model.d_model = d;
        model.d_ff = d;
        let engine = Engine::modeled(model, SystemConfig::preset(SystemPreset::H200x8));
        let (s, _, _) =
            compare(&engine, &Scenario::concentrated(0.80, 4), 32_768, &LlepConfig::default(), 8);
        t.row(vec![format!("{d}"), format!("{s:.2}x")]);
    }
    t
}

/// Fig. 8 — grouped-GEMM cost vs number of experts at fixed total FLOPs
/// (modeled Eq.-3 column + real native-GEMM measurement column).
pub fn fig_8(measure_real: bool) -> Table {
    let sys = SystemConfig::preset(SystemPreset::H200x8);
    let gemm = crate::costmodel::GemmCostModel::from_system(&sys);
    let mut t = Table::new(&["experts", "modeled (H200)", "measured (this CPU)"]);
    let total_tokens: u64 = 65_536;
    for &n in &[1usize, 2, 4, 8, 16, 32, 64, 128] {
        let model = ModelConfig {
            d_model: 8192,
            d_ff: 8192,
            swiglu: false,
            ..ModelConfig::preset(ModelPreset::Fig1Layer)
        };
        let per = vec![total_tokens / n as u64; n];
        let modeled = gemm.device_compute_time(&per, &model);
        let measured = if measure_real {
            // scaled-down real measurement: same split shape at D=H=128
            let d = 128;
            let tokens = 4096usize;
            let mut rng = Rng::new(9);
            let w = crate::tensor::Mat::randn(d, d, 0.02, &mut rng);
            let x = crate::tensor::Mat::randn(tokens / n, d, 0.1, &mut rng);
            let start = std::time::Instant::now();
            for _ in 0..n {
                std::hint::black_box(crate::tensor::matmul(&x, &w));
            }
            format!("{:.3} ms", start.elapsed().as_secs_f64() * 1e3)
        } else {
            "-".into()
        };
        t.row(vec![n.to_string(), crate::metrics::format_secs(modeled), measured]);
    }
    t
}

/// Fig. 9 — speedup vs number of experts (4 hot experts).
pub fn fig_9() -> Table {
    let mut t = Table::new(&["experts", "speedup (80% into 4)", "speedup (95% into 4)"]);
    for &n in &[16usize, 32, 64, 128, 256] {
        let mut model = ModelConfig::preset(ModelPreset::Fig1Layer);
        model.num_experts = n;
        let engine = Engine::modeled(model, SystemConfig::preset(SystemPreset::H200x8));
        let llep = LlepConfig::default();
        let (s80, _, _) = compare(&engine, &Scenario::concentrated(0.80, 4), 32_768, &llep, 10);
        let (s95, _, _) = compare(&engine, &Scenario::concentrated(0.95, 4), 32_768, &llep, 10);
        t.row(vec![n.to_string(), format!("{s80:.2}x"), format!("{s95:.2}x")]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_grid_matches_paper() {
        let scs = paper_scenarios(128);
        assert_eq!(scs.len(), 1 + 4 * 3);
        assert_eq!(scs[0], Scenario::balanced());
        // small expert counts drop the 16-hot rows
        assert_eq!(paper_scenarios(8).len(), 1 + 4 * 2);
    }

    #[test]
    fn fig1a_speedup_shape() {
        let t = fig_1a();
        assert_eq!(t.rows.len(), 13);
        // balanced row ~1x; most-extreme row > 2x
        let balanced: f64 = t.rows[0][3].trim_end_matches('x').parse().unwrap();
        assert!(balanced > 0.9 && balanced < 1.1, "balanced {balanced}");
        let extreme: f64 = t.rows[12][3].trim_end_matches('x').parse().unwrap();
        assert!(extreme > 2.0, "95% into 1 should be >2x, got {extreme}");
    }

    #[test]
    fn fig1b_memory_shape() {
        let t = fig_1b();
        // extreme scenario: EP uses multiples of LLEP memory
        let ratio: f64 = t.rows[12][3].trim_end_matches('x').parse().unwrap();
        assert!(ratio > 2.0, "memory ratio {ratio}");
    }

    #[test]
    fn fig3_dominant_expert_is_e11() {
        let (per_expert, per_gpu) = fig_3();
        assert_eq!(per_expert.rows[0][0], "E11");
        // E11's max share well above balanced 3.1%
        let share: f64 =
            per_expert.rows[0][1].trim_end_matches('%').parse().unwrap();
        assert!(share > 10.0, "E11 share {share}%");
        assert_eq!(per_gpu.rows.len(), 8);
    }

    #[test]
    fn fig7b_speedup_grows_with_hidden() {
        let t = fig_7b();
        let first: f64 = t.rows[0][1].trim_end_matches('x').parse().unwrap();
        let last: f64 = t.rows.last().unwrap()[1].trim_end_matches('x').parse().unwrap();
        assert!(last > first, "speedup should scale with hidden size: {first} -> {last}");
    }

    #[test]
    fn fig9_speedup_grows_with_experts() {
        let t = fig_9();
        let first: f64 = t.rows[0][2].trim_end_matches('x').parse().unwrap();
        let last: f64 = t.rows.last().unwrap()[2].trim_end_matches('x').parse().unwrap();
        assert!(last > first, "speedup should scale with N: {first} -> {last}");
    }
}
